"""Symbolic tracer: build CKKS DFGs from Python programs.

The handle mirrors repro.core.ckks's API so the same program shape can be
run functionally (small ring) and costed/optimized (production ring).

``repro.runtime.compile.TraceContext`` extends this builder with the
attributes real execution needs (plaintext specs, exact scales, level
management ops) — programs traced there both simulate AND run on the
keyswitch engine via ``repro.runtime``.
"""
from __future__ import annotations

import dataclasses

from repro.dfg.graph import DFG, OpKind


@dataclasses.dataclass
class Handle:
    b: "ProgramBuilder"
    nid: int
    limbs: int

    def rot(self, steps: int) -> "Handle":
        nid = self.b.g.add(OpKind.ROT, (self.nid,), limbs=self.limbs,
                           steps=steps, dnum=self.b.dnum(self.limbs))
        return Handle(self.b, nid, self.limbs)

    def conj(self) -> "Handle":
        nid = self.b.g.add(OpKind.CONJ, (self.nid,), limbs=self.limbs,
                           dnum=self.b.dnum(self.limbs))
        return Handle(self.b, nid, self.limbs)

    def pmul(self, pt_tag: str = "pt") -> "Handle":
        nid = self.b.g.add(OpKind.PMUL, (self.nid,), limbs=self.limbs,
                           pt=pt_tag)
        return Handle(self.b, nid, self.limbs)

    def padd(self, pt_tag: str = "pt") -> "Handle":
        nid = self.b.g.add(OpKind.PADD, (self.nid,), limbs=self.limbs,
                           pt=pt_tag)
        return Handle(self.b, nid, self.limbs)

    def cadd(self, other: "Handle") -> "Handle":
        limbs = min(self.limbs, other.limbs)   # implicit level_down
        nid = self.b.g.add(OpKind.CADD, (self.nid, other.nid), limbs=limbs)
        return Handle(self.b, nid, limbs)

    def cmult(self, other: "Handle") -> "Handle":
        limbs = min(self.limbs, other.limbs)   # implicit level_down
        nid = self.b.g.add(OpKind.CMULT, (self.nid, other.nid),
                           limbs=limbs, dnum=self.b.dnum(limbs))
        return Handle(self.b, nid, limbs)

    def square(self) -> "Handle":
        return self.cmult(self)

    def rescale(self) -> "Handle":
        nid = self.b.g.add(OpKind.RESCALE, (self.nid,), limbs=self.limbs)
        return Handle(self.b, nid, self.limbs - 1)

    def output(self) -> int:
        return self.b.g.add(OpKind.OUTPUT, (self.nid,), limbs=self.limbs)


class ProgramBuilder:
    def __init__(self, N: int = 1 << 16, alpha: int = 12):
        self.g = DFG(N=N)
        self.alpha = alpha

    def dnum(self, limbs: int) -> int:
        return -(-limbs // self.alpha)

    def input(self, limbs: int, tag: str = "in") -> Handle:
        nid = self.g.add(OpKind.INPUT, (), limbs=limbs, tag=tag)
        return Handle(self, nid, limbs)

    def sum_tree(self, hs: list[Handle]) -> Handle:
        assert hs
        while len(hs) > 1:
            nxt = [hs[i].cadd(hs[i + 1]) for i in range(0, len(hs) - 1, 2)]
            if len(hs) % 2:
                nxt.append(hs[-1])
            hs = nxt
        return hs[0]
