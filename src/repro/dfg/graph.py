"""Dataflow-graph IR for CKKS programs.

Nodes are polynomial-level operators (the paper's Table I granularity);
edges are ciphertext/plaintext dependencies.  Each node carries enough
static information (limb count, domain, ring degree) for exact
computation / memory / communication accounting.
"""
from __future__ import annotations

import dataclasses
import enum
from collections import defaultdict, deque
from typing import Iterable


class OpKind(enum.Enum):
    INPUT = "input"
    OUTPUT = "output"
    # --- ComOps (paper: xPU) ---
    NTT = "ntt"
    INTT = "intt"
    BCONV = "bconv"
    MODUP = "modup"
    MODDOWN = "moddown"
    # --- MemOps (paper: xMU) ---
    IP = "ip"              # inner product with evk digits
    PMUL = "pmul"          # plaintext mult
    CADD = "cadd"          # ct-ct add
    CSUB = "csub"          # ct-ct sub (cost-identical to CADD)
    CSCALE = "cscale"      # ct * small integer constant (scaled_double)
    PADD = "padd"
    RESCALE = "rescale"
    LEVEL_DOWN = "level_down"   # drop limbs without scale change
    MOD_RAISE = "mod_raise"     # bootstrap boundary: level 0 -> full chain
    AUTOM = "autom"        # automorphism (permutation)
    # --- composite ops (pre-lowering) ---
    ROT = "rot"            # rotation keyswitch (expands to autom+ks chain)
    CMULT = "cmult"        # ct-ct mult + relinearize keyswitch
    CONJ = "conj"


# ComOp/MemOp classification (paper Table I).
COM_OPS = {OpKind.NTT, OpKind.INTT, OpKind.BCONV, OpKind.MODUP,
           OpKind.MODDOWN}
MEM_OPS = {OpKind.IP, OpKind.PMUL, OpKind.CADD, OpKind.CSUB,
           OpKind.CSCALE, OpKind.PADD, OpKind.RESCALE, OpKind.AUTOM}
# EWOs commute with ModUp/ModDown (paper Sec. II-B2) — the expansion set.
COMMUTATIVE_OPS = {OpKind.PMUL, OpKind.CADD, OpKind.CSUB, OpKind.CSCALE,
                   OpKind.PADD, OpKind.AUTOM}
KEYSWITCH_OPS = {OpKind.ROT, OpKind.CMULT, OpKind.CONJ}


@dataclasses.dataclass
class Node:
    id: int
    op: OpKind
    args: tuple[int, ...] = ()
    # static cost attributes
    limbs: int = 1            # active Q limbs (level+1)
    ext_limbs: int = 0        # extended-basis limbs if in PQ domain (else 0)
    attrs: dict = dataclasses.field(default_factory=dict)

    @property
    def domain_limbs(self) -> int:
        return self.ext_limbs if self.ext_limbs else self.limbs

    @property
    def steps(self) -> int:
        return self.attrs.get("steps", 0)


class DFG:
    def __init__(self, N: int = 1 << 16):
        self.N = N
        self.nodes: dict[int, Node] = {}
        self._next = 0
        self._succs: dict[int, set[int]] = defaultdict(set)

    # ------------------------- construction ---------------------------
    def add(self, op: OpKind, args: Iterable[int] = (), limbs: int = 1,
            ext_limbs: int = 0, **attrs) -> int:
        nid = self._next
        self._next += 1
        args = tuple(args)
        self.nodes[nid] = Node(nid, op, args, limbs, ext_limbs, dict(attrs))
        for a in args:
            self._succs[a].add(nid)
        return nid

    def replace_args(self, nid: int, new_args: tuple[int, ...]):
        node = self.nodes[nid]
        for a in node.args:
            self._succs[a].discard(nid)
        node.args = new_args
        for a in new_args:
            self._succs[a].add(nid)

    # --------------------------- queries -------------------------------
    def succs(self, nid: int) -> set[int]:
        return self._succs[nid]

    def preds(self, nid: int) -> tuple[int, ...]:
        return self.nodes[nid].args

    def topo_order(self) -> list[int]:
        # unique preds: duplicate args (e.g. square = cmult(x, x)) must
        # count once, matching the _succs set representation
        indeg = {i: len(set(n.args)) for i, n in self.nodes.items()}
        q = deque([i for i, d in indeg.items() if d == 0])
        out = []
        while q:
            i = q.popleft()
            out.append(i)
            for s in self._succs[i]:
                indeg[s] -= 1
                if indeg[s] == 0:
                    q.append(s)
        assert len(out) == len(self.nodes), "cycle in DFG"
        return out

    def keyswitch_nodes(self) -> list[int]:
        return [i for i, n in self.nodes.items() if n.op in KEYSWITCH_OPS]

    def count(self, op: OpKind) -> int:
        return sum(1 for n in self.nodes.values() if n.op == op)

    # ------------------------ cost accounting --------------------------
    def op_word_volume(self, nid: int) -> int:
        """Words touched by this op (drives MemOp byte counts & AI)."""
        n = self.nodes[nid]
        l = n.domain_limbs
        if n.op in (OpKind.NTT, OpKind.INTT):
            return l * self.N
        if n.op == OpKind.BCONV:
            return (n.attrs.get("src_limbs", l) + l) * self.N
        if n.op == OpKind.IP:
            dnum = n.attrs.get("dnum", 1)
            return dnum * 3 * l * self.N  # digits + 2 evk components
        return len(n.args) * l * self.N + l * self.N

    def summary(self) -> dict[str, int]:
        out: dict[str, int] = defaultdict(int)
        for n in self.nodes.values():
            out[n.op.value] += 1
        return dict(out)
