"""Dataflow mapping (paper Sec. IV-D): IRF / EVF / hybrid per PKB.

IRF ships keyswitch intermediates to the xMU (no evk on xPU); EVF
preloads one evk on the xPU.  The hybrid scheme picks per PKB: IRF when
IP parallelism > 1 (intermediate reuse amortizes the transfers), EVF for
single-keyswitch PKBs (one evk load is cheaper than two intermediate
transfers).  HE2-SM's 44 MB scratchpad cannot hold an evk, so it is
IRF-only; HE2-LM (84 MB) runs hybrid.
"""
from __future__ import annotations

import dataclasses

from repro.dfg.fusion import CostWeights
from repro.dfg.hoist import OpVolumes, pkb_volumes
from repro.dfg.pkb import PKB


@dataclasses.dataclass
class MappedBlock:
    pkb: PKB
    strategy: str       # 'minks' | 'plain' | 'hoist'
    dataflow: str       # 'IRF' | 'EVF'
    volumes: OpVolumes  # carries the ModUp/ModDown phase split the
    #                     group scheduler stripes across pipeline_groups


def map_program(pkbs: list[PKB], k: int, alpha: int, nh: int,
                mode: str = "hybrid", strategy: str = "hoist",
                weights: CostWeights | None = None) -> list[MappedBlock]:
    """mode: 'IRF' | 'EVF' | 'hybrid'."""
    weights = weights or CostWeights()
    out = []
    for p in pkbs:
        if mode in ("IRF", "EVF"):
            df = mode
        else:
            if p.n_rot > 1:
                df = "IRF"
            else:
                v_irf = pkb_volumes(p, k, alpha, strategy, "IRF", nh)
                v_evf = pkb_volumes(p, k, alpha, strategy, "EVF", nh)
                df = ("IRF" if weights.block_seconds(v_irf)
                      <= weights.block_seconds(v_evf) else "EVF")
        out.append(
            MappedBlock(p, strategy, df,
                        pkb_volumes(p, k, alpha, strategy, df, nh))
        )
    return out
