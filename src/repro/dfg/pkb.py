"""PKB identification and degree-minimized expansion (paper Sec. IV-A).

* identifying: keyswitches are layered by their order along each path
  from the inputs; same-layer rotations connected through commutative
  regions form one PKB.
* expanding: each PKB is greedily expanded with modulus-commutative EWOs
  (PMul/CAdd/PAdd/Autom) so its in-degree (distinct ModUp anchors) and
  out-degree (distinct ModDown sinks) are minimized — these degrees are
  exactly the hoisted ModUp/ModDown counts.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict

from repro.dfg.graph import COMMUTATIVE_OPS, DFG, KEYSWITCH_OPS, OpKind

# rescale is not modulus-commutative, but for PKB connectivity it is a
# pass-through EWO (it neither needs a ModUp nor blocks fusion adjacency)
TRAVERSE_OPS = COMMUTATIVE_OPS | {OpKind.RESCALE}


@dataclasses.dataclass
class PKB:
    dfg: DFG
    layer: int
    rotations: list[int]
    in_anchors: set[int] = dataclasses.field(default_factory=set)
    out_sinks: set[int] = dataclasses.field(default_factory=set)
    region: set[int] = dataclasses.field(default_factory=set)

    @property
    def n_rot(self) -> int:
        return len(self.rotations)

    @property
    def indeg(self) -> int:
        return max(1, len(self.in_anchors))

    @property
    def outdeg(self) -> int:
        return max(1, len(self.out_sinks))

    @property
    def steps(self) -> list[int]:
        return [self.dfg.nodes[r].attrs.get("steps", 0)
                for r in self.rotations]

    @property
    def limbs(self) -> int:
        return max(self.dfg.nodes[r].limbs for r in self.rotations)

    @property
    def dnum(self) -> int:
        return max(self.dfg.nodes[r].attrs.get("dnum", 1)
                   for r in self.rotations)


def keyswitch_layers(dfg: DFG) -> dict[int, int]:
    """layer[n] = number of keyswitches on the longest path before n."""
    depth: dict[int, int] = {}
    for nid in dfg.topo_order():
        node = dfg.nodes[nid]
        d = 0
        for p in node.args:
            inc = 1 if dfg.nodes[p].op in KEYSWITCH_OPS else 0
            d = max(d, depth[p] + inc)
        depth[nid] = d
    return depth


def _back_anchors(dfg: DFG, start: int, ops=COMMUTATIVE_OPS) -> set[int]:
    """Walk backward through `ops` to the ModUp anchor set.

    Degree computation uses COMMUTATIVE_OPS (rescale is a ModDown-side
    boundary); fusion adjacency uses TRAVERSE_OPS (rescale connects)."""
    anchors: set[int] = set()
    stack = [start]
    seen = set()
    while stack:
        nid = stack.pop()
        if nid in seen:
            continue
        seen.add(nid)
        node = dfg.nodes[nid]
        if node.op in ops:
            stack.extend(node.args)
        else:
            anchors.add(nid)
    return anchors


def deep_anchors(dfg: DFG, rot: int) -> set[int]:
    """Anchor set looking through rescale — used for fusion adjacency."""
    return _back_anchors(dfg, dfg.nodes[rot].args[0], TRAVERSE_OPS)


def _forward_region(dfg: DFG, rot: int,
                    ops=COMMUTATIVE_OPS) -> tuple[set[int], set[int]]:
    """Walk forward through `ops`; return (region, sinks)."""
    region: set[int] = set()
    sinks: set[int] = set()
    stack = [rot]
    seen = set()
    while stack:
        nid = stack.pop()
        if nid in seen:
            continue
        seen.add(nid)
        nexts = dfg.succs(nid)
        comm_next = [s for s in nexts if dfg.nodes[s].op in ops]
        if nid != rot and dfg.nodes[nid].op in ops:
            region.add(nid)
        if len(comm_next) < len(nexts) or not nexts:
            sinks.add(nid)          # some consumer needs base domain here
        stack.extend(comm_next)
    return region, sinks


def identify_pkbs(dfg: DFG, rotations_only: bool = True) -> list[PKB]:
    """Layer keyswitches, group connected same-layer ones into PKBs, and
    expand each for minimal degree."""
    layers = keyswitch_layers(dfg)
    ks_kinds = (
        {OpKind.ROT} if rotations_only else KEYSWITCH_OPS
    )
    by_layer: dict[int, list[int]] = defaultdict(list)
    for nid, node in dfg.nodes.items():
        if node.op in ks_kinds:
            by_layer[layers[nid]].append(nid)

    pkbs: list[PKB] = []
    for layer in sorted(by_layer):
        rots = by_layer[layer]
        anchors = {r: _back_anchors(dfg, dfg.nodes[r].args[0]) for r in rots}
        fwd = {r: _forward_region(dfg, r) for r in rots}
        # union-find: same PKB if anchor sets intersect or sinks intersect
        parent = {r: r for r in rots}

        def find(x):
            while parent[x] != x:
                parent[x] = parent[parent[x]]
                x = parent[x]
            return x

        def union(a, b):
            parent[find(a)] = find(b)

        for i, r1 in enumerate(rots):
            for r2 in rots[i + 1 :]:
                if anchors[r1] & anchors[r2] or fwd[r1][1] & fwd[r2][1]:
                    union(r1, r2)
        groups: dict[int, list[int]] = defaultdict(list)
        for r in rots:
            groups[find(r)].append(r)
        for members in groups.values():
            p = PKB(dfg, layer, sorted(members))
            for r in members:
                p.in_anchors |= anchors[r]
                reg, snk = fwd[r]
                p.region |= reg
                p.out_sinks |= snk
            pkbs.append(p)
    return pkbs


def pkb_parallelism_histogram(dfg: DFG) -> list[int]:
    """Per-PKB keyswitch parallelism (Fig. 6 of the paper)."""
    return sorted((p.n_rot for p in identify_pkbs(dfg)), reverse=True)
