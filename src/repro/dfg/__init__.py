"""HERO: hoisting-enhanced DFG optimization framework (paper Sec. IV).

Pipeline:  trace/generate DFG  ->  PKB identify (layering)
        ->  degree-minimized expansion  ->  PKB fusion (DP evaluator)
        ->  hoisting rewrite  ->  IRF/EVF/hybrid dataflow mapping
        ->  repro.sim (performance model) or repro.runtime (compiled
            functional execution on the keyswitch engine).

``repro.runtime.compile.TraceContext`` builds this IR from unmodified
program code and ``repro.runtime.lower`` turns identified/fused PKBs
into real hoisted-rotation-sum invocations; ``repro.runtime.report``
cross-checks the executed op counts against ``hoist.OpVolumes``.
"""
from repro.dfg.graph import DFG, Node, OpKind  # noqa: F401
from repro.dfg.pkb import PKB, identify_pkbs  # noqa: F401
