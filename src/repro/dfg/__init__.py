"""HERO: hoisting-enhanced DFG optimization framework (paper Sec. IV).

Pipeline:  trace/generate DFG  ->  PKB identify (layering)
        ->  degree-minimized expansion  ->  PKB fusion (DP evaluator)
        ->  hoisting rewrite  ->  IRF/EVF/hybrid dataflow mapping
        ->  repro.sim (performance model) or repro.core (functional exec).
"""
from repro.dfg.graph import DFG, Node, OpKind  # noqa: F401
from repro.dfg.pkb import PKB, identify_pkbs  # noqa: F401
