"""PKB fusion (paper Sec. IV-B): inverse-BSGS merging of serial PKBs.

Two serial PKBs (n1 then n2 rotations, EWOs between) fuse into one PKB
whose rotations are the pairwise step sums (Eq. (4)); EWOs are pushed
behind the rotations via Rot(PMul(ct, pt)) = PMul(Rot(ct), Autom(pt)).
Hoisting the fused PKB removes outdeg1 ModDowns + indeg2 ModUps (and
their heterogeneous transfers), at the cost of O(n1*n2) IPs and a larger
evk working set.

A FuseScore-driven interval DP (Eq. (5)) picks the globally optimal
partition of each PKB chain under the evk storage capacity constraint.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.dfg.hoist import OpVolumes, pkb_volumes
from repro.dfg.pkb import PKB


@dataclasses.dataclass
class CostWeights:
    """Seconds per unit — converts OpVolumes to time (defaults: HE2 xPU
    at 768 w/ns NTT, 672-unit BConvU, xMU EWEU 5461 w/ns, 1 TB/s link,
    8-byte words)."""

    ntt: float = 1e-9 / 768
    bconv: float = 1e-9 / 672 / 16
    ip: float = 1e-9 / 5461
    ewo: float = 1e-9 / 5461
    # in-DRAM hierarchical automorphism: near-bank aggregate (~xMU EWEU
    # scale), not the 2048-coeff/cycle single row buffer
    autom: float = 1e-9 / 4000
    comm: float = 8.0 / 1e12          # s per word over the xPU-xMU link
    evk_load: float = 8.0 / 1e12

    def seconds(self, v: OpVolumes) -> float:
        return (v.ntt_words * self.ntt + v.bconv_macs * self.bconv
                + v.ip_macs * self.ip
                + (v.ewo_words + v.ewo_ext_words) * self.ewo
                + v.autom_words * self.autom
                + v.comm_words * self.comm
                + v.evk_load_words * self.evk_load)

    def block_seconds(self, v: OpVolumes) -> float:
        """Latency of one keyswitch block under these weights.

        The default is the linear volume model; hardware-aware weights
        (sim.engine._pipeline_weights) override this with the scheduled
        group-pipeline makespan so the fusion DP optimizes exactly what
        the simulator measures."""
        return self.seconds(v)


class FusedPKB(PKB):
    """PKB-shaped view of a fused group (no graph mutation needed for
    costing; the functional path uses fuse_functional below)."""

    def __init__(self, members: list[PKB], steps: list[int],
                 n_ip: int, region: set[int]):
        first, last = members[0], members[-1]
        rotations = [r for m in members for r in m.rotations]
        super().__init__(first.dfg, first.layer, rotations,
                         set(first.in_anchors), set(last.out_sinks), region)
        self._steps = steps
        self._n_ip = n_ip
        self.members = members

    @property
    def n_rot(self) -> int:          # IPs after fusion
        return self._n_ip

    @property
    def steps(self) -> list[int]:
        return self._steps

    @property
    def limbs(self) -> int:
        return max(m.limbs for m in self.members)


def fuse_pair(p1: PKB, p2: PKB, nh: int) -> FusedPKB:
    """Pairwise-sum the rotation steps (Eq. (4)).

    Paths landing on the SAME fused step merge their plaintext chains
    (PMul/CAdd distribute over rotation), so the IP/evk count is the
    number of DISTINCT sums — the paper's "non-duplicated subset among
    n1*n2 keys".  Arithmetic-progression PKBs (plaintext-matrix x ct,
    ConvBN) overlap heavily, which is where fusion shines.
    """
    s1 = p1.steps
    s2 = p2.steps
    fused_steps = sorted({(a + b) % nh for a in s1 for b in s2})
    n_ip = len(fused_steps)
    region = set(p1.region) | set(p2.region)
    members = (p1.members if isinstance(p1, FusedPKB) else [p1]) + [p2]
    return FusedPKB(members, fused_steps, n_ip, region)


def fuse_group(pkbs: list[PKB], nh: int) -> PKB:
    if len(pkbs) == 1:
        return pkbs[0]
    acc = pkbs[0]
    for p in pkbs[1:]:
        acc = fuse_pair(acc, p, nh)
    return acc


def fusable(p1: PKB, p2: PKB) -> bool:
    """p2 must directly consume p1's outputs (serial adjacency).

    Adjacent layers are fusable; if the anchor/sink sets are resolvable we
    additionally require an actual data dependency.
    """
    if p2.layer != p1.layer + 1:
        return False
    from repro.dfg.pkb import deep_anchors

    reachable = set(p1.out_sinks) | set(p1.rotations) | set(p1.region)
    anchors = set()
    for r in p2.rotations:
        anchors |= deep_anchors(p1.dfg, r)
    return bool(anchors & reachable)


@dataclasses.dataclass
class FusionPlan:
    groups: list[list[int]]          # indices into the pkb list
    score: float                     # seconds saved vs unfused hoisting
    fused: list[PKB]


def fuse_score(group: list[PKB], k: int, alpha: int, nh: int,
               weights: CostWeights, capacity_words: float,
               dataflow: str = "IRF") -> tuple[float, PKB] | None:
    """Savings (s) of fusing `group` vs hoisting each member separately.
    None if the fused evk set exceeds capacity (paper: invalid)."""
    fused = fuse_group(group, nh)
    v_f = pkb_volumes(fused, k, alpha, "hoist", dataflow, nh)
    if v_f.evk_set_words > capacity_words:
        return None
    saved = -weights.block_seconds(v_f)
    for p in group:
        saved += weights.block_seconds(
            pkb_volumes(p, k, alpha, "hoist", dataflow, nh))
    return saved, fused


def optimal_fusion(pkbs: list[PKB], k: int, alpha: int, nh: int,
                   capacity_words: float,
                   weights: CostWeights | None = None,
                   dataflow: str = "IRF",
                   max_group: int = 4) -> FusionPlan:
    """Interval DP (Eq. (5)) over a layer-ordered PKB chain.

    DP[i][j] = best cumulative savings covering PKBs i..j, choosing
    between fusing the whole interval or splitting.  Non-adjacent-layer
    intervals can only split.
    """
    weights = weights or CostWeights()
    pkbs = sorted(pkbs, key=lambda p: p.layer)
    n = len(pkbs)
    if n == 0:
        return FusionPlan([], 0.0, [])

    score = [[0.0] * n for _ in range(n)]
    choice: list[list[list[list[int]]]] = [
        [[[i]] for i in range(n)] for _ in range(n)
    ]
    for i in range(n):
        choice[i][i] = [[i]]

    for length in range(2, n + 1):
        for i in range(0, n - length + 1):
            j = i + length - 1
            best, best_groups = -np.inf, None
            # option 1: fuse whole interval (if chain-adjacent & small)
            if length <= max_group and all(
                pkbs[t + 1].layer == pkbs[t].layer + 1 and
                fusable(pkbs[t], pkbs[t + 1])
                for t in range(i, j)
            ):
                res = fuse_score(pkbs[i : j + 1], k, alpha, nh, weights,
                                 capacity_words, dataflow)
                if res is not None and res[0] > best:
                    best, best_groups = res[0], [list(range(i, j + 1))]
            # option 2: split
            for m in range(i, j):
                s = score[i][m] + score[m + 1][j]
                if s > best:
                    best = s
                    best_groups = choice[i][m] + choice[m + 1][j]
            score[i][j] = best
            choice[i][j] = best_groups
    groups = choice[0][n - 1]
    fused = [fuse_group([pkbs[t] for t in g], nh) for g in groups]
    return FusionPlan(groups, score[0][n - 1], fused)


# ----------------------- functional fusion (Eq. 4) -----------------------

def fuse_functional(steps1, pts1, steps2, pts2, nh: int):
    """Fused (steps, plaintext) list: y = sum_i pt2_i*Rot_{s2_i}(
    sum_j pt1_j*Rot_{s1_j}(x)) == sum_{ij} [pt2_i * roll(pt1_j, -s2_i)]
    * Rot_{s1_j + s2_i}(x).  Verified homomorphically in tests."""
    out_steps, out_pts = [], []
    for s2, p2 in zip(steps2, pts2):
        for s1, p1 in zip(steps1, pts1):
            out_steps.append((s1 + s2) % nh)
            out_pts.append(np.asarray(p2) * np.roll(np.asarray(p1), -s2))
    return out_steps, out_pts
