"""Hoisting cost/lowering model: exact word-level volumes per PKB.

For a PKB at level l-1 (l limbs, ext = l + k extended limbs, dnum digits,
n rotations, in-degree di, out-degree do):

  baseline (per-rotation keyswitch):   n ModUps, n ModDowns, n IPs
  hoisted  (Bossuat double hoisting):  di ModUps, do ModDowns, n IPs,
                                       region EWOs shifted to ext domain

Communication (IRF dataflow, paper Sec. III-B):
  up   (xPU->xMU): ModUp outputs      — dnum*ext*N words per ModUp
  down (xMU->xPU): IP accumulations   — 2*ext*N words per ModDown point

EVF instead loads evks on-chip: dnum*2*ext*N words per distinct evk.
Min-KS serializes rotations into uniform power-of-two hops (popcount of
the step) to reuse a small evk set — fewer keys, more keyswitches.
"""
from __future__ import annotations

import dataclasses

from repro.dfg.graph import DFG, OpKind
from repro.dfg.pkb import PKB


@dataclasses.dataclass
class OpVolumes:
    """Word-level volumes (words = one RNS residue of one coefficient)."""

    ntt_words: float = 0.0      # NTT + INTT butterfly passes
    bconv_macs: float = 0.0     # BConv multiply-accumulates
    # phase attribution of the xPU work (ModUp legs run before the
    # up-link transfer; ModDown legs after the down-link) — the group
    # scheduler needs the split, the analytic model only the totals
    modup_ntt_words: float = 0.0
    modup_bconv_macs: float = 0.0
    moddown_ntt_words: float = 0.0
    moddown_bconv_macs: float = 0.0
    ip_macs: float = 0.0        # IP multiply-accumulates (xMU)
    ewo_words: float = 0.0      # program EWOs (xMU under IRF, else xPU)
    xpu_ewo_words: float = 0.0  # ModDown-internal sub/scale (always xPU)
    ewo_ext_words: float = 0.0  # EWO words shifted to extended domain
    autom_words: float = 0.0
    comm_up_words: float = 0.0      # xPU -> xMU (IRF)
    comm_down_words: float = 0.0    # xMU -> xPU (IRF)
    evk_load_words: float = 0.0     # EVF on-chip evk traffic
    evk_set_words: float = 0.0      # evk working set (storage, xMU HBM)
    modup_count: int = 0
    moddown_count: int = 0
    ip_count: int = 0
    keyswitch_count: int = 0
    relin_count: int = 0        # relinearization keyswitches (CMults)
    # Per-digit ModUp leg volumes — ((ntt_words, bconv_macs), ...) one
    # entry per decomposition digit, derived from the same (dnum, l_ext,
    # N) shapes the keyswitch engine's plans use.  The group scheduler
    # weights its up-phase xPU slices by these instead of a uniform
    # split; blocks of differing dnum drop the legs when summed.
    modup_legs: tuple = ()
    # Per-digit ModDown leg volumes — ((ntt_words, bconv_macs,
    # ewo_words), ...), one entry per decomposition digit.  The IP
    # accumulation streams back digit-by-digit in the same group order
    # the ModUp went up, so the down-phase xPU work (INTT of the
    # returned slice + BConv + subtract/scale) is attributable to the
    # digit whose base limbs it restores.
    moddown_legs: tuple = ()

    _LEG_FIELDS = ("modup_legs", "moddown_legs")

    def __add__(self, o: "OpVolumes") -> "OpVolumes":
        out = OpVolumes(*[
            getattr(self, f.name) + getattr(o, f.name)
            for f in dataclasses.fields(self)
            if f.name not in self._LEG_FIELDS
        ])
        for name in self._LEG_FIELDS:
            setattr(out, name,
                    _merge_legs(getattr(self, name), getattr(o, name)))
        return out

    def scaled(self, c: float) -> "OpVolumes":
        out = OpVolumes(*[
            getattr(self, f.name) * c
            for f in dataclasses.fields(self)
            if f.name not in self._LEG_FIELDS
        ])
        for name in self._LEG_FIELDS:
            setattr(out, name, tuple(
                tuple(x * c for x in leg) for leg in getattr(self, name)
            ))
        return out

    @property
    def compute_words(self) -> float:
        return (self.ntt_words + self.bconv_macs + self.ip_macs
                + self.ewo_words + self.ewo_ext_words + self.autom_words)

    @property
    def comm_words(self) -> float:
        return self.comm_up_words + self.comm_down_words


def _merge_legs(a: tuple, b: tuple) -> tuple:
    """Elementwise sum of per-digit legs (any leg arity); blocks of
    differing dnum (or a legless operand with real volumes) cannot be
    attributed per digit."""
    if not a:
        return b
    if not b:
        return a
    if len(a) != len(b):
        return ()
    return tuple(
        tuple(x + y for x, y in zip(ea, eb)) for ea, eb in zip(a, b)
    )


def _region_ewo_count(pkb: PKB) -> int:
    return sum(
        1 for nid in pkb.region
        if pkb.dfg.nodes[nid].op in (OpKind.PMUL, OpKind.CADD, OpKind.CSUB,
                                     OpKind.CSCALE, OpKind.PADD)
    )


def modup_volumes(l: int, k: int, alpha: int, N: int) -> OpVolumes:
    """One ModUp of an l-limb polynomial to the (l+k)-limb basis."""
    dnum = -(-l // alpha)
    ext = l + k
    v = OpVolumes()
    v.ntt_words = l * N + dnum * max(ext - alpha, 0) * N  # INTT + NTT legs
    v.bconv_macs = sum(
        min(alpha, l - g * alpha) * (ext - min(alpha, l - g * alpha)) * N
        for g in range(dnum)
    )
    v.modup_ntt_words = v.ntt_words
    v.modup_bconv_macs = v.bconv_macs
    v.modup_count = 1
    # per-digit legs: digit g INTTs its own a_g limbs and NTTs the ext-a_g
    # new limbs — exactly the engine plan's (dnum, l_ext, N) shape with a
    # short last group when alpha does not divide l
    v.modup_legs = tuple(
        (
            (min(alpha, l - g * alpha)
             + (ext - min(alpha, l - g * alpha))) * N,
            min(alpha, l - g * alpha) * (ext - min(alpha, l - g * alpha))
            * N,
        )
        for g in range(dnum)
    )
    return v


def moddown_volumes(l: int, k: int, alpha: int, N: int,
                    components: int = 2) -> OpVolumes:
    """ModDown of `components` polynomials from (l+k) limbs back to l."""
    v = OpVolumes()
    v.ntt_words = components * (k * N + l * N)   # INTT(P part) + NTT back
    v.bconv_macs = components * k * l * N
    v.xpu_ewo_words = components * 2 * l * N     # subtract + scale
    v.moddown_ntt_words = v.ntt_words
    v.moddown_bconv_macs = v.bconv_macs
    v.moddown_count = components // 2 if components >= 2 else 1
    # per-digit legs: the IP accumulation streams back in the same digit
    # order it went up, so digit g's returned slice restores its own a_g
    # base limbs — NTT back (a_g rows) plus its share a_g/l of the P-part
    # INTT, BConv into a_g limbs, and the subtract/scale EWO on them.
    # Legs sum exactly to (ntt_words, bconv_macs, xpu_ewo_words).
    dnum = -(-l // alpha)
    v.moddown_legs = tuple(
        (
            components * (min(alpha, l - g * alpha) * N
                          + k * N * min(alpha, l - g * alpha) / l),
            components * k * min(alpha, l - g * alpha) * N,
            components * 2 * min(alpha, l - g * alpha) * N,
        )
        for g in range(dnum)
    )
    return v


def ip_volumes(l: int, k: int, alpha: int, N: int) -> OpVolumes:
    """One rotation's inner product over the extended basis (2 comps)."""
    dnum = -(-l // alpha)
    ext = l + k
    v = OpVolumes()
    v.ip_macs = dnum * ext * N * 2
    v.ip_count = 1
    return v


def evk_words(l: int, k: int, alpha: int, N: int) -> int:
    dnum = -(-l // alpha)
    return dnum * 2 * (l + k) * N


def _minks_hops(steps: list[int], nh: int) -> int:
    """Min-KS keyswitch count.

    Min-KS's primary effect is evk-set reduction (uniform step keys);
    with the BSGS-structured baselines (bs=4, Fig. 7a) the steps are
    already single-hop decomposable with composite keys, so the
    keyswitch count stays ~n.  The parallelism penalty shows up via the
    PKB structure (Fig. 6), not raw counts.
    """
    return len(steps)


def pkb_volumes(pkb: PKB, k: int, alpha: int, strategy: str = "hoist",
                dataflow: str = "IRF", nh: int = 1 << 15) -> OpVolumes:
    """Total volumes for one PKB under a strategy x dataflow choice.

    strategy: 'minks' | 'plain' | 'hoist'
    dataflow: 'IRF' | 'EVF'
    """
    dfg = pkb.dfg
    N = dfg.N
    l = pkb.limbs
    ext = l + k
    n = pkb.n_rot
    di, do = pkb.indeg, pkb.outdeg
    ewo_n = _region_ewo_count(pkb)

    v = OpVolumes()
    if strategy == "hoist":
        for _ in range(di):
            v = v + modup_volumes(l, k, alpha, N)
        v = v + moddown_volumes(l, k, alpha, N, components=2 * do)
        for _ in range(n):
            v = v + ip_volumes(l, k, alpha, N)
        dnum = -(-l // alpha)
        v.autom_words = n * (dnum * ext + l) * N   # ext digits + c0 at base
        v.ewo_ext_words = ewo_n * ext * N * 2
        v.keyswitch_count = n
        distinct = len(set(pkb.steps))
        v.evk_set_words = distinct * evk_words(l, k, alpha, N)
        if dataflow == "IRF":
            dnum = -(-l // alpha)
            v.comm_up_words = di * dnum * ext * N
            v.comm_down_words = do * 2 * ext * N
        else:
            v.evk_load_words = distinct * evk_words(l, k, alpha, N)
    else:
        hops = _minks_hops(pkb.steps, nh) if strategy == "minks" else n
        for _ in range(hops):
            v = v + modup_volumes(l, k, alpha, N)
            v = v + moddown_volumes(l, k, alpha, N, components=2)
            v = v + ip_volumes(l, k, alpha, N)
        v.autom_words = hops * 2 * l * N
        v.ewo_words = ewo_n * l * N * 2
        v.keyswitch_count = hops
        if strategy == "minks":
            # uniform power-of-two hop keys actually used
            bits = set()
            for s in pkb.steps:
                s = s % nh
                bits |= {i for i in range(max(s.bit_length(), 1))
                         if s >> i & 1}
            n_evk = max(len(bits), 1)
        else:
            n_evk = len(set(pkb.steps))
        v.evk_set_words = n_evk * evk_words(l, k, alpha, N)
        if dataflow == "IRF":
            dnum = -(-l // alpha)
            v.comm_up_words = hops * dnum * ext * N
            v.comm_down_words = hops * 2 * ext * N
        else:
            v.evk_load_words = hops * evk_words(l, k, alpha, N)
    return v


def non_pkb_blocks(dfg: DFG, pkbs: list[PKB], k: int, alpha: int,
                   dataflow: str = "IRF") -> tuple[list[OpVolumes], OpVolumes]:
    """Per-keyswitch volumes for CMULT/CONJ outside PKBs + residual EWOs."""
    in_pkb: set[int] = set()
    for p in pkbs:
        in_pkb |= set(p.rotations) | p.region
    N = dfg.N
    blocks: list[OpVolumes] = []
    residual = OpVolumes()
    for nid, node in dfg.nodes.items():
        if nid in in_pkb:
            continue
        l = node.limbs
        if node.op in (OpKind.CMULT, OpKind.CONJ):
            v = (modup_volumes(l, k, alpha, N)
                 + moddown_volumes(l, k, alpha, N, 2)
                 + ip_volumes(l, k, alpha, N))
            if node.op == OpKind.CMULT:
                v.ewo_words += 4 * l * N
                v.relin_count += 1
            v.keyswitch_count += 1
            v.evk_set_words = evk_words(l, k, alpha, N)
            if dataflow == "IRF":
                dnum = -(-l // alpha)
                v.comm_up_words += dnum * (l + k) * N
                v.comm_down_words += 2 * (l + k) * N
            else:
                v.evk_load_words += evk_words(l, k, alpha, N)
            blocks.append(v)
        elif node.op in (OpKind.PMUL, OpKind.CADD, OpKind.CSUB,
                         OpKind.CSCALE, OpKind.PADD, OpKind.RESCALE):
            residual.ewo_words += 2 * l * N
            if node.op == OpKind.RESCALE:
                residual.ntt_words += 2 * N
    return blocks, residual


def program_volumes(dfg: DFG, pkbs: list[PKB], k: int, alpha: int,
                    strategy: str = "hoist", dataflow: str = "IRF",
                    nh: int = 1 << 15) -> OpVolumes:
    """Whole-program volumes: PKBs + non-PKB keyswitches (CMULT relin) +
    standalone EWOs (the latter two via :func:`non_pkb_blocks`, the same
    per-block assembly the simulator schedules)."""
    total = OpVolumes()
    for p in pkbs:
        total = total + pkb_volumes(p, k, alpha, strategy, dataflow, nh)
    blocks, residual = non_pkb_blocks(dfg, pkbs, k, alpha, dataflow)
    for v in blocks:
        total = total + v
    return total + residual
