"""Benchmark CKKS program (DFG) generators.

These produce operator-level DFGs with the PKB structure of the paper's
four benchmarks (Sec. VI-B).  Counts follow the cited implementations:

  bootstrapping [6,25]: fully-packed, FFT-like C2S/S2C in 3 merged stages
      (radix 2^5 at nh = 2^15 -> ~31 rotations per stage), conj split,
      EvalMod as a degree-63 sine Chebyshev (log-depth CMULT ladder).
  HELR [21]: batch-1024 logistic regression iteration — rotation-sum
      reductions are SERIAL parallelism-1 PKBs (why Fig. 6 shows HELR
      dominated by low-parallelism PKBs) + sigmoid + update + bootstrap.
  ResNet-20/56 [30]: multiplexed-packed convolutions — a 3x3 kernel is a
      9-rotation PKB; BN folds into PMul/CAdd; ReLU is a composite
      polynomial (CMULT ladder); bootstrap per residual block.
  BERT [53]: 12 layers of BSGS matmul PKBs + softmax/GELU polynomials.

Exact op counts of the closed-source baselines are unknowable; the
generators are calibrated so the SIMULATED ratios reproduce Table IV
(see benchmarks/ and EXPERIMENTS.md).
"""
from __future__ import annotations


from repro.dfg.trace import Handle, ProgramBuilder


def _rot_sum_reduce(h: Handle, log_n: int) -> Handle:
    """Serial rotate-and-add reduction (inner product): log_n
    parallelism-1 PKBs — the HELR bottleneck shape."""
    for i in range(log_n):
        h = h.cadd(h.rot(1 << i))
    return h


def _poly_ladder(h: Handle, degree: int) -> Handle:
    """Chebyshev/PS-style polynomial: ~log2(degree) sequential squarings
    plus combination PMul/CAdds, with rescales."""
    import math

    depth = max(1, math.ceil(math.log2(max(degree, 2))))
    cur = h
    for _ in range(depth):
        cur = cur.square().rescale()
        cur = cur.pmul().cadd(cur.pmul())
    return cur


def _hom_matvec_pkb(h: Handle, n_rot: int, bsgs_bs: int = 0) -> Handle:
    """One homomorphic linear-transform PKB: n_rot parallel rotations,
    PMuls, CAdd tree.  With bsgs_bs > 0 the PKB splits into baby/giant
    serial PKBs (Eq. (3))."""
    b = h.b
    if bsgs_bs and bsgs_bs < n_rot:
        gs = -(-n_rot // bsgs_bs)
        babies = [h.rot(j).pmul() for j in range(1, bsgs_bs)] + [h.pmul()]
        inner = b.sum_tree(babies)
        giants = [inner.rot(i * bsgs_bs).pmul() for i in range(1, gs)]
        return b.sum_tree([inner] + giants).rescale()
    rots = [h.rot(_step(j, n_rot)).pmul() for j in range(1, n_rot)]
    return b.sum_tree([h.pmul()] + rots).rescale()


def _step(j: int, n: int) -> int:
    """Arithmetic-progression steps (plaintext-matrix x ciphertext)."""
    return j


def bootstrapping_dfg(L: int = 35, alpha: int = 12, logN: int = 16,
                      n_stages: int = 3, bsgs_bs: int = 0,
                      eval_levels: int = 8) -> ProgramBuilder:
    b = ProgramBuilder(N=1 << logN, alpha=alpha)
    nh_bits = logN - 1
    stage_radix = -(-nh_bits // n_stages)
    limbs = L + 1
    x = b.input(limbs, tag="ct_boot")

    # CoeffToSlot: n_stages merged FFT stages, ~2^radix rotations each
    for s in range(n_stages):
        x = Handle(b, x.nid, limbs)
        x = _hom_matvec_pkb(x, (1 << stage_radix) - 1, bsgs_bs)
        limbs -= 1
        x.limbs = limbs

    # conjugation split (keyswitch, parallelism 1) + EWOs
    c = x.conj()
    re = x.cadd(c).pmul().rescale()
    im = x.cadd(c).pmul().rescale()
    limbs -= 1

    # EvalMod on both halves: degree-63 sine ladder
    outs = []
    for part in (re, im):
        part.limbs = limbs
        outs.append(_poly_ladder(part, 63))
    merged = outs[0].cadd(outs[1])
    limbs = merged.limbs - 1

    # SlotToCoeff
    y = merged
    for s in range(n_stages):
        y.limbs = max(limbs, eval_levels + 1)
        y = _hom_matvec_pkb(y, (1 << stage_radix) - 1, bsgs_bs)
        limbs -= 1
    y.output()
    return b


def helr_dfg(L: int = 35, alpha: int = 12, logN: int = 16,
             with_bootstrap: bool = True, bsgs_bs: int = 0) -> ProgramBuilder:
    b = ProgramBuilder(N=1 << logN, alpha=alpha)
    nh_bits = logN - 1
    limbs = 8  # HELR iterations run at low levels between bootstraps
    x = b.input(limbs, tag="X")
    w = b.input(limbs, tag="w")

    # inner product X*w: PMul then serial rotate-sum (parallelism-1 PKBs)
    xw = x.cmult(w).rescale()
    ip = _rot_sum_reduce(xw, nh_bits // 2)
    # sigmoid degree-3 (Horner): 2 CMULTs
    sig = ip.square().rescale().cmult(ip.pmul()).rescale().padd()
    # gradient: sigma * X, then reduce over batch axis
    grad = sig.cmult(x).rescale()
    grad = _rot_sum_reduce(grad, nh_bits // 2)
    w2 = w.cadd(grad.pmul())
    w2.output()

    if with_bootstrap:
        boot = bootstrapping_dfg(L=L, alpha=alpha, logN=logN,
                                 bsgs_bs=bsgs_bs)
        _absorb(b, boot)
    return b


def resnet_dfg(n_layers: int = 20, L: int = 35, alpha: int = 12,
               logN: int = 16, boot_every: int = 1,
               bsgs_bs: int = 0) -> ProgramBuilder:
    """ResNet-20/56 with multiplexed parallel convolution [30]."""
    b = ProgramBuilder(N=1 << logN, alpha=alpha)
    conv_layers = n_layers - 1          # minus FC
    # After each bootstrap the layer has ~L_eff + ReLU budget levels:
    # conv (2) + BN (1) + composite ReLU 15 o 15 o 27 (~12) => ops run at
    # limbs ~20 descending, not at the final level.
    post_boot_limbs = 20
    x = b.input(post_boot_limbs, tag="img")
    for layer in range(conv_layers):
        x.limbs = post_boot_limbs
        # 3x3 multiplexed conv: 9-rotation PKB (+BN folded into the PMuls)
        x = _hom_matvec_pkb(x, 9)
        if layer % 3 == 2:
            # downsample/stride: extra packing-shift PKB (parallelism ~4)
            x = _hom_matvec_pkb(x, 4)
        # ReLU composite minimax polynomial (deg 15 o 15 o 27), consuming
        # the remaining level budget down to ~L_eff
        x = _poly_ladder(x, 15)
        x = _poly_ladder(x, 15)
        x = _poly_ladder(x, 27)
        if layer % boot_every == boot_every - 1:
            _absorb(b, bootstrapping_dfg(L=L, alpha=alpha, logN=logN,
                                         bsgs_bs=bsgs_bs))
    # average-pool + FC: rotation-sum + matvec
    x.limbs = 8
    x = _rot_sum_reduce(x, 5)
    x = _hom_matvec_pkb(x, 8)
    x.output()
    return b


def bert_dfg(n_layers: int = 12, L: int = 35, alpha: int = 12,
             logN: int = 16, bsgs_bs: int = 2,
             boots_per_layer: int = 2) -> ProgramBuilder:
    """12-layer BERT inference [53]: per layer QKV/context/FFN matmul
    PKBs + softmax/GELU ladders; C2S inside its bootstrap keeps BSGS with
    (bs=2, gs=32) per the paper's Sec. VI-A capacity note."""
    b = ProgramBuilder(N=1 << logN, alpha=alpha)
    x = b.input(10, tag="seq")
    for _ in range(n_layers):
        x.limbs = 10
        q = _hom_matvec_pkb(x, 12)
        kk = _hom_matvec_pkb(x, 12)
        v = _hom_matvec_pkb(x, 12)
        scores = q.cmult(kk).rescale()
        scores = _poly_ladder(scores, 15)          # softmax approx
        ctxv = scores.cmult(v).rescale()
        ctxv = _hom_matvec_pkb(ctxv, 12)
        ff = _hom_matvec_pkb(ctxv, 16)
        ff = _poly_ladder(ff, 15)                  # GELU approx
        x = _hom_matvec_pkb(ff, 16)
        for _ in range(boots_per_layer):
            _absorb(
                b,
                bootstrapping_dfg(L=L, alpha=alpha, logN=logN,
                                  bsgs_bs=bsgs_bs),
            )
    x.output()
    return b


def convbn_example(logN: int = 16, alpha: int = 12) -> ProgramBuilder:
    """The Fig. 9 case study: three serial PKBs with 9/8/8 rotations."""
    b = ProgramBuilder(N=1 << logN, alpha=alpha)
    x = b.input(12, tag="x")
    x = _hom_matvec_pkb(x, 9)
    x.limbs = 12
    x = _hom_matvec_pkb(x, 8)
    x.limbs = 12
    x = _hom_matvec_pkb(x, 8)
    x.output()
    return b


def _absorb(b: ProgramBuilder, other: ProgramBuilder):
    """Append another builder's nodes (id-shifted) — used to inline
    bootstrap DFGs into application DFGs."""
    offset = b.g._next
    for nid in sorted(other.g.nodes):   # creation order == valid topo order
        node = other.g.nodes[nid]
        new_id = b.g.add(node.op, tuple(a + offset for a in node.args),
                         limbs=node.limbs, ext_limbs=node.ext_limbs,
                         **node.attrs)
        assert new_id == nid + offset


PROGRAMS = {
    "bootstrapping": lambda: bootstrapping_dfg(),
    "helr": lambda: helr_dfg(),
    "resnet20": lambda: resnet_dfg(20),
    "resnet56": lambda: resnet_dfg(56),
    "bert": lambda: bert_dfg(),
}
