"""Typed error taxonomy for the whole stack (core -> runtime -> serve).

Before this module existed, the failure model of the repo was "an
``assert`` fires or garbage comes out": a level-exhausted ciphertext, a
key generated under different params, or a corrupted limb either killed
the process with a bare ``AssertionError`` (which vanishes entirely
under ``python -O``) or silently produced wrong results.  Neither is
acceptable once the engine serves multi-tenant traffic — the serving
layer must be able to *classify* a failure (is retrying useful? is the
request itself poisoned? is the server overloaded?) and account for
every request.

Taxonomy (all rooted at :class:`ReproError`):

``CiphertextError`` — the request's data is wrong; retrying the same
request can never help (permanent):
  * :class:`LevelExhaustedError`      — no modulus level left to consume
  * :class:`ScaleDriftError`          — scale NaN/non-positive or off trace
  * :class:`ModulusChainMismatchError`— level/limb/key chain disagreement
  * :class:`CorruptCiphertextError`   — limb residues out of range / NaN

``ServingError`` — the serving environment failed, not the data:
  * :class:`KeyUnavailableError`      — tenant keys evicted (RETRYABLE:
    per-tenant seeds are stable, a re-lease regenerates bit-identically)
  * :class:`PlanCacheMissError`       — strict admission refused a cold
    ``(plan signature, width)`` dispatch on the live path
  * :class:`TransientEngineError`     — injected/observed transient
    engine fault (RETRYABLE with backoff)
  * :class:`RequestTimeout`           — virtual-clock deadline exceeded
  * :class:`CircuitOpenError`         — per-tenant breaker is open
  * :class:`InvalidRequestError`      — malformed request (unknown
    program id, bad input tags)

``ConfigError`` — invalid operator-supplied configuration (queue bound,
batch width, registry capacity, ...).  These replaced bare ``assert``s
on user-input paths: validation must survive ``python -O``.

Every error carries a keyword ``context`` dict (tenant, level, rid, ...)
and an optional ``hint`` with the remediation step; both are rendered
into ``str(err)`` so an operator reading a log line knows what to do.
:func:`is_retryable` is the single policy point the server's
retry/backoff loop consults.
"""
from __future__ import annotations


class ReproError(Exception):
    """Root of the typed error taxonomy; carries context + a hint."""

    def __init__(self, message: str, *, hint: str | None = None,
                 **context):
        self.message = message
        self.hint = hint
        self.context = context
        super().__init__(self._render())

    def _render(self) -> str:
        parts = [self.message]
        if self.context:
            kv = ", ".join(f"{k}={v!r}" for k, v in
                           sorted(self.context.items()))
            parts.append(f"[{kv}]")
        if self.hint:
            parts.append(f"(hint: {self.hint})")
        return " ".join(parts)


# ------------------------- ciphertext data errors ----------------------
class CiphertextError(ReproError):
    """The ciphertext itself is unusable — retrying cannot help."""


class LevelExhaustedError(CiphertextError):
    """No modulus level left for the requested op (rescale at level 0)."""


class ScaleDriftError(CiphertextError):
    """Ciphertext scale is NaN/non-positive or drifted off the trace."""


class ModulusChainMismatchError(CiphertextError):
    """Operands/keys disagree about the active modulus chain."""


class CorruptCiphertextError(CiphertextError):
    """Limb residues out of [0, q) (or NaN) — data corruption."""


# ------------------------- serving-environment errors ------------------
class ServingError(ReproError):
    """The serving environment failed; the request data may be fine."""


class KeyUnavailableError(ServingError):
    """Tenant key material is not resident (evicted mid-flight)."""


class PlanCacheMissError(ServingError):
    """Strict admission refused a cold (signature, width) dispatch."""


class TransientEngineError(ServingError):
    """Transient engine fault — retry with backoff is expected to work."""


class RequestTimeout(ServingError):
    """The request's virtual-clock deadline expired before completion."""


class CircuitOpenError(ServingError):
    """Per-tenant circuit breaker is open; request shed without work."""


class InvalidRequestError(ServingError):
    """Malformed request: unknown program id, missing input tags, ..."""


# ------------------------- operator configuration ----------------------
class ConfigError(ReproError):
    """Invalid operator-supplied configuration value."""


# ------------------------- retry policy --------------------------------
# The single policy point for the server's retry loop: key eviction is
# recoverable because per-tenant seeds are stable (a re-lease regenerates
# the keys bit-identically); transient engine faults recover by design.
RETRYABLE_ERRORS = (TransientEngineError, KeyUnavailableError)


def is_retryable(err: BaseException) -> bool:
    """Should the server retry the dispatch that raised ``err``?"""
    return isinstance(err, RETRYABLE_ERRORS)
