"""Roofline analysis from the dry-run's compiled artifacts.

Per (arch x shape x mesh) cell, from results/dryrun/*.json:

  compute term    = per-device HLO FLOPs / peak_FLOPs_per_chip
  memory term     = per-device HLO bytes / HBM_bw
  collective term = per-device collective bytes / ICI link bw

(XLA's SPMD cost analysis is per-partition, i.e. already per-chip.)
Hardware: TPU-v5e-class — 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link.

Also reports MODEL_FLOPS = 6*N(_active)*tokens and the useful-compute
ratio MODEL_FLOPS/chips / HLO_FLOPs (remat/attention/redundancy factor).

Usage: PYTHONPATH=src python -m repro.launch.roofline [--mesh single]
Writes results/roofline.json + a markdown table to stdout.
"""
from __future__ import annotations

import argparse
import json
import pathlib

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results"


def _scan_correction(r: dict) -> float:
    """XLA cost analysis counts a while-loop (scan over layer reps) body
    ONCE.  Correct: T = E + reps*(M - E), where E is the analytic
    outside-loop cost (embedding/lm_head/loss) and M the measured total.
    Returns the multiplier T/M (1.0 for unrolled models)."""
    from repro.configs import get_config
    from repro.models.model import layer_pattern

    cfg = get_config(r["arch"])
    if cfg.enc_dec:
        return 1.0                      # whisper is unrolled
    _, reps = layer_pattern(cfg)
    if reps <= 1:
        return 1.0
    M = r.get("hlo_flops") or 0.0
    if not M:
        return 1.0
    bwd = 3.0 if r["shape"].startswith("train") else 1.0
    E = bwd * 2.0 * r["tokens"] * cfg.d_model * cfg.vocab / r["n_chips"]
    E = min(E, 0.95 * M)
    return (E + reps * (M - E)) / M


def analyze_cell(r: dict) -> dict:
    chips = r["n_chips"]
    corr = _scan_correction(r)
    flops_dev = (r.get("hlo_flops") or 0.0) * corr
    bytes_dev = (r.get("hlo_bytes") or 0.0) * corr
    coll_dev = r["collectives"]["total_bytes"] * corr
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    useful = (r["model_flops"] / chips) / flops_dev if flops_dev else 0.0
    # roofline fraction: useful-model-compute time / bound time
    t_model = (r["model_flops"] / chips) / PEAK_FLOPS
    frac = t_model / bound if bound else 0.0
    return {
        **{f"t_{k}_s": v for k, v in terms.items()},
        "dominant": dominant,
        "bound_s": bound,
        "useful_compute_ratio": useful,
        "roofline_fraction": frac,
        "model_flops_per_chip": r["model_flops"] / chips,
        "scan_correction": corr,
    }


def load_cells(mesh: str):
    out = {}
    for p in sorted((RESULTS / "dryrun").glob(f"*__{mesh}.json")):
        r = json.loads(p.read_text())
        out[(r["arch"], r["shape"])] = r
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    cells = load_cells(args.mesh)
    table = {}
    print("| arch | shape | compute(s) | memory(s) | collective(s) | "
          "dominant | useful | roofline frac |")
    print("|---|---|---|---|---|---|---|---|")
    for (arch, shape), r in cells.items():
        a = analyze_cell(r)
        table[f"{arch}__{shape}"] = {**a, "mesh": args.mesh,
                                     "n_chips": r["n_chips"]}
        print(f"| {arch} | {shape} | {a['t_compute_s']:.2e} | "
              f"{a['t_memory_s']:.2e} | {a['t_collective_s']:.2e} | "
              f"{a['dominant']} | {a['useful_compute_ratio']:.2f} | "
              f"{a['roofline_fraction']:.3f} |")
    (RESULTS / f"roofline_{args.mesh}.json").write_text(
        json.dumps(table, indent=2))


if __name__ == "__main__":
    main()
