"""Serving driver: batched prefill + decode with a KV cache.

  PYTHONPATH=src python -m repro.launch.serve --arch phi3_medium_14b \
      --reduced --batch 4 --prompt-len 16 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.models.model import forward, init_cache, init_params


def generate(cfg, params, prompts: np.ndarray, gen: int):
    """prompts: (B, P) int32 -> (B, P+gen) greedy continuation."""
    B, P = prompts.shape
    max_seq = P + gen
    cache = init_cache(cfg, B, max_seq)
    toks = jnp.asarray(prompts)

    # teacher-forced prefill through the decode path (shares the cache
    # machinery; production prefill uses the batched forward)
    step = jax.jit(lambda p, c, t: forward(p, t, cfg, cache=c))
    last = None
    for t in range(P):
        logits, cache = step(params, cache, toks[:, t : t + 1])
        last = logits
    out = [toks]
    cur = jnp.argmax(last[:, -1], axis=-1)[:, None].astype(jnp.int32)
    for _ in range(gen):
        out.append(cur)
        logits, cache = step(params, cache, cur)
        cur = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    return np.asarray(jnp.concatenate(out, axis=1))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3_medium_14b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab,
                           (args.batch, args.prompt_len)).astype(np.int32)
    t0 = time.time()
    out = generate(cfg, params, prompts, args.gen)
    dt = time.time() - t0
    total_new = args.batch * args.gen
    print(f"[serve] {cfg.name}: generated {total_new} tokens in {dt:.2f}s "
          f"({total_new/dt:.1f} tok/s); output shape {out.shape}")


if __name__ == "__main__":
    main()
