"""Production mesh builders.

A FUNCTION (not a module-level constant) so importing never touches jax
device state — the dry-run must set XLA_FLAGS before any jax init.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


def _auto(n):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_small_mesh(devices: int = 8):
    """Reduced mesh for in-CI dry-run tests (subprocess, 8 host devices)."""
    return jax.make_mesh((devices // 4, 4), ("data", "model"),
                         axis_types=_auto(2))


def dp_axes(mesh) -> tuple[str, ...]:
    """Pure data-parallel axes (pod folds into DP)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def dp_size(mesh) -> int:
    s = 1
    for a in dp_axes(mesh):
        s *= mesh.shape[a]
    return s


def batch_pspec(mesh, batch: int) -> P:
    """Shard batch over DP axes when divisible, else replicate."""
    axes = dp_axes(mesh)
    if batch % dp_size(mesh) == 0:
        return P(axes)
    if "data" in axes and batch % mesh.shape["data"] == 0:
        return P("data")
    return P(None)


def sharding(mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)
