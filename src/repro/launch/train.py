"""Training driver.

  PYTHONPATH=src python -m repro.launch.train --arch stablelm_3b \
      --reduced --steps 100 --batch 8 --seq 128

--reduced uses the smoke config (CPU-runnable end-to-end); the full
configs are exercised via the dry-run.  Checkpoints/resume/elastic come
from repro.train.trainer.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config, reduced_config
from repro.data.pipeline import PipelineConfig, TokenPipeline
from repro.models.model import init_params
from repro.train.optimizer import AdamW
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm_3b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--no-resume", action="store_true")
    args = ap.parse_args()

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    print(f"[train] {cfg.name}: ~{cfg.n_params()/1e6:.1f}M params")
    params = init_params(cfg, jax.random.PRNGKey(0))
    pipe = TokenPipeline(PipelineConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch))
    tcfg = TrainerConfig(
        total_steps=args.steps, ckpt_every=args.ckpt_every,
        ckpt_dir=args.ckpt_dir, microbatches=args.microbatches,
        grad_compression=args.grad_compression,
    )
    trainer = Trainer(cfg, tcfg, AdamW(lr=1e-3, warmup_steps=20))
    params, _, losses = trainer.run(params, pipe,
                                    resume=not args.no_resume)
    n = max(len(losses) // 10, 1)
    print(f"[train] loss {np.mean(losses[:n]):.4f} -> "
          f"{np.mean(losses[-n:]):.4f} over {len(losses)} steps")
    if trainer.stragglers:
        print(f"[train] straggler steps flagged: {trainer.stragglers}")


if __name__ == "__main__":
    main()
