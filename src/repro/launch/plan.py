"""Sharding plans: mesh-aware specs for params, optimizer state, batches
and decode caches, with divisibility sanitization (axes that do not
divide a dimension are dropped rather than failing at lower time)."""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.launch.mesh import batch_pspec, dp_axes
from repro.models.model import param_specs


def _axes_size(mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, str):
        return mesh.shape[entry]
    s = 1
    for a in entry:
        s *= mesh.shape[a]
    return s


def sanitize(spec: P, shape: tuple[int, ...], mesh) -> P:
    """Drop spec axes that don't divide the corresponding dimension."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, entry in zip(shape, entries):
        out.append(entry if dim % _axes_size(mesh, entry) == 0 else None)
    return P(*out)


def sanitize_tree(specs, shapes, mesh):
    return jax.tree.map(
        lambda sp, sh: sanitize(sp, sh.shape, mesh), specs, shapes,
        is_leaf=lambda x: isinstance(x, P),
    )


def param_plan(cfg: ModelConfig, mesh, param_sds):
    specs = param_specs(cfg, param_sds)
    specs = sanitize_tree(specs, param_sds, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def opt_plan(cfg: ModelConfig, mesh, opt_sds, param_shardings):
    """m/v inherit the param sharding (ZeRO-style); step replicated."""
    return {
        "m": param_shardings,
        "v": param_shardings,
        "step": NamedSharding(mesh, P()),
    }


def batch_plan(mesh, batch_sds):
    out = {}
    for k, v in batch_sds.items():
        if k in ("tokens", "labels"):
            out[k] = NamedSharding(mesh, batch_pspec(mesh, v.shape[0]))
        elif k == "positions":
            bp = batch_pspec(mesh, v.shape[-2] if v.ndim == 3 else
                             v.shape[0])
            spec = P(None, *bp) if v.ndim == 3 else bp
            out[k] = NamedSharding(mesh, spec)
        elif k == "embeds":
            out[k] = NamedSharding(
                mesh, sanitize(P(dp_axes(mesh), None, None), v.shape, mesh))
        else:
            out[k] = NamedSharding(mesh, P())
    return out


def cache_plan(cfg: ModelConfig, mesh, cache_sds):
    """Decode caches: batch over DP, long axes over 'model'.

    KV seq axis is model-sharded (sequence-sharded KV attention) because
    GQA head counts (often 8) don't divide the 16-way model axis; SSM
    states shard their feature axis instead."""
    dp = dp_axes(mesh)

    def leaf_spec(name, sds):
        sh = sds.shape
        if name in ("k", "v"):          # (reps, B, S, KV, hd)
            return sanitize(P(None, dp, "model", None, None), sh, mesh)
        if name == "c_kv":              # (reps, B, S, rank)
            return sanitize(P(None, dp, "model", None), sh, mesh)
        if name == "k_rope":            # (reps, B, S, 1, rd)
            return sanitize(P(None, dp, "model", None, None), sh, mesh)
        if name == "conv":              # (reps, B, dc-1, di)
            return sanitize(P(None, dp, None, "model"), sh, mesh)
        if name == "ssm":               # (reps, B, di, ds)
            return sanitize(P(None, dp, "model", None), sh, mesh)
        if name == "C":                 # (reps, B, H, hd, hd)
            return sanitize(P(None, dp, None, "model", None), sh, mesh)
        if name == "n":                 # (reps, B, H, hd)
            return sanitize(P(None, dp, None, "model"), sh, mesh)
        if name in ("h", "c"):          # (reps, B, d)
            return sanitize(P(None, dp, "model"), sh, mesh)
        return P()

    slots = []
    for slot in cache_sds["slots"]:
        slots.append({
            k: NamedSharding(mesh, leaf_spec(k, v)) for k, v in slot.items()
        })
    return {"slots": slots, "idx": NamedSharding(mesh, P())}
