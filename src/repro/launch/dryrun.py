import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this lowers the real train/prefill/serve step against
ShapeDtypeStruct inputs on the production mesh, compiles it, and records
memory_analysis / cost_analysis / per-collective byte counts — the inputs
to the roofline analysis (EXPERIMENTS.md §Dry-run / §Roofline).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch phi3_medium_14b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both]
Results cached as JSON under results/dryrun/ (incremental).
"""  # noqa: E402

import argparse      # noqa: E402
import json          # noqa: E402
import pathlib       # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import numpy as np   # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ARCHS, get_config                    # noqa: E402
from repro.configs.base import n_active_params                 # noqa: E402
from repro.configs.shapes import SHAPES, shapes_for            # noqa: E402
from repro.launch import plan as plan_mod                      # noqa: E402
from repro.launch.mesh import make_production_mesh             # noqa: E402
from repro.models.model import init_cache, init_params         # noqa: E402
from repro.models.steps import (                               # noqa: E402
    input_specs, make_prefill_step, make_serve_step, make_train_step,
)
from repro.train.optimizer import AdamW                        # noqa: E402

RESULTS = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"

# post-partitioning HLO, e.g.:  %all-reduce.3 = f32[1024,256]{1,0}
#   all-reduce(%dot), replica_groups=...
_COLL_RE = re.compile(
    r"=\s*(\w+)\[([\d,]*)\]\S*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
}


def collective_bytes(hlo_text: str) -> dict:
    """Sum output bytes of every collective op in the (pre-)optimized HLO."""
    out = {"all-reduce": 0, "all-gather": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0}
    counts = dict.fromkeys(out, 0)
    for m in _COLL_RE.finditer(hlo_text):
        dt, dims, kind = m.groups()
        nbytes = _DTYPE_BYTES.get(dt, 4)
        for d in dims.split(","):
            if d:
                nbytes *= int(d)
        out[kind] += nbytes
        counts[kind] += 1
    return {"bytes": out, "counts": counts,
            "total_bytes": sum(out.values())}


def _eval_shapes(cfg, shape_kind, shape):
    params_sds = jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0)))
    out = {"params": params_sds}
    if shape_kind == "train":
        opt = AdamW(state_dtype=cfg.optimizer_state_dtype)
        out["opt"] = jax.eval_shape(opt.init, params_sds)
        out["optimizer"] = opt
    if shape_kind == "decode":
        out["cache"] = jax.eval_shape(
            lambda: init_cache(cfg, shape.global_batch, shape.seq_len))
    return out


def _with_cap1(c):
    import dataclasses as _dc

    return _dc.replace(c, moe=_dc.replace(c.moe, capacity_factor=1.0))


VARIANTS = {
    "base": lambda c: c,
    "ce_softmax": lambda c: __import__("dataclasses").replace(
        c, ce_impl="softmax"),
    "expert_ff": lambda c: __import__("dataclasses").replace(
        c, expert_shard="ff"),
    "cap1": _with_cap1,
}


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             force: bool = False, variant: str = "base") -> dict:
    RESULTS.mkdir(parents=True, exist_ok=True)
    suffix = "" if variant == "base" else f"__{variant}"
    out_path = RESULTS / f"{arch}__{shape_name}__{mesh_kind}{suffix}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    t0 = time.time()
    cfg = VARIANTS[variant](get_config(arch))
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = int(np.prod(list(mesh.shape.values())))
    sds = _eval_shapes(cfg, shape.kind, shape)
    batch_sds = input_specs(arch, shape_name)

    p_plan = plan_mod.param_plan(cfg, mesh, sds["params"])
    b_plan = plan_mod.batch_plan(mesh, batch_sds)

    with mesh:
        if shape.kind == "train":
            o_plan = plan_mod.opt_plan(cfg, mesh, sds["opt"], p_plan)
            step = make_train_step(cfg, sds["optimizer"])
            jitted = jax.jit(
                step,
                in_shardings=(p_plan, o_plan, b_plan),
                out_shardings=(p_plan, o_plan, None),
            )
            lowered = jitted.lower(sds["params"], sds["opt"], batch_sds)
        elif shape.kind == "prefill":
            step = make_prefill_step(cfg)
            jitted = jax.jit(step, in_shardings=(p_plan, b_plan))
            lowered = jitted.lower(sds["params"], batch_sds)
        else:
            c_plan = plan_mod.cache_plan(cfg, mesh, sds["cache"])
            step = make_serve_step(cfg)
            jitted = jax.jit(
                step,
                in_shardings=(p_plan, c_plan, b_plan),
                out_shardings=(None, c_plan),
            )
            lowered = jitted.lower(sds["params"], sds["cache"], batch_sds)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        cost = compiled.cost_analysis() or {}
        try:
            mem = compiled.memory_analysis()
            mem_info = {
                "argument_size_bytes": getattr(mem, "argument_size_in_bytes",
                                               None),
                "output_size_bytes": getattr(mem, "output_size_in_bytes",
                                             None),
                "temp_size_bytes": getattr(mem, "temp_size_in_bytes", None),
                "generated_code_size_bytes": getattr(
                    mem, "generated_code_size_in_bytes", None),
            }
        except Exception as e:  # CPU backend may not implement it
            mem_info = {"error": str(e)}

        # collectives are inserted by the SPMD partitioner — parse the
        # POST-compile optimized HLO, not the lowered module
        try:
            hlo_post = compiled.as_text()
        except Exception:
            hlo_post = lowered.as_text()
        coll = collective_bytes(hlo_post)

    # analytic per-device parameter/state bytes (exact from the plan)
    def _sharded_bytes(sds_tree, plans):
        total = 0
        for leaf, ns in zip(jax.tree.leaves(sds_tree),
                            jax.tree.leaves(
                                plans, is_leaf=lambda x: isinstance(
                                    x, NamedSharding))):
            shard_elems = np.prod(ns.shard_shape(leaf.shape)) \
                if hasattr(ns, "shard_shape") else np.prod(leaf.shape)
            total += int(shard_elems) * leaf.dtype.itemsize
        return total

    param_bytes_dev = _sharded_bytes(sds["params"], p_plan)
    state_bytes_dev = param_bytes_dev
    if shape.kind == "train":
        state_bytes_dev += 2 * param_bytes_dev  # m, v (dtype-scaled below)

    n_par = cfg.n_params()
    n_act = n_active_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6 * n_act * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2 * n_act * tokens
    else:
        tokens = shape.global_batch
        model_flops = 2 * n_act * tokens

    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "variant": variant,
        "n_chips": n_chips,
        "status": "ok",
        "lower_s": t_lower, "compile_s": t_compile,
        "hlo_flops": cost.get("flops"),
        "hlo_bytes": cost.get("bytes accessed"),
        "cost_analysis": {k: v for k, v in cost.items()
                          if isinstance(v, (int, float))},
        "memory_analysis": mem_info,
        "collectives": coll,
        "param_bytes_per_device": param_bytes_dev,
        "state_bytes_per_device": state_bytes_dev,
        "n_params": n_par, "n_active_params": n_act,
        "model_flops": model_flops,
        "tokens": tokens,
    }
    out_path.write_text(json.dumps(result, indent=2))
    print(f"[dryrun] {arch} x {shape_name} x {mesh_kind} ({variant}): "
          f"compile {t_compile:.1f}s, HLO flops {cost.get('flops', 0):.3e}, "
          f"collectives {coll['total_bytes']/1e9:.2f} GB")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", default="base", choices=list(VARIANTS))
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = []
    if args.all:
        for arch in ARCHS:
            for shape in shapes_for(arch):
                for m in meshes:
                    cells.append((arch, shape, m))
    else:
        cells = [(args.arch, args.shape, m) for m in meshes]

    failures = []
    for arch, shape, m in cells:
        try:
            run_cell(arch, shape, m, force=args.force,
                     variant=args.variant)
        except Exception as e:
            traceback.print_exc()
            failures.append((arch, shape, m, str(e)))
            (RESULTS / f"{arch}__{shape}__{m}.FAILED").write_text(
                traceback.format_exc())
    print(f"\n[dryrun] {len(cells) - len(failures)}/{len(cells)} cells ok")
    for f in failures:
        print("  FAILED:", f[:3])
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
