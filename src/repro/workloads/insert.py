"""Automatic bootstrap insertion: a level-tracking pass over traced DFGs.

A workload is a sequence of *stages* (one per Dense layer).  Each stage
consumes a data-dependent number of levels (matvec: 1, a degree-d
Chebyshev activation: ~log2(d)+2 — the exact figure depends on the
incoming scale's alignment path), so instead of a static cost table the
planner TRACES each candidate span through a throwaway
``runtime.TraceContext`` at the actual (level, scale) it would run at
and reads consumption off the recorded DFG.  When a stage no longer
fits, a ``Bootstrapper.compile`` program must be spliced in; the cut
point is chosen by scoring every feasible boundary with the total
limb-count of the resulting pre/post DFGs (limbs x N ~ modular-word
traffic, the same currency ``dfg.hoist`` counts) and taking the argmin
— cutting as late as possible wins naturally because post-bootstrap
stages rerun at the (lower) bootstrap output level.

The pass is purely symbolic: nothing is executed, no keys are touched,
and the traces it commits are exactly the ones
``pipeline.compile_workload`` lowers — so the plan can never drift from
the program that runs.
"""
from __future__ import annotations

import dataclasses

from repro.dfg.graph import OpKind
from repro.errors import LevelExhaustedError
from repro.runtime.compile import TraceContext

_IO = (OpKind.INPUT, OpKind.OUTPUT)


def trace_span(params, stages, level: int, scale: float,
               close_at_zero: bool = False):
    """Trace ``stages`` from a (level, scale) input; returns (tc, out).

    ``close_at_zero`` appends the level_down that parks the result at
    level 0 for a following bootstrap segment (mod_raise requires it).
    Input tag is ``"x"``, output tag ``"y"``.
    """
    tc = TraceContext(params)
    h = tc.input("x", level=level, scale=scale)
    for stage in stages:
        h = stage.apply(tc, h)
    if close_at_zero and h.level > 0:
        h = tc.level_down(h, 0)
    tc.output(h, "y")
    return tc, h


def graph_words(tc: TraceContext) -> int:
    """Limb-word proxy for a traced graph's work: sum of active limbs
    over all non-I/O nodes, times N."""
    total = sum(n.limbs for n in tc.g.nodes.values() if n.op not in _IO)
    return total * tc.params.N


@dataclasses.dataclass(frozen=True)
class SpanProbe:
    """Feasibility + cost of one traced span."""

    words: int
    out_level: int
    out_scale: float


def probe_span(params, stages, level: int, scale: float) -> SpanProbe | None:
    """Trace a span at (level, scale); ``None`` if the level budget
    underflows.  Underflow surfaces as assorted exceptions from deep in
    the op implementations (negative chain indices, level_down
    assertions), so feasibility is "traces cleanly AND every node keeps
    >= 1 limb"."""
    if level < 0:
        return None
    try:
        tc, h = trace_span(params, stages, level, scale)
    except Exception:
        return None
    if h.level < 0:
        return None
    if any(n.limbs < 1 for n in tc.g.nodes.values() if n.op not in _IO):
        return None
    return SpanProbe(graph_words(tc), h.level, float(h.scale))


def probe_bootstrap(params, btp, scale: float) -> SpanProbe:
    """Trace one bootstrap at a level-0 input of the given scale and
    report its output (level, scale) — the budget a post-cut segment
    restarts with."""
    tc = TraceContext(params)
    h = tc.input("ct", level=0, scale=scale)
    out = btp.bootstrap(h, ctx=tc)
    tc.output(out, "out")
    return SpanProbe(graph_words(tc), out.level, float(out.scale))


@dataclasses.dataclass(frozen=True)
class PlannedCut:
    """One committed bootstrap insertion point."""

    after_stage: int          # bootstrap splices after stages[:after_stage]
    cut_scale: float          # exact traced scale entering the bootstrap
    scores: dict              # candidate boundary -> limb-word score


@dataclasses.dataclass
class WorkloadPlan:
    """Output of the level-tracking pass: compute spans, cuts, and the
    per-stage level table (for summaries/docs)."""

    spans: list[tuple[int, int]]      # stage-index ranges, cuts between
    cuts: list[PlannedCut]
    table: list[dict]                 # per stage: name/in_level/out_level
    input_level: int
    input_scale: float
    output_level: int
    output_scale: float

    @property
    def n_bootstraps(self) -> int:
        return len(self.cuts)


def _pick_cut(params, stages, seg_start: int, blocked: int, level: int,
              scale: float, btp) -> tuple[int, float, SpanProbe, dict]:
    """Score every boundary j in (seg_start, blocked] as a cut point:
    cost = words(pre-span at the segment level) + words(post-span
    replayed at the bootstrap output level).  The bootstrap's own cost
    is (near-)constant across candidates, so it cancels."""
    best = None
    scores: dict[int, int | None] = {}
    for j in range(seg_start + 1, blocked + 1):
        pre = probe_span(params, stages[seg_start:j], level, scale)
        if pre is None:               # prefix itself no longer fits
            scores[j] = None
            continue
        boot = probe_bootstrap(params, btp, pre.out_scale)
        post = probe_span(params, stages[j:blocked + 1],
                          boot.out_level, boot.out_scale)
        if post is None:              # blocked stage still doesn't fit
            scores[j] = None
            continue
        cost = pre.words + post.words
        scores[j] = cost
        # ties break toward the LATER cut (smaller wasted level gap)
        if best is None or cost <= best[0]:
            best = (cost, j, pre.out_scale, boot)
    if best is None:
        raise LevelExhaustedError(
            f"stage '{stages[blocked].name}' does not fit the "
            f"post-bootstrap budget of this parameter set "
            f"(L={params.L}); use deeper params or a cheaper stage")
    _, j, cut_scale, boot = best
    return j, cut_scale, boot, scores


def plan_cuts(model, params, btp=None, input_level: int | None = None,
              input_scale: float | None = None) -> WorkloadPlan:
    """The level-tracking pass: walk the stages, tracing each growing
    span at its actual (level, scale); when a stage underflows, choose
    the cheapest feasible cut boundary and splice a bootstrap there.

    Raises :class:`repro.errors.LevelExhaustedError` if a cut is needed
    but no ``btp`` was provided, or if no feasible cut exists.
    """
    stages = list(model.layers)
    level = params.L if input_level is None else int(input_level)
    scale = float(params.scale if input_scale is None else input_scale)

    spans: list[tuple[int, int]] = []
    cuts: list[PlannedCut] = []
    seg_start, seg_level, seg_scale = 0, level, scale
    i = 0
    while i < len(stages):
        probe = probe_span(params, stages[seg_start:i + 1],
                           seg_level, seg_scale)
        if probe is not None:
            i += 1
            continue
        if i == seg_start:
            if not cuts:
                raise LevelExhaustedError(
                    f"stage '{stages[i].name}' does not fit at input "
                    f"level {seg_level}; raise input_level (<= L="
                    f"{params.L}) or shrink the stage")
            raise LevelExhaustedError(
                f"stage '{stages[i].name}' does not fit the "
                f"post-bootstrap budget (level {seg_level}); use deeper "
                f"params or a cheaper stage")
        if btp is None:
            raise LevelExhaustedError(
                f"workload '{model.name}' exhausts the level budget at "
                f"stage '{stages[i].name}' (input level {level}); pass "
                f"a Bootstrapper to enable automatic insertion")
        j, cut_scale, boot, scores = _pick_cut(
            params, stages, seg_start, i, seg_level, seg_scale, btp)
        spans.append((seg_start, j))
        cuts.append(PlannedCut(j, cut_scale, scores))
        seg_start, seg_level, seg_scale = j, boot.out_level, boot.out_scale
        # NOTE: i is not advanced — the blocked stage re-probes from the
        # fresh post-bootstrap segment.
    spans.append((seg_start, len(stages)))

    # Per-stage level table from the committed spans (incremental
    # re-trace; spans are short so this is cheap).
    table: list[dict] = []
    seg_iter = iter(zip(spans, [None] + list(cuts)))
    lvl, sc = level, scale
    for (a, b), cut in seg_iter:
        if cut is not None:
            boot = probe_bootstrap(params, btp, cut.cut_scale)
            table.append({"stage": "<bootstrap>", "in_level": 0,
                          "out_level": boot.out_level})
            lvl, sc = boot.out_level, boot.out_scale
        for s in range(a, b):
            p = probe_span(params, stages[a:s + 1], lvl, sc)
            prev = (probe_span(params, stages[a:s], lvl, sc)
                    if s > a else SpanProbe(0, lvl, sc))
            table.append({"stage": stages[s].name,
                          "in_level": prev.out_level,
                          "out_level": p.out_level})
        p = probe_span(params, stages[a:b], lvl, sc)
        out_level, out_scale = p.out_level, p.out_scale

    return WorkloadPlan(spans=spans, cuts=cuts, table=table,
                        input_level=level, input_scale=scale,
                        output_level=out_level, output_scale=out_scale)
