"""Encrypted-inference model zoo: packed dense layers + polynomial acts.

The paper's end-to-end claims (Table IV) rest on application workloads,
not bootstrapping alone — this module defines the models those
workloads run.  A :class:`Dense` layer is a diagonally-banded weight
matrix evaluated with the BSGS matvec from :mod:`repro.core.linear`
(baby-step PKB feeding a giant-step PKB, Eq. (3)), an optional bias
added as a plaintext at the ciphertext's exact (level, scale), and an
optional activation evaluated as a Chebyshev interpolant through
:func:`repro.core.polyeval.eval_chebyshev_bsgs` (Paterson-Stockmeyer,
O(sqrt d) CMults).  Because every op goes through the context's public
API, the SAME layer code runs eagerly on a ``CKKSContext`` or traces
through ``runtime.TraceContext`` — that symmetry is what makes the
compiled-vs-eager bit-exactness gates of ``tests/test_workloads.py``
possible.

Magnitude discipline: activations are interpolated on [-1, 1], so
weights are row-normalized to a configurable inf-norm ``gain`` and
sample inputs are bounded; the bootstrap-shaped MLP additionally keeps
every intermediate at |m| ~ 1e-2 because EvalMod's sine approximation
is only linear near 0 (m/q0 must stay small — see
``core/bootstrap.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.core import linear
from repro.core.polyeval import chebyshev_coeffs, eval_chebyshev_bsgs


@dataclasses.dataclass(frozen=True)
class Activation:
    """A pointwise nonlinearity and its Chebyshev interpolant."""

    name: str
    fn: Callable[[np.ndarray], np.ndarray]
    degree: int
    coeffs: np.ndarray = dataclasses.field(repr=False, default=None)

    def __post_init__(self):
        if self.coeffs is None:
            object.__setattr__(
                self, "coeffs", chebyshev_coeffs(self.fn, self.degree))


def sigmoid4(degree: int = 15) -> Activation:
    """sigmoid(4t) on [-1, 1] — the logistic-regression link (HELR-style
    rescaled argument so the transition is visible inside the
    interpolation interval).  Chebyshev error: ~2e-3 at degree 7,
    ~6e-6 at degree 15."""
    return Activation("sigmoid4", lambda t: 1.0 / (1.0 + np.exp(-4.0 * t)),
                      degree)


def scaled_tanh(scale: float = 0.1, degree: int = 7) -> Activation:
    """scale * tanh(t): an odd activation whose output magnitude stays
    ~``scale`` — the bootstrap-friendly nonlinearity (post-activation
    messages must sit in EvalMod's near-linear sine region)."""
    return Activation(f"tanh*{scale:g}",
                      lambda t, s=scale: s * np.tanh(t), degree)


@dataclasses.dataclass
class Dense:
    """One packed dense layer: diagonal matvec -> +bias -> activation."""

    name: str
    A: np.ndarray                     # (nh, nh) real, diagonally banded
    bias: np.ndarray | None = None    # (nh,) real
    act: Activation | None = None
    bs: int = 4                       # BSGS baby-step block size

    def __post_init__(self):
        self._diags = linear.matrix_diagonals(self.A)

    @property
    def diags(self) -> dict[int, np.ndarray]:
        return self._diags

    def apply(self, ctx, ct):
        """Evaluate the layer on any context exposing the public op API
        (eager ``CKKSContext`` or ``runtime.TraceContext``)."""
        giants = {d // self.bs for d in self._diags}
        if self.bs > 0 and len(giants) > 1:
            out = linear.matvec_bsgs(ctx, ct, self._diags, bs=self.bs)
        else:
            out = linear.matvec_diag(ctx, ct, self._diags)
        if self.bias is not None:
            # pt_add keeps ct.scale and adds pt.m raw: the bias MUST be
            # encoded at the ciphertext's exact (level, scale).
            pt = ctx.encode(self.bias, level=out.level, scale=out.scale)
            out = ctx.pt_add(out, pt)
        if self.act is not None:
            out = eval_chebyshev_bsgs(ctx, out, self.act.coeffs)
        return out

    def reference(self, x: np.ndarray) -> np.ndarray:
        y = linear.matvec_plain(self.A, x)
        if self.bias is not None:
            y = y + self.bias
        return self.act.fn(np.real(y)) if self.act is not None else y


@dataclasses.dataclass
class Workload:
    """An encrypted-inference application: a stack of Dense layers plus
    the plaintext reference and a seeded input sampler."""

    name: str
    layers: list[Dense]
    input_mag: float = 1.0            # sample() magnitude bound
    tolerance: float = 5e-3           # decrypt-accuracy floor (gated)

    @property
    def nh(self) -> int:
        return self.layers[0].A.shape[0]

    def apply(self, ctx, ct):
        for layer in self.layers:
            ct = layer.apply(ctx, ct)
        return ct

    def reference(self, x: np.ndarray) -> np.ndarray:
        for layer in self.layers:
            x = layer.reference(x)
        return np.real(x)

    def sample(self, rng: np.random.Generator) -> np.ndarray:
        return rng.uniform(-1.0, 1.0, self.nh) * self.input_mag


def _band_matrix(nh: int, offsets, rng: np.random.Generator,
                 gain: float) -> np.ndarray:
    """Random matrix supported on the given generalized diagonals,
    row-normalized so the inf-norm is exactly ``gain`` (keeps matvec
    outputs inside the activation's interpolation interval)."""
    A = np.zeros((nh, nh))
    for d in offsets:
        vals = rng.uniform(-1.0, 1.0, nh)
        idx = np.arange(nh)
        A[idx, (idx + d) % nh] = vals
    A *= gain / np.abs(A).sum(axis=1, keepdims=True)
    return A


def logreg(nh: int, seed: int = 0, degree: int = 15, n_diags: int = 8,
           bs: int = 4, gain: float = 0.8) -> Workload:
    """Packed logistic regression: one banded matvec + sigmoid(4t).

    Level cost: 1 (matvec) + 8 (degree-15 Chebyshev) = 9 levels."""
    rng = np.random.default_rng(seed)
    A = _band_matrix(nh, range(n_diags), rng, gain)
    b = rng.uniform(-0.1, 0.1, nh)
    layer = Dense("logits", A, bias=b, act=sigmoid4(degree), bs=bs)
    return Workload("logreg", [layer], input_mag=1.0, tolerance=5e-3)


def mlp(nh: int, seed: int = 0, n_diags: int = 8, bs: int = 4,
        gain: float = 0.8) -> Workload:
    """Two dense layers with degree-7 sigmoid activations.  Level
    cost: (1+6) + (1+6) = 14 levels (degree-7 Chebyshev error ~2e-3;
    a degree-3 head would blow the 6e-3 decrypt floor at ~2e-2)."""
    rng = np.random.default_rng(seed)
    A1 = _band_matrix(nh, range(n_diags), rng, gain)
    b1 = rng.uniform(-0.1, 0.1, nh)
    A2 = _band_matrix(nh, range(n_diags), rng, gain)
    b2 = rng.uniform(-0.1, 0.1, nh)
    layers = [
        Dense("hidden", A1, bias=b1, act=sigmoid4(degree=7), bs=bs),
        Dense("head", A2, bias=b2, act=sigmoid4(degree=7), bs=bs),
    ]
    return Workload("mlp", layers, input_mag=1.0, tolerance=6e-3)


def mlp_bootstrap(nh: int, seed: int = 0, n_diags: int = 8,
                  bs: int = 4, gain: float = 0.8) -> Workload:
    """The bootstrap-exercising MLP: magnitudes kept ~1e-2 so the
    mid-pipeline bootstrap's EvalMod stays in its accurate region.

    Layer 1 costs 1 + 6 = 7 levels (degree-7 scaled tanh); layer 2 is a
    bias-free linear head (1 level).  Compiled with ``input_level=7``
    the planner must splice a bootstrap between them."""
    rng = np.random.default_rng(seed)
    A1 = _band_matrix(nh, range(n_diags), rng, gain)
    b1 = rng.uniform(-0.02, 0.02, nh)
    A2 = _band_matrix(nh, range(n_diags), rng, gain)
    layers = [
        Dense("hidden", A1, bias=b1, act=scaled_tanh(0.1, degree=7), bs=bs),
        Dense("head", A2, bias=None, act=None, bs=bs),
    ]
    return Workload("mlp_boot", layers, input_mag=0.3, tolerance=2e-2)
