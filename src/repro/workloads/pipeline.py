"""Compile and execute whole workloads through the PR 3-5 runtime.

A compiled workload is a chain of *segments*: compute segments (the
planner's stage spans traced with ``TraceContext`` and lowered via
``compile_program`` — PKB fusion applies per segment) alternating with
bootstrap segments (``Bootstrapper.compile`` programs spliced at the
planner's cut points, compiled at the exact traced scale entering the
cut).  Execution chains ``ProgramExecutor.run`` / ``run_batched`` over
the segments, so every segment rides the engine's cached jit plans and
the vmap ct-batching path; reports reconcile per segment and aggregate.

Bit-exactness story: compute segments traced with ``fusion=False`` are
bit-exact with the eager replay (``WorkloadProgram.run_eager``) because
the traced scale floats are replayed verbatim by the executor, the
segment output ciphertext therefore carries the exact scale the next
segment's INPUT node was traced at, and the bootstrap segment was
compiled with ``input_scale`` pinned to that same float.
"""
from __future__ import annotations

import dataclasses

from repro.runtime.compile import CompiledProgram, compile_program
from repro.runtime.exec import ProgramExecutor
from repro.runtime.report import program_blocks
from repro.workloads.insert import WorkloadPlan, plan_cuts, trace_span
from repro.workloads.models import Workload


@dataclasses.dataclass
class Segment:
    """One link of the chain: a compiled program plus its wiring."""

    kind: str                          # "compute" | "bootstrap"
    compiled: CompiledProgram
    span: tuple[int, int] | None       # stage-index range (compute only)
    in_tag: str
    out_tag: str
    closed: bool = False               # compute span ends level_down(0)


def _out_node(compiled: CompiledProgram, tag: str):
    return compiled.dfg.nodes[compiled.outputs[tag]]


@dataclasses.dataclass
class WorkloadProgram:
    """A planned, compiled workload: segments + the plan that produced
    them."""

    model: Workload
    params: object
    plan: WorkloadPlan
    segments: list[Segment]
    fused: bool
    exact: bool

    @property
    def n_bootstraps(self) -> int:
        return sum(1 for s in self.segments if s.kind == "bootstrap")

    @property
    def input_level(self) -> int:
        return self.plan.input_level

    @property
    def input_scale(self) -> float:
        return self.plan.input_scale

    @property
    def output_level(self) -> int:
        return self.plan.output_level

    @property
    def output_scale(self) -> float:
        return self.plan.output_scale

    def predicted_modups(self) -> int:
        return sum(s.compiled.summary()["predicted_modups"]
                   for s in self.segments)

    def summary(self) -> dict:
        return {
            "workload": self.model.name,
            "fused": self.fused,
            "exact": self.exact,
            "n_segments": len(self.segments),
            "n_bootstraps": self.n_bootstraps,
            "input_level": self.input_level,
            "output_level": self.output_level,
            "predicted_modups": self.predicted_modups(),
            "levels": self.plan.table,
            "segments": [
                {"kind": s.kind, "span": s.span,
                 **s.compiled.summary()} for s in self.segments
            ],
        }

    def run_eager(self, ctx, ct, btp=None):
        """Replay the committed plan op-by-op on an eager context —
        the baseline the compiled path must be bit-exact with
        (``fusion=False``) and strictly beat on ModUps."""
        stages = self.model.layers
        for seg in self.segments:
            if seg.kind == "compute":
                a, b = seg.span
                for stage in stages[a:b]:
                    ct = stage.apply(ctx, ct)
                if seg.closed and ct.level > 0:
                    ct = ctx.level_down(ct, 0)
            else:
                if btp is None:
                    raise ValueError(
                        "run_eager on a workload with bootstrap "
                        "segments needs the Bootstrapper")
                ct = btp.bootstrap(ct)
        return ct


def compile_workload(model: Workload, params, btp=None,
                     input_level: int | None = None,
                     input_scale: float | None = None,
                     fusion: bool = False,
                     exact: bool = True) -> WorkloadProgram:
    """Plan (with automatic bootstrap insertion), trace, and lower a
    workload.  ``fusion``/``exact`` are forwarded to every segment's
    ``compile_program`` / ``Bootstrapper.compile``."""
    plan = plan_cuts(model, params, btp=btp, input_level=input_level,
                     input_scale=input_scale)
    stages = list(model.layers)
    segments: list[Segment] = []
    level, scale = plan.input_level, plan.input_scale
    for k, (a, b) in enumerate(plan.spans):
        close = k < len(plan.spans) - 1
        tc, _ = trace_span(params, stages[a:b], level, scale,
                           close_at_zero=close)
        compiled = compile_program(tc, fusion=fusion, exact=exact)
        segments.append(Segment("compute", compiled, (a, b), "x", "y",
                                closed=close))
        node = _out_node(compiled, "y")
        level, scale = node.limbs - 1, float(node.attrs["scale"])
        if close:
            boot = btp.compile(input_scale=scale, fusion=fusion,
                               exact=exact)
            segments.append(Segment("bootstrap", boot, None, "ct", "out"))
            bnode = _out_node(boot, "out")
            level, scale = bnode.limbs - 1, float(bnode.attrs["scale"])
    return WorkloadProgram(model=model, params=params, plan=plan,
                           segments=segments, fused=fusion, exact=exact)


@dataclasses.dataclass
class WorkloadResult:
    """Chained execution output + per-segment reports."""

    output: object                    # Ciphertext, or list when batched
    reports: list | None = None

    def reconcile(self) -> dict:
        """Aggregate exact reconciliation: every segment's executed
        counters must equal its dfg.hoist prediction."""
        if not self.reports:
            raise ValueError("run with with_report=True to reconcile")
        per = [r.reconcile() for r in self.reports]
        return {
            "counts_match": all(p["counts_match"] for p in per),
            "segments": per,
        }


class WorkloadExecutor:
    """Chains ``ProgramExecutor`` over a workload's segments."""

    def __init__(self, ctx):
        self.ctx = ctx
        self.ex = ProgramExecutor(ctx)

    def run(self, wp: WorkloadProgram, ct, with_report: bool = False,
            validate: bool = False) -> WorkloadResult:
        reports = [] if with_report else None
        for seg in wp.segments:
            res = self.ex.run(seg.compiled, {seg.in_tag: ct},
                              with_report=with_report, validate=validate)
            ct = res[seg.out_tag]
            if with_report:
                reports.append(res.report)
        return WorkloadResult(ct, reports)

    def run_batched(self, wp: WorkloadProgram, cts: list,
                    with_report: bool = False,
                    validate: bool = False) -> WorkloadResult:
        reports = [] if with_report else None
        for seg in wp.segments:
            res = self.ex.run_batched(seg.compiled, {seg.in_tag: cts},
                                      with_report=with_report,
                                      validate=validate)
            cts = res[seg.out_tag]
            if with_report:
                reports.append(res.report)
        return WorkloadResult(cts, reports)


def workload_blocks(wp: WorkloadProgram, batch: int = 1) -> list:
    """Concatenated per-segment keyswitch-block volumes — the feed for
    the Sec. V group-level pipeline scheduler."""
    blocks = []
    for seg in wp.segments:
        blocks.extend(program_blocks(seg.compiled, batch))
    return blocks


def scheduled_result(wp: WorkloadProgram, hw, batch: int = 1,
                     mode: str = "pipelined"):
    """What would the HE^2 hardware do with this workload: schedule the
    lowered blocks on the xPU/xMU/link/evk timelines."""
    from repro.sim.engine import simulate_blocks

    return simulate_blocks(workload_blocks(wp, batch), hw,
                           name=f"workload:{wp.model.name}", mode=mode)
