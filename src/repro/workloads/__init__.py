"""Encrypted inference workloads through the compiled runtime.

Real applications — packed logistic regression, small MLPs — traced
through ``repro.runtime`` with automatic bootstrap insertion when the
level budget runs out:

* :mod:`repro.workloads.models` — diagonally-packed Dense layers with
  Chebyshev polynomial activations; the same source runs eagerly or
  traces.
* :mod:`repro.workloads.insert` — the level-tracking planner that
  probes stage spans symbolically and splices ``Bootstrapper.compile``
  programs at the cheapest cut points.
* :mod:`repro.workloads.pipeline` — multi-segment compilation
  (``compile_workload``), chained batched execution
  (``WorkloadExecutor``), eager replay, and the sim-timeline feed.

Operator guide: ``docs/WORKLOADS.md``.
"""
from repro.workloads.insert import (
    PlannedCut, SpanProbe, WorkloadPlan, plan_cuts, probe_bootstrap,
    probe_span,
)
from repro.workloads.models import (
    Activation, Dense, Workload, logreg, mlp, mlp_bootstrap,
    scaled_tanh, sigmoid4,
)
from repro.workloads.pipeline import (
    Segment, WorkloadExecutor, WorkloadProgram, WorkloadResult,
    compile_workload, scheduled_result, workload_blocks,
)

__all__ = [
    "Activation", "Dense", "Workload", "logreg", "mlp", "mlp_bootstrap",
    "scaled_tanh", "sigmoid4",
    "PlannedCut", "SpanProbe", "WorkloadPlan", "plan_cuts",
    "probe_bootstrap", "probe_span",
    "Segment", "WorkloadExecutor", "WorkloadProgram", "WorkloadResult",
    "compile_workload", "scheduled_result", "workload_blocks",
]
