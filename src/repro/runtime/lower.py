"""Lowering: traced DFG + fusion plan -> executable hoisted/eager steps.

Each PKB (or fused PKB group) is *lifted*: the expression under each of
its sinks is rewritten, by the identities the paper's HERO framework is
built on, into a canonical linear combination

    sink = sum_t  coeff_t * [ prod_f roll(pt_f, -r_f) ] * Rot_{s_t}(anchor)

using Rot_a(Rot_b(x)) = Rot_{a+b}(x) and Rot_s(pt * x) =
roll(pt, -s) * Rot_s(x) (Eq. (4) of the paper).  A lifted sink lowers to
ONE ``hoisted_rotation_sum`` engine invocation; sinks sharing an anchor
ciphertext share one ModUp (cross-block double hoisting).  Anything that
does not lift — PAdds inside a region, for instance — falls back to
eager per-op execution, which keeps the compiled path bit-exact with
the eager one by construction.  Multi-anchor PKBs (the giant-step
blocks of BSGS, whose rotations consume different ciphertexts) stay
eager under ``exact=True``; with ``exact=False`` they lower to
``MultiHoistedStep``s that accumulate every rotation's IP in the
extended basis and close the sum with ONE ModDown.

Relinearization is lowered through the same keyswitch-family hierarchy
(see ``KeyswitchFamilyStep``): every CMULT node becomes a ``RelinStep``
on the engine's ``relin`` entry point (bit-exact with eager
``CKKSContext.multiply``), and with ``exact=False`` the sum-of-CMult
closures of the BSGS Chebyshev evaluation (CAdd trees over >= 2
same-level CMULTs, ``polyeval.eval_chebyshev_bsgs``'s giant-step
product sums) merge into ``MultiRelinStep``s — all relin IPs of the
closure accumulate in the extended basis and ONE ModDown closes the
block, the relin analogue of the multi-anchor rotation lowering.

With ``fusion=True`` the lift is allowed to recurse across the members
of an ``optimal_fusion`` group, composing serial PKBs into one block
(strictly fewer ModUps/ModDowns, numerically equivalent).  Without it
the lift stops at direct rotations of the anchor, which preserves
bit-exactness.
"""
from __future__ import annotations

import dataclasses

from repro import obs
from repro.dfg.fusion import optimal_fusion
from repro.dfg.graph import OpKind
from repro.dfg.pkb import PKB, identify_pkbs
from repro.runtime.compile import CompiledProgram, TraceContext

# A term key: (rotation step, sorted ((pt id, roll), ...) factor tuple).
Term = tuple[int, tuple[tuple[int, int], ...]]


class Unliftable(Exception):
    """Raised when a sink expression has no hoisted-rotation-sum form."""


class KeyswitchFamilyStep:
    """Base of every step dispatched on the keyswitch engine.

    The keyswitch family has two flavors sharing the ModUp -> IP ->
    ModDown datapath: *rotation* (``HoistedStep``/``MultiHoistedStep``,
    per-step galois keys, digits rotated in the eval domain) and
    *relinearization* (``RelinStep``/``MultiRelinStep``, the d2
    tensor-product component against the one program-wide mult key).
    The ``Multi*`` variants of both accumulate several terms' IPs in the
    extended basis and close them with ONE ModDown (``exact=False``
    lowering only — the merged approximate-FBC rounding differs from
    the per-term trajectory).  All subclasses carry ``out`` (the DFG
    node the step produces) and ``level``.
    """

    family = "keyswitch"
    out: int
    level: int


@dataclasses.dataclass
class HoistedStep(KeyswitchFamilyStep):
    """One hoisted-rotation-sum invocation producing node ``out``."""

    family = "rotation"

    out: int
    anchor: int
    level: int
    steps: list[int]                        # sorted distinct steps
    # step -> [(coeff, factors)], or None for a pure rotation sum
    pt_terms: dict[int, list[tuple[float, tuple]]] | None
    pt_scale: float = 1.0                   # combined plaintext scale
    exact: bool = True                      # single-factor, unrotated pts
    fused_members: int = 1
    fresh_modup: bool = True                # False -> digits shared

    @property
    def n_rot(self) -> int:
        return len(self.steps)


@dataclasses.dataclass
class MultiHoistedStep(KeyswitchFamilyStep):
    """One multi-anchor accumulation closed by a SINGLE ModDown.

    ``sink = sum_i Rot_{s_i}(anchor_i) [+ sum_j passthrough_j]`` where
    the rotations consume DIFFERENT anchor ciphertexts (the giant-step
    phase of BSGS).  Each anchor still needs its own ModUp (shared with
    any sibling hoisted block via the program-wide digits cache), but
    the per-rotation IP results accumulate in the extended basis and
    ONE ModDown closes the whole sum — versus one ModDown per rotation
    on the eager path.  Trades bit-exactness for the ModDown saving
    (``exact=False`` lowering only): the approximate-FBC rounding of the
    merged ModDowns differs from the per-rotation trajectory.
    """

    out: int
    level: int
    rot_terms: list[tuple[int, int]]        # (anchor nid, step != 0)
    passthrough: list[int]                  # anchors added unrotated
    # anchors whose ModUp this step performs (not already cached when
    # the step runs); filled in program order by ``lower_program``
    fresh_anchors: list[int] = dataclasses.field(default_factory=list)

    family = "rotation"

    @property
    def n_rot(self) -> int:
        return len(self.rot_terms)

    @property
    def steps(self) -> list[int]:
        return [s for _, s in self.rot_terms]


@dataclasses.dataclass
class RelinStep(KeyswitchFamilyStep):
    """One engine relinearization producing CMULT node ``out``.

    Executed via ``KeyswitchEngine.relin(_batched)``: tensor product of
    the two argument ciphertexts, ModUp of d2 on the shared plan cache,
    IP against the mult key, one ModDown, base-domain folds — bit-exact
    with the eager ``CKKSContext.multiply`` (``exact=True`` safe)."""

    family = "relin"

    out: int
    level: int
    args: tuple[int, int]                   # (a nid, b nid)


@dataclasses.dataclass
class MultiRelinStep(KeyswitchFamilyStep):
    """One sum-of-CMult closure closed by a SINGLE ModDown.

    ``sink = sum_i CMult(a_i, b_i) [+ sum_j passthrough_j]`` — the
    giant-step product sums of the BSGS Chebyshev evaluation
    (``polyeval.eval_chebyshev_bsgs``).  Each term still pays its own d2
    ModUp (d2 tensors are fresh per CMult), but all relin IPs against
    the shared mult key accumulate in the extended basis and ONE
    ModDown closes the whole sum — versus one ModDown per CMult on the
    per-term path.  ``exact=False`` lowering only (merged ModDown
    rounding), the relin analogue of ``MultiHoistedStep``."""

    family = "relin"

    out: int
    level: int
    cmults: list[tuple[int, tuple[int, int]]]   # (cmult nid, (a, b))
    passthrough: list[int]                      # terms added unmerged

    @property
    def n_relin(self) -> int:
        return len(self.cmults)


@dataclasses.dataclass
class EagerStep:
    """Execute one DFG node directly on the context."""

    nid: int


def _lift(dfg, sink: int, anchor: int, allowed_rots: set[int],
          nh: int) -> tuple[dict[Term, float], set[int]]:
    """Rewrite the expression under ``sink`` over rotations of ``anchor``.

    Returns (terms, visited-interior-nodes).  Raises Unliftable when the
    walk reaches anything outside {anchor, allowed rots, PMul, CAdd,
    CSub, CScale}."""
    memo: dict[int, dict[Term, float]] = {}
    visited: set[int] = set()

    def ev(nid: int) -> dict[Term, float]:
        if nid == anchor:
            return {(0, ()): 1.0}
        if nid in memo:
            return memo[nid]
        node = dfg.nodes[nid]
        if node.op == OpKind.ROT and nid in allowed_rots:
            s = node.attrs["steps"] % nh
            out: dict[Term, float] = {}
            for (t, fs), c in ev(node.args[0]).items():
                key = ((t + s) % nh,
                       tuple(sorted((p, (r + s) % nh) for p, r in fs)))
                out[key] = out.get(key, 0.0) + c
        elif node.op == OpKind.PMUL:
            pid = node.attrs["pt"]
            out = {}
            for (t, fs), c in ev(node.args[0]).items():
                key = (t, tuple(sorted(fs + ((pid, 0),))))
                out[key] = out.get(key, 0.0) + c
        elif node.op in (OpKind.CADD, OpKind.CSUB):
            out = dict(ev(node.args[0]))
            sign = -1.0 if node.op == OpKind.CSUB else 1.0
            for k, c in ev(node.args[1]).items():
                out[k] = out.get(k, 0.0) + sign * c
        elif node.op == OpKind.CSCALE:
            c0 = float(node.attrs.get("c", 2))
            out = {k: c * c0 for k, c in ev(node.args[0]).items()}
        else:
            raise Unliftable(f"node {nid} ({node.op.value}) blocks hoisting")
        memo[nid] = out
        visited.add(nid)
        return out

    return ev(sink), visited


def _build_step(dfg, sink: int, anchor: int, terms: dict[Term, float],
                pt_specs, exact_only: bool, fused_members: int,
                allow_bare: bool = False) -> HoistedStep:
    """Validate lifted terms and shape them into a HoistedStep."""
    terms = {k: c for k, c in terms.items() if c != 0.0}
    if not terms:
        raise Unliftable("empty expression")
    if not allow_bare:
        if all(s == 0 for (s, _) in terms):
            raise Unliftable("no rotation work — plain EWOs stay eager")
        if len(terms) == 1 and not next(iter(terms))[1]:
            # a lone pt-less rotation is exactly ctx.rotate — keep it
            # eager so the compiled trajectory matches eager bit for bit
            raise Unliftable("single bare rotation")
    with_pt = any(fs for (_, fs) in terms)
    by_step: dict[int, list[tuple[float, tuple]]] = {}
    scale = None
    for (s, fs), c in terms.items():
        if with_pt and not fs:
            raise Unliftable("mixed pt/no-pt terms")
        if not fs and c != 1.0:
            raise Unliftable("scaled pure-rotation term")
        if exact_only and (c != 1.0 or len(fs) > 1
                           or any(r != 0 for _, r in fs)):
            raise Unliftable("needs the Eq. (4) rewrite (fusion only)")
        if fs:
            term_scale = 1.0
            for p, _ in fs:
                term_scale *= pt_specs[p].scale
            if scale is None:
                scale = term_scale
            elif abs(term_scale / scale - 1.0) > 1e-9:
                raise Unliftable("inconsistent combined plaintext scales")
        by_step.setdefault(s, []).append((c, fs))
    node = dfg.nodes[sink]
    return HoistedStep(
        out=sink, anchor=anchor, level=node.limbs - 1,
        steps=sorted(by_step), pt_terms=by_step if with_pt else None,
        pt_scale=scale if scale is not None else 1.0,
        exact=exact_only, fused_members=fused_members,
    )


def _lift_multi(dfg, sink: int, interior: set[int], allowed_rots: set[int],
                nh: int) -> tuple[dict[tuple[int, int], float], set[int]]:
    """Rewrite ``sink`` as sum_i c_i * Rot_{s_i}(anchor_i) over SEVERAL
    anchors.  Anchors are discovered dynamically: any node outside the
    PKB's ``interior`` (region + rotations) terminates the walk as a
    term anchor — this covers both true ModUp anchors and step-0
    passthrough values (e.g. the unrotated first giant-step group of
    BSGS).  Returns ({(anchor, step): coeff}, visited interior nodes);
    raises Unliftable at an in-region op with no rotation-sum form
    (plaintext factors stay on the single-anchor path)."""
    memo: dict[int, dict[tuple[int, int], float]] = {}
    visited: set[int] = set()

    def ev(nid: int) -> dict[tuple[int, int], float]:
        if nid != sink and nid not in interior:
            return {(nid, 0): 1.0}
        if nid in memo:
            return memo[nid]
        node = dfg.nodes[nid]
        if node.op == OpKind.ROT and nid in allowed_rots:
            s = node.attrs["steps"] % nh
            out: dict[tuple[int, int], float] = {}
            for (a, t), c in ev(node.args[0]).items():
                key = (a, (t + s) % nh)
                out[key] = out.get(key, 0.0) + c
        elif node.op in (OpKind.CADD, OpKind.CSUB):
            out = dict(ev(node.args[0]))
            sign = -1.0 if node.op == OpKind.CSUB else 1.0
            for k, c in ev(node.args[1]).items():
                out[k] = out.get(k, 0.0) + sign * c
        elif node.op == OpKind.CSCALE:
            c0 = float(node.attrs.get("c", 2))
            out = {k: c * c0 for k, c in ev(node.args[0]).items()}
        else:
            raise Unliftable(f"node {nid} ({node.op.value}) blocks "
                             f"multi-anchor hoisting")
        memo[nid] = out
        visited.add(nid)
        return out

    return ev(sink), visited


def _lower_multi(dfg, pkb: PKB,
                 nh: int) -> tuple[list[MultiHoistedStep], set[int]]:
    """Lower one multi-anchor PKB (giant-step shape) to single-ModDown
    accumulation steps.  Only pure rotation sums with unit coefficients
    over same-level anchors lift; anything else stays eager."""
    interior = pkb.region | set(pkb.rotations)
    allowed = set(pkb.rotations)
    out_steps: list[MultiHoistedStep] = []
    consumed: set[int] = set()
    for sink in sorted(pkb.out_sinks):
        terms, visited = _lift_multi(dfg, sink, interior, allowed, nh)
        terms = {k: c for k, c in terms.items() if c != 0.0}
        if any(c != 1.0 for c in terms.values()):
            raise Unliftable("scaled multi-anchor term")
        rot_terms = sorted((a, s) for (a, s) in terms if s != 0)
        passthrough = sorted(a for (a, s) in terms if s == 0)
        if len(rot_terms) < 2 or len({a for a, _ in rot_terms}) < 2:
            raise Unliftable("no multi-anchor rotation work")
        anchor_limbs = ({dfg.nodes[a].limbs for a, _ in rot_terms}
                        | {dfg.nodes[a].limbs for a in passthrough})
        if anchor_limbs != {dfg.nodes[sink].limbs}:
            raise Unliftable("anchors at differing levels")
        inner = visited - {sink}
        for nid in inner:             # conservative: no escaping values
            if dfg.succs(nid) - visited:
                raise Unliftable("interior value escapes the region")
        out_steps.append(MultiHoistedStep(
            out=sink, level=dfg.nodes[sink].limbs - 1,
            rot_terms=rot_terms, passthrough=passthrough,
        ))
        consumed |= inner
    return out_steps, consumed


_SUM_OPS = {OpKind.CADD, OpKind.CSUB, OpKind.CSCALE}


def _lift_sum(dfg, sink: int) -> tuple[dict[int, float], set[int]]:
    """Rewrite ``sink`` as sum_i c_i * term_i over non-EWO terms.

    The relin analogue of ``_lift_multi``'s walk: descends through
    CAdd/CSub/CScale only; every other node terminates as a term.
    Returns ({term nid: coeff}, visited interior nodes incl. sink)."""
    memo: dict[int, dict[int, float]] = {}
    visited: set[int] = set()

    def ev(nid: int) -> dict[int, float]:
        node = dfg.nodes[nid]
        if nid != sink and node.op not in _SUM_OPS:
            return {nid: 1.0}
        if nid in memo:
            return memo[nid]
        if node.op in (OpKind.CADD, OpKind.CSUB):
            out = dict(ev(node.args[0]))
            sign = -1.0 if node.op == OpKind.CSUB else 1.0
            for k, c in ev(node.args[1]).items():
                out[k] = out.get(k, 0.0) + sign * c
        elif node.op == OpKind.CSCALE:
            c0 = float(node.attrs.get("c", 2))
            out = {k: c * c0 for k, c in ev(node.args[0]).items()}
        else:
            raise Unliftable(f"node {nid} ({node.op.value}) is no sum")
        memo[nid] = out
        visited.add(nid)
        return out

    return ev(sink), visited


def _relin_closures(dfg, blocked: set[int]) -> tuple[
        dict[int, MultiRelinStep], set[int], set[int]]:
    """Identify sum-of-CMult closures: maximal CAdd trees over >= 2
    same-level unit-coefficient CMULT terms whose values never escape.

    ``blocked``: nodes already claimed by the rotation lowering — a
    closure may not overlap them.  Returns (sink -> step, consumed
    interior nodes, claimed CMULT nids)."""
    steps: dict[int, MultiRelinStep] = {}
    consumed: set[int] = set()
    claimed: set[int] = set()
    for nid in reversed(dfg.topo_order()):
        node = dfg.nodes[nid]
        if node.op not in (OpKind.CADD, OpKind.CSUB):
            continue
        if nid in consumed or nid in blocked:
            continue
        try:
            terms, visited = _lift_sum(dfg, nid)
        except Unliftable:
            continue
        terms = {k: c for k, c in terms.items() if c != 0.0}
        cmults = sorted(t for t in terms
                        if dfg.nodes[t].op == OpKind.CMULT)
        if len(cmults) < 2:
            continue
        if any(terms[t] != 1.0 for t in terms):
            continue                  # scaled terms: keep per-term relin
        if any(dfg.nodes[t].limbs != node.limbs for t in cmults):
            continue                  # terms at differing levels
        if any(t in claimed or t in blocked for t in cmults):
            continue
        inner = (visited - {nid}) | set(cmults)
        if inner & blocked:
            continue
        # conservative: neither interior sums nor merged CMULT values
        # may be consumed outside the closure (their base-domain values
        # are never materialized)
        if any(dfg.succs(v) - visited for v in inner):
            continue
        passthrough = sorted(t for t in terms if t not in cmults)
        if any(dfg.nodes[t].limbs != node.limbs for t in passthrough):
            continue
        steps[nid] = MultiRelinStep(
            out=nid, level=node.limbs - 1,
            cmults=[(t, dfg.nodes[t].args) for t in cmults],
            passthrough=passthrough,
        )
        consumed |= visited - {nid}
        claimed |= set(cmults)
    return steps, consumed, claimed


_DESCEND = {OpKind.CADD, OpKind.CSUB, OpKind.CSCALE, OpKind.PMUL,
            OpKind.PADD}


def _lower_group(dfg, members: list[PKB], nh: int, pt_specs,
                 exact_only: bool) -> tuple[list[HoistedStep], set[int]]:
    """Lower one (possibly fused) PKB group.

    Each sink is lifted whole when possible; a sink whose expression
    mixes in foreign values (e.g. the final CAdd of BSGS sums one baby
    block with the ROTATED other — entangled by the commutative forward
    walk) is decomposed instead: we descend through its EWOs/rotations
    and lower every MAXIMAL liftable subtree, leaving the rest eager.
    This reproduces the eager block structure exactly while still
    sharing one ModUp across all blocks on the same anchor.

    Raises Unliftable only when nothing in the group lifts."""
    first, last = members[0], members[-1]
    # in_anchors walks backward through commutative EWOs and may look
    # THROUGH the value the rotations actually consume — either past a
    # merge CAdd (the re/im merge feeding SlotToCoeff) or past a
    # non-commutative EWO like the PADD closing a Chebyshev activation
    # (whose _lift would fail even though the block hoists fine off the
    # PADD output).  When every rotation reads the same direct
    # argument, that argument IS the anchor; only when the arguments
    # differ do we fall back to the walked anchor, and true
    # multi-anchor blocks (BSGS giant steps) stay on the multi/eager
    # path.
    args = {dfg.nodes[r].args[0] for r in first.rotations}
    if len(args) == 1:
        anchor = next(iter(args))
    elif len(first.in_anchors) == 1:
        anchor = next(iter(first.in_anchors))
    else:
        raise Unliftable("multi-anchor PKB")
    anchor_level = dfg.nodes[anchor].limbs - 1
    allowed = set()
    for m in members:
        allowed |= set(m.rotations)

    steps: dict[int, HoistedStep] = {}
    consumed: set[int] = set()
    tried: set[int] = set()

    def collect(nid: int) -> None:
        if nid in tried or nid == anchor:
            return
        tried.add(nid)
        node = dfg.nodes[nid]
        if node.limbs - 1 == anchor_level:
            try:
                terms, visited = _lift(dfg, nid, anchor, allowed, nh)
                steps[nid] = _build_step(dfg, nid, anchor, terms, pt_specs,
                                         exact_only, len(members))
                consumed.update(visited)
                return
            except Unliftable:
                pass
        if node.op in _DESCEND or (node.op == OpKind.ROT
                                   and nid in allowed):
            for arg in set(node.args):
                collect(arg)

    for sink in sorted(last.out_sinks):
        collect(sink)
    if not steps:
        raise Unliftable("no liftable subexpression in group")
    # interior values with consumers outside the lowered region stay
    # live: lower them as their own (ModUp-sharing) hoisted steps
    for nid in sorted(consumed):
        if nid in steps:
            continue
        if dfg.succs(nid) - consumed:
            terms, _ = _lift(dfg, nid, anchor, allowed, nh)
            nz = {k: c for k, c in terms.items() if c != 0.0}
            if len(nz) == 1 and not next(iter(nz))[1]:
                # exactly ctx.rotate: the single-rotation hoisted
                # trajectory rounds differently from the eager rotate
                # the trace recorded, so re-materialize it eagerly
                consumed.discard(nid)
                continue
            steps[nid] = _build_step(dfg, nid, anchor, terms, pt_specs,
                                     exact_only, len(members),
                                     allow_bare=True)
    return list(steps.values()), consumed - set(steps)


def lower_program(tc: TraceContext, fusion: bool = False,
                  capacity_words: float | None = None,
                  max_group: int = 4, exact: bool = True) -> CompiledProgram:
    params = tc.params
    dfg = tc.g
    nh = params.num_slots
    with obs.span("compile.identify_pkbs", nodes=len(dfg.nodes)) as sp:
        pkbs = sorted(identify_pkbs(dfg), key=lambda p: p.layer)
        sp.set_attrs(n_pkbs=len(pkbs))
    plan = None
    if fusion and pkbs:
        with obs.span("compile.fusion", n_pkbs=len(pkbs),
                      max_group=max_group):
            plan = optimal_fusion(
                pkbs, params.k, params.alpha, nh,
                capacity_words=(capacity_words if capacity_words is not None
                                else float("inf")),
                max_group=max_group,
            )
        groups = plan.groups
    else:
        groups = [[i] for i in range(len(pkbs))]

    hoisted: dict[int, HoistedStep] = {}      # out nid -> step
    multi: dict[int, MultiHoistedStep] = {}
    consumed: set[int] = set()
    for group in groups:
        members = [pkbs[i] for i in group]
        tries = [members] if len(members) == 1 else [members] + [
            [m] for m in members
        ]
        lowered: set[int] = set()             # id() of lowered members
        for attempt in tries:
            try:
                steps, interior = _lower_group(
                    dfg, attempt, nh, tc.pt_specs,
                    exact_only=(len(attempt) == 1),
                )
            except Unliftable:
                continue
            for st in steps:
                hoisted[st.out] = st
            consumed |= interior
            lowered.update(id(m) for m in attempt)
            if attempt is members:
                break
        # members that lowered nowhere: multi-anchor accumulation when
        # bit-exactness was waived, plain eager execution otherwise
        if not exact:
            for m in members:
                if id(m) in lowered:
                    continue
                try:
                    msteps, interior = _lower_multi(dfg, m, nh)
                except Unliftable:
                    continue
                for st in msteps:
                    multi[st.out] = st
                consumed |= interior

    # Relinearization: CMULTs join the keyswitch family.  exact=False
    # first merges sum-of-CMult closures into single-ModDown
    # MultiRelinSteps; every remaining CMULT lowers to a (bit-exact)
    # RelinStep on the engine's relin entry point.
    multi_relin: dict[int, MultiRelinStep] = {}
    if not exact:
        blocked = (consumed | set(hoisted) | set(multi))
        multi_relin, r_consumed, r_claimed = _relin_closures(dfg, blocked)
        consumed |= r_consumed | r_claimed
    relin: dict[int, RelinStep] = {}
    for nid, node in dfg.nodes.items():
        if node.op == OpKind.CMULT and nid not in consumed:
            relin[nid] = RelinStep(out=nid, level=node.limbs - 1,
                                   args=tuple(node.args))

    # Order steps along the topo order; the first (multi-)hoisted step
    # touching an anchor performs its (shared) ModUp.
    steps: list = []
    seen_anchor: set[int] = set()
    for nid in dfg.topo_order():
        if nid in hoisted:
            st = hoisted[nid]
            # a step with only identity terms never keyswitches, so it
            # neither performs nor claims the anchor's shared ModUp
            has_ks = any(s != 0 for s in st.steps)
            st.fresh_modup = has_ks and st.anchor not in seen_anchor
            if has_ks:
                seen_anchor.add(st.anchor)
            steps.append(st)
        elif nid in multi:
            mst = multi[nid]
            term_anchors = list(dict.fromkeys(a for a, _ in mst.rot_terms))
            mst.fresh_anchors = [a for a in term_anchors
                                 if a not in seen_anchor]
            seen_anchor.update(term_anchors)
            steps.append(mst)
        elif nid in relin:
            steps.append(relin[nid])
        elif nid in multi_relin:
            steps.append(multi_relin[nid])
        elif nid in consumed:
            continue
        else:
            steps.append(EagerStep(nid))

    return CompiledProgram(
        params=params, dfg=dfg, pt_specs=tc.pt_specs, inputs=dict(tc.inputs),
        outputs=dict(tc.outputs), steps=steps, pkbs=pkbs, fusion_plan=plan,
        fused=fusion, exact=exact,
    )
