"""Symbolic tracing + compilation front-end for the CKKS runtime.

``TraceContext`` mirrors the op surface of ``repro.core.ckks.CKKSContext``
(encode / pt_add / pt_mul / add / sub / double / multiply / square /
rotate / conjugate / hoisted_rotation_sum / rescale / level_down /
mod_raise) but records a ``dfg.trace.ProgramBuilder`` graph — the same
IR the simulator consumes — instead of computing.  Plaintexts are
recorded as level/scale-parameterized ``PtSpec``s (the raw slot values
plus the exact encode parameters the eager path would use), and
``mod_raise`` becomes an opaque ``OpKind.MOD_RAISE`` boundary node the
executor replays via ``CKKSContext.mod_raise``.  Unmodified program
code (``core.linear.matvec_diag``/``matvec_bsgs``,
``core.polyeval.eval_chebyshev``, ``core.bootstrap.Bootstrapper``)
therefore runs EITHER eagerly or under the tracer; every level/scale
decision the eager code makes is replayed symbolically and baked into
node attributes, which is what keeps the compiled execution bit-exact
with the eager path.

``compile_program`` then runs PKB identification and (optionally) the
HERO fusion DP over the traced graph and lowers the plan to executable
steps (see ``repro.runtime.lower``).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro import obs
from repro.core.params import CKKSParams
from repro.dfg.graph import DFG, OpKind
from repro.dfg.trace import ProgramBuilder


@dataclasses.dataclass
class PtSpec:
    """A plaintext recorded at trace time: the raw slot values plus the
    exact (level, scale) the eager path would have encoded them at."""

    values: np.ndarray
    level: int
    scale: float


@dataclasses.dataclass
class TracePlaintext:
    """Symbolic ``Plaintext`` — carries the id into the pt-spec table."""

    pid: int
    level: int
    scale: float


class TraceHandle:
    """Symbolic ``Ciphertext``: a node id plus the (level, scale) the
    eager path would carry.  Assigning ``.scale`` (as ``mul_const``
    does) writes through to the node's recorded attributes so the
    executor replays the exact same float."""

    def __init__(self, tc: "TraceContext", nid: int, level: int,
                 scale: float):
        self._tc = tc
        self.nid = nid
        self.level = level
        self._scale = scale

    @property
    def scale(self) -> float:
        return self._scale

    @scale.setter
    def scale(self, value: float) -> None:
        self._scale = value
        self._tc.g.nodes[self.nid].attrs["scale"] = value

    @property
    def n_limbs(self) -> int:
        return self.level + 1


class TraceContext:
    """Records CKKS programs as DFGs; mirrors ``CKKSContext``'s op API."""

    def __init__(self, params: CKKSParams):
        self.params = params
        self.b = ProgramBuilder(N=params.N, alpha=params.alpha)
        self.g: DFG = self.b.g
        self.pt_specs: list[PtSpec] = []
        self.inputs: dict[str, int] = {}
        self.outputs: dict[str, int] = {}
        self._rot_cse: dict[tuple, int] = {}

    # ------------------------- helpers --------------------------------
    def chain(self, level: int) -> tuple[int, ...]:
        return self.params.q_chain(level)

    def _dnum(self, level: int) -> int:
        return len(self.params.digit_groups(level))

    def _emit(self, op: OpKind, args: tuple[int, ...], level: int,
              scale: float, **attrs) -> TraceHandle:
        nid = self.g.add(op, args, limbs=level + 1, scale=scale, **attrs)
        return TraceHandle(self, nid, level, scale)

    # ------------------------- program I/O -----------------------------
    def input(self, tag: str = "in", level: int | None = None,
              scale: float | None = None) -> TraceHandle:
        level = self.params.L if level is None else level
        scale = self.params.scale if scale is None else scale
        h = self._emit(OpKind.INPUT, (), level, scale, tag=tag)
        self.g.nodes[h.nid].attrs["level"] = level
        self.inputs[tag] = h.nid
        obs.event("trace.input", tag=tag, level=level, nid=h.nid)
        return h

    def output(self, h: TraceHandle, tag: str = "out") -> int:
        nid = self.g.add(OpKind.OUTPUT, (h.nid,), limbs=h.n_limbs, tag=tag)
        self.outputs[tag] = h.nid
        obs.event("trace.output", tag=tag, nid=nid,
                  nodes=len(self.g.nodes))
        return nid

    # ------------------------- encode ----------------------------------
    def encode(self, z, level: int | None = None,
               scale: float | None = None) -> TracePlaintext:
        level = self.params.L if level is None else level
        scale = self.params.scale if scale is None else scale
        self.pt_specs.append(PtSpec(np.asarray(z), level, scale))
        return TracePlaintext(len(self.pt_specs) - 1, level, scale)

    # ------------------------- EWOs ------------------------------------
    def add(self, a: TraceHandle, b: TraceHandle) -> TraceHandle:
        assert a.level == b.level, "level mismatch (use level_down)"
        return self._emit(OpKind.CADD, (a.nid, b.nid), a.level, a.scale)

    def sub(self, a: TraceHandle, b: TraceHandle) -> TraceHandle:
        assert a.level == b.level
        return self._emit(OpKind.CSUB, (a.nid, b.nid), a.level, a.scale)

    def double(self, ct: TraceHandle) -> TraceHandle:
        return self._emit(OpKind.CSCALE, (ct.nid,), ct.level, ct.scale, c=2)

    def pt_add(self, a: TraceHandle, pt: TracePlaintext) -> TraceHandle:
        return self._emit(OpKind.PADD, (a.nid,), a.level, a.scale,
                          pt=pt.pid)

    def pt_mul(self, a: TraceHandle, pt: TracePlaintext,
               rescale: bool = True) -> TraceHandle:
        out = self._emit(OpKind.PMUL, (a.nid,), a.level,
                         a.scale * pt.scale, pt=pt.pid)
        return self.rescale(out) if rescale else out

    # ------------------------- level management ------------------------
    def rescale(self, ct: TraceHandle) -> TraceHandle:
        q_last = self.chain(ct.level)[-1]
        return self._emit(OpKind.RESCALE, (ct.nid,), ct.level - 1,
                          ct.scale / q_last)

    def level_down(self, ct: TraceHandle, target: int) -> TraceHandle:
        assert target <= ct.level
        if target == ct.level:
            return ct
        return self._emit(OpKind.LEVEL_DOWN, (ct.nid,), target, ct.scale,
                          target=target)

    def mod_raise(self, ct: TraceHandle) -> TraceHandle:
        """Bootstrap boundary: an opaque node lifting level 0 -> L.

        The centered-CRT lift has no symbolic form; the executor replays
        it via ``CKKSContext.mod_raise`` (scale is preserved, the level
        jumps to the top of the chain)."""
        assert ct.level == 0, "mod_raise consumes a level-0 ciphertext"
        return self._emit(OpKind.MOD_RAISE, (ct.nid,), self.params.L,
                          ct.scale)

    # ------------------------- mult / rotate ---------------------------
    def multiply(self, a: TraceHandle, b: TraceHandle,
                 rescale: bool = True) -> TraceHandle:
        assert a.level == b.level
        out = self._emit(OpKind.CMULT, (a.nid, b.nid), a.level,
                         a.scale * b.scale, dnum=self._dnum(a.level))
        return self.rescale(out) if rescale else out

    def square(self, a: TraceHandle, rescale: bool = True) -> TraceHandle:
        return self.multiply(a, a, rescale=rescale)

    def rotate(self, ct: TraceHandle, steps: int) -> TraceHandle:
        steps = steps % self.params.num_slots
        if steps == 0:
            return ct
        key = (OpKind.ROT, ct.nid, steps)
        if key in self._rot_cse:          # CSE: same rotation of the same
            nid = self._rot_cse[key]      # value is the same node
            return TraceHandle(self, nid, ct.level, ct.scale)
        h = self._emit(OpKind.ROT, (ct.nid,), ct.level, ct.scale,
                       steps=steps, dnum=self._dnum(ct.level))
        self._rot_cse[key] = h.nid
        return h

    def conjugate(self, ct: TraceHandle) -> TraceHandle:
        key = (OpKind.CONJ, ct.nid, 0)
        if key in self._rot_cse:
            return TraceHandle(self, self._rot_cse[key], ct.level, ct.scale)
        h = self._emit(OpKind.CONJ, (ct.nid,), ct.level, ct.scale,
                       dnum=self._dnum(ct.level))
        self._rot_cse[key] = h.nid
        return h

    # ------------------------- hoisted rotations -----------------------
    def hoisted_rotation_sum(
        self, ct: TraceHandle, steps_list: list[int],
        pts: list[TracePlaintext] | None = None, rescale: bool = True,
    ) -> TraceHandle:
        """Recorded at ELEMENTARY granularity (rot/pmul/cadd) so the
        compiler re-discovers the PKB, re-hoists it, and may fuse it
        with serial neighbours — the eager call's block structure is a
        special case the lowering reproduces bit-exactly."""
        terms: list[TraceHandle] = []
        for i, s in enumerate(steps_list):
            h = self.rotate(ct, s)
            if pts is not None:
                h = self.pt_mul(h, pts[i], rescale=False)
            terms.append(h)
        out = terms[0]
        for t in terms[1:]:
            out = self.add(out, t)
        if pts is not None and rescale:
            out = self.rescale(out)
        return out


# --------------------------- compilation --------------------------------

@dataclasses.dataclass
class CompiledProgram:
    """A lowered program: ordered steps over the traced DFG.

    ``steps`` mixes ``lower.HoistedStep`` (fused PKBs -> one hoisted-
    rotation-sum engine invocation each, ModUp shared per anchor) and
    ``lower.EagerStep`` (everything else, op-by-op on the engine).
    """

    params: CKKSParams
    dfg: DFG
    pt_specs: list[PtSpec]
    inputs: dict[str, int]
    outputs: dict[str, int]
    steps: list
    pkbs: list
    fusion_plan: object | None
    fused: bool
    exact: bool = True

    @property
    def n_hoisted(self) -> int:
        from repro.runtime.lower import HoistedStep

        return sum(1 for s in self.steps if isinstance(s, HoistedStep))

    @property
    def n_multi(self) -> int:
        from repro.runtime.lower import MultiHoistedStep

        return sum(1 for s in self.steps
                   if isinstance(s, MultiHoistedStep))

    @property
    def n_relin(self) -> int:
        from repro.runtime.lower import RelinStep

        return sum(1 for s in self.steps if isinstance(s, RelinStep))

    @property
    def n_multi_relin(self) -> int:
        from repro.runtime.lower import MultiRelinStep

        return sum(1 for s in self.steps
                   if isinstance(s, MultiRelinStep))

    @property
    def n_eager(self) -> int:
        return len(self.steps) - (self.n_hoisted + self.n_multi
                                  + self.n_relin + self.n_multi_relin)

    def summary(self) -> dict:
        from repro.runtime.lower import (
            HoistedStep, MultiHoistedStep, MultiRelinStep, RelinStep,
        )

        hoisted = [s for s in self.steps if isinstance(s, HoistedStep)]
        multi = [s for s in self.steps if isinstance(s, MultiHoistedStep)]
        relin = [s for s in self.steps if isinstance(s, RelinStep)]
        mrelin = [s for s in self.steps if isinstance(s, MultiRelinStep)]
        return {
            "nodes": len(self.dfg.nodes),
            "pkbs": len(self.pkbs),
            "fused": self.fused,
            "exact": self.exact,
            "hoisted_steps": len(hoisted),
            "multi_anchor_steps": len(multi),
            "shared_modups": sum(1 for s in hoisted if not s.fresh_modup),
            "relin_steps": len(relin),
            "multi_relin_steps": len(mrelin),
            "merged_relins": sum(s.n_relin for s in mrelin),
            "eager_steps": self.n_eager,
            "predicted_modups": (
                sum(1 for s in hoisted if s.fresh_modup)
                + sum(len(s.fresh_anchors) for s in multi)
                + len(relin)
                + sum(s.n_relin for s in mrelin)
            ),
            "predicted_relin_moddowns": len(relin) + len(mrelin),
        }


def compile_program(tc: TraceContext, fusion: bool = False,
                    capacity_words: float | None = None,
                    max_group: int = 4,
                    exact: bool = True) -> CompiledProgram:
    """Lower a traced program onto the keyswitch engine.

    fusion=False (default) guarantees bit-exactness with the eager path:
    PKBs are hoisted (ModUp shared per anchor ciphertext) but the Eq. (4)
    inverse-BSGS rewrite is off.  fusion=True runs the
    ``dfg.fusion.optimal_fusion`` DP and lowers fused groups to single
    hoisted blocks with pairwise-summed steps and combined plaintexts —
    numerically equivalent, not bit-identical (different evk
    trajectories), and strictly fewer ModUps/ModDowns.

    Relinearization always compiles through the keyswitch family: every
    CMULT lowers to a ``lower.RelinStep`` on the engine's ``relin``
    entry point (bit-exact with eager ``CKKSContext.multiply``).

    exact=False additionally lowers multi-anchor PKBs (the giant-step
    phase of BSGS, whose rotations consume different ciphertexts) to
    ``lower.MultiHoistedStep`` blocks, and sum-of-CMult closures (the
    giant-step product sums of ``polyeval.eval_chebyshev_bsgs``) to
    ``lower.MultiRelinStep`` blocks: per-term IPs accumulate in the
    extended basis and ONE ModDown closes the whole sum, instead of one
    ModDown per rotation/relin.  Numerically close but not
    bit-identical (the approximate-FBC rounding of the merged ModDowns
    differs); see ``tests/test_runtime_bootstrap.py`` and
    ``tests/test_relin.py`` for the measured error bounds.
    """
    from repro.runtime.lower import lower_program

    with obs.span("compile.program", nodes=len(tc.g.nodes),
                  fusion=fusion, exact=exact) as sp:
        compiled = lower_program(tc, fusion=fusion,
                                 capacity_words=capacity_words,
                                 max_group=max_group, exact=exact)
        if sp:
            sp.set_attrs(**{k: v for k, v in compiled.summary().items()
                            if isinstance(v, (int, float, bool, str))})
    return compiled
