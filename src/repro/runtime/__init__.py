"""repro.runtime — DFG-compiled program executor for the CKKS scheme.

The HERO pipeline in ``repro.dfg`` (PKB identification -> degree-
minimized expansion -> fusion DP -> dataflow mapping) drives the
*simulator*; this package closes the loop by lowering the same IR onto
the *functional* runtime:

  trace   (compile.TraceContext)  — run unmodified program code
          (``core.linear`` matvec/BSGS, ``core.polyeval`` Chebyshev,
          ``core.bootstrap`` C2S/EvalMod/S2C) against a symbolic context
          that mirrors ``CKKSContext`` (add/sub/double, pt_add/pt_mul,
          multiply, rotate/conjugate, hoisted_rotation_sum, rescale/
          level_down/mod_raise — emitting CADD/CSUB/CSCALE/PADD/PMUL/
          CMULT/ROT/CONJ/RESCALE/LEVEL_DOWN/MOD_RAISE nodes) and records
          a ``dfg.trace.ProgramBuilder`` graph, the same IR the
          simulator consumes;
  compile (compile.compile_program) — identify PKBs, optionally run the
          ``dfg.fusion.optimal_fusion`` DP, and lower (lower.py) fused
          plans to keyswitch-family steps: hoisted-rotation-sum blocks,
          one ``RelinStep`` per CMULT, + eager engine EWOs;
          ``exact=False`` additionally lowers multi-anchor giant-step
          PKBs and sum-of-CMult closures to single-ModDown accumulation
          blocks (``MultiHoistedStep``/``MultiRelinStep``);
  execute (exec.ProgramExecutor)  — run the lowered plan on a real
          ``CKKSContext``/``KeyswitchEngine``, sharing one ModUp across
          every block anchored on the same ciphertext, and batching
          independent ciphertexts through ONE jit trace via ``jax.vmap``
          over the ct axis;
  report  (report.ExecutionReport) — actual ModUp/ModDown/IP/NTT counts
          plus the engine's real (dnum, l_ext, N) plan shapes, cross-
          checked against ``dfg.hoist``'s predicted OpVolumes and fed
          into the ``sim.schedule`` group pipeline
          (``report.program_blocks`` exposes the same per-block volumes
          for arbitrary packed traffic, not just one program).

The compiled artifacts are long-lived, key-free objects: a
``CompiledProgram`` + the engine's jit plan caches serve requests from
ANY tenant, which is what the serving layer (``repro.serve``) builds
on — it packs `(tenant, program)` request batches into
``run_batched``'s warmed shapes and swaps per-tenant keys underneath
(see ``docs/SERVING.md``).
"""
from repro.runtime.compile import (  # noqa: F401
    CompiledProgram, TraceContext, compile_program,
)
from repro.runtime.exec import ProgramExecutor  # noqa: F401
from repro.runtime.report import ExecutionReport  # noqa: F401
