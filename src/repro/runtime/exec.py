"""Executor: run a ``CompiledProgram`` on a real ``CKKSContext``.

Two entry points:

* :meth:`ProgramExecutor.run` — one ciphertext per program input.
  Hoisted steps sharing an anchor share ONE ModUp (``ctx.hoist_digits``
  once per anchor, digits fed to every block); relin steps run the
  shared ``core.ckks.tensor_product`` + the engine's ``relin`` family
  (``MultiRelinStep``: per-term d2 ModUps, one merged ModDown);
  everything is dispatched through the exact same engine entry points
  the eager path uses, which is what makes ``fusion=False``
  compilation bit-exact with eager code.

* :meth:`ProgramExecutor.run_batched` — a LIST of independent
  ciphertexts per input.  The whole batch flows through the engine's
  vmap entry points: one jit trace per (op, level, shape) plan covers
  every ciphertext (``engine.trace_counts`` asserts this), elementwise
  ops broadcast over the leading ct axis, and plaintext/evk tensors are
  shared across the batch.  Results are bit-exact with the per-ct run.

Plan-cache contract of ``run_batched``: the leading batch width is part
of every traced shape, so a dispatch at a NEW width retraces each plan
the program touches, while a repeated ``(program plan, width)`` pair is
retrace-free — for ciphertexts from any source, because jit plans carry
no key material (evk/plaintext tensors are looked up per dispatch).
Callers that must never retrace on the request path — the serving layer
(``repro.serve``) is the canonical one — pin a fixed set of widths up
front and right-pad partial batches to the nearest warmed width
(``serve.scheduler.PlanCache`` is the explicit admission policy over
``(plan signature, width)`` pairs).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import poly
from repro.core.ckks import CKKSContext, Ciphertext, Plaintext, \
    tensor_product
from repro.dfg.graph import OpKind
from repro.errors import (
    InvalidRequestError, ModulusChainMismatchError, ScaleDriftError,
)
from repro.runtime.compile import CompiledProgram
from repro.runtime.lower import (
    EagerStep, HoistedStep, KeyswitchFamilyStep, MultiHoistedStep,
    MultiRelinStep, RelinStep,
)


@dataclasses.dataclass
class ExecResult:
    outputs: dict[str, Ciphertext | list[Ciphertext]]
    report: object | None = None

    def __getitem__(self, tag: str):
        return self.outputs[tag]


class ProgramExecutor:
    """Binds compiled programs to one ``CKKSContext``.

    Plaintext encodings are cached per (program, plaintext) so repeated
    executions reuse the engine's hoisted plaintext/evk tensor caches.
    """

    def __init__(self, ctx: CKKSContext):
        self.ctx = ctx
        self._pt_cache: dict[tuple, Plaintext] = {}
        # pins compiled programs so the id()-based cache keys can never
        # be recycled by a different program; bounded FIFO
        self._pins: dict[int, CompiledProgram] = {}
        self._pins_max = 32
        self._rescale_fns: dict[int, object] = {}

    def _pin(self, compiled: CompiledProgram) -> None:
        if id(compiled) in self._pins:
            return
        while len(self._pins) >= self._pins_max:
            dead, _ = self._pins.popitem()
            self._pt_cache = {k: v for k, v in self._pt_cache.items()
                              if k[0] != dead}
        self._pins[id(compiled)] = compiled

    def _encode_spec(self, compiled: CompiledProgram, pid: int) -> Plaintext:
        """Encode a traced plaintext spec exactly as the eager path would
        (same values/level/scale floats); cached per (program, pt)."""
        key = (id(compiled), "pt", pid)
        if key not in self._pt_cache:
            spec = compiled.pt_specs[pid]
            self._pt_cache[key] = self.ctx.encode(
                spec.values, level=spec.level, scale=spec.scale)
        return self._pt_cache[key]

    # ------------------------- public API ------------------------------
    def run(self, compiled: CompiledProgram,
            inputs: dict[str, Ciphertext],
            with_report: bool = False,
            validate: bool = False) -> ExecResult:
        """``validate=True`` turns on the per-step invariant checker:
        ciphertext health (level/scale/limb range) verified at every
        keyswitch-block boundary and output.  Opt-in per request — the
        checks run as eager jnp reductions OUTSIDE any jit trace, so
        the engine's plan caches (and ``trace_counts``) are untouched,
        but each check pays a device sync."""
        return self._run(compiled, inputs, batch=0,
                         with_report=with_report, validate=validate)

    def run_batched(self, compiled: CompiledProgram,
                    inputs: dict[str, list[Ciphertext]],
                    with_report: bool = False,
                    validate: bool = False) -> ExecResult:
        """Execute over B independent ciphertexts per input at once."""
        if not self.ctx.use_engine:
            raise NotImplementedError("batched execution needs the engine")
        batch = None
        stacked = {}
        for tag, cts in inputs.items():
            if len({(c.level, c.scale) for c in cts}) != 1:
                raise ModulusChainMismatchError(
                    f"batched inputs for '{tag}' mix levels/scales",
                    hint="a batch must be homogeneous; split mixed-"
                         "level requests into separate dispatches",
                    tag=tag,
                    levels=sorted({c.level for c in cts}),
                    scales=sorted({c.scale for c in cts}))
            batch = len(cts) if batch is None else batch
            if len(cts) != batch:
                raise InvalidRequestError(
                    f"input '{tag}' has {len(cts)} ciphertexts but the "
                    f"batch width is {batch}",
                    hint="every input tag must carry one ciphertext "
                         "per batch slot",
                    tag=tag)
            stacked[tag] = Ciphertext(
                jnp.stack([c.c0 for c in cts]),
                jnp.stack([c.c1 for c in cts]),
                cts[0].level, cts[0].scale,
            )
        res = self._run(compiled, stacked, batch=batch,
                        with_report=with_report, validate=validate)
        outputs = {
            tag: [Ciphertext(ct.c0[b], ct.c1[b], ct.level, ct.scale)
                  for b in range(batch)]
            for tag, ct in res.outputs.items()
        }
        return ExecResult(outputs, res.report)

    # ------------------------- execution loop --------------------------
    def _run(self, compiled: CompiledProgram, inputs, batch: int,
             with_report: bool, validate: bool = False) -> ExecResult:
        ctx = self.ctx
        self._pin(compiled)
        missing = [t for t in compiled.inputs if t not in inputs]
        if missing:
            raise InvalidRequestError(
                "request is missing program input tags",
                hint="supply one ciphertext (list) per traced input",
                missing=missing, expected=sorted(compiled.inputs))
        before = ctx.counters.snapshot()
        values: dict[int, Ciphertext] = {}
        digits: dict[int, object] = {}
        outputs: dict[str, Ciphertext] = {}
        # Prefetch the enabled flag once: the disabled hot path is one
        # boolean per step (plus the no-op run span below).
        tracing = obs.TRACER.enabled
        with obs.span("exec.run", batch=batch,
                      n_steps=len(compiled.steps), validate=validate):
            for step in compiled.steps:
                if tracing:
                    self._exec_step_traced(compiled, step, values, digits,
                                           outputs, inputs, batch, validate)
                else:
                    self._exec_step(compiled, step, values, digits,
                                    outputs, inputs, batch, validate)
                if validate and isinstance(step, KeyswitchFamilyStep):
                    try:
                        self._check_block(step, values[step.out])
                    except Exception as err:
                        self._note_validate_failure(compiled, step, err)
                        raise
            if validate:
                for tag, ct in outputs.items():
                    ctx.check_ciphertext(ct, where=f"output '{tag}'")
        report = None
        if with_report:
            from repro.runtime.report import build_report

            report = build_report(
                compiled, ctx, ctx.counters.delta(before),
                batch=max(batch, 1),
            )
        return ExecResult(outputs, report)

    # ------------------------- step dispatch ---------------------------
    def _exec_step(self, compiled, step, values, digits, outputs, inputs,
                   batch: int, validate: bool) -> None:
        if isinstance(step, HoistedStep):
            self._exec_hoisted(compiled, step, values, digits, batch)
        elif isinstance(step, MultiHoistedStep):
            self._exec_multi(compiled, step, values, digits, batch)
        elif isinstance(step, RelinStep):
            self._exec_relin(compiled, step, values, batch)
        elif isinstance(step, MultiRelinStep):
            self._exec_multi_relin(compiled, step, values, batch)
        else:
            self._exec_eager(compiled, step, values, outputs, inputs,
                             batch, validate)

    def _step_label(self, compiled, step) -> tuple[str, int]:
        if isinstance(step, KeyswitchFamilyStep):
            return type(step).__name__, step.out
        return compiled.dfg.nodes[step.nid].op.value, step.nid

    def _exec_step_traced(self, compiled, step, values, digits, outputs,
                          inputs, batch: int, validate: bool) -> None:
        """Tracing mirror of ``_exec_step``: one span per step carrying
        the real wall clock (``block_until_ready`` on the produced ct —
        a device sync, which is why this path is opt-in) and the op
        counts the step actually incremented.  The dispatched code is
        byte-identical, so jit plan caches see the same trace keys."""
        ctx = self.ctx
        label, out_id = self._step_label(compiled, step)
        before = ctx.counters.snapshot()
        eng = ctx.engine if ctx.use_engine else None
        with obs.span(f"exec.step.{label}", out=out_id, batch=batch,
                      level=getattr(step, "level", None),
                      backend=eng.backend if eng else "none",
                      interpret=bool(eng and eng.backend == "pallas"
                                     and eng.interpret)) as sp:
            self._exec_step(compiled, step, values, digits, outputs,
                            inputs, batch, validate)
            out = values.get(out_id)
            if out is not None:
                jax.block_until_ready(out.c0)
                jax.block_until_ready(out.c1)
            d = ctx.counters.delta(before)
            sp.set_attrs(modup=d.modup, moddown=d.moddown, ip=d.ip,
                         keyswitch=d.keyswitch, relin=d.relin)

    def _note_validate_failure(self, compiled, step, err) -> None:
        """Chaos-run traces show WHERE a poisoned ciphertext was caught:
        attach the failing block's dfg.hoist step volumes to the trace
        before the typed error propagates."""
        if not obs.TRACER.enabled:
            return
        from repro.runtime.report import step_volumes

        v = step_volumes(compiled, step)
        vols = {}
        if v is not None:
            vols = {f: getattr(v, f, 0) for f in
                    ("modup_count", "moddown_count", "ip_count",
                     "keyswitch_count", "relin_count", "evk_set_words",
                     "comm_up_words", "comm_down_words")}
        obs.event("exec.validate_failure",
                  step=type(step).__name__, out=step.out,
                  level=step.level, error=type(err).__name__,
                  detail=str(err), **vols)

    # ------------------------- hoisted steps ---------------------------
    def _exec_hoisted(self, compiled, step: HoistedStep, values, digits,
                      batch: int) -> None:
        ctx = self.ctx
        ct = values[step.anchor]
        lvl = ct.level
        assert lvl == step.level, "anchor level drifted from the trace"
        pts = None
        if step.pt_terms is not None:
            pts = [self._step_pt(compiled, step, s) for s in step.steps]
        dig = None
        if ctx.use_engine and any(s != 0 for s in step.steps):
            dig = digits.get(step.anchor)
            if dig is None:
                dig = (ctx.engine.modup_batched(ct.c1, lvl) if batch
                       else ctx.hoist_digits(ct))
                digits[step.anchor] = dig
        if batch:
            out = self._hoisted_batched(ct, step, pts, dig)
        else:
            out = ctx.hoisted_rotation_sum(ct, step.steps, pts,
                                           rescale=False, digits=dig)
        self._finish(compiled, step.out, out, values)

    def _hoisted_batched(self, ct, step: HoistedStep, pts, dig):
        """Batched mirror of ``CKKSContext.hoisted_rotation_sum`` —
        including its step-0 split (identity terms are plain EWOs, never
        keyswitches)."""
        ctx = self.ctx
        lvl = ct.level
        nz = [i for i, s in enumerate(step.steps) if s != 0]
        out = None
        if nz:
            nz_steps = [step.steps[i] for i in nz]
            nz_pts = [pts[i] for i in nz] if pts is not None else None
            gs = [ctx.pc.rns.galois_for_rotation(s) for s in nz_steps]
            keys = [ctx.keys.rot_key(s) for s in nz_steps]
            pm_ext = pm_base = pm_ext_m = None
            if nz_pts is not None:
                pm_ext, pm_base, pm_ext_m = ctx._pm_stack(tuple(nz_pts),
                                                          lvl)
            c0, c1 = ctx.engine.hoisted_rotation_sum_batched(
                ct.c0, ct.c1, gs, keys, lvl, pm_ext, pm_base, pm_ext_m,
                digits=dig,
            )
            scale = ct.scale * (nz_pts[0].scale if nz_pts is not None
                                else 1.0)
            out = Ciphertext(c0, c1, lvl, scale)
        return ctx.add_zero_step_terms(out, ct, step.steps, pts)

    def _exec_multi(self, compiled, step: MultiHoistedStep, values,
                    digits, batch: int) -> None:
        """Multi-anchor accumulation: one ModUp per (uncached) anchor,
        per-term IPs summed in the extended basis, ONE ModDown."""
        ctx = self.ctx
        if not ctx.use_engine:
            raise NotImplementedError(
                "exact=False multi-anchor steps require the engine path")
        lvl = step.level
        c0s, digs, gs, keys = [], [], [], []
        for anchor, s in step.rot_terms:
            ct = values[anchor]
            assert ct.level == lvl, "anchor level drifted from the trace"
            dig = digits.get(anchor)
            if dig is None:
                dig = (ctx.engine.modup_batched(ct.c1, lvl) if batch
                       else ctx.hoist_digits(ct))
                digits[anchor] = dig
            c0s.append(ct.c0)
            digs.append(dig)
            gs.append(ctx.pc.rns.galois_for_rotation(s))
            keys.append(ctx.keys.rot_key(s))
        if batch:
            c0, c1 = ctx.engine.multi_hoisted_rotation_sum_batched(
                c0s, digs, gs, keys, lvl)
        else:
            c0, c1 = ctx.engine.multi_hoisted_rotation_sum(
                c0s, digs, gs, keys, lvl)
        out = Ciphertext(c0, c1, lvl, values[step.rot_terms[0][0]].scale)
        for anchor in step.passthrough:
            out = ctx.add(out, values[anchor])
        self._finish(compiled, step.out, out, values)

    # ------------------------- relin steps -----------------------------
    def _exec_relin(self, compiled, step: RelinStep, values,
                    batch: int) -> None:
        """One CMULT through the keyswitch family: shared tensor product
        + engine relin (ModUp -> IP -> ModDown -> folds, one jit plan).
        Bit-exact with eager ``CKKSContext.multiply(rescale=False)``."""
        ctx = self.ctx
        a, b = values[step.args[0]], values[step.args[1]]
        lvl = step.level
        assert a.level == lvl and b.level == lvl, \
            "relin operand level drifted from the trace"
        if not ctx.use_engine:
            out = ctx.multiply(a, b, rescale=False)
        else:
            mods = ctx.pc.mods(ctx.chain(lvl))
            d0, d1, d2 = tensor_product(a, b, mods)
            key = ctx.keys.mult_key
            if batch:
                c0, c1 = ctx.engine.relin_batched(d0, d1, d2, key, lvl)
            else:
                c0, c1 = ctx.engine.relin(d0, d1, d2, key, lvl)
            out = Ciphertext(c0, c1, lvl, a.scale * b.scale)
        self._finish(compiled, step.out, out, values)

    def _exec_multi_relin(self, compiled, step: MultiRelinStep, values,
                          batch: int) -> None:
        """Sum-of-CMult closure: per-term d2 ModUp (the engine's shared
        ``modup`` entry point, same digits interface as the rotations),
        all relin IPs accumulated in the extended basis, ONE ModDown."""
        ctx = self.ctx
        if not ctx.use_engine:
            raise NotImplementedError(
                "exact=False multi-relin steps require the engine path")
        lvl = step.level
        mods = ctx.pc.mods(ctx.chain(lvl))
        d0s, d1s, digs = [], [], []
        scale = None
        for _nid, (an, bn) in step.cmults:
            a, b = values[an], values[bn]
            assert a.level == lvl and b.level == lvl, \
                "relin operand level drifted from the trace"
            d0, d1, d2 = tensor_product(a, b, mods)
            d0s.append(d0)
            d1s.append(d1)
            digs.append(ctx.engine.modup_batched(d2, lvl) if batch
                        else ctx.engine.modup(d2, lvl))
            scale = a.scale * b.scale if scale is None else scale
        key = ctx.keys.mult_key
        if batch:
            c0, c1 = ctx.engine.multi_relin_sum_batched(
                d0s, d1s, digs, key, lvl)
        else:
            c0, c1 = ctx.engine.multi_relin_sum(d0s, d1s, digs, key, lvl)
        out = Ciphertext(c0, c1, lvl, scale)
        for nid in step.passthrough:
            out = ctx.add(out, values[nid])
        self._finish(compiled, step.out, out, values)

    def _step_pt(self, compiled, step: HoistedStep, s: int) -> Plaintext:
        """The (possibly fused) plaintext multiplying Rot_s(anchor)."""
        terms = step.pt_terms[s]
        specs = compiled.pt_specs
        (c0, fs0) = terms[0]
        if len(terms) == 1 and c0 == 1.0 and len(fs0) == 1 \
                and fs0[0][1] == 0:
            # exact single-plaintext term: encode precisely as traced
            return self._encode_spec(compiled, fs0[0][0])
        key = (id(compiled), "fused", step.out, s)
        if key not in self._pt_cache:
            val = None
            for c, fs in terms:
                term = np.asarray(c, dtype=complex)
                for pid, r in fs:
                    term = term * np.roll(specs[pid].values, -r)
                val = term if val is None else val + term
            self._pt_cache[key] = self.ctx.encode(
                val, level=step.level, scale=step.pt_scale)
        return self._pt_cache[key]

    # ------------------------- invariant checker -----------------------
    def _check_block(self, step, ct: Ciphertext) -> None:
        """Block-boundary invariants (opt-in): the ciphertext leaving a
        keyswitch-family step is healthy and still on the traced level.
        Raises typed ``CiphertextError``s; runs eagerly (no jit)."""
        where = f"{type(step).__name__}(out={step.out})"
        if ct.level != step.level:
            raise ModulusChainMismatchError(
                f"level drifted off the trace at {where}",
                hint="the executed program diverged from its trace — "
                     "recompile the program for this context",
                level=ct.level, traced=step.level)
        self.ctx.check_ciphertext(ct, where=where)

    # ------------------------- eager steps -----------------------------
    def _node_pt(self, compiled, node) -> Plaintext:
        return self._encode_spec(compiled, node.attrs["pt"])

    def _exec_eager(self, compiled, step: EagerStep, values, outputs,
                    inputs, batch: int, validate: bool = False) -> None:
        ctx = self.ctx
        node = compiled.dfg.nodes[step.nid]
        op = node.op
        a = values[node.args[0]] if node.args else None
        if op == OpKind.INPUT:
            tag = node.attrs["tag"]
            ct = inputs[tag]
            # user-input validation: typed (asserts vanish under -O)
            if ct.level != node.attrs["level"]:
                raise ModulusChainMismatchError(
                    f"input '{tag}' level disagrees with the trace",
                    hint="encrypt the input at the program's traced "
                         "level (or recompile for this level)",
                    tag=tag, level=ct.level,
                    traced=node.attrs["level"])
            traced_scale = node.attrs["scale"]
            if not abs(ct.scale / traced_scale - 1.0) < 1e-9:
                raise ScaleDriftError(
                    f"input '{tag}' scale disagrees with the trace",
                    hint="encrypt the input at the program's traced "
                         "scale",
                    tag=tag, scale=ct.scale, traced=traced_scale)
            if validate:
                ctx.check_ciphertext(ct, where=f"input '{tag}'")
            values[step.nid] = ct
            return
        if op == OpKind.OUTPUT:
            outputs[node.attrs["tag"]] = a
            return
        if op == OpKind.ROT:
            out = self._rotate(a, node.attrs["steps"], batch)
        elif op == OpKind.CONJ:
            out = self._conjugate(a, batch)
        elif op == OpKind.CADD:
            out = ctx.add(a, values[node.args[1]])
        elif op == OpKind.CSUB:
            out = ctx.sub(a, values[node.args[1]])
        elif op == OpKind.CSCALE:
            out = ctx.double(a)
        elif op == OpKind.PMUL:
            out = ctx.pt_mul(a, self._node_pt(compiled, node),
                             rescale=False)
        elif op == OpKind.PADD:
            out = ctx.pt_add(a, self._node_pt(compiled, node))
        elif op == OpKind.RESCALE:
            out = self._rescale(a, batch)
        elif op == OpKind.MOD_RAISE:
            out = self._mod_raise(a, batch)
        elif op == OpKind.LEVEL_DOWN:
            n = node.attrs["target"] + 1
            out = Ciphertext(a.c0[..., :n, :], a.c1[..., :n, :],
                             node.attrs["target"], a.scale)
        else:
            raise NotImplementedError(f"cannot execute {op.value}")
        self._finish(compiled, step.nid, out, values)

    def _finish(self, compiled, nid: int, out: Ciphertext, values) -> None:
        """Replay the trace-time scale float (identical arithmetic to the
        eager path; for fused blocks it pins the unfused trajectory)."""
        scale = compiled.dfg.nodes[nid].attrs.get("scale")
        if scale is not None:
            out.scale = scale
        values[nid] = out

    # ----- batched op mirrors (engine vmap + broadcasting EWOs) --------
    def _rotate(self, ct, steps: int, batch: int) -> Ciphertext:
        ctx = self.ctx
        if not batch:
            return ctx.rotate(ct, steps)
        g = ctx.pc.rns.galois_for_rotation(steps)
        c0, c1 = ctx.engine.apply_galois_batched(
            ct.c0, ct.c1, g, ctx.keys.rot_key(steps), ct.level)
        return Ciphertext(c0, c1, ct.level, ct.scale)

    def _conjugate(self, ct, batch: int) -> Ciphertext:
        ctx = self.ctx
        if not batch:
            return ctx.conjugate(ct)
        g = ctx.pc.rns.galois_conjugate()
        c0, c1 = ctx.engine.apply_galois_batched(
            ct.c0, ct.c1, g, ctx.keys.conj_key, ct.level)
        return Ciphertext(c0, c1, ct.level, ct.scale)

    def _mod_raise(self, ct, batch: int) -> Ciphertext:
        """Bootstrap boundary (centered-CRT lift, numpy object math) —
        executed per ciphertext even under batching."""
        ctx = self.ctx
        if not batch:
            return ctx.mod_raise(ct)
        outs = [ctx.mod_raise(Ciphertext(ct.c0[b], ct.c1[b], ct.level,
                                         ct.scale))
                for b in range(int(ct.c0.shape[0]))]
        return Ciphertext(jnp.stack([o.c0 for o in outs]),
                          jnp.stack([o.c1 for o in outs]),
                          outs[0].level, ct.scale)

    def _rescale(self, ct, batch: int) -> Ciphertext:
        ctx = self.ctx
        if not batch:
            return ctx.rescale(ct)
        lvl = ct.level
        if lvl not in self._rescale_fns:
            self._rescale_fns[lvl] = jax.jit(jax.vmap(
                partial(poly.rescale, level=lvl, pc=ctx.pc)
            ))
        fn = self._rescale_fns[lvl]
        q_last = ctx.chain(lvl)[-1]
        return Ciphertext(fn(ct.c0), fn(ct.c1), lvl - 1,
                          ct.scale / q_last)
