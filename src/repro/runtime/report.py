"""Execution reports: close the predict -> execute -> validate loop.

``build_report`` pairs the op counters actually incremented during an
execution (ModUp/ModDown/IP invocations + NTT/BConv work derived from
the engine's real (dnum, l_ext, N) plan shapes) with the OpVolumes that
``repro.dfg.hoist`` predicts for the same lowered plan.  ``reconcile``
asserts the counts agree exactly; ``scheduled_result`` feeds the
per-block volumes into the event-driven group scheduler
(``repro.sim.schedule``) so a functional execution yields the paper's
performance-model latency for the very plan that just ran.

``program_blocks`` exposes the same lowering->sim-block translation as
a standalone function: the scheduler consumes ANY packed block stream,
and the serving layer (``repro.serve.simfeed``) concatenates the blocks
of a whole multi-program batch log to replay live traffic on the
hardware timelines.
"""
from __future__ import annotations

import dataclasses

from repro.core.counters import OpCounters
from repro.dfg.graph import OpKind
from repro.dfg.hoist import (
    OpVolumes, evk_words, ip_volumes, moddown_volumes, modup_volumes,
)
from repro.runtime.compile import CompiledProgram
from repro.runtime.lower import (
    HoistedStep, KeyswitchFamilyStep, MultiHoistedStep, MultiRelinStep,
    RelinStep,
)


def _keyswitch_volumes(l: int, k: int, alpha: int, N: int,
                       dataflow: str = "IRF") -> OpVolumes:
    v = (modup_volumes(l, k, alpha, N)
         + moddown_volumes(l, k, alpha, N, 2)
         + ip_volumes(l, k, alpha, N))
    v.keyswitch_count = 1
    v.evk_set_words = evk_words(l, k, alpha, N)
    if dataflow == "IRF":
        dnum = -(-l // alpha)
        v.comm_up_words = dnum * (l + k) * N
        v.comm_down_words = 2 * (l + k) * N
    return v


def step_volumes(compiled: CompiledProgram, step,
                 shared_modup: bool = True) -> OpVolumes | None:
    """dfg.hoist-predicted volumes of one lowered step (None: no work).

    ``shared_modup=False`` models the seed execution path, which has no
    digits-in entry point: every hoisted block performs its own ModUp."""
    p = compiled.params
    k, alpha, N = p.k, p.alpha, p.N
    if isinstance(step, HoistedStep):
        l = step.level + 1
        fresh = step.fresh_modup or not shared_modup
        # step-0 terms are plain base-domain EWOs (no IP, no evk) — see
        # CKKSContext.hoisted_rotation_sum
        nz = [s for s in step.steps if s != 0]
        if not nz:
            v = OpVolumes()
            v.ewo_words = len(step.steps) * 2 * l * N
            return v
        v = OpVolumes()
        if fresh:
            v = v + modup_volumes(l, k, alpha, N)
        v = v + moddown_volumes(l, k, alpha, N, 2)
        for _ in range(len(nz)):
            v = v + ip_volumes(l, k, alpha, N)
        v.keyswitch_count = len(nz)
        v.evk_set_words = len(set(nz)) * evk_words(l, k, alpha, N)
        v.ewo_words = (len(step.steps) - len(nz)) * 2 * l * N
        dnum = -(-l // alpha)
        if fresh:
            v.comm_up_words = dnum * (l + k) * N
        v.comm_down_words = 2 * (l + k) * N
        return v
    if isinstance(step, MultiHoistedStep):
        l = step.level + 1
        v = OpVolumes()
        fresh = (len(step.fresh_anchors) if shared_modup
                 else len({a for a, _ in step.rot_terms}))
        for _ in range(fresh):
            v = v + modup_volumes(l, k, alpha, N)
        v = v + moddown_volumes(l, k, alpha, N, 2)
        for _ in range(step.n_rot):
            v = v + ip_volumes(l, k, alpha, N)
        v.keyswitch_count = step.n_rot
        v.evk_set_words = len(set(step.steps)) * evk_words(l, k, alpha, N)
        dnum = -(-l // alpha)
        v.comm_up_words = fresh * dnum * (l + k) * N
        v.comm_down_words = 2 * (l + k) * N
        # base-domain adds for the passthrough terms
        v.ewo_words = len(step.passthrough) * 2 * l * N
        return v
    if isinstance(step, RelinStep):
        l = step.level + 1
        v = _keyswitch_volumes(l, k, alpha, N)
        v.ewo_words += 4 * l * N      # tensor-product EWOs
        v.relin_count = 1
        return v
    if isinstance(step, MultiRelinStep):
        l = step.level + 1
        n = step.n_relin
        v = OpVolumes()
        for _ in range(n):
            v = v + modup_volumes(l, k, alpha, N)
            v = v + ip_volumes(l, k, alpha, N)
        v = v + moddown_volumes(l, k, alpha, N, 2)
        v.keyswitch_count = n
        v.relin_count = n
        # ONE shared mult key serves every merged term
        v.evk_set_words = evk_words(l, k, alpha, N)
        v.ewo_words = (n * 4 * l * N
                       + len(step.passthrough) * 2 * l * N)
        dnum = -(-l // alpha)
        v.comm_up_words = n * dnum * (l + k) * N
        v.comm_down_words = 2 * (l + k) * N
        return v
    node = compiled.dfg.nodes[step.nid]
    l = node.limbs
    # no eager CMULT branch: lower_program turns every CMULT into a
    # RelinStep (or merges it into a MultiRelinStep), handled above
    if node.op in (OpKind.ROT, OpKind.CONJ):
        return _keyswitch_volumes(l, k, alpha, N)
    if node.op in (OpKind.PMUL, OpKind.CADD, OpKind.CSUB, OpKind.CSCALE,
                   OpKind.PADD):
        v = OpVolumes()
        v.ewo_words = 2 * l * N
        return v
    if node.op == OpKind.RESCALE:
        v = OpVolumes()
        v.ewo_words = 2 * l * N
        v.ntt_words = 2 * N
        return v
    if node.op == OpKind.MOD_RAISE:
        # bootstrap boundary: INTT both components off the base prime,
        # NTT back over the full chain (the centered lift is host-side)
        v = OpVolumes()
        l_in = compiled.dfg.nodes[node.args[0]].limbs
        v.ntt_words = 2 * (l_in + l) * N
        return v
    return None


def program_blocks(compiled: CompiledProgram, batch: int = 1) -> list:
    """Sim blocks of one compiled program executed over ``batch`` cts.

    Keyswitch-family steps stream through 2*dnum pipeline groups with
    per-digit ModUp leg weights; volumes scale linearly with the batch.
    Shared by ``ExecutionReport.scheduled_result`` and the serving
    layer's traffic replay (``repro.serve.simfeed``)."""
    from repro.sim.engine import Block

    alpha = compiled.params.alpha
    blocks = []
    for step in compiled.steps:
        v = step_volumes(compiled, step)
        if v is None:
            continue
        if isinstance(step, KeyswitchFamilyStep):
            # rotation AND relin blocks stream through 2*dnum
            # pipeline groups with per-digit ModUp leg weights
            dnum = -(-(step.level + 1) // alpha)
        elif v.keyswitch_count:
            dnum = -(-compiled.dfg.nodes[step.nid].limbs // alpha)
        else:
            dnum = 1
        blocks.append(Block(v.scaled(batch), max(dnum, 1)))
    return blocks


def predicted_volumes(compiled: CompiledProgram,
                      shared_modup: bool = True) -> OpVolumes:
    total = OpVolumes()
    for step in compiled.steps:
        v = step_volumes(compiled, step, shared_modup)
        if v is not None:
            total = total + v
    return total


@dataclasses.dataclass
class ExecutionReport:
    """Actual vs predicted op counts for one compiled execution."""

    executed: OpCounters            # per batch of ``batch`` ciphertexts
    predicted: OpVolumes            # dfg.hoist model of the lowered plan
    plan_shapes: dict[int, tuple]   # level -> engine (dnum, l_ext, N)
    batch: int
    lowering: dict

    def reconcile(self) -> dict:
        """Exact count agreement + work-volume ratios.

        Counts must match exactly (the lowered plan IS what ran); the
        NTT/BConv word ratios compare the analytic model's uniform-digit
        approximation against the engine plans' true short last groups,
        so they are ~1 but not pinned."""
        e, p, b = self.executed, self.predicted, self.batch
        out = {
            "modup": (e.modup, p.modup_count * b),
            "moddown": (e.moddown, p.moddown_count * b),
            "ip": (e.ip, p.ip_count * b),
            "keyswitch": (e.keyswitch, p.keyswitch_count * b),
            "relin": (e.relin, p.relin_count * b),
        }
        out["counts_match"] = all(a == x for a, x in out.values())
        ks_ntt = p.modup_ntt_words + p.moddown_ntt_words
        out["ntt_ratio"] = (e.ntt_words / (ks_ntt * b)) if ks_ntt else 1.0
        ks_bc = p.modup_bconv_macs + p.moddown_bconv_macs
        out["bconv_ratio"] = (e.bconv_macs / (ks_bc * b)) if ks_bc else 1.0
        out["ip_macs_ratio"] = (e.ip_macs / (p.ip_macs * b)
                                if p.ip_macs else 1.0)
        return out

    def validate_plan_shapes(self, params) -> bool:
        """The hoist model's dnum/ext must equal the engine's plans."""
        for level, (dnum, l_ext, N) in self.plan_shapes.items():
            if dnum != len(params.digit_groups(level)):
                return False
            if l_ext != level + 1 + params.k or N != params.N:
                return False
        return True

    def scheduled_result(self, compiled: CompiledProgram, hw,
                         mode: str = "pipelined"):
        """Feed the executed plan's per-block volumes into the sim's
        event-driven group scheduler -> predicted hardware latency."""
        from repro.sim.engine import simulate_blocks

        return simulate_blocks(program_blocks(compiled, self.batch), hw,
                               name="runtime", mode=mode)


def build_report(compiled: CompiledProgram, ctx, executed: OpCounters,
                 batch: int = 1) -> ExecutionReport:
    plans = getattr(ctx.engine, "_plans", {})
    return ExecutionReport(
        executed=executed,
        # the seed path has no digits-in entry point, so its prediction
        # charges every hoisted block its own ModUp
        predicted=predicted_volumes(compiled,
                                    shared_modup=ctx.use_engine),
        plan_shapes={lvl: (p.dnum, p.l_ext, p.N)
                     for lvl, p in plans.items()},
        batch=batch,
        lowering=compiled.summary(),
    )
