"""Heterogeneous CKKS accelerator performance model (paper Secs. V-VII).

Event-driven group-level pipeline simulator over HERO-mapped DFGs
(sim.schedule), with the closed-form analytic combiner retained as
mode="analytic" for regression comparison.  Reproduces the paper's
evaluation: Table IV end-to-end latency/EDP/EDAP, Fig. 14 ablation,
Fig. 15 HERO reductions, Fig. 16 utilization, Fig. 17 bandwidth/
capacity sensitivity.
"""
from repro.sim.hw import HWConfig, SHARP, SHARP_XMU, HE2_SM, HE2_LM  # noqa: F401
from repro.sim.engine import simulate_program, SimResult  # noqa: F401
from repro.sim.schedule import ENGINES, Schedule, Task, run_schedule  # noqa: F401
