"""Hardware configurations (paper Table II/III).

Throughputs in words/ns (36-bit words, 4.5 B).  Power in W, area in mm^2.
"""
from __future__ import annotations

import dataclasses

WORD_BYTES = 4.5  # 36-bit datapath


@dataclasses.dataclass(frozen=True)
class HWConfig:
    name: str
    # xPU compute throughputs (words/ns)
    ntt_tput: float
    bconv_tput: float          # MACs/ns
    ewe_tput: float            # xPU element-wise ops
    # xMU (near-memory) — 0 disables the heterogeneous path
    xmu_tput: float = 0.0      # MACs/ns across all bank PEs
    # memory / link
    hbm_bw_tbs: float = 1.0    # off-chip / heterogeneous link, TB/s
    hbm_cap_gb: float = 8.0
    onchip_mb: float = 180.0
    # pipelining capabilities (Sec. V)
    dual_overlap: bool = False     # compute<->comm + inter-op overlap
    intt_resident: bool = False    # parallel BConv->NTT / NTT paths
    memop_fusion: bool = False     # xMU fused IP+PMul+Autom pass (Fig 10d)
    # energy/area (Table III)
    power_xpu_w: float = 100.0
    power_xmu_w: float = 0.0
    area_mm2: float = 200.0
    # pJ per byte moved across the heterogeneous link / off-chip
    link_pj_per_byte: float = 7.0

    @property
    def link_words_per_ns(self) -> float:
        return self.hbm_bw_tbs * 1e12 / WORD_BYTES / 1e9

    def evk_capacity_words(self, reserve_ct_gb: float = 1.0) -> float:
        """HBM words available for the evk working set."""
        return (self.hbm_cap_gb - reserve_ct_gb) * 1e9 / WORD_BYTES


# --- SHARP [25]: monolithic ASIC, EVF + Min-KS, big scratchpad ----------
SHARP = HWConfig(
    name="SHARP",
    ntt_tput=1024, bconv_tput=16384, ewe_tput=2048,
    xmu_tput=0.0, hbm_bw_tbs=1.0, onchip_mb=198.0,
    dual_overlap=False, intt_resident=False,
    power_xpu_w=94.0, power_xmu_w=0.0, area_mm2=179.0,
)

# --- SHARP-xMU: SHARP xPU + bank-level xMU, IRF dataflow ----------------
SHARP_XMU = HWConfig(
    name="SHARP-xMU",
    ntt_tput=1024, bconv_tput=16384, ewe_tput=2048,
    xmu_tput=5461, hbm_bw_tbs=1.0, onchip_mb=198.0,
    dual_overlap=False, intt_resident=False,
    power_xpu_w=94.0, power_xmu_w=11.8, area_mm2=179.0 + 12.2,
)

# --- HE2-SM: small scratchpad (44 MB), IRF only -------------------------
HE2_SM = HWConfig(
    name="HE2-SM",
    ntt_tput=768, bconv_tput=672 * 16, ewe_tput=512,
    xmu_tput=5461, hbm_bw_tbs=1.0, onchip_mb=44.0,
    dual_overlap=True, intt_resident=True, memop_fusion=True,
    power_xpu_w=74.5, power_xmu_w=23.6, area_mm2=71.9,
)

# --- HE2-LM: 84 MB scratchpad, hybrid IRF/EVF ----------------------------
HE2_LM = HWConfig(
    name="HE2-LM",
    ntt_tput=768, bconv_tput=672 * 16, ewe_tput=512,
    xmu_tput=5461, hbm_bw_tbs=1.0, onchip_mb=84.0,
    dual_overlap=True, intt_resident=True, memop_fusion=True,
    power_xpu_w=79.7, power_xmu_w=23.6, area_mm2=80.2,
)

CONFIGS = {c.name: c for c in (SHARP, SHARP_XMU, HE2_SM, HE2_LM)}


def with_bandwidth(cfg: HWConfig, tbs: float) -> HWConfig:
    return dataclasses.replace(cfg, name=f"{cfg.name}@{tbs}TB/s",
                               hbm_bw_tbs=tbs)


def with_capacity(cfg: HWConfig, gb: float) -> HWConfig:
    return dataclasses.replace(cfg, name=f"{cfg.name}@{gb}GB",
                               hbm_cap_gb=gb)
