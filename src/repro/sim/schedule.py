"""Event-driven group-level pipeline scheduler (paper Sec. V).

Each keyswitch block is expanded into its 2*dnum pipeline groups; every
group contributes a chain of tasks across the five hardware engines

    xpu   — ModUp legs (INTT/BConv/NTT) and, after the down transfer,
            ModDown legs + internal sub/scale
    link  — the heterogeneous xPU<->HBM interface, shared by both
            directions (up: ModUp outputs to the xMU; down: IP
            accumulations back) exactly like the analytic model's
            single t_comm budget
    xmu   — IP MACs, extended-domain EWOs, automorphism on bank PEs
    evk   — off-chip evk stream (EVF traffic due this block)

A discrete-event list scheduler places tasks onto explicit per-engine
timelines (FIFO by task id among ready tasks), which yields exact
fill/drain behaviour and cross-block overlap: group g of block i+1
starts on the xPU as soon as group g of block i has drained back
(streaming data dependency), while block i's later groups are still in
the xMU or on the link.  Designs without dual-level overlap
(hw.dual_overlap=False) execute one group per block and a hard barrier
between blocks, which reproduces the serial/naive models exactly.

Stall attribution is measured from gaps in the timelines instead of
algebraic residuals: comm stall is wall-clock time where a link is busy
but neither compute engine is; mem stall is time where only the evk
stream is busy.
"""
from __future__ import annotations

import dataclasses
import heapq
from collections import defaultdict

from repro.sim.hw import HWConfig, WORD_BYTES


def pipeline_groups(dnum: int, pipelined: bool = True) -> int:
    """Number of pipeline groups a keyswitch block decomposes into.

    The paper streams each block as 2*dnum groups (one per digit for the
    up-phase, one per digit for the down-phase, Sec. V); non-pipelined
    designs execute the block as a single group.  The analytic
    combiner's fill term divides by the same count.
    """
    return max(2 * dnum, 2) if pipelined else 1

XPU = "xpu"
XMU = "xmu"
LINK = "link"
EVK = "evk"
ENGINES = (XPU, XMU, LINK, EVK)


@dataclasses.dataclass
class Task:
    tid: int
    engine: str
    duration: float
    deps: list[int]
    label: str
    block: int
    group: int
    start: float = 0.0
    end: float = 0.0


@dataclasses.dataclass
class Schedule:
    """Result of an event-driven run: placed tasks + per-engine traces."""

    tasks: list[Task]
    makespan: float

    def timeline(self, engine: str) -> list[Task]:
        return sorted((t for t in self.tasks if t.engine == engine),
                      key=lambda t: t.start)

    def timelines(self) -> dict[str, list[tuple[float, float, str]]]:
        return {
            e: [(t.start, t.end, t.label) for t in self.timeline(e)]
            for e in ENGINES
        }

    def busy(self, engine: str) -> float:
        return sum(t.duration for t in self.tasks if t.engine == engine)

    def utilization(self) -> dict[str, float]:
        if not self.makespan:
            return {e: 0.0 for e in ENGINES}
        return {e: self.busy(e) / self.makespan for e in ENGINES}

    # ---- stall attribution from timeline gaps --------------------------
    def _busy_intervals(self, engines: tuple[str, ...]):
        ivs = sorted(
            (t.start, t.end) for t in self.tasks
            if t.engine in engines and t.duration > 0
        )
        merged: list[list[float]] = []
        for s, e in ivs:
            if merged and s <= merged[-1][1]:
                merged[-1][1] = max(merged[-1][1], e)
            else:
                merged.append([s, e])
        return merged

    def exposed_time(self, engines: tuple[str, ...],
                     hidden_by: tuple[str, ...]) -> float:
        """Wall-clock time where `engines` are busy but none of
        `hidden_by` is — i.e. stall exposed on the critical path."""
        cover = self._busy_intervals(hidden_by)
        exposed = 0.0
        for s, e in self._busy_intervals(engines):
            for cs, ce in cover:
                if ce <= s:
                    continue
                if cs >= e:
                    break
                lo, hi = max(s, cs), min(e, ce)
                exposed -= max(0.0, hi - lo)
            exposed += e - s
        return max(0.0, exposed)

    @property
    def comm_stall_s(self) -> float:
        return self.exposed_time((LINK,), (XPU, XMU))

    @property
    def mem_stall_s(self) -> float:
        return self.exposed_time((EVK,), (XPU, XMU, LINK))

    # ---- energy integrated over the timelines --------------------------
    def busy_integral(self, engines: tuple[str, ...]) -> float:
        """Seconds covered by the merged busy intervals of ``engines``."""
        return sum(e - s for s, e in self._busy_intervals(engines))

    def energy_breakdown(self, hw: HWConfig) -> dict[str, float]:
        """Per-engine energy from the placed timelines.

        Dynamic power integrates over each engine's merged
        ``_busy_intervals`` (not pre-scheduling busy-time totals), plus
        the 10% static floor over the makespan; link/evk energy charges
        the bytes actually streamed during their busy intervals
        (interval seconds x link bandwidth x pJ/B) — post-cache evk
        traffic, not the raw EVF volume estimate."""
        static = 0.10 * self.makespan
        moved = (self.busy_integral((LINK,)) + self.busy_integral((EVK,)))
        link_bytes = moved * hw.hbm_bw_tbs * 1e12
        return {
            XPU: hw.power_xpu_w * (self.busy_integral((XPU,)) + static),
            XMU: hw.power_xmu_w * (self.busy_integral((XMU,)) + static),
            LINK: link_bytes * hw.link_pj_per_byte * 1e-12,
        }

    def energy_j(self, hw: HWConfig) -> float:
        return sum(self.energy_breakdown(hw).values())


class _TaskGraph:
    def __init__(self) -> None:
        self.tasks: list[Task] = []

    def add(self, engine: str, duration: float, deps: list[Task],
            label: str, block: int, group: int) -> Task:
        t = Task(len(self.tasks), engine, duration,
                 [d.tid for d in deps], label, block, group)
        self.tasks.append(t)
        return t

    def chain(self, stages: list[tuple[str, float]], deps: list[Task],
              label: str, block: int, group: int) -> list[Task]:
        """Create the non-empty stages of a serial chain; the first
        created task inherits `deps`, later ones depend on the previous."""
        out: list[Task] = []
        prev = deps
        for engine, dur in stages:
            if dur <= 0.0:
                continue
            t = self.add(engine, dur, prev, f"{label}/{engine}", block,
                         group)
            prev = [t]
            out.append(t)
        return out


def _xpu_phase_split(v, hw: HWConfig) -> float:
    """Fraction of a block's xPU time spent before the up-link (ModUp
    legs + unattributed work) vs after the down-link (ModDown legs +
    internal sub/scale).  Proportional apportioning keeps the per-engine
    busy totals identical to the analytic model's."""
    up = (v.modup_ntt_words / hw.ntt_tput
          + v.modup_bconv_macs / hw.bconv_tput)
    up += (max(v.ntt_words - v.modup_ntt_words - v.moddown_ntt_words, 0.0)
           / hw.ntt_tput)
    up += (max(v.bconv_macs - v.modup_bconv_macs - v.moddown_bconv_macs,
               0.0) / hw.bconv_tput)
    down = (v.moddown_ntt_words / hw.ntt_tput
            + v.moddown_bconv_macs / hw.bconv_tput
            + v.xpu_ewo_words / hw.ewe_tput)
    total = up + down
    return up / total if total > 0 else 1.0


def _up_slice_weights(v, hw: HWConfig, groups: int) -> list[float]:
    """Per-slice weights for the up-phase xPU work.

    When the block carries per-digit ModUp leg volumes (``v.modup_legs``,
    derived from the keyswitch engine's real (dnum, l_ext, N) plan
    shapes), slice g is weighted by digit g % len(legs)'s actual leg
    seconds — a short last decomposition group gets a proportionally
    shorter xPU slice, which changes fill/drain without changing any
    busy total.  The legs only need to TILE the slice count (groups %
    len(legs) == 0): multi-anchor blocks from the compiled runtime
    (``runtime.lower.MultiHoistedStep``) merge several same-level ModUps
    into dnum summed legs while still streaming 2*dnum groups, and keep
    the per-digit weighting here.  Falls back to a uniform split when
    legs are unavailable or do not tile the groups."""
    legs = getattr(v, "modup_legs", ())
    if not legs or groups % len(legs):
        return [1.0 / groups] * groups
    w = [ntt / hw.ntt_tput + bc / hw.bconv_tput for ntt, bc in legs]
    total = sum(w) * (groups // len(legs))
    if total <= 0.0:
        return [1.0 / groups] * groups
    return [w[g % len(legs)] / total for g in range(groups)]


def _down_slice_weights(v, hw: HWConfig, groups: int) -> list[float]:
    """Per-slice weights for the down-phase xPU work.

    Mirror of :func:`_up_slice_weights` for the ModDown side: the IP
    accumulation streams back digit-by-digit in the same group order the
    ModUp went up, so slice g's post-link xPU work (NTT back + BConv +
    subtract/scale of digit g's base limbs) is weighted by
    ``v.moddown_legs`` — a short last decomposition group drains
    proportionally faster.  Identical to the uniform split when digits
    are uniform; falls back to it when legs are unavailable or do not
    tile the groups."""
    legs = getattr(v, "moddown_legs", ())
    if not legs or groups % len(legs):
        return [1.0 / groups] * groups
    w = [ntt / hw.ntt_tput + bc / hw.bconv_tput + ewo / hw.ewe_tput
         for ntt, bc, ewo in legs]
    total = sum(w) * (groups // len(legs))
    if total <= 0.0:
        return [1.0 / groups] * groups
    return [w[g % len(legs)] / total for g in range(groups)]


def build_block_tasks(graph: _TaskGraph, block_idx: int, times: dict,
                      v, hw: HWConfig,
                      prev_outputs: list[Task],
                      prev_all: list[Task]) -> list[Task]:
    """Expand one mapped block into group tasks; returns the per-group
    output tasks the next block's groups may stream after.

    `times` is the analytic per-engine time dict (engine.py) so that the
    scheduled model's busy totals agree with the analytic ones exactly.
    """
    t_xpu, t_xmu, t_evk = times["xpu"], times["xmu"], times["evk"]
    link_s_per_word = WORD_BYTES / (hw.hbm_bw_tbs * 1e12)
    t_up = v.comm_up_words * link_s_per_word
    t_down = v.comm_down_words * link_s_per_word
    pipelined = hw.dual_overlap and hw.xmu_tput > 0
    groups = pipeline_groups(times["dnum"], pipelined)
    f_up = _xpu_phase_split(v, hw)
    up_w = _up_slice_weights(v, hw, groups)
    down_w = _down_slice_weights(v, hw, groups)

    outputs: list[Task] = []
    for g in range(groups):
        if pipelined:
            # stream after the same group of the previous block
            deps = ([prev_outputs[min(g, len(prev_outputs) - 1)]]
                    if prev_outputs else [])
        else:
            deps = prev_all  # hard barrier: no inter-block overlap
        if hw.xmu_tput == 0:
            # monolithic: all compute on the xPU; evk stream overlaps
            chain = graph.chain([(XPU, (t_xpu + t_xmu) / groups)], deps,
                                f"b{block_idx}.g{g}", block_idx, g)
            ev = graph.chain([(EVK, t_evk / groups)], deps,
                             f"b{block_idx}.g{g}.evk", block_idx, g)
            outputs.append((chain or ev or prev_outputs[-1:] or [None])[-1])
            continue
        up_chain = graph.chain(
            [(XPU, f_up * t_xpu * up_w[g]), (LINK, t_up / groups)],
            deps, f"b{block_idx}.g{g}.up", block_idx, g)
        if pipelined:
            # evk digits stream ahead on their own engine
            ev = graph.chain([(EVK, t_evk / groups)], deps,
                             f"b{block_idx}.g{g}.evk", block_idx, g)
            xmu_deps = (up_chain[-1:] if up_chain else deps) + ev
        else:
            # naive design fetches the key on the critical path
            ev = graph.chain([(EVK, t_evk / groups)],
                             up_chain[-1:] if up_chain else deps,
                             f"b{block_idx}.g{g}.evk", block_idx, g)
            xmu_deps = ev or (up_chain[-1:] if up_chain else deps)
        down_chain = graph.chain(
            [(XMU, t_xmu / groups), (LINK, t_down / groups),
             (XPU, (1.0 - f_up) * t_xpu * down_w[g])],
            xmu_deps, f"b{block_idx}.g{g}.down", block_idx, g)
        last = (down_chain or up_chain or ev)
        outputs.append(last[-1] if last else
                       (prev_outputs[-1] if prev_outputs else None))
    return [t for t in outputs if t is not None]


def run_schedule(tasks: list[Task]) -> Schedule:
    """Deterministic list scheduling: among ready tasks each engine runs
    the lowest task id first (in-order issue per engine, out-of-order
    across engines)."""
    indeg = {t.tid: len(t.deps) for t in tasks}
    dependents: dict[int, list[int]] = defaultdict(list)
    by_id = {t.tid: t for t in tasks}
    for t in tasks:
        for d in t.deps:
            dependents[d].append(t.tid)
    ready: dict[str, list[int]] = defaultdict(list)
    for t in tasks:
        if indeg[t.tid] == 0:
            heapq.heappush(ready[t.engine], t.tid)
    engine_free: dict[str, float] = defaultdict(float)
    running: dict[str, bool] = defaultdict(bool)
    events: list[tuple[float, int]] = []
    now = 0.0

    def dispatch(now: float) -> None:
        for e in list(ready):
            if running[e] or not ready[e]:
                continue
            tid = heapq.heappop(ready[e])
            t = by_id[tid]
            t.start = max(now, engine_free[e])
            t.end = t.start + t.duration
            engine_free[e] = t.end
            running[e] = True
            heapq.heappush(events, (t.end, tid))

    dispatch(now)
    done = 0
    while events:
        now, tid = heapq.heappop(events)
        done += 1
        t = by_id[tid]
        running[t.engine] = False
        for d in dependents[tid]:
            indeg[d] -= 1
            if indeg[d] == 0:
                heapq.heappush(ready[by_id[d].engine], by_id[d].tid)
        dispatch(now)
    if done != len(tasks):
        raise RuntimeError(
            f"schedule deadlock: {len(tasks) - done} tasks never ran")
    return Schedule(tasks, max((t.end for t in tasks), default=0.0))


def schedule_blocks(block_times: list[tuple[dict, object]],
                    hw: HWConfig) -> Schedule:
    """Schedule a program: `block_times` pairs the analytic engine-time
    dict of each block with its OpVolumes, in program order."""
    graph = _TaskGraph()
    prev_outputs: list[Task] = []
    prev_all: list[Task] = []
    for i, (times, v) in enumerate(block_times):
        n0 = len(graph.tasks)
        prev_outputs = build_block_tasks(graph, i, times, v, hw,
                                         prev_outputs, prev_all)
        prev_all = graph.tasks[n0:]
    return run_schedule(graph.tasks)


def scheduled_block_time(times: dict, v, hw: HWConfig) -> float:
    """Group-pipeline makespan of a single block — the cost the fusion
    DP and the hybrid dataflow choice optimize under mode='pipelined'."""
    return schedule_blocks([(times, v)], hw).makespan
