"""Block-level pipelined performance model.

Per keyswitch block (a hoisted PKB or a standalone CMULT/CONJ):

  t_xpu   — INTT/BConv/NTT (+ base-domain EWOs; on monolithic designs all
            MemOps run here too, out of the scratchpad)
  t_xmu   — IP MACs + ext-domain EWOs + automorphism on bank PEs
  t_comm  — heterogeneous transfers over the xPU<->HBM interface (IRF)
  t_evk   — off-chip evk fetch (EVF); distinct keys are cached in the
            scratchpad when they fit (Min-KS reuse; HE2-LM's one-evk
            buffer), so traffic is counted once per distinct key

Pipeline combining (Fig. 11):
  * monolithic EVF (SHARP): latency = max(compute, evk-stream) — memory
    stall is whatever evk traffic compute fails to hide.
  * naive heterogeneous (SHARP-xMU): serial xPU -> comm -> xMU (b).
  * HE2 dual-level overlap: latency = max(engines incl. comm & evk) +
    fill/drain across the 2*dnum pipelined groups (d); INTT-Resident
    further overlaps the BConv->NTT and NTT paths (e).
"""
from __future__ import annotations

import dataclasses

from repro.dfg.fusion import CostWeights, optimal_fusion
from repro.dfg.hoist import OpVolumes, non_pkb_blocks, pkb_volumes
from repro.dfg.mapping import map_program
from repro.dfg.pkb import PKB, identify_pkbs
from repro.sim.hw import HWConfig, WORD_BYTES


@dataclasses.dataclass
class SimResult:
    name: str
    latency_s: float = 0.0
    xpu_busy_s: float = 0.0
    xmu_busy_s: float = 0.0
    comm_busy_s: float = 0.0
    comm_stall_s: float = 0.0
    mem_stall_s: float = 0.0
    energy_j: float = 0.0
    volumes: OpVolumes = dataclasses.field(default_factory=OpVolumes)

    @property
    def edp(self) -> float:           # J*ms
        return self.energy_j * self.latency_s * 1e3

    def edap(self, area_mm2: float) -> float:
        return self.edp * area_mm2

    @property
    def comm_stall_frac(self) -> float:
        return self.comm_stall_s / self.latency_s if self.latency_s else 0.0

    @property
    def xpu_util(self) -> float:
        return self.xpu_busy_s / self.latency_s if self.latency_s else 0.0

    @property
    def xmu_util(self) -> float:
        return self.xmu_busy_s / self.latency_s if self.latency_s else 0.0


def _block_engine_times(v: OpVolumes, hw: HWConfig, dnum: int,
                        evk_words_due: float) -> dict:
    ns = 1e-9
    t_ntt = v.ntt_words / hw.ntt_tput * ns
    t_bconv = v.bconv_macs / hw.bconv_tput * ns
    if hw.intt_resident:
        # BConv->NTT || NTT parallel paths: overlap NTT legs with BConv
        t_xpu_core = max(t_ntt, t_bconv) + 0.15 * min(t_ntt, t_bconv)
    elif hw.dual_overlap:
        t_xpu_core = max(t_ntt, t_bconv) + 0.3 * min(t_ntt, t_bconv)
    else:
        t_xpu_core = t_ntt + t_bconv

    if hw.memop_fusion:
        # fused IP+PMul+Autom xMU pass: permutation folds into addressing
        mem_words = v.ip_macs + v.ewo_ext_words + v.ewo_words
    else:
        mem_words = (v.ip_macs + v.ewo_ext_words + v.autom_words
                     + v.ewo_words)
    if hw.xmu_tput > 0:
        t_xpu = t_xpu_core + v.xpu_ewo_words / hw.ewe_tput * ns
        t_xmu = mem_words / hw.xmu_tput * ns
    else:
        # monolithic: MemOps on the xPU EWEU out of the scratchpad
        t_xpu = t_xpu_core + (v.xpu_ewo_words + mem_words) \
            / hw.ewe_tput * ns
        t_xmu = 0.0

    t_comm = v.comm_words * WORD_BYTES / (hw.hbm_bw_tbs * 1e12)
    t_evk = evk_words_due * WORD_BYTES / (hw.hbm_bw_tbs * 1e12)
    return {"xpu": t_xpu, "xmu": t_xmu, "comm": t_comm, "evk": t_evk,
            "dnum": dnum}


def _combine(times: dict, hw: HWConfig) -> tuple[float, float, float]:
    """-> (latency, comm_stall, mem_stall) for one block."""
    t_xpu, t_xmu, t_comm, t_evk = (times["xpu"], times["xmu"],
                                   times["comm"], times["evk"])
    if hw.xmu_tput == 0:
        compute = t_xpu + t_xmu
        lat = max(compute, t_evk)
        return lat, 0.0, lat - compute
    if hw.dual_overlap:
        g = max(2 * times["dnum"], 2)
        parts = [t_xpu, t_xmu, t_comm, t_evk]
        bound = max(parts)
        fill = (sum(parts) - bound) / g
        lat = bound + fill
        no_comm = max(t_xpu, t_xmu, t_evk)
        no_comm += (t_xpu + t_xmu + t_evk - no_comm) / g
        no_evk = max(t_xpu, t_xmu, t_comm)
        no_evk += (t_xpu + t_xmu + t_comm - no_evk) / g
        return lat, max(0.0, lat - no_comm), max(0.0, lat - no_evk)
    # naive heterogeneous: serialized critical path (Fig. 11(b))
    lat = t_xpu + t_comm + t_xmu + t_evk
    return lat, t_comm, t_evk


@dataclasses.dataclass
class Block:
    volumes: OpVolumes
    dnum: int
    evk_keys: tuple = ()        # (key-id, words) pairs this block touches
    streams_evk: bool = False   # EVF: traffic due on first touch


def block_time(v: OpVolumes, dnum: int, hw: HWConfig,
               evk_words_due: float = 0.0) -> float:
    return _combine(_block_engine_times(v, hw, dnum, evk_words_due), hw)[0]


def simulate_blocks(blocks: list[Block], hw: HWConfig,
                    name: str) -> SimResult:
    res = SimResult(name=name)
    cached: set = set()
    cache_words = hw.onchip_mb * 1e6 / WORD_BYTES
    for b in blocks:
        due = 0.0
        if b.streams_evk:
            for key, words in b.evk_keys:
                if key in cached and words <= cache_words:
                    continue
                due += words
                if words <= cache_words:
                    cached.add(key)
        t = _block_engine_times(b.volumes, hw, b.dnum, due)
        lat, cstall, mstall = _combine(t, hw)
        res.latency_s += lat
        res.xpu_busy_s += t["xpu"]
        res.xmu_busy_s += t["xmu"]
        res.comm_busy_s += t["comm"]
        res.comm_stall_s += cstall
        res.mem_stall_s += mstall
        res.volumes = res.volumes + b.volumes
    link_bytes = (res.volumes.comm_words + res.volumes.evk_load_words) \
        * WORD_BYTES
    # busy-time dynamic power + 10% static floor
    res.energy_j = (
        hw.power_xpu_w * (res.xpu_busy_s + 0.10 * res.latency_s)
        + hw.power_xmu_w * (res.xmu_busy_s + 0.10 * res.latency_s)
        + link_bytes * hw.link_pj_per_byte * 1e-12
    )
    return res


def _evk_keys_for(pkb: PKB, strategy: str, k: int, alpha: int, nh: int):
    """Distinct evk identities a block touches (for the EVF cache)."""
    from repro.dfg.hoist import evk_words

    l = pkb.limbs
    w = evk_words(l, k, alpha, pkb.dfg.N)
    if strategy == "minks":
        bits = set()
        for s in pkb.steps:
            s = s % nh
            bits |= {i for i in range(max(s.bit_length(), 1)) if s >> i & 1}
        return tuple((("rot2", b, l), w) for b in (bits or {0}))
    return tuple((("rot", s, l), w) for s in set(pkb.steps))


def simulate_program(dfg, hw: HWConfig, strategy: str = "hoist",
                     dataflow: str = "hybrid", fusion: bool = False,
                     nh: int = 1 << 15, k: int = 12, alpha: int = 12,
                     name: str | None = None) -> SimResult:
    """strategy: 'minks' | 'plain' | 'hoist'; dataflow 'IRF'|'EVF'|'hybrid'.
    fusion=True applies the HERO DP (scored with THIS hw's pipeline model)
    before mapping."""
    pkbs = identify_pkbs(dfg)
    if fusion:
        plan = optimal_fusion(
            pkbs, k, alpha, nh, capacity_words=hw.evk_capacity_words(),
            weights=_pipeline_weights(hw), dataflow="IRF",
        )
        pkbs = plan.fused
    mode = dataflow
    if dataflow == "hybrid" and hw.onchip_mb < 60:
        mode = "IRF"      # SM cannot buffer an evk on-chip
    mapped = map_program(pkbs, k, alpha, nh, mode=mode, strategy=strategy)
    blocks = []
    for m in mapped:
        streams = m.dataflow == "EVF"
        blocks.append(Block(
            m.volumes, m.pkb.dnum,
            _evk_keys_for(m.pkb, strategy, k, alpha, nh) if streams else (),
            streams,
        ))
    extra, residual = non_pkb_blocks(
        dfg, pkbs, k, alpha,
        dataflow=("IRF" if mode == "IRF" else "EVF"),
    )
    for v in extra:
        # relin/conj keys are shared program-wide; identity by size
        key = (("relin", v.evk_set_words), v.evk_set_words)
        blocks.append(Block(v, max(1, v.ip_count), (key,), mode != "IRF"))
    blocks.append(Block(residual, 1))
    return simulate_blocks(
        blocks, hw,
        name or f"{hw.name}/{strategy}/{dataflow}" + ("/fused" if fusion else ""),
    )


def _pipeline_weights(hw: HWConfig) -> CostWeights:
    """CostWeights whose .seconds() delegates to the hw pipeline model —
    so the fusion DP optimizes what the simulator measures."""

    class _W(CostWeights):
        def seconds(self, v: OpVolumes) -> float:  # type: ignore[override]
            dnum = max(1, round(v.modup_count or 1))
            return block_time(v, dnum, hw,
                              v.evk_load_words and v.evk_set_words or 0.0)

    return _W()
