"""Block-level pipelined performance model.

Per keyswitch block (a hoisted PKB or a standalone CMULT/CONJ):

  t_xpu   — INTT/BConv/NTT (+ base-domain EWOs; on monolithic designs all
            MemOps run here too, out of the scratchpad)
  t_xmu   — IP MACs + ext-domain EWOs + automorphism on bank PEs
  t_comm  — heterogeneous transfers over the xPU<->HBM interface (IRF)
  t_evk   — off-chip evk fetch (EVF); distinct keys are cached in the
            scratchpad when they fit (Min-KS reuse; HE2-LM's one-evk
            buffer), so traffic is counted once per distinct key

Two latency models share the per-block engine times:

  * mode="pipelined" (default) — the event-driven group scheduler
    (sim.schedule): blocks expand into 2*dnum group task chains placed
    on explicit engine timelines, with cross-block streaming overlap on
    dual-overlap designs and exact fill/drain.  Stalls are measured
    from timeline gaps and per-engine occupancy traces are attached to
    the result.
  * mode="analytic" — the closed-form combiner (Fig. 11): per block
    max(engines) + fill/(2*dnum), blocks summed serially.  Kept for
    regression comparison against the scheduler.
"""
from __future__ import annotations

import dataclasses

from repro.dfg.fusion import CostWeights, optimal_fusion
from repro.dfg.hoist import OpVolumes, non_pkb_blocks
from repro.dfg.mapping import map_program
from repro.dfg.pkb import PKB, identify_pkbs
from repro.sim.hw import HWConfig, WORD_BYTES
from repro.sim.schedule import (
    ENGINES, pipeline_groups, schedule_blocks, scheduled_block_time,
)


@dataclasses.dataclass
class SimResult:
    name: str
    latency_s: float = 0.0
    xpu_busy_s: float = 0.0
    xmu_busy_s: float = 0.0
    comm_busy_s: float = 0.0
    comm_stall_s: float = 0.0
    mem_stall_s: float = 0.0
    energy_j: float = 0.0
    volumes: OpVolumes = dataclasses.field(default_factory=OpVolumes)
    mode: str = "analytic"
    # mode="pipelined" extras: per-engine occupancy traces
    # {engine: [(start_s, end_s, label), ...]} and busy seconds
    timelines: dict = dataclasses.field(default_factory=dict, repr=False)
    engine_busy_s: dict = dataclasses.field(default_factory=dict)
    # pipelined extra: Schedule.energy_breakdown(hw) — per-engine joules
    # (obs.registry.publish_energy mirrors this into the metrics registry)
    energy_by_engine: dict = dataclasses.field(default_factory=dict)

    @property
    def edp(self) -> float:           # J*ms
        return self.energy_j * self.latency_s * 1e3

    def edap(self, area_mm2: float) -> float:
        return self.edp * area_mm2

    @property
    def comm_stall_frac(self) -> float:
        return self.comm_stall_s / self.latency_s if self.latency_s else 0.0

    @property
    def xpu_util(self) -> float:
        return self.xpu_busy_s / self.latency_s if self.latency_s else 0.0

    @property
    def xmu_util(self) -> float:
        return self.xmu_busy_s / self.latency_s if self.latency_s else 0.0

    def engine_util(self, engine: str) -> float:
        if not self.latency_s:
            return 0.0
        return self.engine_busy_s.get(engine, 0.0) / self.latency_s


def _block_engine_times(v: OpVolumes, hw: HWConfig, dnum: int,
                        evk_words_due: float) -> dict:
    ns = 1e-9
    t_ntt = v.ntt_words / hw.ntt_tput * ns
    t_bconv = v.bconv_macs / hw.bconv_tput * ns
    if hw.intt_resident:
        # BConv->NTT || NTT parallel paths: overlap NTT legs with BConv
        t_xpu_core = max(t_ntt, t_bconv) + 0.15 * min(t_ntt, t_bconv)
    elif hw.dual_overlap:
        t_xpu_core = max(t_ntt, t_bconv) + 0.3 * min(t_ntt, t_bconv)
    else:
        t_xpu_core = t_ntt + t_bconv

    if hw.memop_fusion:
        # fused IP+PMul+Autom xMU pass: permutation folds into addressing
        mem_words = v.ip_macs + v.ewo_ext_words + v.ewo_words
    else:
        mem_words = (v.ip_macs + v.ewo_ext_words + v.autom_words
                     + v.ewo_words)
    if hw.xmu_tput > 0:
        t_xpu = t_xpu_core + v.xpu_ewo_words / hw.ewe_tput * ns
        t_xmu = mem_words / hw.xmu_tput * ns
    else:
        # monolithic: MemOps on the xPU EWEU out of the scratchpad
        t_xpu = t_xpu_core + (v.xpu_ewo_words + mem_words) \
            / hw.ewe_tput * ns
        t_xmu = 0.0

    t_comm = v.comm_words * WORD_BYTES / (hw.hbm_bw_tbs * 1e12)
    t_evk = evk_words_due * WORD_BYTES / (hw.hbm_bw_tbs * 1e12)
    return {"xpu": t_xpu, "xmu": t_xmu, "comm": t_comm, "evk": t_evk,
            "dnum": dnum}


def _combine(times: dict, hw: HWConfig) -> tuple[float, float, float]:
    """-> (latency, comm_stall, mem_stall) for one block (analytic)."""
    t_xpu, t_xmu, t_comm, t_evk = (times["xpu"], times["xmu"],
                                   times["comm"], times["evk"])
    if hw.xmu_tput == 0:
        compute = t_xpu + t_xmu
        lat = max(compute, t_evk)
        return lat, 0.0, lat - compute
    if hw.dual_overlap:
        g = pipeline_groups(times["dnum"])
        parts = [t_xpu, t_xmu, t_comm, t_evk]
        bound = max(parts)
        fill = (sum(parts) - bound) / g
        lat = bound + fill
        no_comm = max(t_xpu, t_xmu, t_evk)
        no_comm += (t_xpu + t_xmu + t_evk - no_comm) / g
        no_evk = max(t_xpu, t_xmu, t_comm)
        no_evk += (t_xpu + t_xmu + t_comm - no_evk) / g
        return lat, max(0.0, lat - no_comm), max(0.0, lat - no_evk)
    # naive heterogeneous: serialized critical path (Fig. 11(b))
    lat = t_xpu + t_comm + t_xmu + t_evk
    return lat, t_comm, t_evk


@dataclasses.dataclass
class Block:
    volumes: OpVolumes
    dnum: int
    evk_keys: tuple = ()        # (key-id, words) pairs this block touches
    streams_evk: bool = False   # EVF: traffic due on first touch


def block_time(v: OpVolumes, dnum: int, hw: HWConfig,
               evk_words_due: float = 0.0,
               mode: str = "analytic") -> float:
    times = _block_engine_times(v, hw, dnum, evk_words_due)
    if mode == "pipelined":
        return scheduled_block_time(times, v, hw)
    return _combine(times, hw)[0]


def _evk_due(b: Block, cached: set, cache_words: float) -> float:
    due = 0.0
    if b.streams_evk:
        for key, words in b.evk_keys:
            if key in cached and words <= cache_words:
                continue
            due += words
            if words <= cache_words:
                cached.add(key)
    return due


def simulate_blocks(blocks: list[Block], hw: HWConfig, name: str,
                    mode: str = "pipelined") -> SimResult:
    if mode not in ("pipelined", "analytic"):
        raise ValueError(f"mode must be 'pipelined' or 'analytic', got "
                         f"{mode!r}")
    res = SimResult(name=name, mode=mode)
    cached: set = set()
    cache_words = hw.onchip_mb * 1e6 / WORD_BYTES
    block_times = []
    for b in blocks:
        due = _evk_due(b, cached, cache_words)
        t = _block_engine_times(b.volumes, hw, b.dnum, due)
        block_times.append((t, b.volumes))
        res.xpu_busy_s += t["xpu"]
        res.xmu_busy_s += t["xmu"]
        res.comm_busy_s += t["comm"]
        res.volumes = res.volumes + b.volumes
        if mode == "analytic":
            lat, cstall, mstall = _combine(t, hw)
            res.latency_s += lat
            res.comm_stall_s += cstall
            res.mem_stall_s += mstall
    if mode == "pipelined":
        sched = schedule_blocks(block_times, hw)
        res.latency_s = sched.makespan
        res.comm_stall_s = sched.comm_stall_s
        res.mem_stall_s = sched.mem_stall_s
        res.timelines = sched.timelines()
        res.engine_busy_s = {e: sched.busy(e) for e in ENGINES}
        # energy integrated over the placed per-engine busy intervals
        res.energy_by_engine = sched.energy_breakdown(hw)
        res.energy_j = sum(res.energy_by_engine.values())
        return res
    link_bytes = (res.volumes.comm_words + res.volumes.evk_load_words) \
        * WORD_BYTES
    # analytic mode: busy-time dynamic power + 10% static floor
    res.energy_j = (
        hw.power_xpu_w * (res.xpu_busy_s + 0.10 * res.latency_s)
        + hw.power_xmu_w * (res.xmu_busy_s + 0.10 * res.latency_s)
        + link_bytes * hw.link_pj_per_byte * 1e-12
    )
    return res


def _evk_keys_for(pkb: PKB, strategy: str, k: int, alpha: int, nh: int):
    """Distinct evk identities a block touches (for the EVF cache)."""
    from repro.dfg.hoist import evk_words

    l = pkb.limbs
    w = evk_words(l, k, alpha, pkb.dfg.N)
    if strategy == "minks":
        bits = set()
        for s in pkb.steps:
            s = s % nh
            bits |= {i for i in range(max(s.bit_length(), 1)) if s >> i & 1}
        return tuple((("rot2", b, l), w) for b in (bits or {0}))
    return tuple((("rot", s, l), w) for s in set(pkb.steps))


def simulate_program(dfg, hw: HWConfig, strategy: str = "hoist",
                     dataflow: str = "hybrid", fusion: bool = False,
                     nh: int = 1 << 15, k: int = 12, alpha: int = 12,
                     name: str | None = None,
                     mode: str = "pipelined") -> SimResult:
    """strategy: 'minks' | 'plain' | 'hoist'; dataflow 'IRF'|'EVF'|'hybrid'.
    fusion=True applies the HERO DP (scored with THIS hw's pipeline model)
    before mapping.  mode: 'pipelined' (event-driven group scheduler) or
    'analytic' (closed-form per-block combiner, serial block sum)."""
    pkbs = identify_pkbs(dfg)
    weights = _pipeline_weights(hw, mode)
    if fusion:
        plan = optimal_fusion(
            pkbs, k, alpha, nh, capacity_words=hw.evk_capacity_words(),
            weights=weights, dataflow="IRF",
        )
        pkbs = plan.fused
    df_mode = dataflow
    if dataflow == "hybrid" and hw.onchip_mb < 60:
        df_mode = "IRF"      # SM cannot buffer an evk on-chip
    mapped = map_program(pkbs, k, alpha, nh, mode=df_mode,
                         strategy=strategy, weights=weights)
    blocks = []
    for m in mapped:
        streams = m.dataflow == "EVF"
        blocks.append(Block(
            m.volumes, m.pkb.dnum,
            _evk_keys_for(m.pkb, strategy, k, alpha, nh) if streams else (),
            streams,
        ))
    extra, residual = non_pkb_blocks(
        dfg, pkbs, k, alpha,
        dataflow=("IRF" if df_mode == "IRF" else "EVF"),
    )
    for v in extra:
        # relin/conj keys are shared program-wide; identity by size
        key = (("relin", v.evk_set_words), v.evk_set_words)
        # relin/conj blocks stream the 2*dnum group pipeline like every
        # other keyswitch; the real dnum is the ModUp leg count (one leg
        # per decomposition digit), so their xPU up-phase slices carry
        # per-digit weights instead of one undifferentiated volume lump
        dnum = len(v.modup_legs) if v.modup_legs else v.ip_count
        blocks.append(Block(v, max(1, dnum), (key,),
                            df_mode != "IRF"))
    blocks.append(Block(residual, 1))
    return simulate_blocks(
        blocks, hw,
        name or f"{hw.name}/{strategy}/{dataflow}"
        + ("/fused" if fusion else ""),
        mode=mode,
    )


def _pipeline_weights(hw: HWConfig, mode: str = "pipelined") -> CostWeights:
    """CostWeights whose block cost delegates to the hw pipeline model —
    so the fusion DP and the hybrid dataflow choice optimize what the
    simulator measures (the scheduled group-pipeline makespan under
    mode='pipelined', the closed-form block time under 'analytic')."""

    class _W(CostWeights):
        def block_seconds(self, v: OpVolumes) -> float:  # noqa: D102
            dnum = max(1, round(v.modup_count or 1))
            return block_time(v, dnum, hw,
                              v.evk_load_words and v.evk_set_words or 0.0,
                              mode=mode)

        def seconds(self, v: OpVolumes) -> float:  # type: ignore[override]
            return self.block_seconds(v)

    return _W()
