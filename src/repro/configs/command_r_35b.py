"""command-r-35b [dense]: 40L d=8192 64H (kv=8) ff=22528 v=256000.
GQA, no-bias.  [hf:CohereForAI/c4ai-command-r-v01; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b", family="dense", n_layers=40, d_model=8192,
    n_heads=64, n_kv_heads=8, d_ff=22528, vocab=256000,
    bias=False, fsdp=True,
)

REDUCED = ModelConfig(
    name="command-r-35b-smoke", family="dense", n_layers=2, d_model=64,
    n_heads=8, n_kv_heads=2, d_ff=160, vocab=512,
)
