"""Assigned input shapes and per-arch applicability.

  train_4k     seq 4096,   global_batch 256   (training)
  prefill_32k  seq 32768,  global_batch 32    (inference prefill)
  decode_32k   seq 32768,  global_batch 128   (decode: 1 new token, KV=32k)
  long_500k    seq 524288, global_batch 1     (long-context decode)

long_500k requires sub-quadratic attention: only the hybrid (jamba: Mamba
state + sliding-window attention) and SSM (xlstm: recurrent state) archs
run it; the eight pure full-attention archs skip it (DESIGN.md
§Arch-applicability).  Encoder-only archs would skip decode shapes; none
were assigned (whisper is enc-dec and keeps a decoder KV cache).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

SUBQUADRATIC = {"jamba_1_5_large_398b", "xlstm_1_3b"}


def shapes_for(arch: str) -> list[str]:
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if arch in SUBQUADRATIC:
        out.append("long_500k")
    return out


def skipped_shapes(arch: str) -> list[str]:
    return [] if arch in SUBQUADRATIC else ["long_500k"]


# Reduced shapes for CPU smoke tests.
SMOKE_SHAPES = {
    "train": ShapeSpec("smoke_train", 32, 4, "train"),
    "decode": ShapeSpec("smoke_decode", 64, 2, "decode"),
}
