"""moonshot-v1-16b-a3b [moe]: 48L d=2048 16H expert-ff=1408 v=163840,
64 experts top-6 (kimi/moonlight).  [hf:moonshotai/Moonlight-16B-A3B; hf]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe", n_layers=48, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=1408, vocab=163840,
    moe=MoEConfig(n_experts=64, top_k=6, d_expert_ff=1408),
)

REDUCED = ModelConfig(
    name="moonshot-v1-16b-a3b-smoke", family="moe", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=96, vocab=512,
    moe=MoEConfig(n_experts=8, top_k=2, d_expert_ff=96),
)
