"""arctic-480b [moe]: 35L d=7168 56H (kv=8) expert-ff=4864 v=32000,
128 experts top-2 + dense residual FFN.  [hf:Snowflake/snowflake-arctic-base; hf]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe", n_layers=35, d_model=7168,
    n_heads=56, n_kv_heads=8, d_ff=4864, vocab=32000,
    moe=MoEConfig(n_experts=128, top_k=2, d_expert_ff=4864,
                  dense_residual_ff=4864),
    fsdp=True, optimizer_state_dtype="bfloat16",
)

REDUCED = ModelConfig(
    name="arctic-480b-smoke", family="moe", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, d_ff=96, vocab=512,
    moe=MoEConfig(n_experts=8, top_k=2, d_expert_ff=96,
                  dense_residual_ff=96),
)
