"""qwen2-vl-2b [vlm]: 28L d=1536 12H (kv=2) ff=8960 v=151936.
M-RoPE (3-section temporal/height/width), dynamic-resolution vision
frontend is a STUB (input_specs provides patch embeddings).
[arXiv:2409.12191; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b", family="vlm", n_layers=28, d_model=1536,
    n_heads=12, n_kv_heads=2, d_ff=8960, vocab=151936,
    pos="mrope", frontend="vision", bias=True,
)

REDUCED = ModelConfig(
    name="qwen2-vl-2b-smoke", family="vlm", n_layers=2, d_model=48,
    n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
    pos="mrope", frontend="vision", bias=True,
)
