"""Model configuration schema shared by all 10 assigned architectures."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert_ff: int
    dense_residual_ff: int = 0   # arctic: parallel dense FFN
    every: int = 1               # MoE layer cadence (jamba: 2)
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_dim: int = 64
    qk_rope_dim: int = 32
    v_head_dim: int = 64


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | hybrid | vlm | ssm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    attn: str = "gqa"            # gqa | mla | none
    mla: MLAConfig | None = None
    moe: MoEConfig | None = None
    pos: str = "rope"            # rope | mrope | learned | none
    rope_pct: float = 1.0        # partial rotary (stablelm: 0.25)
    rope_theta: float = 10000.0
    mlp: str = "swiglu"          # swiglu | gelu
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    bias: bool = False
    # hybrid (jamba): one attention layer per `attn_every`, mamba otherwise
    attn_every: int = 0
    mamba_d_state: int = 16
    mamba_expand: int = 2
    mamba_d_conv: int = 4
    # xLSTM: one sLSTM per `slstm_every` blocks, mLSTM otherwise
    slstm_every: int = 0
    # encoder-decoder (whisper)
    enc_dec: bool = False
    n_enc_layers: int = 0
    frontend: str = "none"       # vision | audio | none — STUB embeddings
    tie_embeddings: bool = True
    sliding_window: int = 0      # long-context attention window (hybrid)
    dtype: str = "bfloat16"
    # distribution hints
    fsdp: bool = False           # shard params over the data axis too
    optimizer_state_dtype: str = "float32"  # bf16 for >=100B models
    # perf-iteration knobs (EXPERIMENTS.md §Perf)
    ce_impl: str = "gather"      # gather (logsumexp) | softmax (full array)
    expert_shard: str = "dmodel"  # FSDP axis on experts: dmodel | ff

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def n_params(self) -> int:
        """Approximate parameter count (for MODEL_FLOPS accounting)."""
        d, L = self.d_model, self.n_layers
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        per = 0
        for i in range(L):
            is_attn = (self.attn_every == 0 or
                       (i % self.attn_every == self.attn_every - 1))
            if self.family == "ssm":
                di = self.mamba_expand * d
                per += 2 * d * 2 * di + 2 * di * d  # up/gate + mlstm + down
                continue
            if is_attn and self.attn != "none":
                if self.attn == "mla" and self.mla:
                    m = self.mla
                    per += d * m.q_lora_rank + m.q_lora_rank * self.n_heads \
                        * (m.qk_nope_dim + m.qk_rope_dim)
                    per += d * (m.kv_lora_rank + m.qk_rope_dim)
                    per += m.kv_lora_rank * self.n_heads \
                        * (m.qk_nope_dim + m.v_head_dim)
                    per += self.n_heads * m.v_head_dim * d
                else:
                    per += d * self.hd * (self.n_heads + 2 * self.n_kv_heads)
                    per += self.n_heads * self.hd * d
            elif self.attn_every:
                di = self.mamba_expand * d
                per += d * 2 * di + di * d + di * self.mamba_d_state * 2
            if self.moe and (i % self.moe.every == 0):
                per += self.moe.n_experts * 3 * d * self.moe.d_expert_ff
                per += self.moe.n_experts * d  # router
                if self.moe.dense_residual_ff:
                    per += 3 * d * self.moe.dense_residual_ff
            elif self.d_ff:
                mult = 3 if self.mlp == "swiglu" else 2
                per += mult * d * self.d_ff
        enc = 0
        if self.enc_dec:
            enc = self.n_enc_layers * (
                4 * d * d + (3 if self.mlp == "swiglu" else 2) * d * self.d_ff
            ) + L * 4 * d * d  # cross-attention in decoder
        return emb + per + enc


def n_active_params(cfg: ModelConfig) -> int:
    """Active (per-token) params for MoE — drives 6*N_active*D."""
    if not cfg.moe:
        return cfg.n_params()
    full = cfg.n_params()
    moe_layers = sum(1 for i in range(cfg.n_layers)
                     if i % cfg.moe.every == 0)
    expert_params = moe_layers * cfg.moe.n_experts * 3 * cfg.d_model \
        * cfg.moe.d_expert_ff
    active_expert = moe_layers * cfg.moe.top_k * 3 * cfg.d_model \
        * cfg.moe.d_expert_ff
    return full - expert_params + active_expert
