"""minicpm3-4b [dense+MLA]: 62L d=2560 40H ff=6400 v=73448.
Multi-head Latent Attention (q_lora 768, kv_lora 256, nope 64, rope 32).
[hf:openbmb/MiniCPM3-4B; hf]"""
from repro.configs.base import MLAConfig, ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b", family="dense", n_layers=62, d_model=2560,
    n_heads=40, n_kv_heads=40, d_ff=6400, vocab=73448,
    attn="mla", mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256,
                              qk_nope_dim=64, qk_rope_dim=32, v_head_dim=64),
)

REDUCED = ModelConfig(
    name="minicpm3-4b-smoke", family="dense", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=160, vocab=512,
    attn="mla", mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                              qk_nope_dim=8, qk_rope_dim=8, v_head_dim=8),
)
