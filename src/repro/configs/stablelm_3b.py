"""stablelm-3b [dense]: 32L d=2560 32H (kv=32, MHA) ff=6912 v=50304.
Partial rotary (25%) per the StableLM-2 family.
[hf:stabilityai/stablelm-2-1_6b; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b", family="dense", n_layers=32, d_model=2560,
    n_heads=32, n_kv_heads=32, d_ff=6912, vocab=50304,
    pos="rope", rope_pct=0.25, mlp="swiglu", norm="layernorm", bias=True,
)

REDUCED = ModelConfig(
    name="stablelm-3b-smoke", family="dense", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=160, vocab=512,
    pos="rope", rope_pct=0.25, mlp="swiglu", norm="layernorm", bias=True,
)
