"""jamba-1.5-large-398b [hybrid]: 72L d=8192 64H (kv=8) ff=24576 v=65536,
Mamba+attention 1:7 interleave, MoE 16e top-2 every other layer.
[arXiv:2403.19887; hf]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid", n_layers=72, d_model=8192,
    n_heads=64, n_kv_heads=8, d_ff=24576, vocab=65536,
    moe=MoEConfig(n_experts=16, top_k=2, d_expert_ff=24576, every=2),
    attn_every=8, mamba_d_state=16, mamba_expand=2, mamba_d_conv=4,
    sliding_window=4096,   # long_500k: attention layers use SWA
    fsdp=True, optimizer_state_dtype="bfloat16",
)

REDUCED = ModelConfig(
    name="jamba-1.5-large-398b-smoke", family="hybrid", n_layers=4,
    d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
    moe=MoEConfig(n_experts=4, top_k=2, d_expert_ff=128, every=2),
    attn_every=4, mamba_d_state=8, mamba_expand=2, mamba_d_conv=4,
    sliding_window=64,
)
