"""xlstm-1.3b [ssm]: 48L d=2048 4H v=50304, d_ff=0 (projection blocks).
mLSTM blocks (chunkwise-parallel matrix memory) with one sLSTM block per 8.
[arXiv:2405.04517; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm", n_layers=48, d_model=2048,
    n_heads=4, n_kv_heads=4, d_ff=0, vocab=50304,
    attn="none", pos="none", slstm_every=8, mamba_expand=2,
)

REDUCED = ModelConfig(
    name="xlstm-1.3b-smoke", family="ssm", n_layers=2, d_model=64,
    n_heads=2, n_kv_heads=2, d_ff=0, vocab=512,
    attn="none", pos="none", slstm_every=2, mamba_expand=2,
)
