"""whisper-base [audio]: 6L enc + 6L dec, d=512 8H ff=2048 v=51865.
Enc-dec; conv audio frontend is a STUB (input_specs provides mel-frame
embeddings).  [arXiv:2212.04356; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="audio", n_layers=6, d_model=512,
    n_heads=8, n_kv_heads=8, d_ff=2048, vocab=51865,
    enc_dec=True, n_enc_layers=6, frontend="audio",
    pos="learned", mlp="gelu", norm="layernorm", bias=True,
)

REDUCED = ModelConfig(
    name="whisper-base-smoke", family="audio", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, d_ff=128, vocab=512,
    enc_dec=True, n_enc_layers=2, frontend="audio",
    pos="learned", mlp="gelu", norm="layernorm", bias=True,
)
