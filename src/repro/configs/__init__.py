"""Architecture config registry: one module per assigned architecture."""
from repro.configs.base import ModelConfig, MoEConfig, MLAConfig  # noqa: F401


def get_config(arch: str):
    import importlib

    mod = importlib.import_module(f"repro.configs.{arch.replace('-', '_')}")
    return mod.CONFIG


def reduced_config(arch: str):
    import importlib

    mod = importlib.import_module(f"repro.configs.{arch.replace('-', '_')}")
    return mod.REDUCED


ARCHS = [
    "stablelm_3b", "minicpm3_4b", "phi3_medium_14b", "command_r_35b",
    "arctic_480b", "moonshot_v1_16b_a3b", "jamba_1_5_large_398b",
    "qwen2_vl_2b", "xlstm_1_3b", "whisper_base",
]
