"""Homomorphic linear transforms: diagonal method + BSGS.

y = A @ z for an (nh x nh) complex matrix A is computed as
    y = sum_d diag_d(A) * rot_d(z)
over the non-zero generalized diagonals d.  BSGS splits d = i*bs + j
(Eq. (3) of the paper) — exactly the two-serial-PKB structure HERO fuses.
Both paths use the hoisted rotation-sum primitive (one ModUp per block).

Both functions only touch the context's public op API, so they run
eagerly on a ``CKKSContext`` or trace through the compiled runtime's
``repro.runtime.compile.TraceContext`` unchanged — the compiled path
additionally shares one ModUp across all baby-step blocks and, with
``fusion=True``, collapses baby x giant into a single hoisted block.
"""
from __future__ import annotations

import numpy as np

from repro.core.ckks import CKKSContext, Ciphertext


def matrix_diagonals(A: np.ndarray, tol: float = 1e-12) -> dict[int, np.ndarray]:
    """Generalized diagonals diag_d[i] = A[i, (i+d) mod nh], nonzero only."""
    nh = A.shape[0]
    out = {}
    for d in range(nh):
        diag = np.array([A[i, (i + d) % nh] for i in range(nh)])
        if np.abs(diag).max() > tol:
            out[d] = diag
    return out


def matvec_diag(ctx: CKKSContext, ct: Ciphertext,
                diags: dict[int, np.ndarray], rescale: bool = True) -> Ciphertext:
    """Single-PKB evaluation: one hoisted block over all diagonals."""
    steps = sorted(diags)
    pts = [ctx.encode(diags[d], level=ct.level) for d in steps]
    return ctx.hoisted_rotation_sum(ct, steps, pts, rescale=rescale)


def matvec_bsgs(ctx: CKKSContext, ct: Ciphertext,
                diags: dict[int, np.ndarray], bs: int,
                rescale: bool = True) -> Ciphertext:
    """BSGS evaluation: baby-step PKB (bs rotations, hoisted) feeding a
    giant-step PKB (<=gs rotations, hoisted).

    y = sum_i rot_{i*bs}( sum_j rot_{-i*bs}(diag_{i*bs+j}) * rot_j(z) )
    """
    nh = ctx.params.num_slots
    groups: dict[int, dict[int, np.ndarray]] = {}
    for d, v in diags.items():
        groups.setdefault(d // bs, {})[d % bs] = v

    inner_cts: list[Ciphertext] = []
    giant_steps: list[int] = []
    for i, inner in sorted(groups.items()):
        steps = sorted(inner)
        pts = [
            ctx.encode(np.roll(inner[j], i * bs), level=ct.level)
            for j in steps
        ]
        # Baby-step PKB: shared ModUp across the j-rotations of this group.
        inner_cts.append(
            ctx.hoisted_rotation_sum(ct, steps, pts, rescale=False)
        )
        giant_steps.append((i * bs) % nh)

    # Giant-step PKB: rotate each combined result once and sum.
    out = None
    for g, ict in zip(giant_steps, inner_cts):
        rot = ctx.rotate(ict, g)
        out = rot if out is None else ctx.add(out, rot)
    return ctx.rescale(out) if rescale else out


def matvec_plain(A: np.ndarray, z: np.ndarray) -> np.ndarray:
    return A @ z
