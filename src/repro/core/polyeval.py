"""Homomorphic polynomial evaluation in the Chebyshev basis.

Used by EvalMod in bootstrapping (scaled-sine approximation) and by HELR
(sigmoid).  Chebyshev recurrences keep coefficients O(1) on [-1, 1]
(power-basis coefficients of sine approximants blow up exponentially).

Scale management: every ciphertext carries an exact float scale; all
cross-term additions go through ``align`` which mod-switches and
scale-corrects via a constant multiplication.

All helpers take the context as a parameter and only use its public op
API (encode/pt_mul/multiply/double/level_down/...), so they run
unchanged against either the functional ``CKKSContext`` or the
runtime's symbolic ``repro.runtime.compile.TraceContext`` — the same
source compiles through the DFG runtime and executes eagerly.  The
compiled bootstrap (``core.bootstrap.Bootstrapper.compile``) traces the
two EvalMod Chebyshev branches through here; every ``mul_const`` /
``align`` scale decision is recorded on the nodes and replayed by the
executor, which is what keeps that pipeline bit-exact end to end.
"""
from __future__ import annotations

import math

import numpy as np

from repro.core.ckks import CKKSContext, Ciphertext


def mul_const(ctx: CKKSContext, ct: Ciphertext, c: complex,
              target_scale: float) -> Ciphertext:
    """ct * c with the product's post-rescale scale forced to target_scale."""
    q_last = ctx.chain(ct.level)[-1]
    pt_scale = target_scale * q_last / ct.scale
    pt = ctx.encode(
        np.full(ctx.params.num_slots, complex(c)),
        level=ct.level, scale=pt_scale,
    )
    out = ctx.pt_mul(ct, pt, rescale=True)
    out.scale = target_scale  # exact by construction
    return out


def add_const(ctx: CKKSContext, ct: Ciphertext, c: complex) -> Ciphertext:
    pt = ctx.encode(
        np.full(ctx.params.num_slots, complex(c)),
        level=ct.level, scale=ct.scale,
    )
    return ctx.pt_add(ct, pt)


def align(ctx: CKKSContext, ct: Ciphertext, level: int,
          scale: float) -> Ciphertext:
    """Bring ct to (level, scale): mod-switch down + constant-mul fixup."""
    assert level <= ct.level
    if abs(ct.scale / scale - 1.0) < 1e-12:
        return ctx.level_down(ct, level)
    if level == ct.level:
        # need a scale fix but no level to burn — multiply and land lower
        raise ValueError("cannot fix scale without a spare level")
    ct = ctx.level_down(ct, level + 1)
    return ctx.level_down(mul_const(ctx, ct, 1.0, scale), level)


class ChebyshevEvaluator:
    """Builds T_k(x) ciphertexts on demand and combines them."""

    def __init__(self, ctx: CKKSContext, ct_x: Ciphertext):
        self.ctx = ctx
        self.ct = ct_x
        self.T: dict[int, Ciphertext] = {1: ct_x}

    def get(self, k: int) -> Ciphertext:
        if k in self.T:
            return self.T[k]
        ctx = self.ctx
        if k % 2 == 0:
            half = self.get(k // 2)
            sq = ctx.multiply(half, half, rescale=True)
            out = add_const(ctx, ctx.double(sq), -1.0)
        else:
            a, b = (k + 1) // 2, (k - 1) // 2
            ta, tb = self.get(a), self.get(b)
            lvl = min(ta.level, tb.level)
            if abs(ta.scale / tb.scale - 1.0) > 1e-9:
                lvl -= 1
                scale = ctx.params.scale
                ta = align(ctx, ta, lvl, scale)
                tb = align(ctx, tb, lvl, scale)
            else:
                ta, tb = ctx.level_down(ta, lvl), ctx.level_down(tb, lvl)
            prod = ctx.multiply(ta, tb, rescale=True)
            prod2 = ctx.double(prod)
            # T_a*T_b*2 - T_{a-b};  a-b == 1 here.
            t1 = self.get(1)
            t1a = align(ctx, t1, prod2.level, prod2.scale)
            out = ctx.sub(prod2, t1a)
        self.T[k] = out
        return out


def eval_chebyshev(ctx: CKKSContext, ct: Ciphertext,
                   coeffs: np.ndarray, tol: float = 1e-13,
                   ev: ChebyshevEvaluator | None = None) -> Ciphertext:
    """sum_k coeffs[k] * T_k(ct) for x in [-1, 1].

    ``ev``: a shared :class:`ChebyshevEvaluator` whose T_k cache is
    reused (and extended) instead of rebuilding the basis — the BSGS
    evaluation routes its sub-polynomials through here.
    """
    d = len(coeffs) - 1
    if ev is None:
        ev = ChebyshevEvaluator(ctx, ct)
    needed = [k for k in range(1, d + 1) if abs(coeffs[k]) > tol]
    for k in needed:
        ev.get(k)
    min_lvl = min(ev.T[k].level for k in needed) - 1
    target_scale = ctx.params.scale
    acc = None
    for k in needed:
        tk = ev.T[k]
        tk = ctx.level_down(tk, min_lvl + 1)
        term = mul_const(ctx, tk, complex(coeffs[k]), target_scale)
        term = ctx.level_down(term, min_lvl)
        acc = term if acc is None else ctx.add(acc, term)
    return add_const(ctx, acc, complex(coeffs[0]))


# ---------------------- BSGS (Paterson-Stockmeyer) -----------------------

def _trim_degree(c, tol: float) -> int:
    d = len(c) - 1
    while d > 0 and abs(c[d]) <= tol:
        d -= 1
    return d


def cheb_divmod(c: np.ndarray, g: int) -> tuple[np.ndarray, np.ndarray]:
    """Chebyshev-basis division: c = q * T_g + r with deg r < g.

    Uses 2*T_g*T_i = T_{g+i} + T_{g-i}: q_0 = c_g, q_i = 2*c_{g+i}, and
    r_{g-i} = c_{g-i} - c_{g+i}.  Requires deg(c) <= 2g (guaranteed when
    g is the largest power-of-two giant step below deg(c))."""
    d = len(c) - 1
    assert g <= d <= 2 * g, (d, g)
    q = np.zeros(d - g + 1, dtype=complex)
    r = np.array(c[:g], dtype=complex)
    q[0] = c[g]
    for i in range(1, d - g + 1):
        q[i] = 2 * c[g + i]
        r[g - i] -= c[g + i]
    return q, r


def eval_chebyshev_bsgs(ctx: CKKSContext, ct: Ciphertext,
                        coeffs: np.ndarray, bs: int | None = None,
                        tol: float = 1e-13) -> Ciphertext:
    """sum_k coeffs[k] * T_k(ct) via baby-step/giant-step products.

    Paterson-Stockmeyer in the Chebyshev basis: only T_1..T_bs and the
    giant steps T_{2^j * bs} are materialized (``bs`` defaults to the
    power of two nearest sqrt(deg)); the polynomial is peeled into
    quotient/remainder chains by :func:`cheb_divmod`, so the evaluation
    becomes a SUM of giant-step products q_i(x) * T_{g_i}(x) — O(sqrt d)
    CMults instead of the O(d) of the dense T_k recurrence.

    Every product of one closure is built at a common level WITHOUT
    rescaling (scales pinned to scale^2 exactly), summed, and closed by
    ONE rescale: traced through the compiled runtime this is a
    sum-of-CMult closure, which ``runtime.lower`` turns into a
    ``MultiRelinStep`` — all relin IPs accumulate in the extended basis
    and ONE ModDown closes the block (``exact=False``).
    """
    d = _trim_degree(coeffs, tol)
    if bs is None:
        bs = 1 << max(1, round(math.log2(math.sqrt(d + 1))))
    if d < max(bs, 2) or d < 4:
        return eval_chebyshev(ctx, ct, coeffs[: d + 1], tol=tol)
    ev = ChebyshevEvaluator(ctx, ct)
    g_top = bs
    while g_top * 2 <= d:
        g_top *= 2
    for g in [bs << j for j in range((g_top // bs).bit_length())]:
        ev.get(g)                     # giants built shallow-first
    return _ps_eval(ctx, ev, np.asarray(coeffs[: d + 1], dtype=complex),
                    bs, tol)


def _ps_eval(ctx: CKKSContext, ev: ChebyshevEvaluator, c: np.ndarray,
             bs: int, tol: float) -> Ciphertext:
    """One recursion level of the BSGS evaluation: peel giant-step
    products off ``c``, evaluate the quotients (recursively), and close
    products + remainder terms with a single rescale."""
    d = _trim_degree(c, tol)
    if d < bs:
        return eval_chebyshev(ctx, ev.ct, c[: d + 1], tol=tol, ev=ev)

    prods: list[tuple[np.ndarray, int]] = []
    rem = np.array(c[: d + 1], dtype=complex)
    while _trim_degree(rem, tol) >= bs:
        dr = _trim_degree(rem, tol)
        g = bs
        while g * 2 <= dr:
            g *= 2
        q, rem = cheb_divmod(rem[: dr + 1], g)
        prods.append((q, g))

    # constant quotients need no CMult — they are plain pt-mul terms
    pairs: list[tuple[Ciphertext, int]] = []
    direct: list[tuple[complex, int]] = []
    for q, g in prods:
        if _trim_degree(q, tol) == 0:
            if abs(q[0]) > tol:
                direct.append((complex(q[0]), g))
            continue
        pairs.append((_ps_eval(ctx, ev, q, bs, tol), g))
    direct += [(complex(rem[b]), b)
               for b in range(1, _trim_degree(rem, tol) + 1)
               if abs(rem[b]) > tol]
    # one closure: every product CMult and pt-mul passthrough lands at
    # the same level and the exact scale^2, summed, then ONE rescale
    S = ctx.params.scale
    P = S * S
    lvls = [min(qe.level - 1, ev.get(g).level) for qe, g in pairs]
    lvls += [ev.get(k).level for _, k in direct]
    lvl = min(lvls)
    nh = ctx.params.num_slots
    acc = None
    for qe, g in pairs:
        tg = ctx.level_down(ev.get(g), lvl)
        qel = align(ctx, qe, lvl, P / tg.scale)
        prod = ctx.multiply(qel, tg, rescale=False)
        prod.scale = P                # exact by construction
        acc = prod if acc is None else ctx.add(acc, prod)
    for coef, k in direct:
        tk = ctx.level_down(ev.get(k), lvl)
        pt = ctx.encode(np.full(nh, complex(coef)), level=lvl,
                        scale=P / tk.scale)
        term = ctx.pt_mul(tk, pt, rescale=False)
        term.scale = P
        acc = term if acc is None else ctx.add(acc, term)
    out = ctx.rescale(acc)
    if abs(rem[0]) > tol:
        out = add_const(ctx, out, complex(rem[0]))
    return out


def eval_poly_horner(ctx: CKKSContext, ct: Ciphertext,
                     coeffs: np.ndarray) -> Ciphertext:
    """Power-basis Horner — for short, well-conditioned polynomials
    (e.g. HELR's degree-3/5/7 sigmoid).  acc <- acc*x + c_k."""
    acc = None
    for c in coeffs[::-1]:
        if acc is None:
            acc = ("const", complex(c))
            continue
        if isinstance(acc, tuple):
            acc = mul_const(ctx, ct, acc[1], ctx.params.scale)
        else:
            lvl = min(acc.level, ct.level)
            if acc.level != lvl or abs(acc.scale - ctx.params.scale) > 1e-9:
                acc = align(ctx, acc, lvl - 1, ctx.params.scale)
                lvl -= 1
            acc = ctx.multiply(acc, ctx.level_down(ct, lvl), rescale=True)
        acc = add_const(ctx, acc, complex(c))
    return acc


def chebyshev_coeffs(fn, degree: int):
    """Chebyshev interpolation of fn on [-1, 1]."""
    k = np.arange(degree + 1)
    x = np.cos(np.pi * (k + 0.5) / (degree + 1))
    return np.polynomial.chebyshev.chebfit(x, fn(x), degree)
