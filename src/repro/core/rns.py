"""RNS precomputed tables: NTT twiddles, basis-conversion constants,
automorphism permutations.

Tables are built once per parameter set with exact Python integers and
stored as numpy uint64 arrays; ``repro.core.poly`` lifts them to jnp.
"""
from __future__ import annotations

from functools import lru_cache

import numpy as np

from repro.core import nt
from repro.core.params import CKKSParams


class PrimeTables:
    """Per-prime negacyclic NTT tables (DIT, bit-reversed input)."""

    def __init__(self, p: int, logn: int):
        self.p = p
        self.logn = logn
        n = 1 << logn
        self.n = n
        psi = nt.root_of_unity(2 * n, p)       # 2n-th root: negacyclic twist
        omega = psi * psi % p                  # n-th root for the cyclic NTT
        self.psi = psi
        self.omega = omega
        self.n_inv = nt.modinv(n, p)

        idx = np.arange(n, dtype=object)
        self.psi_pows = np.array(
            [pow(psi, int(i), p) for i in idx], dtype=np.uint64
        )
        psi_inv = nt.modinv(psi, p)
        self.psi_inv_pows = np.array(
            [pow(psi_inv, int(i), p) for i in idx], dtype=np.uint64
        )
        self.bitrev = np.array(nt.bit_reverse_indices(n), dtype=np.int64)

        omega_inv = nt.modinv(omega, p)
        # Stage s (s = 0..logn-1) has 2^s twiddles w^(n >> (s+1) * j).
        self.stage_tw = [
            np.array(
                [pow(omega, (n >> (s + 1)) * j, p) for j in range(1 << s)],
                dtype=np.uint64,
            )
            for s in range(logn)
        ]
        self.stage_tw_inv = [
            np.array(
                [pow(omega_inv, (n >> (s + 1)) * j, p) for j in range(1 << s)],
                dtype=np.uint64,
            )
            for s in range(logn)
        ]


def ntt_ref(a: np.ndarray, t: PrimeTables) -> np.ndarray:
    """Reference negacyclic forward NTT (numpy uint64, exact)."""
    p = np.uint64(t.p)
    x = (a.astype(np.uint64) * t.psi_pows) % p
    x = x[t.bitrev]
    n = t.n
    for s in range(t.logn):
        m = 1 << s
        x = x.reshape(n // (2 * m), 2 * m)
        u = x[:, :m]
        v = (x[:, m:] * t.stage_tw[s][None, :]) % p
        x = np.concatenate([(u + v) % p, (u + p - v) % p], axis=1)
    return x.reshape(n)


def intt_ref(a: np.ndarray, t: PrimeTables) -> np.ndarray:
    """Reference negacyclic inverse NTT."""
    p = np.uint64(t.p)
    x = a.astype(np.uint64)[t.bitrev]
    n = t.n
    for s in range(t.logn):
        m = 1 << s
        x = x.reshape(n // (2 * m), 2 * m)
        u = x[:, :m]
        v = (x[:, m:] * t.stage_tw_inv[s][None, :]) % p
        x = np.concatenate([(u + v) % p, (u + p - v) % p], axis=1)
    x = x.reshape(n)
    x = (x * np.uint64(t.n_inv)) % p
    return (x * t.psi_inv_pows) % p


class RNSContext:
    """All tables for a CKKSParams instance, stacked per-limb for jnp use."""

    def __init__(self, params: CKKSParams):
        self.params = params
        self.all_primes: tuple[int, ...] = params.q_primes + params.p_primes
        self.prime_index = {p: i for i, p in enumerate(self.all_primes)}
        self.tables = [PrimeTables(p, params.logN) for p in self.all_primes]
        self.moduli = np.array(self.all_primes, dtype=np.uint64)

        logn, n = params.logN, params.N
        n_limbs = len(self.all_primes)
        self.psi_pows = np.stack([t.psi_pows for t in self.tables])
        self.psi_inv_pows = np.stack([t.psi_inv_pows for t in self.tables])
        self.n_inv = np.array([t.n_inv for t in self.tables], dtype=np.uint64)
        self.bitrev = self.tables[0].bitrev  # same for all primes
        # stage_tw[s]: (n_limbs, 2^s)
        self.stage_tw = [
            np.stack([t.stage_tw[s] for t in self.tables]) for s in range(logn)
        ]
        self.stage_tw_inv = [
            np.stack([t.stage_tw_inv[s] for t in self.tables])
            for s in range(logn)
        ]
        assert self.psi_pows.shape == (n_limbs, n)

    def limb_ids(self, primes: tuple[int, ...]) -> np.ndarray:
        return np.array([self.prime_index[p] for p in primes], dtype=np.int64)

    # ---------------- basis conversion constants ----------------------
    @lru_cache(maxsize=None)
    def bconv_consts(
        self, src: tuple[int, ...], dst: tuple[int, ...]
    ) -> tuple[np.ndarray, np.ndarray]:
        """Fast-basis-conversion constants src -> dst.

        Returns (qhat_inv_mod_src[i], qhat_mod_dst[i, j]) with
        qhat_i = prod(src)/src_i.  FBC: y_j = sum_i [x_i * qhat_inv_i]_{s_i}
        * (qhat_i mod d_j) mod d_j (approximate: off by a small multiple of
        prod(src), absorbed by ModDown rounding / scheme noise).
        """
        prod = 1
        for s in src:
            prod *= s
        qhat_inv = np.array(
            [nt.modinv(prod // s, s) for s in src], dtype=np.uint64
        )
        qhat_mod = np.array(
            [[(prod // s) % d for d in dst] for s in src], dtype=np.uint64
        )
        return qhat_inv, qhat_mod

    @lru_cache(maxsize=None)
    def p_inv_mod_q(self, level: int) -> np.ndarray:
        """P^{-1} mod q_i for ModDown at ``level``."""
        P = self.params.P
        return np.array(
            [nt.modinv(P, q) for q in self.params.q_chain(level)],
            dtype=np.uint64,
        )

    @lru_cache(maxsize=None)
    def q_last_inv(self, level: int) -> np.ndarray:
        """q_level^{-1} mod q_i (i < level) for rescale."""
        chain = self.params.q_chain(level)
        q_last = chain[-1]
        return np.array(
            [nt.modinv(q_last, q) for q in chain[:-1]], dtype=np.uint64
        )

    # ---------------- automorphism tables ------------------------------
    @lru_cache(maxsize=None)
    def autom_tables(self, galois: int) -> tuple[np.ndarray, np.ndarray]:
        """Gather indices + sign for b(X) = a(X^galois) in coeff domain.

        b[j] = sign[j] * a[src[j]]  (sign encoded as 0 -> +, 1 -> negate).
        """
        n = self.params.N
        two_n = 2 * n
        kinv = nt.modinv(galois, two_n)
        j = np.arange(n, dtype=np.int64)
        i0 = (j * kinv) % two_n
        src = i0 % n
        neg = (i0 >= n).astype(np.uint64)
        return src, neg

    @lru_cache(maxsize=None)
    def autom_eval_perm(self, galois: int) -> np.ndarray:
        """Eval-domain automorphism as a pure permutation (no signs).

        The negacyclic NTT evaluates at psi^(2j+1) (natural order), so
        a(X^g) at point j is a's value at the point with odd exponent
        g*(2j+1) mod 2N:  out[j] = in[perm[j]].  This is how real FHE
        libraries apply Galois in the NTT domain — one gather, exactly
        equal to the coeff-domain INTT -> permute -> NTT round trip.
        """
        n = self.params.N
        two_n = 2 * n
        j = np.arange(n, dtype=np.int64)
        return ((galois * (2 * j + 1)) % two_n - 1) // 2

    def galois_for_rotation(self, steps: int) -> int:
        """Galois element 5^steps mod 2N rotating slots left by ``steps``."""
        two_n = 2 * self.params.N
        return pow(5, steps % self.params.num_slots, two_n)

    GALOIS_CONJ = -1  # sentinel; conjugation uses element 2N-1

    def galois_conjugate(self) -> int:
        return 2 * self.params.N - 1
