"""Key generation: secret key, keyswitching (evk) keys, rotation/conj keys.

evk construction (level-independent gadget): for full-chain digit group
D_j (alpha consecutive primes of the Q chain),

    G_j = Qhat_j * (Qhat_j^{-1} mod Q_j)   (== 1 mod q in D_j, 0 elsewhere)

    evk_j = (-a_j s + e_j + P * G_j * s',  a_j)   mod (Q_L * P)

so that at ANY level l the digits of the level-l chain (prefixes of the
full-chain groups) reconstruct: sum_j X_j * G_j == x (mod Q_l).
"""
from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp
import numpy as np

from repro.core import poly
from repro.core.params import CKKSParams

_SIGMA = 3.2


@dataclasses.dataclass
class EvalKey:
    """dnum digits x 2 components over the extended basis Q_L u P (eval)."""

    digits: list  # list of (2, L+1+k, N) jnp uint64


def sample_ternary(rng: np.random.Generator, n: int, h: int | None = None):
    if h is None:
        return rng.integers(-1, 2, n).astype(np.int64)
    s = np.zeros(n, dtype=np.int64)
    idx = rng.choice(n, size=h, replace=False)
    s[idx] = rng.choice([-1, 1], size=h)
    return s


def sample_gaussian(rng: np.random.Generator, n: int) -> np.ndarray:
    return np.round(rng.normal(0.0, _SIGMA, n)).astype(np.int64)


def to_rns(coeffs: np.ndarray, primes: tuple[int, ...]) -> np.ndarray:
    """Signed int coeffs -> (l, N) uint64 residues (coeff domain)."""
    out = np.empty((len(primes), coeffs.shape[0]), dtype=np.uint64)
    for i, q in enumerate(primes):
        out[i] = np.mod(coeffs, q).astype(np.uint64)
    return out


class KeyChain:
    """Holds sk and generates evks lazily; rotation keys cached by step."""

    def __init__(self, params: CKKSParams, pc: poly.PolyContext,
                 seed: int = 1234, hamming_weight: int | None = None):
        self.params = params
        self.pc = pc
        self.rng = np.random.default_rng(seed)
        # Sparse secrets (small h) bound the ModRaise overflow |I| <= ~h/2,
        # keeping EvalMod's sine-approximation range small (bootstrapping
        # convention; uniform ternary otherwise).
        self.s_coeffs = sample_ternary(self.rng, params.N, h=hamming_weight)
        self.ext_primes = params.q_primes + params.p_primes
        # sk in eval domain over the full extended basis.
        s_rns = to_rns(self.s_coeffs, self.ext_primes)
        self.s_eval = poly.ntt(jnp.asarray(s_rns), self.ext_primes, pc)
        self._rot_keys: dict[int, EvalKey] = {}
        self._mult_key: EvalKey | None = None
        self._conj_key: EvalKey | None = None
        self._gadgets = self._make_gadgets()

    # ------------------------------------------------------------------
    def _make_gadgets(self) -> list[np.ndarray]:
        """P*G_j reduced mod every extended-basis prime: (dnum, L+1+k)."""
        p = self.params
        full_chain = p.q_chain(p.L)
        groups = p.digit_groups(p.L)
        P = p.P
        out = []
        for D in groups:
            Qj = math.prod(D)
            Qhat = math.prod(full_chain) // Qj
            cj = pow(Qhat % Qj, -1, Qj)
            Gj = Qhat * cj  # integer; == 1 mod D primes, 0 mod others
            vec = np.array(
                [(P * Gj) % r for r in self.ext_primes], dtype=np.uint64
            )
            out.append(vec)
        return out

    def _gen_evk(self, s_prime_eval: jnp.ndarray) -> EvalKey:
        """evk for switching s_prime -> s. s_prime_eval: (L+1+k, N) eval."""
        p, pc = self.params, self.pc
        primes = self.ext_primes
        mods = pc.mods(primes)
        digits = []
        for j in range(p.dnum):
            a_rns = np.stack(
                [
                    self.rng.integers(0, q, p.N, dtype=np.uint64)
                    for q in primes
                ]
            )
            a_eval = poly.ntt(jnp.asarray(a_rns), primes, pc)
            e_rns = to_rns(sample_gaussian(self.rng, p.N), primes)
            e_eval = poly.ntt(jnp.asarray(e_rns), primes, pc)
            b = poly.sub(
                poly.add(
                    poly.mul_scalar(
                        s_prime_eval, jnp.asarray(self._gadgets[j]), mods
                    ),
                    e_eval,
                    mods,
                ),
                poly.mul(a_eval, self.s_eval, mods),
                mods,
            )
            digits.append(jnp.stack([b, a_eval]))
        return EvalKey(digits=digits)

    # ------------------------------------------------------------------
    @property
    def mult_key(self) -> EvalKey:
        if self._mult_key is None:
            mods = self.pc.mods(self.ext_primes)
            s2 = poly.mul(self.s_eval, self.s_eval, mods)
            self._mult_key = self._gen_evk(s2)
        return self._mult_key

    def rot_key(self, steps: int) -> EvalKey:
        steps = steps % self.params.num_slots
        if steps not in self._rot_keys:
            g = self.pc.rns.galois_for_rotation(steps)
            s_rot = poly.automorphism(
                self.s_eval, self.ext_primes, g, self.pc
            )
            self._rot_keys[steps] = self._gen_evk(s_rot)
        return self._rot_keys[steps]

    @property
    def conj_key(self) -> EvalKey:
        if self._conj_key is None:
            g = self.pc.rns.galois_conjugate()
            s_c = poly.automorphism(self.s_eval, self.ext_primes, g, self.pc)
            self._conj_key = self._gen_evk(s_c)
        return self._conj_key
