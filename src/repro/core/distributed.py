"""Distributed keyswitch: the paper's IRF-vs-EVF dataflow as a sharding
choice on the TPU mesh (DESIGN.md §Hardware adaptation).

The keyswitch inner product  acc_c[r] = sum_j digits[j,r,:] * evk[j,c,r,:]
is embarrassingly parallel over extended-basis limbs r.  Two layouts:

  IRF (intermediate results flow):
      evk is permanently LIMB-SHARDED across the mesh 'model' axis (it
      never moves — the xMU-resident evk of the paper).  ModUp produces
      digits COEFF-SHARDED (each device transformed its slice); an
      all_to_all re-shards them limb-wise before the local IP.
      Moved bytes/device: dnum * ext * N / P  words  (the intermediates).

  EVF (evk flows):
      digits stay coeff-sharded; the evk is all-gathered to every device,
      which computes its coefficient slice of all limbs.
      Moved bytes/device: dnum * 2 * ext * N * (P-1)/P  words (the keys).

IRF moves ~2x less per keyswitch, and hoisted PKBs amortize ONE digit
transfer over n rotations — exactly the paper's Fig. 3/4 trade-off,
reproduced here as measurable collective bytes in the compiled HLO
(see tests/test_distributed.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map


def _local_ip(digits, evk, mods):
    """digits: (dnum, l, n); evk: (dnum, 2, l, n); mods: (l, 1) uint64."""
    acc0 = jnp.zeros(digits.shape[1:], jnp.uint64)
    acc1 = jnp.zeros(digits.shape[1:], jnp.uint64)
    for j in range(digits.shape[0]):
        acc0 = (acc0 + (digits[j] * evk[j, 0]) % mods) % mods
        acc1 = (acc1 + (digits[j] * evk[j, 1]) % mods) % mods
    return acc0, acc1


def ip_irf(mesh, axis: str = "model"):
    """IRF inner product: digits coeff-sharded in, limb-sharded out.

    Returns a jitted fn(digits (dnum,L,N), evk (dnum,2,L,N), mods (L,1)).
    evk is limb-sharded and never moves; digits cross the mesh once.
    """
    n_dev = mesh.shape[axis]

    def body(digits, evk, mods):
        # digits arrive coeff-sharded: local (dnum, L, N/P).
        # all_to_all: split limb axis, concat coeff axis -> (dnum, L/P, N)
        d = jax.lax.all_to_all(digits, axis, split_axis=1, concat_axis=2,
                               tiled=True)
        return _local_ip(d, evk, mods)

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(None, None, axis),        # digits: coeff-sharded
                  P(None, None, axis, None),  # evk: limb-sharded, resident
                  P(axis, None)),             # per-limb moduli
        out_specs=(P(axis, None), P(axis, None)),
    )
    return jax.jit(fn), n_dev


def ip_evf(mesh, axis: str = "model"):
    """EVF inner product: the KEYS flow — evk (limb-sharded at rest) is
    re-sharded coefficient-wise to meet the stationary digits.  Moves
    dnum*2*ext*N*(P-1)/P words vs IRF's dnum*ext*N*(P-1)/P: the 2x the
    paper's Fig. 3 attributes to moving both evk components."""

    def body(digits, evk_shard, mods):
        evk = jax.lax.all_to_all(evk_shard, axis, split_axis=3,
                                 concat_axis=2, tiled=True)
        return _local_ip(digits, evk, mods)

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(None, None, axis),        # digits stay put (coeff)
                  P(None, None, axis, None),  # evk limb-sharded at rest
                  P(None, None)),
        out_specs=(P(None, axis), P(None, axis)),
    )
    return jax.jit(fn), mesh.shape[axis]


def reference_ip(digits, evk, mods):
    """Single-device oracle (same math, no mesh)."""
    return _local_ip(digits, evk, mods)


def measure_collectives(fn, *sds):
    """Lower+compile a distributed fn against ShapeDtypeStructs and return
    per-kind collective byte counts (same parser as the dry-run).

    NOTE: the single-process CPU backend lowers in-process all_to_all to
    transposes, so this returns 0 there — use comm_bytes_per_device for
    the analytic volume (exact for these fixed layouts)."""
    from repro.launch.dryrun import collective_bytes

    lowered = fn.lower(*sds)
    compiled = lowered.compile()
    return collective_bytes(compiled.as_text())


def comm_bytes_per_device(kind: str, dnum: int, ext: int, n: int,
                          p: int, word_bytes: int = 8) -> float:
    """Exact per-device interconnect bytes of one inner product.

    IRF: the digit tensor crosses the mesh once (all_to_all),
    EVF: both evk components cross (all_to_all) — 2x IRF, the paper's
    Fig. 3 single-keyswitch trade-off.  A hoisted PKB with r rotations
    pays IRF ONCE for all r (digits shared) but EVF r times (distinct
    keys), which is why hoisting flips the preferred dataflow."""
    moved = {"IRF": dnum * ext * n, "EVF": dnum * 2 * ext * n}[kind]
    return moved * (p - 1) / p * word_bytes / p
