"""CKKS bootstrapping: ModRaise -> CoeffToSlot -> EvalMod -> SlotToCoeff.

Follows the FFT-like factorized bootstrapping of Chen-Chillotti-Song [6]
(the paper's configuration: "FFT-like bootstrapping with three stages").

Factorization trick: the special FFT splits into radix-2 stage matrices
with exactly 3 generalized diagonals {0, +gap, -gap}.  The bit-reversal
permutation is NOT applied homomorphically: C2S (DIF direction) leaves
slots in bit-reversed order, EvalMod is slot-wise (order-agnostic), and
S2C (DIT direction) consumes bit-reversed input — the permutations cancel.

Stages are merged into ``n_groups`` (default 3) dense products whose
diagonals drive hoisted/BSGS homomorphic matvecs — these are precisely the
PKBs of the paper's bootstrapping DFG.
"""
from __future__ import annotations

import numpy as np

from repro.core import linear, poly
from repro.core.ckks import CKKSContext, Ciphertext
from repro.core.encoding import centered_crt
from repro.core.keys import to_rns
from repro.core.polyeval import chebyshev_coeffs, eval_chebyshev


# --------------------- stage matrices (numpy, exact) ---------------------

def _c2s_stage_diags(enc, ln: int) -> dict[int, np.ndarray]:
    """Diagonals of one fft_special_inv stage (block length ln)."""
    nh, M = enc.Nh, enc.M
    lenh, lenq = ln >> 1, ln << 2
    d0 = np.zeros(nh, dtype=complex)
    dp = np.zeros(nh, dtype=complex)   # offset +lenh
    dm = np.zeros(nh, dtype=complex)   # offset -lenh (== nh-lenh)
    idx = (lenq - (enc.rot_group[:lenh] % lenq)) * (M // lenq)
    w = enc.ksi[idx]
    for t in range(nh):
        pos = t % ln
        if pos < lenh:
            d0[t] = 1.0
            dp[t] = 1.0
        else:
            j = pos - lenh
            d0[t] = -w[j]
            dm[t] = w[j]
    return _merge_diags(nh, d0, dp, dm, lenh)


def _merge_diags(nh, d0, dp, dm, lenh):
    """Offsets +lenh and -lenh coincide when ln == nh — merge, don't clobber."""
    out = {0: d0}
    po, mo = lenh, (nh - lenh) % nh
    if po == mo:
        out[po] = dp + dm
    else:
        out[po] = dp
        out[mo] = dm
    return out


def _s2c_stage_diags(enc, ln: int) -> dict[int, np.ndarray]:
    """Diagonals of one fft_special stage (block length ln)."""
    nh, M = enc.Nh, enc.M
    lenh, lenq = ln >> 1, ln << 2
    d0 = np.zeros(nh, dtype=complex)
    dp = np.zeros(nh, dtype=complex)
    dm = np.zeros(nh, dtype=complex)
    idx = (enc.rot_group[:lenh] % lenq) * (M // lenq)
    w = enc.ksi[idx]
    for t in range(nh):
        pos = t % ln
        if pos < lenh:
            d0[t] = 1.0
            dp[t] = w[pos]
        else:
            j = pos - lenh
            d0[t] = -w[j]
            dm[t] = 1.0
    return _merge_diags(nh, d0, dp, dm, lenh)


def _diags_to_matrix(diags: dict[int, np.ndarray], nh: int) -> np.ndarray:
    A = np.zeros((nh, nh), dtype=complex)
    for d, v in diags.items():
        for t in range(nh):
            A[t, (t + d) % nh] = v[t]
    return A


def _group(mats: list[np.ndarray], n_groups: int) -> list[np.ndarray]:
    """Compose consecutive stage matrices into n_groups products.

    mats are in APPLICATION order (mats[0] applied first)."""
    n = len(mats)
    sizes = [n // n_groups + (1 if i < n % n_groups else 0)
             for i in range(n_groups)]
    out, i = [], 0
    for s in sizes:
        g = mats[i]
        for m in mats[i + 1 : i + s]:
            g = m @ g
        out.append(g)
        i += s
    return out


class Bootstrapper:
    def __init__(self, ctx: CKKSContext, n_groups: int = 3,
                 mod_K: int = 6, cheb_degree: int = 40, bsgs_bs: int = 0):
        self.ctx = ctx
        enc = ctx.encoder
        nh = enc.Nh
        self.n_groups = n_groups
        self.mod_K = mod_K
        self.cheb_degree = cheb_degree
        self.bsgs_bs = bsgs_bs

        # C2S: fft_special_inv stages applied ln=Nh..2, bitrev omitted,
        # 1/nh folded into the last group.
        lns = [1 << s for s in range(enc.Nh.bit_length() - 1, 0, -1)]
        c2s_mats = [
            _diags_to_matrix(_c2s_stage_diags(enc, ln), nh) for ln in lns
        ]
        self.c2s_groups = _group(c2s_mats, n_groups)
        self.c2s_groups[-1] = self.c2s_groups[-1] / nh

        # S2C: fft_special stages applied ln=2..Nh on bit-reversed input.
        lns_f = [1 << s for s in range(1, enc.Nh.bit_length())]
        s2c_mats = [
            _diags_to_matrix(_s2c_stage_diags(enc, ln), nh) for ln in lns_f
        ]
        self.s2c_groups = _group(s2c_mats, n_groups)

        # EvalMod: F(x) = sin(2*pi*x)/(2*pi) on [-K-1/2, K+1/2].
        K = mod_K + 0.5
        self.eval_range = K
        self.cheb = chebyshev_coeffs(
            lambda t: np.sin(2 * np.pi * K * t) / (2 * np.pi), cheb_degree
        )

    # ------------------------------------------------------------------
    def mod_raise(self, ct: Ciphertext) -> Ciphertext:
        """Lift a level-0 ciphertext to the full chain (exact, coeffs < q0)."""
        ctx = self.ctx
        p = ctx.params
        assert ct.level == 0
        base = (p.q_primes[0],)
        full = p.q_chain(p.L)
        out = []
        for comp in (ct.c0, ct.c1):
            coeff = poly.intt(comp, base, ctx.pc)
            centered = centered_crt(np.asarray(coeff), base)
            lifted = to_rns(centered.astype(np.int64), full)
            out.append(poly.ntt(np.asarray(lifted), full, ctx.pc))
        return Ciphertext(out[0], out[1], p.L, ct.scale)

    def _matvec(self, ct: Ciphertext, mat: np.ndarray) -> Ciphertext:
        diags = linear.matrix_diagonals(mat)
        if self.bsgs_bs and len(diags) > self.bsgs_bs:
            return linear.matvec_bsgs(self.ctx, ct, diags, self.bsgs_bs)
        return linear.matvec_diag(self.ctx, ct, diags)

    def coeff_to_slot(self, ct: Ciphertext) -> Ciphertext:
        for g in self.c2s_groups:
            ct = self._matvec(ct, g)
        return ct

    def slot_to_coeff(self, ct: Ciphertext) -> Ciphertext:
        for g in self.s2c_groups:
            ct = self._matvec(ct, g)
        return ct

    def eval_mod(self, ct: Ciphertext, q0_over_scale: float) -> Ciphertext:
        """EvalMod on real-valued slots: x = m/q0 + I -> ~m/q0."""
        ctx = self.ctx
        nh = ctx.params.num_slots
        # normalize to [-1, 1]: u = x / K
        pre = ctx.encode(
            np.full(nh, 1.0 / (self.eval_range * q0_over_scale)),
            level=ct.level,
        )
        u = ctx.pt_mul(ct, pre, rescale=True)
        out = eval_chebyshev(ctx, u, self.cheb)
        post = ctx.encode(np.full(nh, q0_over_scale), level=out.level)
        return ctx.pt_mul(out, post, rescale=True)

    # ------------------------------------------------------------------
    def bootstrap(self, ct: Ciphertext) -> Ciphertext:
        """Full pipeline.  Input at level 0, output at a higher level."""
        ctx = self.ctx
        p = ctx.params
        nh = p.num_slots
        q0 = p.q_primes[0]

        raised = self.mod_raise(ct)
        t = self.coeff_to_slot(raised)

        # split real/imag: re = (t + conj t)/2, im = (t - conj t)/(2i)
        tc = ctx.conjugate(t)
        half = ctx.encode(np.full(nh, 0.5), level=t.level)
        re = ctx.pt_mul(ctx.add(t, tc), half, rescale=True)
        mhalf_i = ctx.encode(np.full(nh, -0.5j), level=t.level)
        im = ctx.pt_mul(ctx.sub(t, tc), mhalf_i, rescale=True)

        q0_over_scale = q0 / ct.scale
        re_m = self.eval_mod(re, q0_over_scale)
        im_m = self.eval_mod(im, q0_over_scale)

        lvl = min(re_m.level, im_m.level)
        i_pt = ctx.encode(np.full(nh, 1.0j), level=lvl, scale=1.0)
        im_i = ctx.pt_mul(ctx.level_down(im_m, lvl), i_pt, rescale=False)
        merged = ctx.add(ctx.level_down(re_m, lvl), im_i)

        return self.slot_to_coeff(merged)
