"""CKKS bootstrapping: ModRaise -> CoeffToSlot -> EvalMod -> SlotToCoeff.

Follows the FFT-like factorized bootstrapping of Chen-Chillotti-Song [6]
(the paper's configuration: "FFT-like bootstrapping with three stages").

Factorization trick: the special FFT splits into radix-2 stage matrices
with exactly 3 generalized diagonals {0, +gap, -gap}.  The bit-reversal
permutation is NOT applied homomorphically: C2S (DIF direction) leaves
slots in bit-reversed order, EvalMod is slot-wise (order-agnostic), and
S2C (DIT direction) consumes bit-reversed input — the permutations cancel.

Stages are merged into ``n_groups`` (default 3) dense products whose
diagonals are evaluated as BSGS matvecs (shape-derived baby-step block
size unless ``bsgs_bs`` overrides it) — these are precisely the serial
PKB chains of the paper's bootstrapping DFG (Sec. IV).

Every pipeline method only touches the context's public op API, so the
same source runs EITHER eagerly on a ``CKKSContext`` OR symbolically
under the compiled runtime's ``repro.runtime.compile.TraceContext``:
:meth:`Bootstrapper.compile` traces the full ModRaise -> C2S -> re/im
split -> EvalMod x2 -> merge -> S2C pipeline and lowers it through
``repro.runtime`` (baby-step blocks share one ModUp per anchor; with
``exact=False`` the giant-step rotations of each matvec close with ONE
ModDown — see ``runtime.lower.MultiHoistedStep``).
"""
from __future__ import annotations

import math

import numpy as np

from repro.core import linear
from repro.core.ckks import CKKSContext, Ciphertext
from repro.core.polyeval import (
    chebyshev_coeffs, eval_chebyshev, eval_chebyshev_bsgs,
)


# --------------------- stage matrices (numpy, exact) ---------------------

def _c2s_stage_diags(enc, ln: int) -> dict[int, np.ndarray]:
    """Diagonals of one fft_special_inv stage (block length ln)."""
    nh, M = enc.Nh, enc.M
    lenh, lenq = ln >> 1, ln << 2
    d0 = np.zeros(nh, dtype=complex)
    dp = np.zeros(nh, dtype=complex)   # offset +lenh
    dm = np.zeros(nh, dtype=complex)   # offset -lenh (== nh-lenh)
    idx = (lenq - (enc.rot_group[:lenh] % lenq)) * (M // lenq)
    w = enc.ksi[idx]
    for t in range(nh):
        pos = t % ln
        if pos < lenh:
            d0[t] = 1.0
            dp[t] = 1.0
        else:
            j = pos - lenh
            d0[t] = -w[j]
            dm[t] = w[j]
    return _merge_diags(nh, d0, dp, dm, lenh)


def _merge_diags(nh, d0, dp, dm, lenh):
    """Offsets +lenh and -lenh coincide when ln == nh — merge, don't clobber."""
    out = {0: d0}
    po, mo = lenh, (nh - lenh) % nh
    if po == mo:
        out[po] = dp + dm
    else:
        out[po] = dp
        out[mo] = dm
    return out


def _s2c_stage_diags(enc, ln: int) -> dict[int, np.ndarray]:
    """Diagonals of one fft_special stage (block length ln)."""
    nh, M = enc.Nh, enc.M
    lenh, lenq = ln >> 1, ln << 2
    d0 = np.zeros(nh, dtype=complex)
    dp = np.zeros(nh, dtype=complex)
    dm = np.zeros(nh, dtype=complex)
    idx = (enc.rot_group[:lenh] % lenq) * (M // lenq)
    w = enc.ksi[idx]
    for t in range(nh):
        pos = t % ln
        if pos < lenh:
            d0[t] = 1.0
            dp[t] = w[pos]
        else:
            j = pos - lenh
            d0[t] = -w[j]
            dm[t] = 1.0
    return _merge_diags(nh, d0, dp, dm, lenh)


def _diags_to_matrix(diags: dict[int, np.ndarray], nh: int) -> np.ndarray:
    A = np.zeros((nh, nh), dtype=complex)
    for d, v in diags.items():
        for t in range(nh):
            A[t, (t + d) % nh] = v[t]
    return A


def _group(mats: list[np.ndarray], n_groups: int) -> list[np.ndarray]:
    """Compose consecutive stage matrices into n_groups products.

    mats are in APPLICATION order (mats[0] applied first)."""
    n = len(mats)
    sizes = [n // n_groups + (1 if i < n % n_groups else 0)
             for i in range(n_groups)]
    out, i = [], 0
    for s in sizes:
        g = mats[i]
        for m in mats[i + 1 : i + s]:
            g = m @ g
        out.append(g)
        i += s
    return out


def auto_bsgs_bs(offsets, nh: int) -> int:
    """Shape-derived baby-step block size for diagonal offsets.

    The merged FFT stage matrices have diagonals at MULTIPLES of a gap
    (the radix stride), so the d = i*bs + j split of Eq. (3) only
    exposes shared baby steps when bs is a multiple of that stride: we
    take bs = g * 2^floor(log2(sqrt(m))) where g = gcd of the (nonzero)
    offsets and the slot count and m the diagonal count — the largest
    power-of-two baby count not above sqrt(m), which minimizes
    baby + giant rotations.  Returns 0 (dense single hoisted block) when
    the matrix is too sparse for the split to expose any giant-step
    structure."""
    offs = [d % nh for d in offsets if d % nh]
    if len(offsets) < 4 or not offs:
        return 0
    g = math.gcd(nh, *offs)
    n_baby = 1 << (math.isqrt(len(offsets)).bit_length() - 1)
    return g * n_baby if n_baby >= 2 else 0


class Bootstrapper:
    """``bsgs_bs``: baby-step block size for the stage matvecs.  ``None``
    (default) derives it per matrix via :func:`auto_bsgs_bs`; ``0`` forces
    the dense single-block ``matvec_diag`` path; any other value is used
    as-is whenever the matrix's diagonal offsets span more than one
    giant-step group (``d // bs``) — otherwise the split would expose no
    giant-step structure and the dense path is taken."""

    def __init__(self, ctx: CKKSContext, n_groups: int = 3,
                 mod_K: int = 6, cheb_degree: int = 40,
                 bsgs_bs: int | None = None,
                 cheb_bs: int | None = None):
        self.ctx = ctx
        enc = ctx.encoder
        nh = enc.Nh
        self.n_groups = n_groups
        self.mod_K = mod_K
        self.cheb_degree = cheb_degree
        self.bsgs_bs = bsgs_bs
        # EvalMod polynomial evaluation: ``None`` (default) evaluates the
        # Chebyshev approximant with giant-step products
        # (``polyeval.eval_chebyshev_bsgs``, O(sqrt d) CMults whose sums
        # compile to merged-ModDown relin blocks); ``0`` forces the dense
        # T_k recurrence; any other value overrides the baby-step count.
        self.cheb_bs = cheb_bs

        # C2S: fft_special_inv stages applied ln=Nh..2, bitrev omitted,
        # 1/nh folded into the last group.
        lns = [1 << s for s in range(enc.Nh.bit_length() - 1, 0, -1)]
        c2s_mats = [
            _diags_to_matrix(_c2s_stage_diags(enc, ln), nh) for ln in lns
        ]
        self.c2s_groups = _group(c2s_mats, n_groups)
        self.c2s_groups[-1] = self.c2s_groups[-1] / nh

        # S2C: fft_special stages applied ln=2..Nh on bit-reversed input.
        lns_f = [1 << s for s in range(1, enc.Nh.bit_length())]
        s2c_mats = [
            _diags_to_matrix(_s2c_stage_diags(enc, ln), nh) for ln in lns_f
        ]
        self.s2c_groups = _group(s2c_mats, n_groups)

        # EvalMod: F(x) = sin(2*pi*x)/(2*pi) on [-K-1/2, K+1/2].
        K = mod_K + 0.5
        self.eval_range = K
        self.cheb = chebyshev_coeffs(
            lambda t: np.sin(2 * np.pi * K * t) / (2 * np.pi), cheb_degree
        )

    # ------------------------------------------------------------------
    def mod_raise(self, ct: Ciphertext) -> Ciphertext:
        """Lift a level-0 ciphertext to the full chain (exact, coeffs < q0)."""
        return CKKSContext.mod_raise(self.ctx, ct)

    def _matvec(self, ctx, ct: Ciphertext, mat: np.ndarray) -> Ciphertext:
        diags = linear.matrix_diagonals(mat)
        bs = self.bsgs_bs
        if bs is None:
            bs = auto_bsgs_bs(sorted(diags), ctx.params.num_slots)
        if bs and len({d // bs for d in diags}) > 1:
            return linear.matvec_bsgs(ctx, ct, diags, bs)
        return linear.matvec_diag(ctx, ct, diags)

    def coeff_to_slot(self, ct: Ciphertext, ctx=None) -> Ciphertext:
        ctx = self.ctx if ctx is None else ctx
        for g in self.c2s_groups:
            ct = self._matvec(ctx, ct, g)
        return ct

    def slot_to_coeff(self, ct: Ciphertext, ctx=None) -> Ciphertext:
        ctx = self.ctx if ctx is None else ctx
        for g in self.s2c_groups:
            ct = self._matvec(ctx, ct, g)
        return ct

    def eval_mod(self, ct: Ciphertext, q0_over_scale: float,
                 ctx=None) -> Ciphertext:
        """EvalMod on real-valued slots: x = m/q0 + I -> ~m/q0."""
        ctx = self.ctx if ctx is None else ctx
        nh = ctx.params.num_slots
        # normalize to [-1, 1]: u = x / K
        pre = ctx.encode(
            np.full(nh, 1.0 / (self.eval_range * q0_over_scale)),
            level=ct.level,
        )
        u = ctx.pt_mul(ct, pre, rescale=True)
        if self.cheb_bs == 0:
            out = eval_chebyshev(ctx, u, self.cheb)
        else:
            out = eval_chebyshev_bsgs(ctx, u, self.cheb, bs=self.cheb_bs)
        post = ctx.encode(np.full(nh, q0_over_scale), level=out.level)
        return ctx.pt_mul(out, post, rescale=True)

    # ------------------------------------------------------------------
    def bootstrap(self, ct: Ciphertext, ctx=None) -> Ciphertext:
        """Full pipeline.  Input at level 0, output at a higher level.

        ``ctx`` defaults to the eager context; passing the runtime's
        ``TraceContext`` records the same pipeline as a DFG instead (see
        :meth:`compile`)."""
        ctx = self.ctx if ctx is None else ctx
        p = ctx.params
        nh = p.num_slots
        q0 = p.q_primes[0]

        raised = ctx.mod_raise(ct)
        t = self.coeff_to_slot(raised, ctx)

        # split real/imag: re = (t + conj t)/2, im = (t - conj t)/(2i)
        tc = ctx.conjugate(t)
        half = ctx.encode(np.full(nh, 0.5), level=t.level)
        re = ctx.pt_mul(ctx.add(t, tc), half, rescale=True)
        mhalf_i = ctx.encode(np.full(nh, -0.5j), level=t.level)
        im = ctx.pt_mul(ctx.sub(t, tc), mhalf_i, rescale=True)

        q0_over_scale = q0 / ct.scale
        re_m = self.eval_mod(re, q0_over_scale, ctx)
        im_m = self.eval_mod(im, q0_over_scale, ctx)

        lvl = min(re_m.level, im_m.level)
        i_pt = ctx.encode(np.full(nh, 1.0j), level=lvl, scale=1.0)
        im_i = ctx.pt_mul(ctx.level_down(im_m, lvl), i_pt, rescale=False)
        merged = ctx.add(ctx.level_down(re_m, lvl), im_i)

        return self.slot_to_coeff(merged, ctx)

    # ------------------------------------------------------------------
    def compile(self, input_scale: float | None = None,
                fusion: bool = False, exact: bool = True):
        """Trace the full bootstrap pipeline and lower it through the
        compiled runtime (``repro.runtime``).

        The traced program takes one level-0 input tagged ``"ct"`` (its
        scale must match ``input_scale``, default the params scale) and
        produces one output tagged ``"out"``.  ``exact=True`` (default)
        keeps the lowering bit-exact with :meth:`bootstrap`; ``exact=
        False`` additionally lowers the multi-anchor giant-step PKBs of
        every BSGS stage to single-ModDown blocks (numerically close but
        not bit-identical — the accumulation crosses ModDown boundaries).
        """
        from repro.runtime import TraceContext, compile_program

        params = self.ctx.params
        scale = params.scale if input_scale is None else input_scale
        tc = TraceContext(params)
        h = tc.input("ct", level=0, scale=scale)
        out = self.bootstrap(h, ctx=tc)
        tc.output(out, "out")
        return compile_program(tc, fusion=fusion, exact=exact)
