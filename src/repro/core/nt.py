"""Exact number theory helpers (pure Python ints — used at setup time only).

Everything here runs once per parameter set; hot paths live in
``repro.core.poly`` (jnp) and ``repro.kernels`` (Pallas).
"""
from __future__ import annotations

from functools import lru_cache

# Deterministic Miller-Rabin witness set, valid for all n < 3.3e24.
_MR_WITNESSES = (2, 3, 5, 7, 11, 13, 17, 19, 23, 29, 31, 37)


def is_prime(n: int) -> bool:
    if n < 2:
        return False
    for p in _MR_WITNESSES:
        if n % p == 0:
            return n == p
    d, r = n - 1, 0
    while d % 2 == 0:
        d //= 2
        r += 1
    for a in _MR_WITNESSES:
        x = pow(a, d, n)
        if x in (1, n - 1):
            continue
        for _ in range(r - 1):
            x = x * x % n
            if x == n - 1:
                break
        else:
            return False
    return True


def modinv(a: int, m: int) -> int:
    return pow(a % m, m - 2, m) if is_prime(m) else pow(a % m, -1, m)


def find_primes(count: int, bits: int, step_mod: int, avoid=()) -> list[int]:
    """``count`` primes p ≡ 1 (mod step_mod), p < 2**bits, descending from 2**bits.

    ``step_mod`` is 2N for negacyclic NTT support.
    """
    primes: list[int] = []
    avoid = set(avoid)
    # Start at the largest candidate ≡ 1 mod step_mod below 2**bits.
    p = (1 << bits) - ((1 << bits) - 1) % step_mod
    while len(primes) < count:
        if p <= step_mod:
            raise ValueError(f"ran out of {bits}-bit primes ≡ 1 mod {step_mod}")
        if p not in avoid and is_prime(p):
            primes.append(p)
        p -= step_mod
    return primes


@lru_cache(maxsize=None)
def primitive_root(p: int) -> int:
    """Smallest primitive root mod prime p."""
    factors = _factorize(p - 1)
    for g in range(2, p):
        if all(pow(g, (p - 1) // f, p) != 1 for f in factors):
            return g
    raise ValueError(f"no primitive root found for {p}")


def root_of_unity(order: int, p: int) -> int:
    """An element of exact multiplicative order ``order`` mod prime p."""
    if (p - 1) % order != 0:
        raise ValueError(f"{order} does not divide {p}-1")
    g = primitive_root(p)
    w = pow(g, (p - 1) // order, p)
    assert pow(w, order, p) == 1 and pow(w, order // 2, p) != 1
    return w


def _factorize(n: int) -> set[int]:
    out, d = set(), 2
    while d * d <= n:
        while n % d == 0:
            out.add(d)
            n //= d
        d += 1
    if n > 1:
        out.add(n)
    return out


def bit_reverse_indices(n: int) -> list[int]:
    bits = n.bit_length() - 1
    return [int(format(i, f"0{bits}b")[::-1], 2) if bits else 0 for i in range(n)]
