"""CKKS canonical-embedding encoder/decoder (special FFT, numpy complex128).

Follows the HEAAN reference algorithm: slots z in C^{N/2} map to a real
polynomial m(X) via the embedding at odd powers of the 2N-th root of unity,
ordered by the rotation group 5^j mod 2N (so slot rotation == Galois
automorphism X -> X^5).
"""
from __future__ import annotations

import numpy as np

from repro.core import nt
from repro.core.params import CKKSParams


class Encoder:
    def __init__(self, params: CKKSParams):
        self.params = params
        N = params.N
        M = 2 * N
        Nh = N // 2
        self.N, self.M, self.Nh = N, M, Nh
        self.rot_group = np.array(
            [pow(5, i, M) for i in range(Nh)], dtype=np.int64
        )
        j = np.arange(M + 1)
        self.ksi = np.exp(2j * np.pi * j / M)
        self.bitrev = np.array(nt.bit_reverse_indices(Nh), dtype=np.int64)

    # ---- special FFT (slot <-> coeff), vectorized per stage -------------
    def fft_special(self, vals: np.ndarray) -> np.ndarray:
        v = vals[self.bitrev].copy()
        Nh, M = self.Nh, self.M
        ln = 2
        while ln <= Nh:
            lenh, lenq = ln >> 1, ln << 2
            idx = (self.rot_group[:lenh] % lenq) * (M // lenq)
            w = self.ksi[idx]
            v = v.reshape(Nh // ln, ln)
            u, t = v[:, :lenh], v[:, lenh:] * w[None, :]
            v = np.concatenate([u + t, u - t], axis=1)
            ln <<= 1
        return v.reshape(Nh)

    def fft_special_inv(self, vals: np.ndarray) -> np.ndarray:
        v = vals.copy()
        Nh, M = self.Nh, self.M
        ln = Nh
        while ln >= 2:
            lenh, lenq = ln >> 1, ln << 2
            idx = (lenq - (self.rot_group[:lenh] % lenq)) * (M // lenq)
            w = self.ksi[idx]
            v = v.reshape(Nh // ln, ln)
            u = v[:, :lenh] + v[:, lenh:]
            t = (v[:, :lenh] - v[:, lenh:]) * w[None, :]
            v = np.concatenate([u, t], axis=1)
            ln >>= 1
        v = v.reshape(Nh)[self.bitrev]
        return v / Nh

    # ---- encode / decode -------------------------------------------------
    def encode(self, z: np.ndarray, scale: float,
               primes: tuple[int, ...]) -> np.ndarray:
        """Complex slots -> (len(primes), N) uint64 residues, coeff domain."""
        z = np.asarray(z, dtype=np.complex128)
        if z.shape != (self.Nh,):
            full = np.zeros(self.Nh, dtype=np.complex128)
            full[: z.shape[0]] = z
            z = full
        vals = self.fft_special_inv(z)
        coeffs = np.empty(self.N, dtype=object)
        re = np.round(vals.real * scale).astype(object)
        im = np.round(vals.imag * scale).astype(object)
        coeffs[: self.Nh] = re
        coeffs[self.Nh :] = im
        out = np.empty((len(primes), self.N), dtype=np.uint64)
        for i, q in enumerate(primes):
            out[i] = np.array([int(c) % q for c in coeffs], dtype=np.uint64)
        return out

    def decode(self, residues: np.ndarray, scale: float,
               primes: tuple[int, ...]) -> np.ndarray:
        """(len(primes), N) residues (coeff domain) -> complex slots."""
        coeffs = centered_crt(residues, primes)
        vals = (
            coeffs[: self.Nh].astype(np.float64)
            + 1j * coeffs[self.Nh :].astype(np.float64)
        ) / scale
        return self.fft_special(vals)


def centered_crt(residues: np.ndarray, primes: tuple[int, ...]) -> np.ndarray:
    """Exact CRT lift to centered big ints (object array)."""
    Q = 1
    for q in primes:
        Q *= q
    acc = np.zeros(residues.shape[1], dtype=object)
    for i, q in enumerate(primes):
        qhat = Q // q
        c = (qhat * nt.modinv(qhat, q)) % Q
        acc = (acc + residues[i].astype(object) * c) % Q
    half = Q // 2
    return np.where(acc > half, acc - Q, acc)
