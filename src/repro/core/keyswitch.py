"""Batched, jit-compiled keyswitch engine: ModUp -> IP -> ModDown.

The seed runtime executes keyswitch as per-digit / per-rotation Python
loops over generic uint64 ``%`` jnp ops.  This engine replaces that hot
path with one traced program per ``(level, dnum)`` plan:

  * digits live as ONE stacked ``(dnum, l_ext, N)`` tensor — ModUp is a
    single batched INTT over all base limbs, a block-diagonal BConv
    contraction (per-digit constants packed into one ``(dnum, alpha,
    l_ext)`` tensor), and one batched NTT over all dnum x l_ext new
    limbs, with own-limb passthrough applied as a gather + where;
  * the inner product is one fused contraction against the pre-stacked
    evk tensor ``(dnum, 2, l_ext, N)`` — the ``kernels/fused_ip``
    layout;
  * hoisted rotations apply automorphisms IN THE EVAL DOMAIN via one
    precomputed gather-index tensor ``(R, N)`` covering all digits and
    rotations (see ``RNSContext.autom_eval_perm``) — no per-rotation
    INTT/NTT round trips;
  * ModDown runs batched over both accumulator polynomials at once.

Every plan traces once under ``jax.jit`` and is cached; re-dispatch at
the same level is a cache hit (``trace_counts`` records trace events).
The cache key is the full dispatch SHAPE — op, level/dnum, hoisted term
count, and (for the ``*_batched`` entry points) the leading batch
width — and never key material: evk and plaintext tensors are separate
per-``id(evk)`` device caches resolved at dispatch time.  One traced
plan therefore serves every ciphertext owner; the multi-tenant serving
layer (``repro.serve``) leans on exactly this split, sharing one
engine's plans across tenants while swapping per-tenant ``KeyChain``s
underneath, and treats ``(plan signature, batch width)`` as its
admission-policy object (``docs/SERVING.md``).

The compiled runtime (``repro.runtime``) drives three extensions of the
same plans: ``modup``/``digits=`` split the hoisted entry point so one
ModUp feeds every block anchored on the same ciphertext (callers pass
``digits=`` to reuse a prior ``modup``'s stacked ``(dnum, l_ext, N)``
tensor instead of paying a fresh ModUp), the ``*_batched`` entry
points ``jax.vmap`` a whole batch of independent ciphertexts through
one trace (either backend; a new batch width is a new trace, hence the
serving layer's fixed-width padding), and every dispatch tallies
``OpCounters`` so reports can reconcile executed ModUp/ModDown/IP
counts against ``dfg.hoist`` predictions.

Relinearization is the second member of the keyswitch family and runs
on the SAME plan caches: ``relin``/``relin_batched`` keyswitch the d2
tensor-product component against the mult key (accepting pre-computed
``digits=`` exactly like the hoisted rotations), and
``multi_relin_sum(_batched)`` accumulates the IPs of several relin
terms in the extended basis and closes them with ONE batched ModDown —
the relin analogue of ``multi_hoisted_rotation_sum`` (ARK-style lazy
ModDown), driven by ``runtime.lower.MultiRelinStep``.

Backends (``PolyContext.backend``):
  * ``"jnp"``    — exact uint64 ``(a * b) % q`` ops, batched as above.
  * ``"pallas"`` — uint32 Montgomery Pallas kernel suite.  ModUp runs
    the FUSED kernel (``kernels/modup``): one ``pallas_call`` per digit
    executes INTT -> BConv tree-reduce -> NTT with the digit
    VMEM-resident across all three phases (the BConv scale folded into
    the INTT post-twist), no per-phase HBM intermediates.  ModDown and
    the inner product dispatch ``kernels/ntt``/``kernels/bconv``/
    ``kernels/fused_ip``; ``interpret=True`` off-TPU.  The kernels'
    bit-reversed eval order is bridged to the core's natural order by a
    single ``bitrev`` permutation at kernel boundaries; Montgomery evk /
    plaintext tables are built once per context and cached.  Every
    kernel wrapper carries a ``jax.custom_vmap`` rule folding the batch
    axis into its grid, so the ``*_batched`` entry points run on either
    backend — the serving layer and compiled runtime batch pallas plans
    exactly like jnp ones.

Both backends are bit-exact with the seed per-digit path on every entry
point, batched included (enforced by ``tests/test_keyswitch_engine.py``
and ``tests/test_relin.py``).
"""
from __future__ import annotations

from typing import TYPE_CHECKING

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import poly
from repro.core.counters import OpCounters
from repro.errors import ModulusChainMismatchError
from repro.kernels.bconv.ops import bconv_kernel
from repro.kernels.fused_ip.ops import fused_ip_mont
from repro.kernels.modops import default_interpret, qinv_neg_host
from repro.kernels.modup.ops import modup_digit
from repro.kernels.ntt.ops import ntt_fwd, ntt_inv, tables_for

if TYPE_CHECKING:  # avoid importing keys at runtime (ckks -> keyswitch)
    from repro.core.keys import EvalKey

# Source-limb chunk bounding the (dnum, chunk, l_ext, N) BConv
# intermediate — the VMEM-resident working-set analogue of the Pallas
# BConvU's coefficient blocking.
_CHUNK = 8


def ext_rows(params, level: int) -> np.ndarray:
    """Rows of a full-basis (Q_L u P) evk tensor active at ``level``."""
    L, k = params.L, params.k
    return np.concatenate(
        [np.arange(level + 1), np.arange(L + 1, L + 1 + k)]
    )


def _to_mont_host_rows(arr: np.ndarray, q: np.ndarray) -> np.ndarray:
    """Montgomery-convert (..., l, N) uint64 with per-row moduli (l,).

    Exact object-int arithmetic, vectorized; done once per evk/plaintext
    and cached by the engine.
    """
    shape = (1,) * (arr.ndim - 2) + (len(q), 1)
    qcol = q.astype(object).reshape(shape)
    return ((arr.astype(object) << 32) % qcol).astype(np.uint32)


class KeyswitchPlan:
    """Per-level constants: index tensors, packed BConv constants, mods."""

    def __init__(self, pc: poly.PolyContext, level: int):
        params = pc.params
        rns = pc.rns
        self.level = level
        self.base: tuple[int, ...] = params.q_chain(level)
        self.ext: tuple[int, ...] = self.base + params.p_primes
        self.groups = params.digit_groups(level)
        self.dnum = len(self.groups)
        self.alpha = max(len(D) for D in self.groups)
        self.group_sizes = tuple(len(D) for D in self.groups)
        self.l = len(self.base)
        self.l_ext = len(self.ext)
        self.k = len(params.p_primes)
        self.N = params.N

        # Static primes tuples for batched NTT dispatch (duplicates OK).
        self.ext_tiled = self.ext * self.dnum
        self.p_tiled = params.p_primes * 2
        self.base_tiled = self.base * 2

        self.base_mods = jnp.asarray(np.array(self.base, dtype=np.uint64))
        self.ext_mods = jnp.asarray(np.array(self.ext, dtype=np.uint64))
        self.p_mods = jnp.asarray(np.array(params.p_primes, dtype=np.uint64))

        # --- ModUp: per-limb scale constants + block-diagonal reduce ---
        qinv = np.zeros(self.l, dtype=np.uint64)
        src_idx = np.zeros((self.dnum, self.alpha), dtype=np.int32)
        C = np.zeros((self.dnum, self.alpha, self.l_ext), dtype=np.uint64)
        row = 0
        for j, D in enumerate(self.groups):
            qhat_inv, qhat_mod = rns.bconv_consts(tuple(D), self.ext)
            for i in range(len(D)):
                qinv[row + i] = qhat_inv[i]
                src_idx[j, i] = row + i
                C[j, i] = qhat_mod[i]
            row += len(D)
        self.qinv = jnp.asarray(qinv)
        self.src_idx = jnp.asarray(src_idx)
        self.C = jnp.asarray(C)

        # Own-limb passthrough: digit j keeps its eval-domain rows.
        own_idx = np.zeros((self.dnum, self.l_ext), dtype=np.int32)
        own_mask = np.zeros((self.dnum, self.l_ext), dtype=bool)
        base_pos = {p: i for i, p in enumerate(self.base)}
        for j, D in enumerate(self.groups):
            for r, p in enumerate(self.ext):
                if p in D:
                    own_idx[j, r] = base_pos[p]
                    own_mask[j, r] = True
        self.own_idx = jnp.asarray(own_idx)
        self.own_mask = jnp.asarray(own_mask)

        # --- ModDown: P -> Q_level conversion constants ---
        md_qhat_inv, md_C = rns.bconv_consts(params.p_primes, self.base)
        self.md_qhat_inv = jnp.asarray(md_qhat_inv)
        self.md_C = jnp.asarray(md_C)                  # (k, l)
        self.pinv = jnp.asarray(rns.p_inv_mod_q(level))

        # --- Pallas backend extras ---
        self.bitrev = np.asarray(pc.rns.bitrev)
        q32 = np.array(self.ext, dtype=np.uint32).reshape(self.l_ext, 1)
        qneg32 = np.array(
            [qinv_neg_host(q) for q in self.ext], dtype=np.uint32
        ).reshape(self.l_ext, 1)
        self.q32 = jnp.asarray(q32)
        self.qneg32 = jnp.asarray(qneg32)


class KeyswitchEngine:
    """Jit-compiled batched keyswitch over a ``PolyContext``.

    One trace per (level, op-shape); evk tensors stacked (and, for the
    pallas backend, Montgomery-converted) once per key and cached.
    """

    def __init__(self, pc: poly.PolyContext,
                 counters: OpCounters | None = None):
        self.pc = pc
        self.params = pc.params
        self.backend = pc.backend
        self.interpret = default_interpret()
        self.tabs = tables_for(pc.params) if self.backend == "pallas" else None
        self.counters = counters if counters is not None else OpCounters()
        self._plans: dict[int, KeyswitchPlan] = {}
        self._ks_fns: dict[int, object] = {}
        self._galois_fns: dict[int, object] = {}
        self._hoist_fns: dict[tuple, object] = {}
        self._modup_fns: dict[int, object] = {}
        self._batch_fns: dict[tuple, object] = {}
        self._evk_full: dict[int, tuple] = {}     # id(evk) -> (evk, stacked)
        self._evk_level: dict[tuple, jnp.ndarray] = {}
        self._evk_group: dict[tuple, jnp.ndarray] = {}
        self._perm_cache: dict[tuple, jnp.ndarray] = {}
        self.trace_counts: dict[tuple, int] = {}

    # ------------------------- op counting -----------------------------
    def _note_keyswitch(self, plan: KeyswitchPlan, m: int = 1) -> None:
        c = self.counters
        c.note_modup(plan.l, plan.l_ext, plan.group_sizes, plan.N, m)
        c.note_ip(plan.dnum, plan.l_ext, plan.N, 1, m)
        c.note_moddown(plan.l, plan.k, plan.N, m)
        c.keyswitch += m

    def _note_hoisted(self, plan: KeyswitchPlan, n_rot: int,
                      with_modup: bool, m: int = 1) -> None:
        c = self.counters
        if with_modup:
            c.note_modup(plan.l, plan.l_ext, plan.group_sizes, plan.N, m)
        c.note_ip(plan.dnum, plan.l_ext, plan.N, n_rot, m)
        c.note_moddown(plan.l, plan.k, plan.N, m)
        c.keyswitch += m * n_rot
        c.rotation += m * n_rot
        c.hoisted_blocks += m

    def _note_relin(self, plan: KeyswitchPlan, with_modup: bool,
                    n: int = 1, m: int = 1) -> None:
        """n relinearizations of m ciphertexts sharing one ModDown each
        (n > 1: a merged multi-relin block — ONE ModDown total)."""
        c = self.counters
        if with_modup:
            c.note_modup(plan.l, plan.l_ext, plan.group_sizes, plan.N,
                         m * n)
        c.note_ip(plan.dnum, plan.l_ext, plan.N, n, m)
        c.note_moddown(plan.l, plan.k, plan.N, m)
        c.keyswitch += m * n
        c.relin += m * n
        if n > 1:
            c.relin_blocks += m

    # ------------------------- plans / tracing -------------------------
    def _plan(self, level: int) -> KeyswitchPlan:
        if level not in self._plans:
            self._plans[level] = KeyswitchPlan(self.pc, level)
        return self._plans[level]

    def _count_trace(self, key: tuple) -> None:
        n = self.trace_counts.get(key, 0) + 1
        self.trace_counts[key] = n
        # a repeat trace of the same plan key is a retrace — exactly
        # what the serving layer's zero-retrace gate hunts for
        obs.event("engine.jit_trace", key=str(key), count=n,
                  retrace=n > 1)

    def _note_dispatch(self, op: str) -> None:
        """Kernel-dispatch event: lets Perfetto traces tell a pallas
        executor span (fused ModUp kernel, interpret flag recorded) from
        a jnp one (op-by-op uint64) without changing the span labels."""
        obs.event(
            "engine.kernel_dispatch", op=op, backend=self.backend,
            modup="fused" if self.backend == "pallas" else "op-by-op",
            interpret=self.backend == "pallas" and self.interpret,
        )

    # ------------------------- evk stacking ----------------------------
    def _admit_evk(self, evk: EvalKey) -> None:
        """Cache-admission guard: an evk generated under different
        ``CKKSParams`` (wrong digit count or extended-basis shape) must
        be rejected HERE, at the cache boundary, not hoped past — a
        mis-shaped key either crashes deep inside a jit trace or
        silently keyswitches with garbage gadgets.  Runs only on cache
        miss, so the hot path never pays for it."""
        p = self.params
        want_digits = p.dnum
        want_shape = (2, p.L + 1 + p.k, p.N)
        if len(evk.digits) != want_digits:
            raise ModulusChainMismatchError(
                "evk digit count disagrees with the engine's params",
                hint="the key was generated under different CKKSParams; "
                     "regenerate it with this context's KeyChain",
                evk_digits=len(evk.digits), dnum=want_digits)
        got = tuple(evk.digits[0].shape)
        if got != want_shape:
            raise ModulusChainMismatchError(
                "evk digit shape disagrees with the extended basis",
                hint="the key was generated under a different modulus "
                     "chain; regenerate it with this context's KeyChain",
                evk_shape=got, expected=want_shape)

    def _evk_stacked(self, evk: EvalKey) -> jnp.ndarray:
        """(dnum_full, 2, L+1+k, N) uint64, cached per key object."""
        key = id(evk)
        if key not in self._evk_full:
            self._admit_evk(evk)
            self._evk_full[key] = (evk, jnp.stack(evk.digits))
            obs.event("engine.evk_admit", cached=len(self._evk_full))
        return self._evk_full[key][1]

    def evk_tensor(self, evk: EvalKey, level: int) -> jnp.ndarray:
        """Level-sliced evk tensor (dnum, 2, l_ext, N) — uint64 for the
        jnp backend, Montgomery uint32 for pallas.  Cached."""
        key = (id(evk), level)
        if key not in self._evk_level:
            plan = self._plan(level)
            full = self._evk_stacked(evk)
            sl = full[: plan.dnum][:, :, ext_rows(self.params, level)]
            if self.backend == "pallas":
                sl = jnp.asarray(_to_mont_host_rows(
                    np.asarray(sl), np.array(plan.ext, dtype=np.uint64)
                ))
            self._evk_level[key] = sl
        return self._evk_level[key]

    def evk_group_tensor(self, evks: list[EvalKey],
                         level: int) -> jnp.ndarray:
        """(R, dnum, 2, l_ext, N) stack for a hoisted rotation group.
        Bounded (FIFO eviction) — rotation groups vary across programs."""
        key = (tuple(id(k) for k in evks), level)
        if key not in self._evk_group:
            while len(self._evk_group) >= 64:
                self._evk_group.pop(next(iter(self._evk_group)))
            self._evk_group[key] = jnp.stack(
                [self.evk_tensor(k, level) for k in evks]
            )
        return self._evk_group[key]

    def perm_tensor(self, galois_list: list[int]) -> jnp.ndarray:
        """(R, N) eval-domain automorphism gather indices."""
        key = tuple(galois_list)
        if key not in self._perm_cache:
            self._perm_cache[key] = jnp.asarray(np.stack(
                [self.pc.rns.autom_eval_perm(g).astype(np.int32)
                 for g in galois_list]
            ))
        return self._perm_cache[key]

    # ------------------------- traced primitives -----------------------
    def _ntt(self, x, primes, plan: KeyswitchPlan):
        """Batched forward NTT, core (natural) eval order in/out."""
        if self.backend == "pallas":
            y = ntt_fwd(x.astype(jnp.uint32), primes, self.tabs,
                        interpret=self.interpret)
            return y[:, plan.bitrev].astype(jnp.uint64)
        return poly.ntt(x, primes, self.pc)

    def _intt(self, x, primes, plan: KeyswitchPlan):
        if self.backend == "pallas":
            y = ntt_inv(x[:, plan.bitrev].astype(jnp.uint32), primes,
                        self.tabs, interpret=self.interpret)
            return y.astype(jnp.uint64)
        return poly.intt(x, primes, self.pc)

    def _modup(self, a, plan: KeyswitchPlan):
        """(l, N) eval -> (dnum, l_ext, N) eval, all digits at once."""
        if self.backend == "pallas":
            # ONE fused pallas_call per digit (kernels/modup): INTT ->
            # BConv tree-reduce -> NTT with the digit VMEM-resident
            # across all three phases — no per-phase HBM intermediates.
            # The bitrev bridge happens ONCE at each boundary; own-limb
            # passthrough stays outside the kernel (shared below).
            x = a[:, plan.bitrev].astype(jnp.uint32)
            digs = []
            row = 0
            for D in plan.groups:
                digs.append(modup_digit(
                    x[row : row + len(D)], tuple(D), plan.ext,
                    self.tabs, self.pc.rns, interpret=self.interpret,
                ))
                row += len(D)
            conv = jnp.stack(digs)[:, :, plan.bitrev].astype(jnp.uint64)
        else:
            coeff = self._intt(a, plan.base, plan)
            t = (coeff * plan.qinv[:, None]) % plan.base_mods[:, None]
            td = t[plan.src_idx]                       # (dnum, alpha, N)
            em = plan.ext_mods[None, :, None]
            conv = jnp.zeros(
                (plan.dnum, plan.l_ext, plan.N), dtype=jnp.uint64
            )
            for i in range(0, plan.alpha, _CHUNK):
                part = (
                    td[:, i : i + _CHUNK, None, :]
                    * plan.C[:, i : i + _CHUNK, :, None]
                ) % em[None]
                conv = (conv + part.sum(axis=1)) % em
            conv = conv.reshape(plan.dnum * plan.l_ext, plan.N)
            conv = self._ntt(conv, plan.ext_tiled, plan)
            conv = conv.reshape(plan.dnum, plan.l_ext, plan.N)
        own = a[plan.own_idx]                          # (dnum, l_ext, N)
        return jnp.where(plan.own_mask[:, :, None], own, conv)

    def _ip(self, digits, evk, plan: KeyswitchPlan):
        """(dnum, l_ext, N) x (dnum, 2, l_ext, N) -> (2, l_ext, N)."""
        if self.backend == "pallas":
            a0, a1 = fused_ip_mont(
                digits.astype(jnp.uint32), evk, None, plan.q32, plan.qneg32,
                interpret=self.interpret,
            )
            return jnp.stack([a0, a1]).astype(jnp.uint64)
        em = plan.ext_mods[None, None, :, None]
        prod = (digits[:, None] * evk) % em            # (dnum, 2, l_ext, N)
        return prod.sum(axis=0) % em[0]

    def _moddown2(self, acc, plan: KeyswitchPlan):
        """Batched ModDown of both accumulators: (2, l_ext, N) -> (2, l, N)."""
        xq, xp = acc[:, : plan.l], acc[:, plan.l :]
        xpc = self._intt(
            xp.reshape(2 * plan.k, plan.N), plan.p_tiled, plan
        )
        bm = plan.base_mods[None, :, None]
        if self.backend == "pallas":
            conv = jnp.stack([
                bconv_kernel(
                    xpc[c * plan.k : (c + 1) * plan.k].astype(jnp.uint32),
                    self.params.p_primes, plan.base, self.pc.rns,
                    interpret=self.interpret,
                )
                for c in range(2)
            ]).astype(jnp.uint64)
        else:
            xpc = xpc.reshape(2, plan.k, plan.N)
            t = (xpc * plan.md_qhat_inv[None, :, None]) % plan.p_mods[None, :, None]
            conv = jnp.zeros((2, plan.l, plan.N), dtype=jnp.uint64)
            for i in range(0, plan.k, _CHUNK):
                part = (
                    t[:, i : i + _CHUNK, None, :]
                    * plan.md_C[None, i : i + _CHUNK, :, None]
                ) % bm[:, None]
                conv = (conv + part.sum(axis=1)) % bm
        conv = self._ntt(
            conv.reshape(2 * plan.l, plan.N), plan.base_tiled, plan
        ).reshape(2, plan.l, plan.N)
        diff = (xq + bm - conv) % bm
        return (diff * plan.pinv[None, :, None]) % bm

    # ------------------------- jitted entry points ---------------------
    def _ks_fn(self, level: int):
        if level not in self._ks_fns:
            plan = self._plan(level)

            def fn(a, evk):
                self._count_trace(("keyswitch", level))
                digits = self._modup(a, plan)
                d = self._moddown2(self._ip(digits, evk, plan), plan)
                return d[0], d[1]

            self._ks_fns[level] = jax.jit(fn)
        return self._ks_fns[level]

    def _galois_fn(self, level: int):
        if level not in self._galois_fns:
            plan = self._plan(level)

            def fn(c0, c1, perm, evk):
                self._count_trace(("galois", level))
                digits = self._modup(c1[:, perm], plan)
                d = self._moddown2(self._ip(digits, evk, plan), plan)
                bm = plan.base_mods[:, None]
                return (c0[:, perm] + d[0]) % bm, d[1]

            self._galois_fns[level] = jax.jit(fn)
        return self._galois_fns[level]

    def _hoist_core(self, plan: KeyswitchPlan, n_rot: int, with_pt: bool,
                    c0, digits, perms, evk_all, pm_ext, pm_base, pm_ext_m):
        """Hoisted-rotation-sum body AFTER ModUp: rotate digits, IP,
        accumulate, one batched ModDown.  Shared by the monolithic,
        digits-in and vmap-batched entry points (bit-exact across all)."""
        # One gather rotates ALL digits for ALL rotations.
        d_rot = jnp.transpose(
            digits[:, :, perms], (2, 0, 1, 3)
        )                                      # (R, dnum, l_ext, N)
        em = plan.ext_mods[None, :, None]
        if self.backend == "pallas":
            acc = None
            for r in range(n_rot):
                a0, a1 = fused_ip_mont(
                    d_rot[r].astype(jnp.uint32), evk_all[r],
                    pm_ext_m[r] if with_pt else None,
                    plan.q32, plan.qneg32, interpret=self.interpret,
                )
                ipr = jnp.stack([a0, a1]).astype(jnp.uint64)
                acc = ipr if acc is None else (acc + ipr) % em
        else:
            prod = (d_rot[:, :, None] * evk_all) % em[None, None]
            ip = prod.sum(axis=1) % em[None]   # (R, 2, l_ext, N)
            if with_pt:
                ip = (ip * pm_ext[:, None]) % em[None]
            acc = ip.sum(axis=0) % em
        bm = plan.base_mods[None, :, None]
        c0r = jnp.transpose(c0[:, perms], (1, 0, 2))  # (R, l, N)
        if with_pt:
            c0r = (c0r * pm_base) % bm
        base0 = c0r.sum(axis=0) % plan.base_mods[:, None]
        d = self._moddown2(acc, plan)
        return (base0 + d[0]) % plan.base_mods[:, None], d[1]

    def _hoist_fn(self, level: int, n_rot: int, with_pt: bool):
        key = (level, n_rot, with_pt)
        if key not in self._hoist_fns:
            plan = self._plan(level)

            def fn(c0, c1, perms, evk_all, pm_ext, pm_base, pm_ext_m):
                self._count_trace(("hoisted", level, n_rot, with_pt))
                digits = self._modup(c1, plan)
                return self._hoist_core(plan, n_rot, with_pt, c0, digits,
                                        perms, evk_all, pm_ext, pm_base,
                                        pm_ext_m)

            self._hoist_fns[key] = jax.jit(fn)
        return self._hoist_fns[key]

    def _hoist_digits_fn(self, level: int, n_rot: int, with_pt: bool):
        """Hoisted sum from PRE-COMPUTED digits — the runtime shares one
        ModUp across sibling blocks anchored on the same ciphertext."""
        key = ("digits", level, n_rot, with_pt)
        if key not in self._hoist_fns:
            plan = self._plan(level)

            def fn(c0, digits, perms, evk_all, pm_ext, pm_base, pm_ext_m):
                self._count_trace(("hoisted_digits", level, n_rot, with_pt))
                return self._hoist_core(plan, n_rot, with_pt, c0, digits,
                                        perms, evk_all, pm_ext, pm_base,
                                        pm_ext_m)

            self._hoist_fns[key] = jax.jit(fn)
        return self._hoist_fns[key]

    def _acc_ip_ext(self, plan: KeyswitchPlan, n: int, d_terms, evk_all):
        """sum_r IP(d_terms[r], evk_all[r]) in the extended basis.

        ``d_terms``: (n, dnum, l_ext, N); ``evk_all``: (n | 1, dnum, 2,
        l_ext, N) — a leading 1 broadcasts ONE shared evk (the relin
        mult key) over every term.  The single accumulation body behind
        both merged-ModDown flavors (multi-anchor rotation sums and
        multi-relin closures), on either backend."""
        em = plan.ext_mods[None, :, None]
        if self.backend == "pallas":
            shared = evk_all.shape[0] == 1
            acc = None
            for r in range(n):
                a0, a1 = fused_ip_mont(
                    d_terms[r].astype(jnp.uint32),
                    evk_all[0] if shared else evk_all[r], None,
                    plan.q32, plan.qneg32, interpret=self.interpret,
                )
                ipr = jnp.stack([a0, a1]).astype(jnp.uint64)
                acc = ipr if acc is None else (acc + ipr) % em
            return acc
        prod = (d_terms[:, :, None] * evk_all) % em[None, None]
        ip = prod.sum(axis=1) % em[None]       # (n, 2, l_ext, N)
        return ip.sum(axis=0) % em

    def _multi_core(self, plan: KeyswitchPlan, n: int, c0s, digits,
                    perms, evk_all):
        """Multi-anchor accumulation body: rotate each anchor's digits
        by ITS perm, IP against ITS evk, accumulate every term in the
        extended basis, and close with ONE batched ModDown."""
        d_rot = jax.vmap(lambda d, p: d[:, :, p])(digits, perms)
        acc = self._acc_ip_ext(plan, n, d_rot, evk_all)
        bm = plan.base_mods[:, None]
        c0r = jax.vmap(lambda c, p: c[:, p])(c0s, perms)   # (n, l, N)
        base0 = c0r.sum(axis=0) % bm
        d = self._moddown2(acc, plan)
        return (base0 + d[0]) % bm, d[1]

    def _multi_fn(self, level: int, n: int):
        key = ("multi", level, n)
        if key not in self._hoist_fns:
            plan = self._plan(level)

            def fn(c0s, digits, perms, evk_all):
                self._count_trace(("multi_hoisted", level, n))
                return self._multi_core(plan, n, c0s, digits, perms,
                                        evk_all)

            self._hoist_fns[key] = jax.jit(fn)
        return self._hoist_fns[key]

    # ------------------------- relinearization -------------------------
    # Relin is the OTHER member of the keyswitch family: same ModUp ->
    # IP -> ModDown datapath, with the d2 tensor-product component in
    # the role of the rotated c1 and the (single, program-wide) mult key
    # in the role of the per-step rotation keys.  The entry points below
    # reuse the same KeyswitchPlan/jit caches and the same
    # ``modup``/``digits=`` digits interface as the hoisted rotations.
    def _relin_core(self, plan: KeyswitchPlan, d0, d1, digits, evk):
        """IP + ModDown of relin digits, folded into (d0, d1)."""
        d = self._moddown2(self._ip(digits, evk, plan), plan)
        bm = plan.base_mods[:, None]
        return (d0 + d[0]) % bm, (d1 + d[1]) % bm

    def _relin_fn(self, level: int, digits_in: bool):
        key = ("relin", level, digits_in)
        if key not in self._hoist_fns:
            plan = self._plan(level)

            def fn(d0, d1, x, evk):
                self._count_trace(("relin", level, digits_in))
                digits = x if digits_in else self._modup(x, plan)
                return self._relin_core(plan, d0, d1, digits, evk)

            self._hoist_fns[key] = jax.jit(fn)
        return self._hoist_fns[key]

    def _multi_relin_core(self, plan: KeyswitchPlan, n: int, d0s, d1s,
                          digits, evk):
        """Multi-relin accumulation body: every term's IP (against the
        SHARED mult key) accumulates in the extended basis; ONE batched
        ModDown closes the sum — the relin analogue of ``_multi_core``
        (ARK-style lazy/deferred ModDown over summed relin outputs)."""
        acc = self._acc_ip_ext(plan, n, digits, evk[None])
        bm = plan.base_mods[:, None]
        base0 = d0s.sum(axis=0) % bm
        base1 = d1s.sum(axis=0) % bm
        d = self._moddown2(acc, plan)
        return (base0 + d[0]) % bm, (base1 + d[1]) % bm

    def _multi_relin_fn(self, level: int, n: int):
        key = ("multi_relin", level, n)
        if key not in self._hoist_fns:
            plan = self._plan(level)

            def fn(d0s, d1s, digits, evk):
                self._count_trace(("multi_relin", level, n))
                return self._multi_relin_core(plan, n, d0s, d1s, digits,
                                              evk)

            self._hoist_fns[key] = jax.jit(fn)
        return self._hoist_fns[key]

    def _modup_fn(self, level: int):
        if level not in self._modup_fns:
            plan = self._plan(level)

            def fn(a):
                self._count_trace(("modup", level))
                return self._modup(a, plan)

            self._modup_fns[level] = jax.jit(fn)
        return self._modup_fns[level]

    # ------------------------- batched (vmap) fns ----------------------
    def _batched_fn(self, key: tuple, make):
        """jit(vmap) plan cache: one trace per (op, level, shape) plan —
        re-dispatch at the same batch shape is a cache hit (asserted by
        ``trace_counts``, which only increments while tracing)."""
        if key not in self._batch_fns:
            self._batch_fns[key] = jax.jit(make())
        return self._batch_fns[key]

    def _ks_batched_fn(self, level: int):
        plan = self._plan(level)

        def make():
            def fn(ab, evk):
                self._count_trace(("keyswitch_b", level))

                def one(a):
                    digits = self._modup(a, plan)
                    d = self._moddown2(self._ip(digits, evk, plan), plan)
                    return d[0], d[1]

                return jax.vmap(one)(ab)

            return fn

        return self._batched_fn(("keyswitch_b", level), make)

    def _galois_batched_fn(self, level: int):
        plan = self._plan(level)

        def make():
            def fn(c0b, c1b, perm, evk):
                self._count_trace(("galois_b", level))
                bm = plan.base_mods[:, None]

                def one(c0, c1):
                    digits = self._modup(c1[:, perm], plan)
                    d = self._moddown2(self._ip(digits, evk, plan), plan)
                    return (c0[:, perm] + d[0]) % bm, d[1]

                return jax.vmap(one)(c0b, c1b)

            return fn

        return self._batched_fn(("galois_b", level), make)

    def _hoist_batched_fn(self, level: int, n_rot: int, with_pt: bool,
                          digits_in: bool):
        plan = self._plan(level)

        def make():
            def fn(c0b, xb, perms, evk_all, pm_ext, pm_base, pm_ext_m):
                self._count_trace(
                    ("hoisted_b", level, n_rot, with_pt, digits_in))

                def one(c0, x):
                    digits = x if digits_in else self._modup(x, plan)
                    return self._hoist_core(
                        plan, n_rot, with_pt, c0, digits, perms, evk_all,
                        pm_ext, pm_base, pm_ext_m,
                    )

                return jax.vmap(one)(c0b, xb)

            return fn

        return self._batched_fn(
            ("hoisted_b", level, n_rot, with_pt, digits_in), make)

    def _multi_batched_fn(self, level: int, n: int):
        plan = self._plan(level)

        def make():
            def fn(c0s, digits, perms, evk_all):
                self._count_trace(("multi_hoisted_b", level, n))

                def one(c0s_1, digits_1):
                    return self._multi_core(plan, n, c0s_1, digits_1,
                                            perms, evk_all)

                return jax.vmap(one, in_axes=(1, 1))(c0s, digits)

            return fn

        return self._batched_fn(("multi_hoisted_b", level, n), make)

    def _relin_batched_fn(self, level: int, digits_in: bool):
        plan = self._plan(level)

        def make():
            def fn(d0b, d1b, xb, evk):
                self._count_trace(("relin_b", level, digits_in))

                def one(d0, d1, x):
                    digits = x if digits_in else self._modup(x, plan)
                    return self._relin_core(plan, d0, d1, digits, evk)

                return jax.vmap(one)(d0b, d1b, xb)

            return fn

        return self._batched_fn(("relin_b", level, digits_in), make)

    def _multi_relin_batched_fn(self, level: int, n: int):
        plan = self._plan(level)

        def make():
            def fn(d0s, d1s, digits, evk):
                self._count_trace(("multi_relin_b", level, n))

                def one(d0s_1, d1s_1, digits_1):
                    return self._multi_relin_core(plan, n, d0s_1, d1s_1,
                                                  digits_1, evk)

                return jax.vmap(one, in_axes=(1, 1, 1))(d0s, d1s, digits)

            return fn

        return self._batched_fn(("multi_relin_b", level, n), make)

    def _modup_batched_fn(self, level: int):
        plan = self._plan(level)

        def make():
            def fn(ab):
                self._count_trace(("modup_b", level))
                return jax.vmap(lambda a: self._modup(a, plan))(ab)

            return fn

        return self._batched_fn(("modup_b", level), make)

    # ------------------------- public API ------------------------------
    def keyswitch(self, a, evk: EvalKey, level: int):
        """ModUp -> IP -> ModDown of poly ``a``: (d0, d1) under Q_level."""
        self._note_dispatch("keyswitch")
        self._note_keyswitch(self._plan(level))
        return self._ks_fn(level)(a, self.evk_tensor(evk, level))

    def apply_galois(self, c0, c1, galois: int, evk: EvalKey, level: int):
        """Fused rotate: eval-domain automorphism + keyswitch of c1."""
        self._note_dispatch("rotate")
        self._note_keyswitch(self._plan(level))
        self.counters.rotation += 1
        perm = self.perm_tensor([galois])[0]
        return self._galois_fn(level)(
            c0, c1, perm, self.evk_tensor(evk, level)
        )

    def modup(self, a, level: int):
        """Standalone ModUp of poly ``a`` -> (dnum, l_ext, N) digits.

        The runtime executor shares the result across all hoisted blocks
        anchored on the same ciphertext (cross-block double hoisting)."""
        self._note_dispatch("modup")
        plan = self._plan(level)
        self.counters.note_modup(plan.l, plan.l_ext, plan.group_sizes,
                                 plan.N)
        return self._modup_fn(level)(a)

    def hoisted_rotation_sum(self, c0, c1, galois_list: list[int],
                             evks: list[EvalKey], level: int,
                             pm_ext=None, pm_base=None, pm_ext_mont=None,
                             digits=None):
        """sum_r [pt_r *] Rot(ct, r): ONE ModUp, ONE (batched) ModDown.

        pm_ext/pm_base: (R, l_ext, N) / (R, l, N) PModUp'd plaintexts
        (uint64); pm_ext_mont: Montgomery uint32 form (pallas backend,
        which reads it INSTEAD of pm_ext — pm_ext may then be None).
        ``digits``: pre-computed ModUp digits from :meth:`modup` — the
        internal ModUp is skipped (bit-exact with the monolithic path).
        """
        self._note_dispatch("hoisted_rotation_sum")
        plan = self._plan(level)
        self._note_hoisted(plan, len(galois_list), digits is None)
        perms = self.perm_tensor(galois_list)
        evk_all = self.evk_group_tensor(evks, level)
        with_pt = pm_base is not None
        if digits is not None:
            fn = self._hoist_digits_fn(level, len(galois_list), with_pt)
            return fn(c0, digits, perms, evk_all, pm_ext, pm_base,
                      pm_ext_mont)
        fn = self._hoist_fn(level, len(galois_list), with_pt)
        return fn(c0, c1, perms, evk_all, pm_ext, pm_base, pm_ext_mont)

    def multi_hoisted_rotation_sum(self, c0s, digits_list, galois_list,
                                   evks, level: int):
        """sum_i Rot_{g_i}(ct_i) over DIFFERENT anchor ciphertexts with
        ONE ModDown (``runtime.lower.MultiHoistedStep``).

        ``c0s``/``digits_list``: per-term c0 polynomials and pre-computed
        ModUp digits (from :meth:`modup` — each anchor pays its own
        ModUp, shared with sibling hoisted blocks via the runtime's
        digits cache).  Per-term IPs accumulate in the extended basis;
        a single batched ModDown closes the sum — numerically close to,
        but not bit-identical with, per-rotation keyswitches (the
        approximate-FBC rounding of the merged ModDowns differs).
        """
        self._note_dispatch("multi_hoisted_rotation_sum")
        plan = self._plan(level)
        n = len(galois_list)
        c = self.counters
        c.note_ip(plan.dnum, plan.l_ext, plan.N, n)
        c.note_moddown(plan.l, plan.k, plan.N)
        c.keyswitch += n
        c.rotation += n
        perms = self.perm_tensor(galois_list)
        evk_all = self.evk_group_tensor(evks, level)
        return self._multi_fn(level, n)(
            jnp.stack(c0s), jnp.stack(digits_list), perms, evk_all
        )

    def relin(self, d0, d1, d2, evk: EvalKey, level: int, digits=None):
        """Relinearize a degree-2 ciphertext: (d0, d1) + KS(d2).

        The relin member of the keyswitch family: ModUp of the d2
        tensor-product component (skipped when pre-computed ``digits``
        from :meth:`modup` are passed — same digits-cache interface as
        the hoisted rotations), one IP against the mult key, one batched
        ModDown, and the base-domain folds into d0/d1 — all inside one
        cached jit plan.  Bit-exact with keyswitch-then-add.
        """
        self._note_dispatch("relin")
        plan = self._plan(level)
        self._note_relin(plan, digits is None)
        fn = self._relin_fn(level, digits is not None)
        x = digits if digits is not None else d2
        return fn(d0, d1, x, self.evk_tensor(evk, level))

    def multi_relin_sum(self, d0s, d1s, digits_list, evk: EvalKey,
                        level: int):
        """sum_i [(d0_i, d1_i) + KS(d2_i)] with ONE ModDown
        (``runtime.lower.MultiRelinStep``).

        ``digits_list``: per-term pre-computed ModUp digits of the d2
        components (from :meth:`modup` — each term pays its own ModUp;
        d2 tensors are fresh per CMult, so unlike rotation anchors they
        never share one).  Every term's IP against the SHARED mult key
        accumulates in the extended basis and a single batched ModDown
        closes the sum — numerically close to, but not bit-identical
        with, per-term relinearization (the approximate-FBC rounding of
        the merged ModDowns differs), exactly like
        :meth:`multi_hoisted_rotation_sum`.
        """
        self._note_dispatch("multi_relin_sum")
        plan = self._plan(level)
        n = len(digits_list)
        self._note_relin(plan, with_modup=False, n=n)
        return self._multi_relin_fn(level, n)(
            jnp.stack(d0s), jnp.stack(d1s), jnp.stack(digits_list),
            self.evk_tensor(evk, level),
        )

    # -------- batched public API (leading ct axis, jnp backend) --------
    def keyswitch_batched(self, ab, evk: EvalKey, level: int):
        """Batched keyswitch of (B, l, N) polys through ONE jit trace."""
        self._note_dispatch("keyswitch_batched")
        self._note_keyswitch(self._plan(level), m=int(ab.shape[0]))
        return self._ks_batched_fn(level)(ab, self.evk_tensor(evk, level))

    def apply_galois_batched(self, c0b, c1b, galois: int, evk: EvalKey,
                             level: int):
        self._note_dispatch("rotate_batched")
        self._note_keyswitch(self._plan(level), m=int(c0b.shape[0]))
        self.counters.rotation += int(c0b.shape[0])
        perm = self.perm_tensor([galois])[0]
        return self._galois_batched_fn(level)(
            c0b, c1b, perm, self.evk_tensor(evk, level)
        )

    def modup_batched(self, ab, level: int):
        self._note_dispatch("modup_batched")
        plan = self._plan(level)
        plan_sizes = plan.group_sizes
        self.counters.note_modup(plan.l, plan.l_ext, plan_sizes, plan.N,
                                 m=int(ab.shape[0]))
        return self._modup_batched_fn(level)(ab)

    def multi_hoisted_rotation_sum_batched(self, c0s, digits_list,
                                           galois_list, evks, level: int):
        """Batched multi-anchor accumulation: per-term (B, l, N) c0s and
        (B, dnum, l_ext, N) digits, vmapped over the ct axis."""
        self._note_dispatch("multi_hoisted_rotation_sum_batched")
        plan = self._plan(level)
        n = len(galois_list)
        m = int(c0s[0].shape[0])
        c = self.counters
        c.note_ip(plan.dnum, plan.l_ext, plan.N, n, m)
        c.note_moddown(plan.l, plan.k, plan.N, m)
        c.keyswitch += m * n
        c.rotation += m * n
        perms = self.perm_tensor(galois_list)
        evk_all = self.evk_group_tensor(evks, level)
        return self._multi_batched_fn(level, n)(
            jnp.stack(c0s), jnp.stack(digits_list), perms, evk_all
        )

    def relin_batched(self, d0b, d1b, d2b, evk: EvalKey, level: int,
                      digits=None):
        """Batched relinearization of (B, l, N) degree-2 components
        through ONE jit trace (``digits``: (B, dnum, l_ext, N))."""
        self._note_dispatch("relin_batched")
        plan = self._plan(level)
        self._note_relin(plan, digits is None, m=int(d0b.shape[0]))
        fn = self._relin_batched_fn(level, digits is not None)
        x = digits if digits is not None else d2b
        return fn(d0b, d1b, x, self.evk_tensor(evk, level))

    def multi_relin_sum_batched(self, d0s, d1s, digits_list,
                                evk: EvalKey, level: int):
        """Batched multi-relin accumulation: per-term (B, l, N) d0/d1
        and (B, dnum, l_ext, N) digits, vmapped over the ct axis."""
        self._note_dispatch("multi_relin_sum_batched")
        plan = self._plan(level)
        n = len(digits_list)
        self._note_relin(plan, with_modup=False, n=n,
                         m=int(d0s[0].shape[0]))
        return self._multi_relin_batched_fn(level, n)(
            jnp.stack(d0s), jnp.stack(d1s), jnp.stack(digits_list),
            self.evk_tensor(evk, level),
        )

    def hoisted_rotation_sum_batched(self, c0b, c1b, galois_list,
                                     evks, level: int, pm_ext=None,
                                     pm_base=None, pm_ext_mont=None,
                                     digits=None):
        """vmap over the ct axis: (B, l, N) c0/c1 (or (B, dnum, l_ext, N)
        pre-computed ``digits``), shared perm/evk/plaintext tensors."""
        self._note_dispatch("hoisted_rotation_sum_batched")
        plan = self._plan(level)
        self._note_hoisted(plan, len(galois_list), digits is None,
                           m=int(c0b.shape[0]))
        perms = self.perm_tensor(galois_list)
        evk_all = self.evk_group_tensor(evks, level)
        with_pt = pm_base is not None
        fn = self._hoist_batched_fn(level, len(galois_list), with_pt,
                                    digits is not None)
        x = digits if digits is not None else c1b
        return fn(c0b, x, perms, evk_all, pm_ext, pm_base, pm_ext_mont)
