"""Lightweight op counters for the CKKS runtime.

``CKKSContext`` (and its ``KeyswitchEngine``) increment these at dispatch
time — outside any jit trace — so runtime reports and parity tests can
assert *how many* ModUp/ModDown/IP invocations actually ran, not just
that values matched.  Word/MAC volumes are derived from the engine's
real per-level plan shapes (the digit group sizes and extended-basis
width), which makes them directly comparable against the analytic
predictions in ``repro.dfg.hoist`` (see ``repro.runtime.report``).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class OpCounters:
    """Invocation counts + plan-shape-derived work volumes.

    Counts follow the conventions of ``dfg.hoist.OpVolumes``: one ModDown
    of both accumulator polynomials counts once; one IP covers all dnum
    digits of one rotation/relinearization.
    """

    modup: int = 0
    moddown: int = 0
    ip: int = 0
    keyswitch: int = 0          # logical keyswitches (rotations + relins)
    rotation: int = 0
    relin: int = 0              # relinearization keyswitches (CMults)
    hoisted_blocks: int = 0
    relin_blocks: int = 0       # merged multi-relin accumulation blocks
    ntt_words: float = 0.0      # INTT + NTT butterfly-pass words
    bconv_macs: float = 0.0
    ip_macs: float = 0.0

    # ------------------------- note_* helpers --------------------------
    def note_modup(self, l: int, ext: int, group_sizes: tuple[int, ...],
                   N: int, m: int = 1) -> None:
        """One ModUp of an l-limb poly to the ext-limb basis (m cts)."""
        self.modup += m
        self.ntt_words += m * (l + sum(ext - a for a in group_sizes)) * N
        self.bconv_macs += m * sum(a * (ext - a) for a in group_sizes) * N

    def note_moddown(self, l: int, k: int, N: int, m: int = 1) -> None:
        """One batched 2-poly ModDown from (l+k) limbs back to l."""
        self.moddown += m
        self.ntt_words += m * 2 * (k + l) * N
        self.bconv_macs += m * 2 * k * l * N

    def note_ip(self, dnum: int, ext: int, N: int, n: int = 1,
                m: int = 1) -> None:
        """n inner products over the extended basis (2 components each)."""
        self.ip += m * n
        self.ip_macs += m * n * dnum * ext * N * 2

    # ------------------------- bookkeeping -----------------------------
    def snapshot(self) -> "OpCounters":
        return dataclasses.replace(self)

    def delta(self, since: "OpCounters") -> "OpCounters":
        return OpCounters(*[
            getattr(self, f.name) - getattr(since, f.name)
            for f in dataclasses.fields(self)
        ])

    def reset(self) -> None:
        for f in dataclasses.fields(self):
            setattr(self, f.name, f.default)

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)
