"""CKKS core: RNS polynomial arithmetic, scheme ops, bootstrap stages.

All exact modular arithmetic is carried out in uint64 (products of two
<2^30 residues fit exactly), which requires jax x64 mode.  Model code in
``repro.models`` pins every dtype explicitly, so enabling x64 here is safe
for the rest of the framework.
"""
import jax

jax.config.update("jax_enable_x64", True)

from repro.core.params import CKKSParams, SMALL_TEST_PARAMS, PAPER_PARAMS  # noqa: E402,F401
