"""CKKS parameter sets (RNS prime chains, decomposition, scale).

The paper (Table II) uses N=2^16, L=35, k=12, alpha=12, dnum=3 with 36-bit
words at 128-bit security.  JAX has no 36-bit integer type, so the functional
implementation uses <=30-bit RNS primes (products of two residues fit uint64
exactly, and the Pallas kernels' 16-bit-limb Montgomery path stays in
uint32).  The *simulator* (repro.sim) models the paper's exact parameter
set; the functional tests run reduced N for CPU tractability — the
arithmetic is dimension-generic.
"""
from __future__ import annotations

import dataclasses
import math
from functools import cached_property

from repro.core import nt


@dataclasses.dataclass(frozen=True)
class CKKSParams:
    """Static CKKS/RNS parameters.

    Attributes:
      logN: log2 of ring degree (ring is Z[X]/(X^N+1)).
      L: maximum level — the Q chain has L+1 primes q_0..q_L.
      alpha: decomposition group size (number of Q primes per digit).
      k: number of special primes (the P basis); k >= alpha.
      q_bits: bit size of the chain primes (q_1..q_L, and the P primes).
      q0_bits: bit size of the base prime q_0 (bigger for decrypt headroom).
      scale_bits: log2 of the encoding scale Delta.
    """

    logN: int = 16
    L: int = 35
    alpha: int = 12
    k: int = 12
    q_bits: int = 30
    q0_bits: int = 30
    scale_bits: int = 28

    @property
    def N(self) -> int:
        return 1 << self.logN

    @property
    def num_slots(self) -> int:
        return self.N // 2

    @property
    def dnum(self) -> int:
        return math.ceil((self.L + 1) / self.alpha)

    @property
    def scale(self) -> float:
        return float(1 << self.scale_bits)

    @cached_property
    def q_primes(self) -> tuple[int, ...]:
        """q_0 .. q_L (q_0 first)."""
        two_n = 2 * self.N
        q0 = nt.find_primes(1, self.q0_bits, two_n)
        rest = nt.find_primes(self.L, self.q_bits, two_n, avoid=q0)
        return tuple(q0 + rest)

    @cached_property
    def p_primes(self) -> tuple[int, ...]:
        two_n = 2 * self.N
        return tuple(
            nt.find_primes(self.k, self.q_bits, two_n, avoid=self.q_primes)
        )

    def q_chain(self, level: int) -> tuple[int, ...]:
        """Primes active at ``level`` (level L = fresh, level 0 = last)."""
        if not 0 <= level <= self.L:
            raise ValueError(f"level {level} out of range [0, {self.L}]")
        return self.q_primes[: level + 1]

    def digit_groups(self, level: int) -> list[tuple[int, ...]]:
        """Decomposition of the level-``level`` chain into dnum groups of
        alpha primes (last group may be short)."""
        chain = self.q_chain(level)
        return [
            tuple(chain[i : i + self.alpha])
            for i in range(0, len(chain), self.alpha)
        ]

    @property
    def P(self) -> int:
        return math.prod(self.p_primes)

    def Q(self, level: int) -> int:
        return math.prod(self.q_chain(level))

    # --- size bookkeeping used by the DFG optimizer / simulator ---------
    def limb_bytes(self, word_bytes: int = 8) -> int:
        return self.N * word_bytes

    def ct_bytes(self, level: int, word_bytes: int = 8) -> int:
        """Two polynomials, level+1 limbs each."""
        return 2 * (level + 1) * self.limb_bytes(word_bytes)

    def evk_bytes(self, level: int | None = None, word_bytes: int = 8) -> int:
        """One evk: dnum digits x 2 polys over the extended basis Q_L u P.

        evks are stored at the top level (L) as in real libraries.
        """
        n_limbs = (self.L + 1) + self.k
        return self.dnum * 2 * n_limbs * self.limb_bytes(word_bytes)


# Paper configuration (used by the simulator and DFG cost models).
PAPER_PARAMS = CKKSParams(logN=16, L=35, alpha=12, k=12, scale_bits=28)

# Functional-test configuration: small ring, shallow chain — runs the full
# scheme (keygen/encrypt/mult/rotate/rescale/keyswitch) on CPU in seconds.
SMALL_TEST_PARAMS = CKKSParams(
    logN=10, L=5, alpha=2, k=2, q_bits=30, q0_bits=30, scale_bits=28
)

# Mid-size configuration for the bootstrap pipeline tests.
BOOT_TEST_PARAMS = CKKSParams(
    logN=11, L=14, alpha=3, k=3, q_bits=30, q0_bits=30, scale_bits=25
)
