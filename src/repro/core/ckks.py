"""RNS-CKKS scheme: encrypt/decrypt, EWOs, keyswitch, rotation, hoisting.

Ciphertext polynomials are (level+1, N) uint64 arrays in EVAL (NTT) domain.
ModUp/ModDown follow the paper's xPU pipeline (INTT -> BConv -> NTT).
The hoisted-rotation API implements "double hoisting" (Bossuat et al. [4]):
one ModUp per ciphertext, one ModDown per linear combination — the
communication-reduction primitive HERO maximizes.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import poly
from repro.core.counters import OpCounters
from repro.core.encoding import Encoder
from repro.core.keys import EvalKey, KeyChain, sample_gaussian, to_rns
from repro.core.keyswitch import (
    KeyswitchEngine, _to_mont_host_rows, ext_rows,
)
from repro.core.params import CKKSParams
from repro.errors import (
    CorruptCiphertextError, LevelExhaustedError,
    ModulusChainMismatchError, ScaleDriftError,
)


@dataclasses.dataclass
class Ciphertext:
    c0: jnp.ndarray  # (level+1, N) eval domain
    c1: jnp.ndarray
    level: int
    scale: float

    @property
    def n_limbs(self) -> int:
        return self.level + 1


@dataclasses.dataclass
class Plaintext:
    m: jnp.ndarray  # (level+1, N) eval domain
    level: int
    scale: float


def tensor_product(a: Ciphertext, b: Ciphertext, mods) -> tuple:
    """(d0, d1, d2) of the degree-2 ciphertext product, pre-relin.

    The single call site for the CMult tensor product: both the eager
    ``CKKSContext.multiply`` and the compiled runtime's ``RelinStep``/
    ``MultiRelinStep`` execution build their d-components here, so the
    relin keyswitch always sees identical operands.  Elementwise mod-q
    ops broadcast over an optional leading batch axis unchanged.
    """
    d0 = poly.mul(a.c0, b.c0, mods)
    d1 = poly.add(
        poly.mul(a.c0, b.c1, mods), poly.mul(a.c1, b.c0, mods), mods
    )
    d2 = poly.mul(a.c1, b.c1, mods)
    return d0, d1, d2


class CKKSContext:
    """Everything needed to run CKKS programs functionally.

    ``backend`` ("jnp" | "pallas") selects the keyswitch engine's
    numeric implementation; ``use_engine=False`` falls back to the seed
    per-digit/per-rotation loop path (kept for benchmarking and parity
    tests — both paths are bit-exact).
    """

    def __init__(self, params: CKKSParams, seed: int = 1234,
                 hamming_weight: int | None = None,
                 backend: str = "jnp", use_engine: bool = True):
        self.params = params
        self.pc = poly.PolyContext(params, backend=backend)
        self.encoder = Encoder(params)
        self.keys = KeyChain(
            params, self.pc, seed=seed, hamming_weight=hamming_weight
        )
        self.rng = np.random.default_rng(seed + 1)
        # Op counters (keyswitch/modup/moddown/ip/rotation invocations),
        # shared with the engine so both dispatch paths tally into one
        # place; runtime reports and fusion tests read the deltas.
        self.counters = OpCounters()
        self.engine = KeyswitchEngine(self.pc, counters=self.counters)
        self.use_engine = use_engine
        # (pt ids, level) -> (pts, pm_ext, pm_base, pm_ext_mont); the pts
        # tuple pins the objects so ids cannot be reused.  Bounded (FIFO
        # eviction): fresh plaintext sets must not accumulate forever.
        self._pm_stacks: dict[tuple, tuple] = {}
        self._pm_stacks_max = 32

    # ------------------------- helpers --------------------------------
    def chain(self, level: int) -> tuple[int, ...]:
        return self.params.q_chain(level)

    def ext_basis(self, level: int) -> tuple[int, ...]:
        return self.chain(level) + self.params.p_primes

    def _ext_rows(self, level: int) -> np.ndarray:
        """Rows of a full-basis evk active at ``level``."""
        return ext_rows(self.params, level)

    # ------------------------- encode / encrypt ------------------------
    def encode(self, z, level: int | None = None,
               scale: float | None = None) -> Plaintext:
        level = self.params.L if level is None else level
        scale = self.params.scale if scale is None else scale
        primes = self.chain(level)
        m = self.encoder.encode(np.asarray(z), scale, primes)
        m_eval = poly.ntt(jnp.asarray(m), primes, self.pc)
        return Plaintext(m=m_eval, level=level, scale=scale)

    def encrypt(self, z, level: int | None = None,
                scale: float | None = None) -> Ciphertext:
        pt = self.encode(z, level, scale)
        level = pt.level
        primes = self.chain(level)
        mods = self.pc.mods(primes)
        N = self.params.N
        a_rns = np.stack(
            [self.rng.integers(0, q, N, dtype=np.uint64) for q in primes]
        )
        a = poly.ntt(jnp.asarray(a_rns), primes, self.pc)
        e = poly.ntt(
            jnp.asarray(to_rns(sample_gaussian(self.rng, N), primes)),
            primes, self.pc,
        )
        s = self._sk_rows(level)
        b = poly.add(poly.sub(e, poly.mul(a, s, mods), mods), pt.m, mods)
        return Ciphertext(c0=b, c1=a, level=level, scale=pt.scale)

    def _sk_rows(self, level: int) -> jnp.ndarray:
        return self.keys.s_eval[: level + 1]

    def decrypt(self, ct: Ciphertext) -> np.ndarray:
        primes = self.chain(ct.level)
        mods = self.pc.mods(primes)
        m_eval = poly.add(
            ct.c0, poly.mul(ct.c1, self._sk_rows(ct.level), mods), mods
        )
        m_coeff = poly.intt(m_eval, primes, self.pc)
        return self.encoder.decode(np.asarray(m_coeff), ct.scale, primes)

    # ------------------------- guard checks ----------------------------
    def _require_same_level(self, a: Ciphertext, b: Ciphertext,
                            op: str) -> None:
        if a.level != b.level:
            raise ModulusChainMismatchError(
                f"{op}: operand levels disagree",
                hint="bring operands to a common level with level_down",
                lhs_level=a.level, rhs_level=b.level)

    def _require_pt_level(self, ct: Ciphertext, pt: Plaintext,
                          op: str) -> None:
        if pt.level < ct.level:
            raise ModulusChainMismatchError(
                f"{op}: plaintext encoded below the ciphertext level",
                hint="re-encode the plaintext at level >= ct.level",
                ct_level=ct.level, pt_level=pt.level)

    def check_ciphertext(self, ct: Ciphertext, where: str = "") -> None:
        """Ciphertext health guard: level sane, scale finite, limbs in
        range.  Raises a typed ``CiphertextError`` on the first violated
        invariant — the serving layer's opt-in per-request validator and
        the runtime executor's block-boundary checker both call this.

        The residue check runs as plain (eager) jnp reductions, so it
        never touches the engine's jit plan caches: turning validation
        on adds ZERO engine retraces (``engine.trace_counts`` is flat).
        """
        tag = f" at {where}" if where else ""
        if not 0 <= ct.level <= self.params.L:
            raise LevelExhaustedError(
                f"ciphertext level out of range{tag}",
                hint="bootstrap (or re-encrypt) before more rescales",
                level=ct.level, L=self.params.L)
        s = float(ct.scale)
        if not np.isfinite(s) or s <= 0.0:
            raise ScaleDriftError(
                f"ciphertext scale is not a positive finite float{tag}",
                hint="the producing op corrupted the scale trajectory",
                scale=ct.scale, level=ct.level)
        n = ct.level + 1
        for name, comp in (("c0", ct.c0), ("c1", ct.c1)):
            if comp.shape[-2] != n:
                raise ModulusChainMismatchError(
                    f"{name} carries {comp.shape[-2]} limbs but level "
                    f"{ct.level} needs {n}{tag}",
                    hint="ciphertext limbs and level drifted apart",
                    limbs=comp.shape[-2], level=ct.level)
        mods = self.pc.mods(self.chain(ct.level))[:, None]
        for name, comp in (("c0", ct.c0), ("c1", ct.c1)):
            if jnp.issubdtype(comp.dtype, jnp.floating):
                if bool(jnp.any(jnp.isnan(comp))):
                    raise CorruptCiphertextError(
                        f"NaN limb in {name}{tag}",
                        hint="a kernel produced NaN output",
                        component=name, level=ct.level)
                continue
            bad = int(jnp.sum(comp >= mods))
            if bad:
                raise CorruptCiphertextError(
                    f"{bad} residue(s) of {name} out of [0, q){tag}",
                    hint="upstream data corruption — do not decrypt; "
                         "re-encrypt and resubmit the request",
                    component=name, level=ct.level, bad_residues=bad)

    # ------------------------- EWOs ------------------------------------
    def add(self, a: Ciphertext, b: Ciphertext) -> Ciphertext:
        self._require_same_level(a, b, "add")
        mods = self.pc.mods(self.chain(a.level))
        return Ciphertext(
            poly.add(a.c0, b.c0, mods), poly.add(a.c1, b.c1, mods),
            a.level, a.scale,
        )

    def sub(self, a: Ciphertext, b: Ciphertext) -> Ciphertext:
        self._require_same_level(a, b, "sub")
        mods = self.pc.mods(self.chain(a.level))
        return Ciphertext(
            poly.sub(a.c0, b.c0, mods), poly.sub(a.c1, b.c1, mods),
            a.level, a.scale,
        )

    def pt_add(self, a: Ciphertext, pt: Plaintext) -> Ciphertext:
        self._require_pt_level(a, pt, "pt_add")
        mods = self.pc.mods(self.chain(a.level))
        return Ciphertext(
            poly.add(a.c0, pt.m[: a.n_limbs], mods), a.c1, a.level, a.scale
        )

    def pt_mul(self, a: Ciphertext, pt: Plaintext,
               rescale: bool = True) -> Ciphertext:
        self._require_pt_level(a, pt, "pt_mul")
        mods = self.pc.mods(self.chain(a.level))
        out = Ciphertext(
            poly.mul(a.c0, pt.m[: a.n_limbs], mods),
            poly.mul(a.c1, pt.m[: a.n_limbs], mods),
            a.level, a.scale * pt.scale,
        )
        return self.rescale(out) if rescale else out

    # ------------------------- level management ------------------------
    def rescale(self, ct: Ciphertext) -> Ciphertext:
        lvl = ct.level
        if lvl < 1:
            raise LevelExhaustedError(
                "rescale at level 0: the modulus chain is exhausted",
                hint="bootstrap the ciphertext (or recompile the program "
                     "with bootstrap insertion) before further mults",
                level=lvl)
        q_last = self.chain(lvl)[-1]
        c0 = poly.rescale(ct.c0, lvl, self.pc)
        c1 = poly.rescale(ct.c1, lvl, self.pc)
        return Ciphertext(c0, c1, lvl - 1, ct.scale / q_last)

    def level_down(self, ct: Ciphertext, target: int) -> Ciphertext:
        if not 0 <= target <= ct.level:
            raise ModulusChainMismatchError(
                "level_down target outside [0, ct.level]",
                hint="level_down only drops limbs; it cannot raise",
                target=target, level=ct.level)
        n = target + 1
        return Ciphertext(ct.c0[:n], ct.c1[:n], target, ct.scale)

    def mod_raise(self, ct: Ciphertext) -> Ciphertext:
        """Lift a level-0 ciphertext to the full chain (exact, coeffs < q0).

        The bootstrap boundary op: each component is brought to the
        coefficient domain, centered-lifted off the q0 basis, and re-NTT'd
        over the full chain — decrypting the result yields m + q0*I with
        |I| bounded by the secret's hamming weight.  The compiled runtime
        executes ``OpKind.MOD_RAISE`` nodes through this entry point.
        """
        from repro.core.encoding import centered_crt
        from repro.core.keys import to_rns

        p = self.params
        if ct.level != 0:
            raise ModulusChainMismatchError(
                "mod_raise expects a level-0 ciphertext",
                hint="consume the remaining levels (or level_down) first",
                level=ct.level)
        base = (p.q_primes[0],)
        full = p.q_chain(p.L)
        out = []
        for comp in (ct.c0, ct.c1):
            coeff = poly.intt(comp, base, self.pc)
            centered = centered_crt(np.asarray(coeff), base)
            lifted = to_rns(centered.astype(np.int64), full)
            out.append(poly.ntt(jnp.asarray(lifted), full, self.pc))
        return Ciphertext(out[0], out[1], p.L, ct.scale)

    # ------------------------- keyswitch core --------------------------
    # The batched jit engine (repro.core.keyswitch) is the default hot
    # path; the seed per-digit loop methods below are retained as the
    # bit-exact reference baseline (benchmarks + parity tests).
    def modup_digits(self, a: jnp.ndarray, level: int) -> list[jnp.ndarray]:
        """Decompose+ModUp a (level+1, N) poly to the extended basis."""
        groups = self.params.digit_groups(level)
        target = self.ext_basis(level)
        out = []
        row = 0
        for D in groups:
            digit = a[row : row + len(D)]
            out.append(
                poly.modup_digit(digit, D, target, self.pc, eval_domain=True)
            )
            row += len(D)
        return out

    def inner_product(self, digits: list[jnp.ndarray], evk: EvalKey,
                      level: int) -> tuple[jnp.ndarray, jnp.ndarray]:
        """IP over the extended basis: (sum_j d_j*evk_j0, sum_j d_j*evk_j1)."""
        rows = self._ext_rows(level)
        ext = self.ext_basis(level)
        mods = self.pc.mods(ext)
        acc0 = acc1 = None
        for j, d in enumerate(digits):
            k = evk.digits[j]
            t0 = poly.mul(d, k[0][rows], mods)
            t1 = poly.mul(d, k[1][rows], mods)
            acc0 = t0 if acc0 is None else poly.add(acc0, t0, mods)
            acc1 = t1 if acc1 is None else poly.add(acc1, t1, mods)
        return acc0, acc1

    def _note_seed_ks(self, level: int, n_ip: int = 1,
                      modups: int = 1) -> None:
        """Seed-path analogue of the engine's dispatch-time counting."""
        c = self.counters
        groups = tuple(len(D) for D in self.params.digit_groups(level))
        l, ext = level + 1, level + 1 + self.params.k
        N = self.params.N
        for _ in range(modups):
            c.note_modup(l, ext, groups, N)
        c.note_ip(len(groups), ext, N, n_ip)
        c.note_moddown(l, self.params.k, N)
        c.keyswitch += n_ip

    def keyswitch_seed(self, a: jnp.ndarray, evk: EvalKey,
                       level: int) -> tuple[jnp.ndarray, jnp.ndarray]:
        """Seed per-digit keyswitch: ModUp -> IP -> ModDown loops."""
        self._note_seed_ks(level)
        digits = self.modup_digits(a, level)
        acc0, acc1 = self.inner_product(digits, evk, level)
        d0 = poly.moddown(acc0, level, self.pc)
        d1 = poly.moddown(acc1, level, self.pc)
        return d0, d1

    def keyswitch(self, a: jnp.ndarray, evk: EvalKey,
                  level: int) -> tuple[jnp.ndarray, jnp.ndarray]:
        """Full keyswitch of poly ``a``: ModUp -> IP -> ModDown."""
        if self.use_engine:
            return self.engine.keyswitch(a, evk, level)
        return self.keyswitch_seed(a, evk, level)

    # ------------------------- mult / rotate ---------------------------
    def multiply(self, a: Ciphertext, b: Ciphertext,
                 rescale: bool = True) -> Ciphertext:
        """CMult: tensor product + relinearization of d2.

        The engine path dispatches the keyswitch-family ``relin`` entry
        point (ModUp -> IP -> ModDown -> base-domain folds, one cached
        jit plan); the seed path keeps the per-digit loops.  Both are
        bit-exact and tally identical ``OpCounters``.
        """
        self._require_same_level(a, b, "multiply")
        lvl = a.level
        mods = self.pc.mods(self.chain(lvl))
        d0, d1, d2 = tensor_product(a, b, mods)
        if self.use_engine:
            c0, c1 = self.engine.relin(d0, d1, d2, self.keys.mult_key, lvl)
        else:
            self.counters.relin += 1
            e0, e1 = self.keyswitch_seed(d2, self.keys.mult_key, lvl)
            c0, c1 = poly.add(d0, e0, mods), poly.add(d1, e1, mods)
        out = Ciphertext(c0, c1, lvl, a.scale * b.scale)
        return self.rescale(out) if rescale else out

    def square(self, a: Ciphertext, rescale: bool = True) -> Ciphertext:
        return self.multiply(a, a, rescale=rescale)

    def double(self, ct: Ciphertext) -> Ciphertext:
        """2*ct without scale change (cheap: residues doubled mod q)."""
        mods = self.pc.mods(self.chain(ct.level))
        two = (mods * 0 + 2).astype(mods.dtype)
        return Ciphertext(
            poly.mul_scalar(ct.c0, two, mods),
            poly.mul_scalar(ct.c1, two, mods),
            ct.level, ct.scale,
        )

    def _apply_galois(self, ct: Ciphertext, galois: int,
                      evk: EvalKey) -> Ciphertext:
        lvl = ct.level
        if self.use_engine:
            c0, c1 = self.engine.apply_galois(ct.c0, ct.c1, galois, evk, lvl)
            return Ciphertext(c0, c1, lvl, ct.scale)
        primes = self.chain(lvl)
        mods = self.pc.mods(primes)
        self.counters.rotation += 1
        c0r = poly.automorphism(ct.c0, primes, galois, self.pc)
        c1r = poly.automorphism(ct.c1, primes, galois, self.pc)
        d0, d1 = self.keyswitch_seed(c1r, evk, lvl)
        return Ciphertext(
            poly.add(c0r, d0, mods), d1, lvl, ct.scale
        )

    def rotate(self, ct: Ciphertext, steps: int) -> Ciphertext:
        steps = steps % self.params.num_slots
        if steps == 0:
            return ct
        g = self.pc.rns.galois_for_rotation(steps)
        return self._apply_galois(ct, g, self.keys.rot_key(steps))

    def conjugate(self, ct: Ciphertext) -> Ciphertext:
        g = self.pc.rns.galois_conjugate()
        return self._apply_galois(ct, g, self.keys.conj_key)

    # ------------------------- hoisted rotations -----------------------
    def hoist_digits(self, ct: Ciphertext) -> jnp.ndarray | None:
        """ModUp of ct.c1 for reuse across hoisted blocks (engine only).

        The compiled runtime (``repro.runtime``) calls this once per
        anchor ciphertext and feeds the digits to every hoisted block it
        anchors — cross-block double hoisting.  Returns None on the seed
        path (which has no digits-in entry point)."""
        if not self.use_engine:
            return None
        return self.engine.modup(ct.c1, ct.level)

    def hoisted_rotation_sum(
        self, ct: Ciphertext, steps_list: list[int],
        pts: list[Plaintext] | None = None, rescale: bool = True,
        digits: jnp.ndarray | None = None,
    ) -> Ciphertext:
        """sum_r pt_r * Rot(ct, r) with ONE ModUp and ONE ModDown.

        This is the hoisting primitive of Fig. 2(c): the ModUp of c1 is
        shared across all rotations; per-rotation IP results (and PModUp'd
        plaintext muls — Eq. (1)) are accumulated in the extended basis;
        a single ModDown closes the block.  ``digits`` (from
        :meth:`hoist_digits`) skips even that ModUp — blocks sharing an
        anchor ciphertext share one ModUp program-wide.

        Step-0 terms never touch the keyswitch machinery: Rot_0 is the
        identity, so they contribute a plain (pt-mul'd) base-domain add —
        one IP fewer per block, no identity-keyswitch noise, and the
        same arithmetic whether the term appears alone (``ctx.pt_mul``)
        or inside a block (which is what keeps the compiled runtime's
        lowering bit-exact regardless of how the 0th diagonal lands).
        """
        steps_norm = [s % self.params.num_slots for s in steps_list]
        nz = [i for i, s in enumerate(steps_norm) if s != 0]
        out = None
        if nz:
            nz_steps = [steps_norm[i] for i in nz]
            nz_pts = [pts[i] for i in nz] if pts is not None else None
            out = self._hoisted_block(ct, nz_steps, nz_pts, digits)
        out = self.add_zero_step_terms(out, ct, steps_norm, pts)
        if pts is not None and rescale:
            out = self.rescale(out)
        return out

    def add_zero_step_terms(self, out, ct: Ciphertext, steps_norm, pts):
        """Fold the identity (step-0) terms of a hoisted block into
        ``out`` as plain base-domain EWOs.  Shared by the eager primitive
        and the runtime's batched mirror (EWOs broadcast over a leading
        ct axis) so the two step-0 paths cannot drift apart."""
        for i, s in enumerate(steps_norm):
            if s != 0:
                continue
            term = (self.pt_mul(ct, pts[i], rescale=False)
                    if pts is not None else ct)
            out = term if out is None else self.add(out, term)
        return out

    def _hoisted_block(
        self, ct: Ciphertext, steps_list: list[int],
        pts: list[Plaintext] | None, digits: jnp.ndarray | None,
    ) -> Ciphertext:
        """The keyswitch part of a hoisted block (nonzero steps only)."""
        lvl = ct.level
        if self.use_engine:
            gs = [self.pc.rns.galois_for_rotation(s) for s in steps_list]
            keys = [self.keys.rot_key(s) for s in steps_list]
            pm_ext = pm_base = pm_ext_m = None
            if pts is not None:
                assert all(pt.level == lvl for pt in pts)
                pm_ext, pm_base, pm_ext_m = self._pm_stack(tuple(pts), lvl)
            c0, c1 = self.engine.hoisted_rotation_sum(
                ct.c0, ct.c1, gs, keys, lvl, pm_ext, pm_base, pm_ext_m,
                digits=digits,
            )
            out_scale = ct.scale * (pts[0].scale if pts is not None else 1.0)
            return Ciphertext(c0, c1, lvl, out_scale)
        assert digits is None, "digits sharing requires the engine path"
        return self._hoisted_rotation_sum_seed(ct, steps_list, pts,
                                               rescale=False)

    def _hoisted_rotation_sum_seed(
        self, ct: Ciphertext, steps_list: list[int],
        pts: list[Plaintext] | None = None, rescale: bool = True,
    ) -> Ciphertext:
        """Seed path: per-rotation automorphism/IP loops (reference)."""
        lvl = ct.level
        self._note_seed_ks(lvl, n_ip=len(steps_list))
        self.counters.rotation += len(steps_list)
        self.counters.hoisted_blocks += 1
        base = self.chain(lvl)
        ext = self.ext_basis(lvl)
        base_mods = self.pc.mods(base)
        ext_mods = self.pc.mods(ext)
        digits = self.modup_digits(ct.c1, lvl)

        pt_ms = None
        if pts is not None:
            pt_ms = []
            for pt in pts:
                assert pt.level == lvl
                pt_ms.append(pt)

        acc0e = acc1e = None
        base0 = None
        for i, steps in enumerate(steps_list):
            steps = steps % self.params.num_slots
            g = self.pc.rns.galois_for_rotation(steps)
            key = self.keys.rot_key(steps)
            # sigma_r commutes with ModUp (coefficient-wise BConv).
            dig_r = [
                poly.automorphism(d, ext, g, self.pc) for d in digits
            ]
            ks0, ks1 = self.inner_product(dig_r, key, lvl)
            c0r = poly.automorphism(ct.c0, base, g, self.pc)
            if pt_ms is not None:
                pm_ext = self._pmodup(pt_ms[i], lvl)
                ks0 = poly.mul(ks0, pm_ext, ext_mods)
                ks1 = poly.mul(ks1, pm_ext, ext_mods)
                c0r = poly.mul(c0r, pt_ms[i].m[: lvl + 1], base_mods)
            acc0e = ks0 if acc0e is None else poly.add(acc0e, ks0, ext_mods)
            acc1e = ks1 if acc1e is None else poly.add(acc1e, ks1, ext_mods)
            base0 = c0r if base0 is None else poly.add(base0, c0r, base_mods)

        d0 = poly.moddown(acc0e, lvl, self.pc)
        d1 = poly.moddown(acc1e, lvl, self.pc)
        out_scale = ct.scale * (pts[0].scale if pts is not None else 1.0)
        out = Ciphertext(
            poly.add(base0, d0, base_mods), d1, lvl, out_scale
        )
        if pts is not None and rescale:
            out = self.rescale(out)
        return out

    def _pmodup(self, pt: Plaintext, level: int) -> jnp.ndarray:
        """PModUp (Eq. (1)): EXACT lift of a plaintext to the extended basis.

        Unlike ciphertext ModUp, the lift must be exact (centered CRT):
        the approximate-FBC +k*Q error would multiply the keyswitch noise
        (which exceeds P/k) and destroy the message — this is why the paper
        cites the dedicated PModUp of MAD [1].  Plaintext coefficients are
        small, so the exact lift is just a centered lift + reduction.

        The centered lift reduces via a vectorized object-array ``%``
        (not a per-coefficient Python loop), and the result is cached on
        the plaintext per level — hoisted blocks reuse the same pt set.
        """
        cache = getattr(pt, "_pmodup_cache", None)
        if cache is None:
            cache = pt._pmodup_cache = {}
        if level in cache:
            return cache[level]
        from repro.core.encoding import centered_crt

        base = self.chain(level)
        ext = self.ext_basis(level)
        coeff = poly.intt(pt.m[: level + 1], base, self.pc)
        centered = centered_crt(np.asarray(coeff), base)
        new = tuple(p for p in ext if p not in base)
        lifted = np.stack(
            [(centered % q).astype(np.uint64) for q in new]
        )
        conv_eval = poly.ntt(jnp.asarray(lifted), new, self.pc)
        out = jnp.concatenate([pt.m[: level + 1], conv_eval], axis=0)
        cache[level] = out
        return out

    def _pm_stack(self, pts: tuple[Plaintext, ...], level: int):
        """Stacked hoisted-block plaintext tensors, cached per (pts, level)
        like the engine's evk group tensors.  The uint64 extended stack is
        only built for the jnp backend (the pallas fused-IP kernel reads
        the Montgomery form instead)."""
        key = (tuple(id(pt) for pt in pts), level)
        if key not in self._pm_stacks:
            pallas = self.pc.backend == "pallas"
            pm_ext = (None if pallas else
                      jnp.stack([self._pmodup(pt, level) for pt in pts]))
            pm_base = jnp.stack([pt.m[: level + 1] for pt in pts])
            pm_ext_m = (jnp.stack(
                [self._pmodup_mont(pt, level) for pt in pts]
            ) if pallas else None)
            while len(self._pm_stacks) >= self._pm_stacks_max:
                self._pm_stacks.pop(next(iter(self._pm_stacks)))
            self._pm_stacks[key] = (pts, pm_ext, pm_base, pm_ext_m)
        return self._pm_stacks[key][1:]

    def _pmodup_mont(self, pt: Plaintext, level: int) -> jnp.ndarray:
        """Montgomery uint32 form of ``_pmodup`` (pallas fused-IP PMul),
        cached alongside the uint64 lift."""
        cache = getattr(pt, "_pmodup_cache", None)
        if cache is None:
            cache = pt._pmodup_cache = {}
        key = (level, "mont")
        if key not in cache:
            pm = np.asarray(self._pmodup(pt, level))
            q = np.array(self.ext_basis(level), dtype=np.uint64)
            cache[key] = jnp.asarray(_to_mont_host_rows(pm, q))
        return cache[key]
