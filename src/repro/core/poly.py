"""Vectorized RNS polynomial arithmetic on jnp uint64 arrays.

A polynomial under a basis of ``l`` primes is a ``(l, N)`` uint64 array of
residues.  Products of two residues (< 2^30) fit uint64 exactly, so plain
``(a * b) % q`` is exact.  Limb selections ("which primes") are static
Python tuples resolved to row indices at trace time — every distinct level
traces once, like a real FHE runtime specializing per level.

Domain convention: ciphertext polynomials live in EVAL (NTT) domain;
ModUp/ModDown run INTT -> BConv -> NTT per the paper's xPU pipeline.
"""
from __future__ import annotations

from functools import lru_cache

import jax.numpy as jnp
import numpy as np

from repro.core.params import CKKSParams
from repro.core.rns import RNSContext


BACKENDS = ("jnp", "pallas")


class PolyContext:
    """jnp-resident tables derived from RNSContext.

    ``backend`` selects the numeric implementation of the keyswitch hot
    path (see ``repro.core.keyswitch``): "jnp" runs batched uint64 jnp
    ops; "pallas" dispatches NTT/BConv/IP to the uint32 Montgomery
    Pallas kernels (``interpret=True`` off-TPU).  Both are bit-exact.
    """

    def __init__(self, params: CKKSParams, backend: str = "jnp"):
        if backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}, got {backend!r}")
        self.params = params
        self.backend = backend
        self.rns = RNSContext(params)
        r = self.rns
        self.moduli = jnp.asarray(r.moduli)            # (n_limbs,)
        self.psi_pows = jnp.asarray(r.psi_pows)        # (n_limbs, N)
        self.psi_inv_pows = jnp.asarray(r.psi_inv_pows)
        self.n_inv = jnp.asarray(r.n_inv)
        self.bitrev = jnp.asarray(r.bitrev)
        self.stage_tw = [jnp.asarray(t) for t in r.stage_tw]
        self.stage_tw_inv = [jnp.asarray(t) for t in r.stage_tw_inv]

    @lru_cache(maxsize=None)
    def limb_rows(self, primes: tuple[int, ...]) -> np.ndarray:
        return self.rns.limb_ids(primes)

    def mods(self, primes: tuple[int, ...]) -> jnp.ndarray:
        return self.moduli[self.limb_rows(primes)]


# --------------------------- elementwise ops ----------------------------

def add(a, b, mods):
    return (a + b) % mods[:, None]


def sub(a, b, mods):
    return (a + mods[:, None] - b) % mods[:, None]


def mul(a, b, mods):
    return (a * b) % mods[:, None]


def neg(a, mods):
    return (mods[:, None] - a) % mods[:, None]


def mul_scalar(a, s, mods):
    """s: (l,) per-limb scalars already reduced."""
    return (a * s[:, None]) % mods[:, None]


# ------------------------------- NTT ------------------------------------

def ntt(x, primes: tuple[int, ...], pc: PolyContext):
    """Negacyclic forward NTT over stacked limbs. x: (l, N) uint64."""
    rows = pc.limb_rows(primes)
    mods = pc.moduli[rows]
    l = len(primes)
    n = pc.params.N
    m1 = mods[:, None]
    x = (x * pc.psi_pows[rows]) % m1
    x = x[:, pc.bitrev]
    m3 = mods[:, None, None]
    for s in range(pc.params.logN):
        m = 1 << s
        x = x.reshape(l, n // (2 * m), 2 * m)
        u = x[..., :m]
        tw = pc.stage_tw[s][rows][:, None, :]
        v = (x[..., m:] * tw) % m3
        x = jnp.concatenate([(u + v) % m3, (u + m3 - v) % m3], axis=-1)
    return x.reshape(l, n)


def intt(x, primes: tuple[int, ...], pc: PolyContext):
    """Negacyclic inverse NTT."""
    rows = pc.limb_rows(primes)
    mods = pc.moduli[rows]
    l = len(primes)
    n = pc.params.N
    x = x[:, pc.bitrev]
    m3 = mods[:, None, None]
    for s in range(pc.params.logN):
        m = 1 << s
        x = x.reshape(l, n // (2 * m), 2 * m)
        u = x[..., :m]
        tw = pc.stage_tw_inv[s][rows][:, None, :]
        v = (x[..., m:] * tw) % m3
        x = jnp.concatenate([(u + v) % m3, (u + m3 - v) % m3], axis=-1)
    x = x.reshape(l, n)
    m1 = mods[:, None]
    x = (x * pc.n_inv[rows][:, None]) % m1
    return (x * pc.psi_inv_pows[rows]) % m1


# --------------------------- basis conversion ---------------------------

def bconv(x, src: tuple[int, ...], dst: tuple[int, ...], pc: PolyContext,
          chunk: int = 8):
    """Fast basis conversion (coeff domain). x: (len(src), N) -> (len(dst), N).

    Approximate FBC — result may be off by a small multiple of prod(src);
    downstream ModDown/rescale absorbs it (standard RNS-CKKS).
    """
    qhat_inv, qhat_mod = pc.rns.bconv_consts(tuple(src), tuple(dst))
    src_mods = pc.mods(tuple(src))
    dst_mods = pc.mods(tuple(dst))
    t = (x * jnp.asarray(qhat_inv)[:, None]) % src_mods[:, None]
    qm = jnp.asarray(qhat_mod)                         # (ls, ld)
    dm = dst_mods[None, :, None]                       # (1, ld, 1)
    # Chunk over source limbs to bound the (ls, ld, N) intermediate.
    ls = len(src)
    acc = jnp.zeros((len(dst), x.shape[1]), dtype=jnp.uint64)
    for i in range(0, ls, chunk):
        part = (t[i : i + chunk, None, :] * qm[i : i + chunk, :, None]) % dm
        acc = (acc + part.sum(axis=0)) % dst_mods[:, None]
    return acc


# --------------------------- ModUp / ModDown ----------------------------

@lru_cache(maxsize=None)
def _modup_perm(digit_primes: tuple[int, ...], new_primes: tuple[int, ...],
                target_primes: tuple[int, ...]) -> np.ndarray:
    """Row permutation assembling concat([digit, converted]) in target order."""
    pos = {p: i for i, p in enumerate(digit_primes + new_primes)}
    return np.array([pos[p] for p in target_primes], dtype=np.int64)


def modup_digit(x_digit, digit_primes, target_primes, pc: PolyContext,
                eval_domain: bool = True):
    """Lift one decomposition digit to the extended basis.

    x_digit: (alpha, N) residues under digit_primes (eval domain if
    eval_domain).  Returns (len(target), N) under ``target_primes``
    (superset containing digit_primes), eval domain.
    INTT -> BConv -> NTT for the new limbs; original limbs pass through.
    """
    coeff = intt(x_digit, digit_primes, pc) if eval_domain else x_digit
    new_primes = tuple(p for p in target_primes if p not in digit_primes)
    converted = bconv(coeff, tuple(digit_primes), new_primes, pc)
    if eval_domain:
        converted = ntt(converted, new_primes, pc)
    perm = _modup_perm(tuple(digit_primes), new_primes, tuple(target_primes))
    return jnp.concatenate([x_digit, converted])[perm]


def moddown(x, level: int, pc: PolyContext, eval_domain: bool = True):
    """Scale down by P: input under (Q_level u P), output under Q_level.

    x rows ordered: q_0..q_level, p_0..p_{k-1}.
    """
    params = pc.params
    q_primes = params.q_chain(level)
    p_primes = params.p_primes
    nq = len(q_primes)
    xq, xp = x[:nq], x[nq:]
    if eval_domain:
        xp_coeff = intt(xp, p_primes, pc)
    else:
        xp_coeff = xp
    conv = bconv(xp_coeff, tuple(p_primes), tuple(q_primes), pc)
    if eval_domain:
        conv = ntt(conv, tuple(q_primes), pc)
    q_mods = pc.mods(tuple(q_primes))
    diff = sub(xq, conv, q_mods)
    pinv = jnp.asarray(pc.rns.p_inv_mod_q(level))
    return mul_scalar(diff, pinv, q_mods)


def rescale(x, level: int, pc: PolyContext, eval_domain: bool = True):
    """Drop the last prime q_level: out_i = (x_i - x_last) / q_level mod q_i."""
    params = pc.params
    chain = params.q_chain(level)
    keep = chain[:-1]
    last = x[-1:]
    if eval_domain:
        last_coeff = intt(last, (chain[-1],), pc)
    else:
        last_coeff = last
    # Re-express x_last's residue under each remaining prime.
    lifted = bconv(last_coeff, (chain[-1],), tuple(keep), pc)
    if eval_domain:
        lifted = ntt(lifted, tuple(keep), pc)
    mods = pc.mods(tuple(keep))
    diff = sub(x[:-1], lifted, mods)
    qinv = jnp.asarray(pc.rns.q_last_inv(level))
    return mul_scalar(diff, qinv, mods)


# --------------------------- automorphism -------------------------------

def automorphism(x, primes: tuple[int, ...], galois: int, pc: PolyContext,
                 eval_domain: bool = True):
    """Apply X -> X^galois.  Functionally applied in coeff domain."""
    if eval_domain:
        x = intt(x, primes, pc)
    src, negmask = pc.rns.autom_tables(galois)
    mods = pc.mods(tuple(primes))[:, None]
    g = x[:, jnp.asarray(src)]
    negm = jnp.asarray(negmask)[None, :]
    g = jnp.where(negm == 1, (mods - g) % mods, g)
    if eval_domain:
        g = ntt(g, primes, pc)
    return g


def automorphism_eval(x, galois: int, pc: PolyContext):
    """Apply X -> X^galois directly in the eval domain: one gather.

    Bit-exact with ``automorphism(..., eval_domain=True)`` — the NTT's
    evaluation points are permuted by the Galois element (see
    ``RNSContext.autom_eval_perm``) — but with no INTT/NTT round trip.
    """
    return x[..., jnp.asarray(pc.rns.autom_eval_perm(galois))]
