"""Unified observability for the HE^2 repro: spans, metrics, Perfetto.

One process-global tracer and metrics registry, opt-in and stdlib-only.
Instrumented modules (``runtime``, ``core``, ``serve``) call the
module-level helpers here; when disabled each call is a branch and a
no-op return, adds zero jit retraces, and costs <2% of end-to-end
runtime (gated in ``benchmarks/bench_bootstrap.py``).

Typical use::

    from repro import obs

    obs.enable()
    ... run workload ...
    obs.export.write_trace("trace.json", tracer=obs.TRACER,
                           timelines=sim_result.timelines)
    print(obs.METRICS.to_text())
"""

from . import budget, export, registry, tracer  # noqa: F401  (re-export)
from .budget import PAPER_STALL_BUDGET, StallBudget, analyze  # noqa: F401
from .registry import (  # noqa: F401
    MetricsRegistry,
    publish_counters,
    publish_energy,
    publish_serving,
)
from .tracer import NULL_SPAN, Span, Tracer  # noqa: F401

#: Process-global tracer; disabled until :func:`enable` is called.
TRACER = Tracer()

#: Process-global metrics registry.
METRICS = MetricsRegistry()


def enabled() -> bool:
    return TRACER.enabled


def enable() -> None:
    """Turn on span collection (idempotent)."""
    TRACER.enable()


def disable() -> None:
    TRACER.disable()


def reset() -> None:
    """Drop collected spans and metrics; keeps the enabled flag."""
    TRACER.reset()
    METRICS.reset()


def span(name: str, **attrs):
    """Open a span on the global tracer (``NULL_SPAN`` when disabled)."""
    return TRACER.span(name, **attrs)


def event(name: str, **attrs) -> None:
    """Record a point event on the global tracer's current span."""
    TRACER.event(name, **attrs)


def metrics() -> MetricsRegistry:
    return METRICS
