"""Perfetto / Chrome-trace export.

Renders heterogeneous timelines into one ``trace.json`` (Chrome Trace
Event Format, the JSON flavour ui.perfetto.dev opens directly):

* **Real wall-clock spans** from :class:`repro.obs.tracer.Tracer` —
  executor steps, compile phases, serve-loop activity — one track per
  Python thread under a per-process group.
* **Virtual scheduled timelines** from ``sim/schedule.py`` — one lane
  per engine (xpu/xmu/link/evk) plus an explicit ``stall`` lane whose
  slices are the exposed communication-stall intervals from
  :mod:`repro.obs.budget`.
* **Virtual serving clock** — per-tenant request lanes built from the
  server's batch records, linked by request id.

All timestamps are emitted in microseconds as the format requires; the
virtual and real domains get separate pids so Perfetto shows them as
side-by-side process groups rather than falsely aligned clocks.
"""

from __future__ import annotations

import json
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

from .budget import stall_intervals
from .tracer import Tracer

# Fixed pid blocks: real spans are pid >= 1000 (one per Python process
# group we name), virtual timelines sit below.
PID_SIM = 1
PID_SERVE_VCLOCK = 2
PID_REAL = 1000

_LANE_ORDER = ("xpu", "xmu", "link", "evk", "stall")


class TraceBuilder:
    """Accumulates Chrome trace events; ``write`` emits the JSON file."""

    def __init__(self):
        self.events: List[Dict[str, Any]] = []
        self._named_procs: Dict[int, str] = {}
        self._named_threads: Dict[Tuple[int, int], str] = {}

    # -- naming -------------------------------------------------------------
    def _name_process(self, pid: int, name: str, sort_index: Optional[int] = None) -> None:
        if self._named_procs.get(pid) == name:
            return
        self._named_procs[pid] = name
        self.events.append({
            "ph": "M", "name": "process_name", "pid": pid, "tid": 0,
            "args": {"name": name},
        })
        if sort_index is not None:
            self.events.append({
                "ph": "M", "name": "process_sort_index", "pid": pid, "tid": 0,
                "args": {"sort_index": sort_index},
            })

    def _name_thread(self, pid: int, tid: int, name: str,
                     sort_index: Optional[int] = None) -> None:
        if self._named_threads.get((pid, tid)) == name:
            return
        self._named_threads[(pid, tid)] = name
        self.events.append({
            "ph": "M", "name": "thread_name", "pid": pid, "tid": tid,
            "args": {"name": name},
        })
        if sort_index is not None:
            self.events.append({
                "ph": "M", "name": "thread_sort_index", "pid": pid, "tid": tid,
                "args": {"sort_index": sort_index},
            })

    # -- primitives ---------------------------------------------------------
    def slice(self, pid: int, tid: int, name: str, ts_us: float, dur_us: float,
              args: Optional[Dict[str, Any]] = None) -> None:
        ev: Dict[str, Any] = {
            "ph": "X", "pid": pid, "tid": tid, "name": name,
            "ts": ts_us, "dur": max(dur_us, 0.0), "cat": "span",
        }
        if args:
            ev["args"] = _jsonable(args)
        self.events.append(ev)

    def instant(self, pid: int, tid: int, name: str, ts_us: float,
                args: Optional[Dict[str, Any]] = None) -> None:
        ev: Dict[str, Any] = {
            "ph": "i", "pid": pid, "tid": tid, "name": name,
            "ts": ts_us, "s": "t", "cat": "event",
        }
        if args:
            ev["args"] = _jsonable(args)
        self.events.append(ev)

    # -- sources ------------------------------------------------------------
    def add_tracer(self, tracer: Tracer, process: str = "executor (wall clock)") -> None:
        """Render finished tracer spans, one track per Python thread."""
        spans = tracer.spans()
        if not spans and not tracer.instants:
            return
        pid = PID_REAL
        self._name_process(pid, process, sort_index=PID_REAL)
        t0 = min(
            [s.start_ns for s in spans] + [ts for _n, ts, _t, _a in tracer.instants],
            default=0,
        )
        tids: Dict[int, int] = {}

        def lane(thread_ident: int) -> int:
            tid = tids.get(thread_ident)
            if tid is None:
                tid = len(tids) + 1
                tids[thread_ident] = tid
                label = "main" if tid == 1 else f"thread-{tid}"
                self._name_thread(pid, tid, label, sort_index=tid)
            return tid

        for s in spans:
            if s.end_ns is None:
                continue
            tid = lane(s.thread)
            args = dict(s.attrs)
            if s.parent_id is not None:
                args["parent_span"] = s.parent_id
            args["span_id"] = s.span_id
            self.slice(pid, tid, s.name, (s.start_ns - t0) / 1e3,
                       (s.end_ns - s.start_ns) / 1e3, args)
            for name, ts, attrs in s.events:
                self.instant(pid, tid, name, (ts - t0) / 1e3, attrs or None)
        for name, ts, thread_ident, attrs in tracer.instants:
            self.instant(pid, lane(thread_ident), name, (ts - t0) / 1e3, attrs or None)

    def add_timelines(self, timelines: Dict[str, Sequence[Tuple[float, float, str]]],
                      process: str = "sim schedule (virtual clock)",
                      pid: int = PID_SIM) -> None:
        """Render a virtual ``{engine: [(start, end, label)]}`` schedule.

        Engine lanes keep their scheduler order; a synthetic ``stall``
        lane holds the exposed communication-stall intervals so the gaps
        the budget gate measures are visible slices, not inferred blanks.
        """
        self._name_process(pid, process, sort_index=pid)
        lanes = [e for e in _LANE_ORDER if e in timelines]
        lanes += [e for e in timelines if e not in lanes]
        for i, eng in enumerate(lanes):
            self._name_thread(pid, i + 1, eng, sort_index=i + 1)
            for start, end, label in timelines[eng]:
                self.slice(pid, i + 1, label, start * 1e6, (end - start) * 1e6,
                           {"engine": eng})
        stall_tid = len(lanes) + 1
        self._name_thread(pid, stall_tid, "stall (comm exposed)", sort_index=stall_tid)
        for start, end in stall_intervals(timelines):
            self.slice(pid, stall_tid, "comm-stall", start * 1e6,
                       (end - start) * 1e6, {"kind": "link busy, compute idle"})

    def add_serving_vclock(self, request_log: Iterable[Dict[str, Any]],
                           process: str = "serving (virtual clock)") -> None:
        """Render per-request lifecycle lanes from the server's request log.

        Each entry: {rid, tenant, program, arrival_s, start_s, end_s,
        outcome, ...}.  One lane per tenant; queue wait and service are
        separate slices linked by rid in args.
        """
        pid = PID_SERVE_VCLOCK
        entries = list(request_log)
        if not entries:
            return
        self._name_process(pid, process, sort_index=pid)
        tids: Dict[str, int] = {}
        for r in entries:
            tenant = str(r.get("tenant", "?"))
            tid = tids.get(tenant)
            if tid is None:
                tid = len(tids) + 1
                tids[tenant] = tid
                self._name_thread(pid, tid, f"tenant {tenant}", sort_index=tid)
            arrival = r.get("arrival_s")
            start = r.get("start_s")
            end = r.get("end_s")
            args = {k: v for k, v in r.items()
                    if k not in ("arrival_s", "start_s", "end_s")}
            if arrival is not None and start is not None and start > arrival:
                self.slice(pid, tid, f"queued rid={r.get('rid')}",
                           arrival * 1e6, (start - arrival) * 1e6, args)
            if start is not None and end is not None:
                name = f"{r.get('outcome', 'run')} rid={r.get('rid')}"
                self.slice(pid, tid, name, start * 1e6, (end - start) * 1e6, args)
            elif arrival is not None and end is not None:
                self.slice(pid, tid, f"{r.get('outcome', 'done')} rid={r.get('rid')}",
                           arrival * 1e6, (end - arrival) * 1e6, args)

    # -- output -------------------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        return {
            "traceEvents": self.events,
            "displayTimeUnit": "ms",
            "otherData": {"generator": "repro.obs.export"},
        }

    def write(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f)
        return path


def _jsonable(obj: Any) -> Any:
    """Best-effort conversion of span attrs to JSON-safe values."""
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, (str, int, float, bool)) or obj is None:
        return obj
    return str(obj)


def write_trace(path: str,
                tracer: Optional[Tracer] = None,
                timelines: Optional[Dict[str, Sequence[Tuple[float, float, str]]]] = None,
                request_log: Optional[Iterable[Dict[str, Any]]] = None,
                sim_process: str = "sim schedule (virtual clock)") -> str:
    """One-call export: any subset of sources into a single trace.json."""
    b = TraceBuilder()
    if timelines:
        b.add_timelines(timelines, process=sim_process)
    if request_log:
        b.add_serving_vclock(request_log)
    if tracer is not None:
        b.add_tracer(tracer)
    return b.write(path)
