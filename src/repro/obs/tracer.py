"""Zero-dependency span tracer for the HE^2 hot path.

Design constraints (ISSUE 8):

* **Opt-in** — the tracer is disabled by default.  A disabled
  ``tracer.span(...)`` call costs one attribute load, one branch and the
  return of a shared no-op singleton; the bench gate asserts this stays
  under 2% of end-to-end runtime.
* **Zero jit retraces** — instrumentation only reads wall clock and
  Python-side counters; nothing observable crosses into traced jax code.
* **Thread-safe context propagation** — the current-span stack lives in
  ``threading.local`` so serve-loop worker threads nest correctly, while
  finished spans land in one lock-guarded list for export.

Spans record ``time.perf_counter_ns`` timestamps, structured attributes
(``set_attrs``) and point events (``event``).  Export to Perfetto is in
:mod:`repro.obs.export`; this module is stdlib-only.
"""

from __future__ import annotations

import threading
import time
from typing import Any, Dict, List, Optional, Tuple


class Span:
    """A finished-or-open span.  Use as a context manager.

    Truthy (unlike :class:`_NullSpan`) so call sites can branch on
    ``if span:`` to skip attribute computation when tracing is off.
    """

    __slots__ = (
        "name",
        "span_id",
        "parent_id",
        "thread",
        "start_ns",
        "end_ns",
        "attrs",
        "events",
        "_tracer",
    )

    def __init__(
        self,
        tracer: "Tracer",
        name: str,
        span_id: int,
        parent_id: Optional[int],
        thread: int,
        attrs: Dict[str, Any],
    ):
        self._tracer = tracer
        self.name = name
        self.span_id = span_id
        self.parent_id = parent_id
        self.thread = thread
        self.start_ns = tracer.clock()
        self.end_ns: Optional[int] = None
        self.attrs = attrs
        self.events: List[Tuple[str, int, Dict[str, Any]]] = []

    # -- structured payload -------------------------------------------------
    def set_attrs(self, **attrs: Any) -> "Span":
        self.attrs.update(attrs)
        return self

    def event(self, name: str, **attrs: Any) -> None:
        """Attach a point-in-time event to this span."""
        self.events.append((name, self._tracer.clock(), attrs))

    # -- lifecycle ----------------------------------------------------------
    def __enter__(self) -> "Span":
        self._tracer._push(self)
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is not None:
            self.attrs.setdefault("error", exc_type.__name__)
        self.end_ns = self._tracer.clock()
        self._tracer._pop(self)

    @property
    def duration_ns(self) -> int:
        end = self.end_ns if self.end_ns is not None else self._tracer.clock()
        return end - self.start_ns

    def __bool__(self) -> bool:
        return True

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"Span({self.name!r}, id={self.span_id}, attrs={self.attrs})"


class _NullSpan:
    """Falsy no-op span returned while tracing is disabled.

    A single shared instance; every method is a no-op so instrumented
    code never needs its own ``if enabled`` guard around attribute or
    event calls.
    """

    __slots__ = ()

    def set_attrs(self, **attrs: Any) -> "_NullSpan":
        return self

    def event(self, name: str, **attrs: Any) -> None:
        return None

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        return None

    def __bool__(self) -> bool:
        return False

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return "NullSpan"


NULL_SPAN = _NullSpan()


class Tracer:
    """Collects spans and instant events from any number of threads."""

    def __init__(self, clock=time.perf_counter_ns):
        self.enabled = False
        self.clock = clock
        self._lock = threading.Lock()
        self._tls = threading.local()
        self._next_id = 0
        self.finished: List[Span] = []
        # Standalone instants: (name, ts_ns, thread_id, attrs).
        self.instants: List[Tuple[str, int, int, Dict[str, Any]]] = []

    # -- control ------------------------------------------------------------
    def enable(self) -> None:
        self.enabled = True

    def disable(self) -> None:
        self.enabled = False

    def reset(self) -> None:
        with self._lock:
            self.finished = []
            self.instants = []
            self._next_id = 0
        self._tls = threading.local()

    # -- span API -----------------------------------------------------------
    def span(self, name: str, **attrs: Any):
        """Open a span; returns ``NULL_SPAN`` when disabled.

        This is the hot-path entry point: when disabled it does one
        branch and returns a shared singleton.
        """
        if not self.enabled:
            return NULL_SPAN
        with self._lock:
            sid = self._next_id
            self._next_id += 1
        stack = getattr(self._tls, "stack", None)
        parent = stack[-1].span_id if stack else None
        return Span(self, name, sid, parent, threading.get_ident(), attrs)

    def event(self, name: str, **attrs: Any) -> None:
        """Record a point event on the current span, or standalone."""
        if not self.enabled:
            return
        stack = getattr(self._tls, "stack", None)
        if stack:
            stack[-1].event(name, **attrs)
        else:
            with self._lock:
                self.instants.append((name, self.clock(), threading.get_ident(), attrs))

    def current(self) -> Optional[Span]:
        stack = getattr(self._tls, "stack", None)
        return stack[-1] if stack else None

    # -- internals ----------------------------------------------------------
    def _push(self, span: Span) -> None:
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = []
            self._tls.stack = stack
        stack.append(span)

    def _pop(self, span: Span) -> None:
        stack = getattr(self._tls, "stack", None)
        if stack and stack[-1] is span:
            stack.pop()
        elif stack and span in stack:  # mismatched exit order; be forgiving
            stack.remove(span)
        with self._lock:
            self.finished.append(span)

    # -- inspection ---------------------------------------------------------
    def spans(self, name: Optional[str] = None) -> List[Span]:
        """Finished spans, optionally filtered by name (prefix match on '*')."""
        with self._lock:
            out = list(self.finished)
        if name is None:
            return out
        if name.endswith("*"):
            pre = name[:-1]
            return [s for s in out if s.name.startswith(pre)]
        return [s for s in out if s.name == name]
