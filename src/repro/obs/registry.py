"""Label-aware metrics registry: counters, gauges, histograms.

One process-global :class:`MetricsRegistry` (held by :mod:`repro.obs`)
receives published numbers from the subsystems that already count things
— ``OpCounters`` (core), ``ServingReport`` (serve) and
``Schedule.energy_breakdown`` (sim) — so a single ``snapshot()`` shows
the whole system and can be reconciled exactly against those sources.

Metric keys are ``(name, labels)`` where labels is a sorted tuple of
``(key, value)`` pairs, so ``counter("fhe.modup", level=3)`` and
``counter("fhe.modup", level=5)`` are distinct series.  Stdlib-only.
"""

from __future__ import annotations

import json
import threading
from typing import Any, Dict, List, Optional, Tuple

LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, Any]) -> LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


class Counter:
    """Monotone counter; one value per label set."""

    __slots__ = ("name", "help", "_values")

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._values: Dict[LabelKey, float] = {}

    def inc(self, amount: float = 1.0, **labels: Any) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name}: negative increment {amount}")
        key = _label_key(labels)
        self._values[key] = self._values.get(key, 0.0) + amount

    def value(self, **labels: Any) -> float:
        return self._values.get(_label_key(labels), 0.0)

    def series(self) -> Dict[LabelKey, float]:
        return dict(self._values)


class Gauge:
    """Point-in-time value; ``set`` overwrites."""

    __slots__ = ("name", "help", "_values")

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._values: Dict[LabelKey, float] = {}

    def set(self, value: float, **labels: Any) -> None:
        self._values[_label_key(labels)] = float(value)

    def value(self, **labels: Any) -> Optional[float]:
        return self._values.get(_label_key(labels))

    def series(self) -> Dict[LabelKey, float]:
        return dict(self._values)


class Histogram:
    """Fixed-bucket histogram with count/sum, per label set."""

    __slots__ = ("name", "help", "buckets", "_counts", "_sums", "_ns")

    DEFAULT_BUCKETS = (
        1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3,
        1e-2, 2.5e-2, 5e-2, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
    )

    def __init__(self, name: str, help: str = "", buckets: Tuple[float, ...] = DEFAULT_BUCKETS):
        self.name = name
        self.help = help
        self.buckets = tuple(sorted(buckets))
        self._counts: Dict[LabelKey, List[int]] = {}
        self._sums: Dict[LabelKey, float] = {}
        self._ns: Dict[LabelKey, int] = {}

    def observe(self, value: float, **labels: Any) -> None:
        key = _label_key(labels)
        counts = self._counts.get(key)
        if counts is None:
            counts = [0] * (len(self.buckets) + 1)  # +1 = overflow bucket
            self._counts[key] = counts
        i = 0
        for i, edge in enumerate(self.buckets):
            if value <= edge:
                counts[i] += 1
                break
        else:
            counts[len(self.buckets)] += 1
        self._sums[key] = self._sums.get(key, 0.0) + value
        self._ns[key] = self._ns.get(key, 0) + 1

    def count(self, **labels: Any) -> int:
        return self._ns.get(_label_key(labels), 0)

    def sum(self, **labels: Any) -> float:
        return self._sums.get(_label_key(labels), 0.0)

    def series(self) -> Dict[LabelKey, Dict[str, Any]]:
        out: Dict[LabelKey, Dict[str, Any]] = {}
        for key, counts in self._counts.items():
            out[key] = {
                "count": self._ns[key],
                "sum": self._sums[key],
                "buckets": list(zip(self.buckets, counts)),
                "overflow": counts[-1],
            }
        return out


class MetricsRegistry:
    """Named metric families; thread-safe creation, single snapshot."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[str, Any] = {}

    def _get(self, cls, name: str, help: str, **kw):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, help, **kw)
                self._metrics[name] = m
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as {type(m).__name__}"
                )
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "", buckets=Histogram.DEFAULT_BUCKETS) -> Histogram:
        return self._get(Histogram, name, help, buckets=buckets)

    def reset(self) -> None:
        with self._lock:
            self._metrics = {}

    # -- exposition ---------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        """All series as a plain dict: {name: {labelstr: value-or-hist}}."""
        out: Dict[str, Any] = {}
        with self._lock:
            metrics = dict(self._metrics)
        for name, m in sorted(metrics.items()):
            series: Dict[str, Any] = {}
            for key, val in m.series().items():
                label_str = ",".join(f"{k}={v}" for k, v in key)
                series[label_str] = val
            out[name] = {
                "type": type(m).__name__.lower(),
                "help": m.help,
                "series": series,
            }
        return out

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.snapshot(), indent=indent, sort_keys=True)

    def to_text(self) -> str:
        """Prometheus-flavoured text exposition (subset, for grepping)."""
        lines: List[str] = []
        snap = self.snapshot()
        for name, fam in snap.items():
            if fam["help"]:
                lines.append(f"# HELP {name} {fam['help']}")
            lines.append(f"# TYPE {name} {fam['type']}")
            for label_str, val in sorted(fam["series"].items()):
                tag = "{" + label_str + "}" if label_str else ""
                if isinstance(val, dict):  # histogram
                    lines.append(f"{name}_count{tag} {val['count']}")
                    lines.append(f"{name}_sum{tag} {val['sum']}")
                else:
                    lines.append(f"{name}{tag} {val}")
        return "\n".join(lines) + ("\n" if lines else "")


# ---------------------------------------------------------------------------
# Publishers: adapt the repo's existing accounting objects into the registry.
# Imported lazily by callers; take plain objects so this module stays
# dependency-free (duck-typed against OpCounters / ServingReport).
# ---------------------------------------------------------------------------

def publish_counters(reg: MetricsRegistry, counters, prefix: str = "fhe") -> None:
    """Publish an ``OpCounters`` snapshot as gauges ``fhe.<field>``.

    Gauges, not counters: OpCounters is itself cumulative and resettable,
    so we mirror its current value rather than re-accumulate.
    """
    for field, value in counters.as_dict().items():
        reg.gauge(f"{prefix}.{field}", help=f"OpCounters.{field}").set(value)


def publish_serving(reg: MetricsRegistry, report) -> None:
    """Publish a ``ServingReport`` so outcomes reconcile with ``accounted``."""
    g = reg.gauge
    g("serving.submitted", help="requests submitted").set(report.submitted)
    g("serving.completed", help="requests completed").set(report.completed)
    g("serving.rejected", help="requests rejected at submit").set(report.rejected)
    g("serving.failed", help="requests failed after retries").set(report.failed)
    g("serving.shed", help="requests shed (overload/deadline)").set(report.shed)
    g("serving.accounted", help="completed+rejected+failed+shed").set(report.accounted)
    g("serving.batches", help="batches dispatched").set(report.batches)
    g("serving.retries", help="re-dispatches after transient faults").set(report.retries)
    lat = reg.histogram("serving.latency_s", help="per-request latency (s)")
    for v in report.latencies_s:
        lat.observe(v)
    # report.tenants holds TenantStats.summary() dicts, not the stats
    # objects, so per-tenant terminal outcomes publish as labeled gauges
    done = g("serving.tenant_completed", help="completed per tenant")
    for tenant, summ in report.tenants.items():
        done.set(summ["completed"], tenant=tenant)


def publish_energy(reg: MetricsRegistry, breakdown: Dict[str, float], config: str = "") -> None:
    """Publish ``Schedule.energy_breakdown(hw)`` joules per engine."""
    g = reg.gauge("sim.energy_j", help="modeled energy per engine (J)")
    for engine, joules in breakdown.items():
        if config:
            g.set(joules, engine=engine, config=config)
        else:
            g.set(joules, engine=engine)
