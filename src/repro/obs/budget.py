"""Communication-stall budget analysis.

The paper's headline observation (§VI) is that under group-level
pipelined execution, communication stalls — intervals where the
inter-chiplet link is busy but *neither* compute engine (XPU/XMU) is —
account for only **6.67%** of total latency on HE^2-SM.  This module
recomputes that fraction from scheduled engine timelines and exposes a
gate the benches run under CI.

Works on the plain ``{engine: [(start, end, label), ...]}`` dict that
``sim.schedule.Schedule.timelines()`` (and ``SimResult.timelines``)
produce, so it stays stdlib-only and usable on deserialized bench JSON.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

Interval = Tuple[float, float]

#: Paper §VI: comm stalls <= 6.67% of latency for HE2-SM pipelined runs.
PAPER_STALL_BUDGET = 0.0667


def merge_intervals(intervals: Sequence[Interval]) -> List[Interval]:
    """Union of possibly-overlapping [start, end) intervals."""
    out: List[Interval] = []
    for s, e in sorted(i for i in intervals if i[1] > i[0]):
        if out and s <= out[-1][1]:
            if e > out[-1][1]:
                out[-1] = (out[-1][0], e)
        else:
            out.append((s, e))
    return out


def subtract_intervals(base: Sequence[Interval], cover: Sequence[Interval]) -> List[Interval]:
    """Parts of ``base`` not covered by ``cover`` (both pre-merged or not)."""
    base = merge_intervals(base)
    cover = merge_intervals(cover)
    out: List[Interval] = []
    ci = 0
    for s, e in base:
        cur = s
        while ci < len(cover) and cover[ci][1] <= cur:
            ci += 1
        j = ci
        while j < len(cover) and cover[j][0] < e:
            cs, ce = cover[j]
            if cs > cur:
                out.append((cur, cs))
            cur = max(cur, ce)
            if cur >= e:
                break
            j += 1
        if cur < e:
            out.append((cur, e))
    return out


def total(intervals: Sequence[Interval]) -> float:
    return sum(e - s for s, e in merge_intervals(intervals))


def busy_intervals(timelines: Dict[str, Sequence[Tuple[float, float, str]]],
                   engines: Sequence[str]) -> List[Interval]:
    """Merged busy intervals across the named engine lanes."""
    raw: List[Interval] = []
    for eng in engines:
        for s, e, _label in timelines.get(eng, ()):
            raw.append((s, e))
    return merge_intervals(raw)


def stall_intervals(timelines: Dict[str, Sequence[Tuple[float, float, str]]],
                    engines: Sequence[str] = ("link",),
                    hidden_by: Sequence[str] = ("xpu", "xmu")) -> List[Interval]:
    """Intervals where ``engines`` are busy but none of ``hidden_by`` is.

    With the defaults this is exactly the paper's communication-stall
    definition, mirroring ``Schedule.exposed_time`` but returning the
    intervals themselves so the exporter can render them as slices.
    """
    return subtract_intervals(
        busy_intervals(timelines, engines),
        busy_intervals(timelines, hidden_by),
    )


@dataclass(frozen=True)
class StallBudget:
    """Result of a stall-budget analysis for one scheduled timeline."""

    name: str
    latency_s: float
    comm_stall_s: float
    budget: float  # allowed fraction

    @property
    def fraction(self) -> float:
        return self.comm_stall_s / self.latency_s if self.latency_s > 0 else 0.0

    @property
    def within(self) -> bool:
        return self.fraction <= self.budget

    def as_dict(self) -> Dict[str, float]:
        return {
            "latency_s": self.latency_s,
            "comm_stall_s": self.comm_stall_s,
            "comm_stall_frac": self.fraction,
            "budget_frac": self.budget,
            "within_budget": self.within,
        }

    def describe(self) -> str:
        status = "within" if self.within else "OVER"
        return (
            f"{self.name}: comm stall {self.comm_stall_s * 1e3:.3f} ms "
            f"/ {self.latency_s * 1e3:.3f} ms = {self.fraction * 100:.2f}% "
            f"({status} {self.budget * 100:.2f}% budget)"
        )


def analyze(timelines: Dict[str, Sequence[Tuple[float, float, str]]],
            latency_s: Optional[float] = None,
            name: str = "schedule",
            budget: float = PAPER_STALL_BUDGET) -> StallBudget:
    """Compute the comm-stall fraction of a scheduled timeline."""
    stalls = stall_intervals(timelines)
    if latency_s is None:
        ends = [e for lane in timelines.values() for _s, e, _l in lane]
        latency_s = max(ends) if ends else 0.0
    return StallBudget(
        name=name,
        latency_s=latency_s,
        comm_stall_s=total(stalls),
        budget=budget,
    )


def check(budget: StallBudget) -> None:
    """CI gate: raise if the stall fraction exceeds the budget."""
    if not budget.within:
        raise RuntimeError(f"stall budget exceeded: {budget.describe()}")
