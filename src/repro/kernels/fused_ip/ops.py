"""jit'd wrapper for the fused IP kernel."""
from __future__ import annotations

from functools import lru_cache

import jax.numpy as jnp
import numpy as np
from jax.custom_batching import custom_vmap

from repro.kernels.fused_ip.fused_ip import fused_ip_pallas
from repro.kernels.fused_ip import ref as _ref
from repro.kernels.modops import default_interpret, qinv_neg_host, to_mont_host


def _mont(arr: np.ndarray, q: np.ndarray) -> np.ndarray:
    """Montgomery-convert along the limb axis (q broadcast per row)."""
    out = np.empty(arr.shape, dtype=np.uint32)
    it = np.ndindex(*arr.shape[:-2])
    for idx in it:
        for r in range(arr.shape[-2]):
            out[idx + (r,)] = to_mont_host(
                arr[idx + (r,)].astype(np.uint64), int(q[r])
            )
    return out


@lru_cache(maxsize=None)
def _ip_dispatch(with_pt: bool, interpret: bool):
    """Rank-polymorphic fused-IP dispatch + ``custom_vmap`` rule.

    Leading batch dims on ``digits`` fold into the kernel's row/grid
    axis (batch-major, ``% l`` index maps for the unbatched evk/pt/
    modulus operands).  Only the digits operand may carry a vmap axis —
    evk, plaintext and moduli are shared per-plan constants."""

    def dispatch(digits, evk, pt, q, qneg):
        l = q.shape[0]
        n = digits.shape[-1]
        dnum = digits.shape[-3]
        lead = digits.shape[:-3]
        d = digits.reshape((-1,) + digits.shape[-3:])      # (B, dnum, l, n)
        d = jnp.moveaxis(d, 0, 1).reshape(dnum, -1, n)     # (dnum, B*l, n)
        a0, a1 = fused_ip_pallas(
            d, evk, pt if with_pt else None, q, qneg, interpret=interpret,
        )
        return a0.reshape(lead + (l, n)), a1.reshape(lead + (l, n))

    fn = custom_vmap(dispatch)

    @fn.def_vmap
    def _rule(axis_size, in_batched, digits, evk, pt, q, qneg):
        del axis_size
        if any(in_batched[1:]):
            raise NotImplementedError(
                "fused_ip: only the digits operand may be vmapped; evk/"
                "plaintext/moduli are per-plan constants")
        return dispatch(digits, evk, pt, q, qneg), (True, True)

    return fn


def fused_ip_mont(digits, evk_mont, pt_mont, q, qneg,
                  interpret: bool | None = None):
    """Deployment-shaped entry: evk/pt are ALREADY Montgomery uint32
    (stored pre-converted, e.g. by the keyswitch engine's per-context
    cache); digits stay normal-form, shape (..., dnum, l, N) — leading
    batch dims (or a ``jax.vmap`` axis) are folded into the kernel grid.
    q/qneg: (l, 1) uint32."""
    if interpret is None:
        interpret = default_interpret()
    with_pt = pt_mont is not None
    if pt_mont is None:
        pt_mont = jnp.zeros((q.shape[0], digits.shape[-1]),
                            dtype=jnp.uint32)
    return _ip_dispatch(with_pt, bool(interpret))(
        digits, evk_mont, pt_mont, q, qneg
    )


def fused_ip_kernel(digits, evk, pt, q, interpret: bool | None = None):
    """NORMAL-form inputs; conversion to Montgomery happens here (in a
    real deployment evk/pt are stored pre-converted — see
    ``fused_ip_mont``)."""
    qv = np.asarray(q, dtype=np.uint32)
    l = qv.shape[0]
    evk_m = _mont(np.asarray(evk), qv)
    pt_m = _mont(np.asarray(pt)[None], qv)[0] if pt is not None else None
    qneg = np.array([qinv_neg_host(int(x)) for x in qv], dtype=np.uint32)
    return fused_ip_mont(
        jnp.asarray(np.asarray(digits, dtype=np.uint32)),
        jnp.asarray(evk_m),
        jnp.asarray(pt_m) if pt_m is not None else None,
        jnp.asarray(qv.reshape(l, 1)),
        jnp.asarray(qneg.reshape(l, 1)),
        interpret=interpret,
    )


def fused_ip_oracle(digits, evk, pt, q):
    return _ref.fused_ip_ref(
        jnp.asarray(np.asarray(digits, dtype=np.uint32)),
        jnp.asarray(np.asarray(evk, dtype=np.uint32)),
        jnp.asarray(np.asarray(pt, dtype=np.uint32)) if pt is not None else None,
        jnp.asarray(np.asarray(q, dtype=np.uint32)),
    )
