"""jit'd wrapper for the fused IP kernel."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels.fused_ip.fused_ip import fused_ip_pallas
from repro.kernels.fused_ip import ref as _ref
from repro.kernels.modops import default_interpret, qinv_neg_host, to_mont_host


def _mont(arr: np.ndarray, q: np.ndarray) -> np.ndarray:
    """Montgomery-convert along the limb axis (q broadcast per row)."""
    out = np.empty(arr.shape, dtype=np.uint32)
    it = np.ndindex(*arr.shape[:-2])
    for idx in it:
        for r in range(arr.shape[-2]):
            out[idx + (r,)] = to_mont_host(
                arr[idx + (r,)].astype(np.uint64), int(q[r])
            )
    return out


def fused_ip_mont(digits, evk_mont, pt_mont, q, qneg,
                  interpret: bool | None = None):
    """Deployment-shaped entry: evk/pt are ALREADY Montgomery uint32
    (stored pre-converted, e.g. by the keyswitch engine's per-context
    cache); digits stay normal-form.  q/qneg: (l, 1) uint32."""
    if interpret is None:
        interpret = default_interpret()
    return fused_ip_pallas(
        digits, evk_mont, pt_mont, q, qneg, interpret=interpret,
    )


def fused_ip_kernel(digits, evk, pt, q, interpret: bool | None = None):
    """NORMAL-form inputs; conversion to Montgomery happens here (in a
    real deployment evk/pt are stored pre-converted — see
    ``fused_ip_mont``)."""
    qv = np.asarray(q, dtype=np.uint32)
    l = qv.shape[0]
    evk_m = _mont(np.asarray(evk), qv)
    pt_m = _mont(np.asarray(pt)[None], qv)[0] if pt is not None else None
    qneg = np.array([qinv_neg_host(int(x)) for x in qv], dtype=np.uint32)
    return fused_ip_mont(
        jnp.asarray(np.asarray(digits, dtype=np.uint32)),
        jnp.asarray(evk_m),
        jnp.asarray(pt_m) if pt_m is not None else None,
        jnp.asarray(qv.reshape(l, 1)),
        jnp.asarray(qneg.reshape(l, 1)),
        interpret=interpret,
    )


def fused_ip_oracle(digits, evk, pt, q):
    return _ref.fused_ip_ref(
        jnp.asarray(np.asarray(digits, dtype=np.uint32)),
        jnp.asarray(np.asarray(evk, dtype=np.uint32)),
        jnp.asarray(np.asarray(pt, dtype=np.uint32)) if pt is not None else None,
        jnp.asarray(np.asarray(q, dtype=np.uint32)),
    )
