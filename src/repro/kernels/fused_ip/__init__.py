from repro.kernels.fused_ip.ops import fused_ip_kernel, fused_ip_oracle  # noqa: F401
