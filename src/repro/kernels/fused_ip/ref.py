"""Pure-jnp uint64 oracle for the fused IP kernel."""
from __future__ import annotations

import jax.numpy as jnp


def fused_ip_ref(digits, evk, pt, q):
    """digits: (dnum, l, N); evk: (dnum, 2, l, N); pt: (l, N) or None;
    all NORMAL-form uint32; q: (l,). Returns (acc0, acc1) uint32."""
    d = digits.astype(jnp.uint64)
    k = evk.astype(jnp.uint64)
    qq = q.astype(jnp.uint64)[None, :, None]
    acc0 = jnp.zeros(d.shape[1:], dtype=jnp.uint64)
    acc1 = jnp.zeros(d.shape[1:], dtype=jnp.uint64)
    for j in range(d.shape[0]):
        acc0 = (acc0 + (d[j] * k[j, 0]) % qq[0]) % qq[0]
        acc1 = (acc1 + (d[j] * k[j, 1]) % qq[0]) % qq[0]
    if pt is not None:
        p = pt.astype(jnp.uint64)
        acc0 = (acc0 * p) % qq[0]
        acc1 = (acc1 * p) % qq[0]
    return acc0.astype(jnp.uint32), acc1.astype(jnp.uint32)
