"""Pallas fused keyswitch inner-product kernel (xMU "MemOp fusion").

Computes, per extended-basis limb r (grid axis):

    acc_c[r] = sum_j digits[j, r, :] * evk[j, c, r, :]   (c = 0, 1)
    optionally followed by  acc_c[r] *= pt[r, :]          (fused PMul)

in ONE pass over VMEM-resident blocks — the paper's Fig. 10(d) fusion that
eliminates the row-switch write-back of the intermediate IP result between
sequential MemOps.  evk and pt are Montgomery-form; digits stay normal.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.modops import add_mod, mont_mul


def _fused_ip_kernel(d_ref, k_ref, pt_ref, q_ref, qneg_ref,
                     o0_ref, o1_ref, *, dnum: int, with_pt: bool):
    q = q_ref[0, 0]
    qn = qneg_ref[0, 0]
    acc0 = mont_mul(d_ref[0, 0, :], k_ref[0, 0, 0, :], q, qn)
    acc1 = mont_mul(d_ref[0, 0, :], k_ref[0, 1, 0, :], q, qn)
    for j in range(1, dnum):                     # trace-time unroll
        dj = d_ref[j, 0, :]
        acc0 = add_mod(acc0, mont_mul(dj, k_ref[j, 0, 0, :], q, qn), q)
        acc1 = add_mod(acc1, mont_mul(dj, k_ref[j, 1, 0, :], q, qn), q)
    if with_pt:
        pm = pt_ref[0, :]
        acc0 = mont_mul(acc0, pm, q, qn)
        acc1 = mont_mul(acc1, pm, q, qn)
    o0_ref[0, :] = acc0
    o1_ref[0, :] = acc1


def fused_ip_pallas(digits, evk_mont, pt_mont, q, qneg,
                    *, interpret: bool = True):
    """digits: (dnum, B*l, N) u32, batch-major rows; evk_mont: (dnum, 2,
    l, N) u32 Montgomery; pt_mont: (l, N) u32 Montgomery or None;
    q/qneg: (l, 1) u32.  Returns (acc0, acc1), each (B*l, N) u32.

    B is inferred from the row count; batched rows read the (unbatched)
    evk/pt/modulus operands via ``% l`` index maps.
    """
    dnum, rows, n = digits.shape
    l = q.shape[0]
    with_pt = pt_mont is not None
    if pt_mont is None:
        pt_mont = jnp.zeros((l, n), dtype=jnp.uint32)
    kernel = functools.partial(_fused_ip_kernel, dnum=dnum, with_pt=with_pt)
    return pl.pallas_call(
        kernel,
        grid=(rows,),
        in_specs=[
            pl.BlockSpec((dnum, 1, n), lambda r: (0, r, 0)),
            pl.BlockSpec((dnum, 2, 1, n), lambda r, l=l: (0, 0, r % l, 0)),
            pl.BlockSpec((1, n), lambda r, l=l: (r % l, 0)),
            pl.BlockSpec((1, 1), lambda r, l=l: (r % l, 0)),
            pl.BlockSpec((1, 1), lambda r, l=l: (r % l, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, n), lambda r: (r, 0)),
            pl.BlockSpec((1, n), lambda r: (r, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, n), jnp.uint32),
            jax.ShapeDtypeStruct((rows, n), jnp.uint32),
        ],
        interpret=interpret,
    )(digits, evk_mont, pt_mont, q, qneg)
