from repro.kernels.ntt.ops import NTTKernelTables, ntt_fwd, ntt_inv  # noqa: F401
