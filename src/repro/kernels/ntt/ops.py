"""jit'd wrappers + per-limb table precomputation for the NTT kernel."""
from __future__ import annotations

from functools import lru_cache

import jax.numpy as jnp
import numpy as np

from repro.core.rns import RNSContext
from repro.kernels.modops import default_interpret, qinv_neg_host, to_mont_host
from repro.kernels.ntt.ntt import ntt_pallas
from repro.kernels.ntt import ref as _ref


class NTTKernelTables:
    """Stacked per-limb uint32 tables (normal + Montgomery forms)."""

    def __init__(self, rns: RNSContext):
        self.rns = rns
        self.logn = rns.params.logN
        n = rns.params.N
        primes = rns.all_primes
        l = len(primes)

        def flat_tw(stage_list, pi):
            out = np.ones(n, dtype=np.uint64)
            for s, tws in enumerate(stage_list):
                m = 1 << s
                out[m : 2 * m] = tws[pi]
            return out

        tw_f = np.stack([flat_tw(rns.stage_tw, i) for i in range(l)])
        tw_i = np.stack([flat_tw(rns.stage_tw_inv, i) for i in range(l)])
        twist_f = rns.psi_pows.astype(np.uint64)
        twist_i = (
            rns.psi_inv_pows.astype(object)
            * rns.n_inv.astype(object)[:, None]
            % rns.moduli.astype(object)[:, None]
        )

        self.q = rns.moduli.astype(np.uint32).reshape(l, 1)
        self.qinv = np.array(
            [qinv_neg_host(int(p)) for p in primes], dtype=np.uint32
        ).reshape(l, 1)
        # normal-form tables (for the oracle)
        self.tw_f = tw_f
        self.tw_i = tw_i
        self.twist_f = twist_f
        self.twist_i = twist_i.astype(np.uint64)
        # Montgomery-form tables (for the kernel)
        self.tw_f_m = np.stack(
            [to_mont_host(tw_f[i], int(primes[i])) for i in range(l)]
        )
        self.tw_i_m = np.stack(
            [to_mont_host(tw_i[i], int(primes[i])) for i in range(l)]
        )
        self.twist_f_m = np.stack(
            [to_mont_host(twist_f[i], int(primes[i])) for i in range(l)]
        )
        self.twist_i_m = np.stack(
            [to_mont_host(self.twist_i[i], int(primes[i])) for i in range(l)]
        )

    def rows(self, primes: tuple[int, ...]) -> np.ndarray:
        return self.rns.limb_ids(primes)


@lru_cache(maxsize=8)
def tables_for(params) -> NTTKernelTables:
    return NTTKernelTables(RNSContext(params))


def ntt_fwd(x, primes, tables: NTTKernelTables,
            interpret: bool | None = None):
    """(l, N) uint32 natural coeffs -> bit-reversed eval order.

    ``primes`` may contain duplicates (batched multi-poly transforms
    tile the limb axis).  ``interpret=None`` auto-detects the backend.
    """
    if interpret is None:
        interpret = default_interpret()
    r = tables.rows(tuple(primes))
    return ntt_pallas(
        x.astype(jnp.uint32),
        jnp.asarray(tables.twist_f_m[r]),
        jnp.asarray(tables.tw_f_m[r]),
        jnp.asarray(tables.q[r]),
        jnp.asarray(tables.qinv[r]),
        logn=tables.logn, inverse=False, interpret=interpret,
    )


def ntt_inv(x, primes, tables: NTTKernelTables,
            interpret: bool | None = None):
    if interpret is None:
        interpret = default_interpret()
    r = tables.rows(tuple(primes))
    return ntt_pallas(
        x.astype(jnp.uint32),
        jnp.asarray(tables.twist_i_m[r]),
        jnp.asarray(tables.tw_i_m[r]),
        jnp.asarray(tables.q[r]),
        jnp.asarray(tables.qinv[r]),
        logn=tables.logn, inverse=True, interpret=interpret,
    )


def ntt_fwd_oracle(x, primes, tables: NTTKernelTables):
    r = tables.rows(tuple(primes))
    return _ref.ntt_fwd_ref(
        x, jnp.asarray(tables.twist_f[r]), jnp.asarray(tables.tw_f[r]),
        jnp.asarray(tables.q[r].astype(np.uint64)),
    )


def ntt_inv_oracle(x, primes, tables: NTTKernelTables):
    r = tables.rows(tuple(primes))
    return _ref.ntt_inv_ref(
        x, jnp.asarray(tables.twist_i[r]), jnp.asarray(tables.tw_i[r]),
        jnp.asarray(tables.q[r].astype(np.uint64)),
    )
