"""jit'd wrappers + per-limb table precomputation for the NTT kernel."""
from __future__ import annotations

from functools import lru_cache

import jax.numpy as jnp
import numpy as np
from jax.custom_batching import custom_vmap

from repro.core.rns import RNSContext
from repro.kernels.modops import default_interpret, qinv_neg_host, to_mont_host
from repro.kernels.ntt.ntt import ntt_pallas
from repro.kernels.ntt import ref as _ref


class NTTKernelTables:
    """Stacked per-limb uint32 tables (normal + Montgomery forms)."""

    def __init__(self, rns: RNSContext):
        self.rns = rns
        self.logn = rns.params.logN
        n = rns.params.N
        primes = rns.all_primes
        l = len(primes)

        def flat_tw(stage_list, pi):
            out = np.ones(n, dtype=np.uint64)
            for s, tws in enumerate(stage_list):
                m = 1 << s
                out[m : 2 * m] = tws[pi]
            return out

        tw_f = np.stack([flat_tw(rns.stage_tw, i) for i in range(l)])
        tw_i = np.stack([flat_tw(rns.stage_tw_inv, i) for i in range(l)])
        twist_f = rns.psi_pows.astype(np.uint64)
        twist_i = (
            rns.psi_inv_pows.astype(object)
            * rns.n_inv.astype(object)[:, None]
            % rns.moduli.astype(object)[:, None]
        )

        self.q = rns.moduli.astype(np.uint32).reshape(l, 1)
        self.qinv = np.array(
            [qinv_neg_host(int(p)) for p in primes], dtype=np.uint32
        ).reshape(l, 1)
        # normal-form tables (for the oracle)
        self.tw_f = tw_f
        self.tw_i = tw_i
        self.twist_f = twist_f
        self.twist_i = twist_i.astype(np.uint64)
        # Montgomery-form tables (for the kernel)
        self.tw_f_m = np.stack(
            [to_mont_host(tw_f[i], int(primes[i])) for i in range(l)]
        )
        self.tw_i_m = np.stack(
            [to_mont_host(tw_i[i], int(primes[i])) for i in range(l)]
        )
        self.twist_f_m = np.stack(
            [to_mont_host(twist_f[i], int(primes[i])) for i in range(l)]
        )
        self.twist_i_m = np.stack(
            [to_mont_host(self.twist_i[i], int(primes[i])) for i in range(l)]
        )

    def rows(self, primes: tuple[int, ...]) -> np.ndarray:
        return self.rns.limb_ids(primes)


@lru_cache(maxsize=8)
def tables_for(params) -> NTTKernelTables:
    return NTTKernelTables(RNSContext(params))


@lru_cache(maxsize=None)
def _ntt_dispatch(tables: NTTKernelTables, rows: tuple, inverse: bool,
                  interpret: bool):
    """Rank-polymorphic NTT dispatch + ``custom_vmap`` rule, cached per
    (tables, limb rows, direction, backend).

    Leading batch dims fold into the kernel's row/grid axis; the limb
    tables are read through ``% l`` index maps, so a ``jax.vmap``-batched
    transform materializes nothing — the vmap rule just re-invokes the
    same dispatch on the batched operand (nesting-safe)."""
    r = np.array(rows)
    # numpy (NOT jnp) constants: the closure is cached across traces, so
    # captured values must never be tracers.
    twist = (tables.twist_i_m if inverse else tables.twist_f_m)[r]
    tw = (tables.tw_i_m if inverse else tables.tw_f_m)[r]
    q = tables.q[r]
    qinv = tables.qinv[r]

    def dispatch(x):
        y = ntt_pallas(
            x.reshape((-1, x.shape[-1])), twist, tw, q, qinv,
            logn=tables.logn, inverse=inverse, interpret=interpret,
        )
        return y.reshape(x.shape)

    fn = custom_vmap(dispatch)

    @fn.def_vmap
    def _rule(axis_size, in_batched, x):
        del axis_size, in_batched  # batch axis is at the front: fold it
        return dispatch(x), True

    return fn


def ntt_fwd(x, primes, tables: NTTKernelTables,
            interpret: bool | None = None):
    """(..., l, N) uint32 natural coeffs -> bit-reversed eval order.

    ``primes`` may contain duplicates (batched multi-poly transforms
    tile the limb axis).  ``interpret=None`` auto-detects the backend.
    ``jax.vmap``-safe via a ``custom_vmap`` rule.
    """
    if interpret is None:
        interpret = default_interpret()
    rows = tuple(int(i) for i in tables.rows(tuple(primes)))
    return _ntt_dispatch(tables, rows, False, bool(interpret))(
        x.astype(jnp.uint32))


def ntt_inv(x, primes, tables: NTTKernelTables,
            interpret: bool | None = None):
    if interpret is None:
        interpret = default_interpret()
    rows = tuple(int(i) for i in tables.rows(tuple(primes)))
    return _ntt_dispatch(tables, rows, True, bool(interpret))(
        x.astype(jnp.uint32))


def ntt_fwd_oracle(x, primes, tables: NTTKernelTables):
    r = tables.rows(tuple(primes))
    return _ref.ntt_fwd_ref(
        x, jnp.asarray(tables.twist_f[r]), jnp.asarray(tables.tw_f[r]),
        jnp.asarray(tables.q[r].astype(np.uint64)),
    )


def ntt_inv_oracle(x, primes, tables: NTTKernelTables):
    r = tables.rows(tuple(primes))
    return _ref.ntt_inv_ref(
        x, jnp.asarray(tables.twist_i[r]), jnp.asarray(tables.tw_i[r]),
        jnp.asarray(tables.q[r].astype(np.uint64)),
    )
