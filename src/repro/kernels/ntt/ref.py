"""Pure-jnp uint64 oracle for the NTT kernel (same DIF/DIT semantics)."""
from __future__ import annotations

import jax.numpy as jnp


def ntt_fwd_ref(x, twist, tw, q):
    """x: (l, N) uint32 natural order; twist/tw NORMAL form (l, N) uint64;
    q: (l, 1) uint64.  Returns (l, N) uint32, bit-reversed eval order."""
    x = x.astype(jnp.uint64)
    twist = twist.astype(jnp.uint64)
    tw = tw.astype(jnp.uint64)
    q = q.astype(jnp.uint64)
    l, n = x.shape
    logn = n.bit_length() - 1
    x = (x * twist) % q
    for s in range(logn - 1, -1, -1):
        m = 1 << s
        xb = x.reshape(l, n // (2 * m), 2 * m)
        u, v = xb[..., :m], xb[..., m:]
        w = tw[:, m : 2 * m][:, None, :]
        q3 = q[:, :, None]
        x = jnp.concatenate(
            [(u + v) % q3, ((u + q3 - v) % q3 * w) % q3], axis=-1
        ).reshape(l, n)
    return x.astype(jnp.uint32)


def ntt_inv_ref(x, twist, tw, q):
    """Inverse: bit-reversed eval -> natural coeff; twist = psi^-i * n^-1."""
    x = x.astype(jnp.uint64)
    twist = twist.astype(jnp.uint64)
    tw = tw.astype(jnp.uint64)
    q = q.astype(jnp.uint64)
    l, n = x.shape
    logn = n.bit_length() - 1
    for s in range(logn):
        m = 1 << s
        xb = x.reshape(l, n // (2 * m), 2 * m)
        u, v = xb[..., :m], xb[..., m:]
        w = tw[:, m : 2 * m][:, None, :]
        q3 = q[:, :, None]
        vw = (v * w) % q3
        x = jnp.concatenate(
            [(u + vw) % q3, (u + q3 - vw) % q3], axis=-1
        ).reshape(l, n)
    return ((x * twist) % q).astype(jnp.uint32)
