"""Pallas negacyclic NTT kernel (radix-2, trace-time-unrolled stages).

TPU adaptation of the paper's iterative NTTU (Fig. 12(a)):

  * DIF (forward, natural -> bit-reversed) and DIT (inverse, bit-reversed
    -> natural) so NO in-kernel permutation/gather is ever needed — the
    eval domain simply lives in bit-reversed order, which all elementwise
    consumers (IP/PMul/CAdd) are indifferent to.
  * One RNS limb's full polynomial is VMEM-resident per grid step
    (N=2^16 x 4 B = 256 KB << 16 MB VMEM); the grid walks limbs, which is
    also the paper's per-limb NTTU parallelism axis.
  * uint32 Montgomery arithmetic (see kernels.modops): data stays in the
    normal domain, twiddles/twists are pre-converted to Montgomery form.

Stage twiddles are packed flat: tw[m + j] = w^{(N >> (s+1)) * j} for
m = 2^s — the classic twiddle-tree layout, one (N,) vector per limb.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.modops import add_mod, mont_mul, sub_mod


def _fwd_body(x, twist, tw, q, qinv, logn: int):
    n = 1 << logn
    x = mont_mul(x, twist, q, qinv)          # psi^i pre-twist (negacyclic)
    for s in range(logn - 1, -1, -1):        # DIF: big blocks first
        m = 1 << s
        xb = x.reshape(n // (2 * m), 2 * m)
        u, v = xb[:, :m], xb[:, m:]
        w = tw[m : 2 * m]                # static slice — stage known at trace
        t = sub_mod(u, v, q)
        x = jnp.concatenate(
            [add_mod(u, v, q), mont_mul(t, w[None, :], q, qinv)], axis=1
        ).reshape(n)
    return x


def _inv_body(x, twist, tw, q, qinv, logn: int):
    n = 1 << logn
    for s in range(logn):                    # DIT: small blocks first
        m = 1 << s
        xb = x.reshape(n // (2 * m), 2 * m)
        u, v = xb[:, :m], xb[:, m:]
        w = tw[m : 2 * m]
        vw = mont_mul(v, w[None, :], q, qinv)
        x = jnp.concatenate(
            [add_mod(u, vw, q), sub_mod(u, vw, q)], axis=1
        ).reshape(n)
    # psi^{-i} * n^{-1} post-twist folded into one Montgomery table
    return mont_mul(x, twist, q, qinv)


def _ntt_kernel(x_ref, twist_ref, tw_ref, q_ref, qinv_ref, o_ref,
                *, logn: int, inverse: bool):
    q = q_ref[0, 0]
    qinv = qinv_ref[0, 0]
    x = x_ref[0, :]
    twist = twist_ref[0, :]
    tw = tw_ref[0, :]
    body = _inv_body if inverse else _fwd_body
    o_ref[0, :] = body(x, twist, tw, q, qinv, logn)


def ntt_pallas(x, twist, tw, q, qinv, *, logn: int, inverse: bool,
               interpret: bool = True):
    """x: (B*l, N) uint32, batch-major rows; twist/tw: (l, N) uint32
    Montgomery; q/qinv: (l, 1).  B is inferred from the row count.

    Grid walks all B*l rows; each program transforms one polynomial in
    VMEM, reading its limb's tables via a ``% l`` index map — batching
    costs no table replication.
    """
    rows, n = x.shape
    l = twist.shape[0]
    assert n == 1 << logn
    kernel = functools.partial(_ntt_kernel, logn=logn, inverse=inverse)
    return pl.pallas_call(
        kernel,
        grid=(rows,),
        in_specs=[
            pl.BlockSpec((1, n), lambda i: (i, 0)),
            pl.BlockSpec((1, n), lambda i, l=l: (i % l, 0)),
            pl.BlockSpec((1, n), lambda i, l=l: (i % l, 0)),
            pl.BlockSpec((1, 1), lambda i, l=l: (i % l, 0)),
            pl.BlockSpec((1, 1), lambda i, l=l: (i % l, 0)),
        ],
        out_specs=pl.BlockSpec((1, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, n), jnp.uint32),
        interpret=interpret,
    )(x, twist, tw, q, qinv)
