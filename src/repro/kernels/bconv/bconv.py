"""Pallas fast-basis-conversion kernel (the paper's tree-based BConvU).

Two passes, mirroring Fig. 12(b):

  scale  : t_i = [x_i * qhat_inv_i]_{q_i}           (grid over src limbs)
  reduce : y_j = sum_i t_i * (qhat_i mod d_j)  mod d_j  (grid over dst
           limbs x coefficient blocks; the per-limb loop is the tree)

The reduce pass keeps one coefficient block of ALL source limbs in VMEM
(ls x BLK x 4 B), which is the VMEM-resident working set the paper's
BConvU pipelines through its adder tree.  Constants are Montgomery-form,
data stays normal-form (see kernels.modops).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.modops import add_mod, mont_mul


def _scale_kernel(x_ref, qinv_mont_ref, q_ref, qneg_ref, o_ref):
    q = q_ref[0, 0]
    qn = qneg_ref[0, 0]
    o_ref[0, :] = mont_mul(x_ref[0, :], qinv_mont_ref[0, 0], q, qn)


def _reduce_kernel(t_ref, c_ref, d_ref, dneg_ref, o_ref, *, ls: int):
    d = d_ref[0, 0]
    dn = dneg_ref[0, 0]
    acc = mont_mul(t_ref[0, :], c_ref[0, 0], d, dn)
    for i in range(1, ls):                       # trace-time adder tree
        acc = add_mod(acc, mont_mul(t_ref[i, :], c_ref[i, 0], d, dn), d)
    o_ref[0, :] = acc


def bconv_pallas(x, qhat_inv_mont, src_q, src_qneg, c_mont, dst_q, dst_qneg,
                 *, block: int = 0, interpret: bool = True):
    """x: (B*ls, N) uint32 coeff domain -> (B*ld, N) under the dst basis,
    batch-major rows (B inferred from the row count).

    qhat_inv_mont: (ls, 1); c_mont: (ls, ld) Montgomery of qhat_i mod d_j;
    src_q/src_qneg: (ls, 1); dst_q/dst_qneg: (ld, 1).  Batched rows read
    their limb's constants via ``% ls`` / ``% ld`` index maps.
    """
    rows, n = x.shape
    ls = qhat_inv_mont.shape[0]
    ld = c_mont.shape[1]
    b = rows // ls
    blk = block or n

    t = pl.pallas_call(
        _scale_kernel,
        grid=(rows,),
        in_specs=[
            pl.BlockSpec((1, n), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, ls=ls: (i % ls, 0)),
            pl.BlockSpec((1, 1), lambda i, ls=ls: (i % ls, 0)),
            pl.BlockSpec((1, 1), lambda i, ls=ls: (i % ls, 0)),
        ],
        out_specs=pl.BlockSpec((1, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, n), jnp.uint32),
        interpret=interpret,
    )(x, qhat_inv_mont, src_q, src_qneg)

    kernel = functools.partial(_reduce_kernel, ls=ls)
    return pl.pallas_call(
        kernel,
        grid=(b * ld, n // blk),
        in_specs=[
            pl.BlockSpec((ls, blk), lambda j, b, ld=ld: (j // ld, b)),
            pl.BlockSpec((ls, 1), lambda j, b, ld=ld: (0, j % ld)),
            pl.BlockSpec((1, 1), lambda j, b, ld=ld: (j % ld, 0)),
            pl.BlockSpec((1, 1), lambda j, b, ld=ld: (j % ld, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk), lambda j, b: (j, b)),
        out_shape=jax.ShapeDtypeStruct((b * ld, n), jnp.uint32),
        interpret=interpret,
    )(t, c_mont, dst_q, dst_qneg)
