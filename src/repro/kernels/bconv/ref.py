"""Pure-jnp uint64 oracle for the BConv kernel."""
from __future__ import annotations

import jax.numpy as jnp


def bconv_ref(x, qhat_inv, src_q, qhat_mod, dst_q):
    """x: (ls, N) uint32; qhat_inv: (ls,); qhat_mod: (ls, ld); NORMAL form."""
    x = x.astype(jnp.uint64)
    qhat_inv = qhat_inv.astype(jnp.uint64)
    src_q = src_q.astype(jnp.uint64)
    qhat_mod = qhat_mod.astype(jnp.uint64)
    dst_q = dst_q.astype(jnp.uint64)
    t = (x * qhat_inv[:, None]) % src_q[:, None]
    ld = qhat_mod.shape[1]
    outs = []
    for j in range(ld):
        d = dst_q[j]
        acc = jnp.zeros(x.shape[1], dtype=jnp.uint64)
        for i in range(x.shape[0]):
            acc = (acc + (t[i] * qhat_mod[i, j]) % d) % d
        outs.append(acc)
    return jnp.stack(outs).astype(jnp.uint32)
