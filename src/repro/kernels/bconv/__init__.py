from repro.kernels.bconv.ops import BConvKernelConsts, bconv_kernel  # noqa: F401
