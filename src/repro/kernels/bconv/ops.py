"""jit'd wrapper + constants for the BConv kernel."""
from __future__ import annotations

from functools import lru_cache

import jax.numpy as jnp
import numpy as np

from repro.core.rns import RNSContext
from repro.kernels.bconv.bconv import bconv_pallas
from repro.kernels.bconv import ref as _ref
from repro.kernels.modops import default_interpret, qinv_neg_host, to_mont_host


class BConvKernelConsts:
    def __init__(self, rns: RNSContext, src: tuple[int, ...],
                 dst: tuple[int, ...]):
        qhat_inv, qhat_mod = rns.bconv_consts(tuple(src), tuple(dst))
        ls, ld = len(src), len(dst)
        self.qhat_inv = qhat_inv
        self.qhat_mod = qhat_mod
        self.src_q = np.array(src, dtype=np.uint32).reshape(ls, 1)
        self.dst_q = np.array(dst, dtype=np.uint32).reshape(ld, 1)
        self.src_qneg = np.array(
            [qinv_neg_host(q) for q in src], dtype=np.uint32
        ).reshape(ls, 1)
        self.dst_qneg = np.array(
            [qinv_neg_host(q) for q in dst], dtype=np.uint32
        ).reshape(ld, 1)
        self.qhat_inv_mont = np.stack(
            [to_mont_host(np.array([qhat_inv[i]]), src[i]) for i in range(ls)]
        )
        self.qhat_mod_mont = np.stack(
            [
                np.array(
                    [int(to_mont_host(np.array([qhat_mod[i, j]]), dst[j])[0])
                     for j in range(ld)],
                    dtype=np.uint32,
                )
                for i in range(ls)
            ]
        )


@lru_cache(maxsize=None)
def _consts(rns_id, src, dst):
    rns = _RNS_REGISTRY[rns_id]
    return BConvKernelConsts(rns, src, dst)


_RNS_REGISTRY: dict[int, RNSContext] = {}


def bconv_kernel(x, src, dst, rns: RNSContext, block: int = 0,
                 interpret: bool | None = None):
    """(ls, N) uint32 -> (ld, N) uint32 via the Pallas kernel."""
    if interpret is None:
        interpret = default_interpret()
    _RNS_REGISTRY[id(rns)] = rns
    c = _consts(id(rns), tuple(src), tuple(dst))
    return bconv_pallas(
        x.astype(jnp.uint32),
        jnp.asarray(c.qhat_inv_mont), jnp.asarray(c.src_q),
        jnp.asarray(c.src_qneg), jnp.asarray(c.qhat_mod_mont),
        jnp.asarray(c.dst_q), jnp.asarray(c.dst_qneg),
        block=block, interpret=interpret,
    )


def bconv_oracle(x, src, dst, rns: RNSContext):
    _RNS_REGISTRY[id(rns)] = rns
    c = _consts(id(rns), tuple(src), tuple(dst))
    return _ref.bconv_ref(
        x, jnp.asarray(c.qhat_inv), jnp.asarray(c.src_q.reshape(-1)),
        jnp.asarray(c.qhat_mod), jnp.asarray(c.dst_q.reshape(-1)),
    )
