"""jit'd wrapper + constants for the BConv kernel."""
from __future__ import annotations

from functools import lru_cache

import jax.numpy as jnp
import numpy as np
from jax.custom_batching import custom_vmap

from repro.core.rns import RNSContext
from repro.kernels.bconv.bconv import bconv_pallas
from repro.kernels.bconv import ref as _ref
from repro.kernels.modops import default_interpret, qinv_neg_host, to_mont_host


class BConvKernelConsts:
    def __init__(self, rns: RNSContext, src: tuple[int, ...],
                 dst: tuple[int, ...]):
        qhat_inv, qhat_mod = rns.bconv_consts(tuple(src), tuple(dst))
        ls, ld = len(src), len(dst)
        self.qhat_inv = qhat_inv
        self.qhat_mod = qhat_mod
        self.src_q = np.array(src, dtype=np.uint32).reshape(ls, 1)
        self.dst_q = np.array(dst, dtype=np.uint32).reshape(ld, 1)
        self.src_qneg = np.array(
            [qinv_neg_host(q) for q in src], dtype=np.uint32
        ).reshape(ls, 1)
        self.dst_qneg = np.array(
            [qinv_neg_host(q) for q in dst], dtype=np.uint32
        ).reshape(ld, 1)
        self.qhat_inv_mont = np.stack(
            [to_mont_host(np.array([qhat_inv[i]]), src[i]) for i in range(ls)]
        )
        self.qhat_mod_mont = np.stack(
            [
                np.array(
                    [int(to_mont_host(np.array([qhat_mod[i, j]]), dst[j])[0])
                     for j in range(ld)],
                    dtype=np.uint32,
                )
                for i in range(ls)
            ]
        )


@lru_cache(maxsize=None)
def _consts(rns_id, src, dst):
    rns = _RNS_REGISTRY[rns_id]
    return BConvKernelConsts(rns, src, dst)


_RNS_REGISTRY: dict[int, RNSContext] = {}


@lru_cache(maxsize=None)
def _bconv_dispatch(rns_id, src, dst, block, interpret):
    """Rank-polymorphic BConv dispatch + ``custom_vmap`` rule, cached.

    Leading batch dims fold into the kernel grids (batch-major rows,
    constants read via ``%`` index maps) — the vmap rule re-invokes the
    same dispatch on the batched operand, so nothing is replicated."""
    c = _consts(rns_id, src, dst)
    ld = len(dst)
    # numpy (NOT jnp) constants: the closure is cached across traces, so
    # captured values must never be tracers.
    consts = (
        c.qhat_inv_mont, c.src_q, c.src_qneg, c.qhat_mod_mont,
        c.dst_q, c.dst_qneg,
    )

    def dispatch(x):
        n = x.shape[-1]
        y = bconv_pallas(
            x.reshape((-1, n)), *consts, block=block, interpret=interpret,
        )
        return y.reshape(x.shape[:-2] + (ld, n))

    fn = custom_vmap(dispatch)

    @fn.def_vmap
    def _rule(axis_size, in_batched, x):
        del axis_size, in_batched  # batch axis is at the front: fold it
        return dispatch(x), True

    return fn


def bconv_kernel(x, src, dst, rns: RNSContext, block: int = 0,
                 interpret: bool | None = None):
    """(..., ls, N) uint32 -> (..., ld, N) uint32 via the Pallas kernel.
    ``jax.vmap``-safe via a ``custom_vmap`` rule."""
    if interpret is None:
        interpret = default_interpret()
    _RNS_REGISTRY[id(rns)] = rns
    return _bconv_dispatch(
        id(rns), tuple(src), tuple(dst), int(block), bool(interpret)
    )(x.astype(jnp.uint32))


def bconv_oracle(x, src, dst, rns: RNSContext):
    _RNS_REGISTRY[id(rns)] = rns
    c = _consts(id(rns), tuple(src), tuple(dst))
    return _ref.bconv_ref(
        x, jnp.asarray(c.qhat_inv), jnp.asarray(c.src_q.reshape(-1)),
        jnp.asarray(c.qhat_mod), jnp.asarray(c.dst_q.reshape(-1)),
    )
