"""Pallas-TPU kernels for the paper's compute hot spots.

The paper's xPU accelerates NTT (iterative radix-2 NTTUs) and BConv
(tree-based BConvUs); its xMU fuses MemOps (IP + PMul).  Here those map to:

  ntt/        radix-2 negacyclic NTT, stages unrolled at trace time,
              one limb's polynomial resident in VMEM per grid step.
  bconv/      scale pass + tree-reduce pass over source limbs.
  fused_ip/   keyswitch inner product with optional fused PMul
              (the xMU "MemOp fusion" of Fig. 10(d)).

All kernels use uint32 Montgomery arithmetic built from 16-bit limb
partial products (``modops``) — TPU has no 64-bit integer multiply and no
mulhi, but 16x16->32 partials + carries are VPU-native.  Kernels are
validated on CPU with interpret=True against pure-jnp oracles (ref.py)
and against the exact uint64 core (repro.core.poly).
"""
