"""uint32 Montgomery modular arithmetic from 16-bit limb partials.

Everything here is elementwise jnp on uint32 and runs identically inside a
Pallas TPU kernel body and as plain jnp.  Constraints:

  * modulus q odd, q < 2^30  (so the REDC accumulator fits uint32)
  * R = 2^32

Montgomery trick used throughout the kernels: keep VALUES in the normal
domain and constants (twiddles, BConv factors, evk) in Montgomery form —
mont_mul(value, const_mont) = value*const mod q, so no domain-conversion
passes are ever needed on the data.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

def mul32_split(a, b):
    """Full 32x32 -> (hi32, lo32) product via 16-bit limbs (no 64-bit ops).

    NOTE: literals stay Python ints so Pallas sees no captured constants.
    """
    a = a.astype(jnp.uint32)
    b = b.astype(jnp.uint32)
    a0, a1 = a & 0xFFFF, a >> 16
    b0, b1 = b & 0xFFFF, b >> 16
    ll = a0 * b0
    lh = a0 * b1
    hl = a1 * b0
    hh = a1 * b1
    mid = lh + hl                      # may wrap
    carry_mid = (mid < lh).astype(jnp.uint32)
    lo = ll + (mid << 16)              # may wrap
    carry_lo = (lo < ll).astype(jnp.uint32)
    hi = hh + (mid >> 16) + (carry_mid << 16) + carry_lo
    return hi, lo


def mont_redc(hi, lo, q, qinv_neg):
    """REDC: (hi*2^32 + lo) * 2^-32 mod q, for T < q*2^32, q < 2^30 odd.

    qinv_neg = -q^{-1} mod 2^32.
    """
    m = lo * qinv_neg                  # mod 2^32 (wrapping)
    mq_hi, _ = mul32_split(m, q)
    carry = (lo != 0).astype(jnp.uint32)
    t = hi + mq_hi + carry             # < 1.5*q, no overflow for q < 2^30
    return jnp.where(t >= q, t - q, t)


def mont_mul(a, b, q, qinv_neg):
    """a * b * 2^-32 mod q.  If b is in Montgomery form (b*2^32 mod q),
    the result is the plain product a*b mod q."""
    hi, lo = mul32_split(a, b)
    return mont_redc(hi, lo, q, qinv_neg)


def add_mod(a, b, q):
    s = a + b                          # < 2q < 2^31, no overflow
    return jnp.where(s >= q, s - q, s)


def sub_mod(a, b, q):
    return jnp.where(a >= b, a - b, a + q - b)


# ----------------------- host-side constant helpers ----------------------

def default_interpret() -> bool:
    """Pallas interpret mode unless a real TPU backend is attached."""
    import jax

    return jax.default_backend() != "tpu"


def qinv_neg_host(q: int) -> np.uint32:
    """-q^{-1} mod 2^32 (host precompute)."""
    return np.uint32((-pow(q, -1, 1 << 32)) % (1 << 32))


def to_mont_host(x: np.ndarray, q: int) -> np.ndarray:
    """Convert constants to Montgomery form on the host (exact ints)."""
    return ((x.astype(object) * (1 << 32)) % q).astype(np.uint32)
