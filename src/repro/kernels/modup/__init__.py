"""Fused Pallas ModUp kernel: INTT -> BConv -> NTT in one pallas_call."""
