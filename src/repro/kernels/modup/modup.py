"""Fused Pallas ModUp kernel: INTT -> BConv reduce -> NTT, one call per digit.

The paper's xPU win is keeping ModUp's three phases on-chip; the op-by-op
backend instead round-trips every intermediate (INTT output, BConv scale,
BConv reduce) through HBM.  This kernel executes the whole digit in ONE
``pallas_call``:

  * grid = (B * ld,) walks the destination limbs (ld = extended-basis
    size), batch-major — limb ``s`` serves batch element ``s // ld``;
  * on each batch element's FIRST step (``s % ld == 0``) the digit's
    ``ls`` source limbs are INTT'd into a persistent VMEM scratch
    ``(ls, N)``.  The BConv per-limb scale ``qhat_inv_i`` is FOLDED into
    the INTT post-twist table (one Montgomery multiply already applies
    ``psi^{-i} * n^{-1}``; composing ``* qhat_inv_i`` is free), so the
    BConvU scale pass disappears entirely;
  * every step then tree-reduces the scratch against one column of the
    Montgomery ``qhat_i mod d_j`` constants and runs the forward NTT of
    that single destination limb — reusing the NTT kernel's trace-time
    butterfly bodies (``_fwd_body`` / ``_inv_body``).

No per-phase intermediate ever reaches HBM: the scratch persists across
sequential grid steps (TPU grids are sequential per core; interpret mode
matches).  VMEM residency at logN=16 is (4*ls + 3) rows of 256 KB —
~7 MB at alpha = 6, well under the 16 MB budget.

Domain bridging stays OUTSIDE the kernel (engine side): inputs are
bit-reversed eval order, outputs bit-reversed eval order, exactly like
``kernels/ntt``.  Data is normal-form uint32, constants Montgomery-form
(see ``kernels.modops``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.modops import add_mod, mont_mul
from repro.kernels.ntt.ntt import _fwd_body, _inv_body


def _modup_kernel(x_ref, tsc_ref, twi_ref, sq_ref, sqn_ref,
                  c_ref, twf_ref, twtf_ref, dq_ref, dqn_ref,
                  o_ref, t_ref, *, ls: int, ld: int, logn: int):
    s = pl.program_id(0)

    @pl.when(s % ld == 0)
    def _intt_sources():
        # Phase 1 (once per batch element): INTT every source limb into
        # the persistent scratch, post-twisted by psi^{-i} n^{-1} qhat_inv
        # — phases 1 and 2a of ModUp in one Montgomery pass each.
        for i in range(ls):
            q = sq_ref[i, 0]
            qn = sqn_ref[i, 0]
            t_ref[i, :] = _inv_body(
                x_ref[i, :], tsc_ref[i, :], twi_ref[i, :], q, qn, logn
            )

    # Phase 2b: adder-tree reduce into destination limb s % ld.
    d = dq_ref[0, 0]
    dn = dqn_ref[0, 0]
    acc = mont_mul(t_ref[0, :], c_ref[0, 0], d, dn)
    for i in range(1, ls):
        acc = add_mod(acc, mont_mul(t_ref[i, :], c_ref[i, 0], d, dn), d)
    # Phase 3: forward NTT of the new limb, straight out of registers.
    o_ref[0, :] = _fwd_body(acc, twf_ref[0, :], twtf_ref[0, :], d, dn, logn)


def modup_pallas(x, twist_i_scaled, tw_i, src_q, src_qneg,
                 c_mont, twist_f, tw_f, dst_q, dst_qneg,
                 *, logn: int, interpret: bool = True):
    """x: (B*ls, N) uint32 bit-reversed eval -> (B*ld, N) bit-reversed
    eval under the destination basis (B inferred from the row count).

    twist_i_scaled/tw_i: (ls, N) Montgomery INTT tables with the BConv
    scale folded into the post-twist; c_mont: (ls, ld) Montgomery
    ``qhat_i mod d_j``; twist_f/tw_f: (ld, N) Montgomery NTT tables;
    src_q/src_qneg: (ls, 1); dst_q/dst_qneg: (ld, 1).
    """
    ls, n = twist_i_scaled.shape
    ld = tw_f.shape[0]
    assert n == 1 << logn
    b = x.shape[0] // ls
    kernel = functools.partial(_modup_kernel, ls=ls, ld=ld, logn=logn)
    return pl.pallas_call(
        kernel,
        grid=(b * ld,),
        in_specs=[
            pl.BlockSpec((ls, n), lambda s, ld=ld: (s // ld, 0)),
            pl.BlockSpec((ls, n), lambda s: (0, 0)),
            pl.BlockSpec((ls, n), lambda s: (0, 0)),
            pl.BlockSpec((ls, 1), lambda s: (0, 0)),
            pl.BlockSpec((ls, 1), lambda s: (0, 0)),
            pl.BlockSpec((ls, 1), lambda s, ld=ld: (0, s % ld)),
            pl.BlockSpec((1, n), lambda s, ld=ld: (s % ld, 0)),
            pl.BlockSpec((1, n), lambda s, ld=ld: (s % ld, 0)),
            pl.BlockSpec((1, 1), lambda s, ld=ld: (s % ld, 0)),
            pl.BlockSpec((1, 1), lambda s, ld=ld: (s % ld, 0)),
        ],
        out_specs=pl.BlockSpec((1, n), lambda s: (s, 0)),
        out_shape=jax.ShapeDtypeStruct((b * ld, n), jnp.uint32),
        scratch_shapes=[pltpu.VMEM((ls, n), jnp.uint32)],
        interpret=interpret,
    )(x, twist_i_scaled, tw_i, src_q, src_qneg,
      c_mont, twist_f, tw_f, dst_q, dst_qneg)
