"""jit'd wrapper, constants, and vmap rule for the fused ModUp kernel."""
from __future__ import annotations

from functools import lru_cache

import jax.numpy as jnp
import numpy as np
from jax.custom_batching import custom_vmap

from repro.core.rns import RNSContext
from repro.kernels.modops import default_interpret, to_mont_host
from repro.kernels.modup import ref as _ref
from repro.kernels.modup.modup import modup_pallas
from repro.kernels.ntt.ops import NTTKernelTables


class ModUpDigitConsts:
    """Per-(source digit, destination basis) kernel tables.

    The BConv per-limb scale ``qhat_inv_i`` is folded into the INTT
    post-twist here, on the host, in exact object-int arithmetic — the
    kernel then needs no scale pass at all.  Normal-form copies feed the
    uint64 oracle."""

    def __init__(self, rns: RNSContext, tabs: NTTKernelTables,
                 src: tuple[int, ...], dst: tuple[int, ...]):
        qhat_inv, qhat_mod = rns.bconv_consts(tuple(src), tuple(dst))
        rs = tabs.rows(tuple(src))
        rd = tabs.rows(tuple(dst))
        ls, ld = len(src), len(dst)
        n = 1 << tabs.logn

        scaled = np.empty((ls, n), dtype=np.uint64)
        for i in range(ls):
            q = int(src[i])
            scaled[i] = (
                tabs.twist_i[rs[i]].astype(object) * int(qhat_inv[i]) % q
            ).astype(np.uint64)
        self.twist_i_scaled = scaled
        self.twist_i_scaled_m = np.stack(
            [to_mont_host(scaled[i], int(src[i])) for i in range(ls)]
        )
        self.tw_i_m = tabs.tw_i_m[rs]
        self.src_q = tabs.q[rs]
        self.src_qneg = tabs.qinv[rs]
        self.qhat_mod = qhat_mod
        self.c_mont = np.stack([
            np.array(
                [int(to_mont_host(np.array([qhat_mod[i, j]]),
                                  int(dst[j]))[0])
                 for j in range(ld)],
                dtype=np.uint32,
            )
            for i in range(ls)
        ])
        self.twist_f_m = tabs.twist_f_m[rd]
        self.tw_f_m = tabs.tw_f_m[rd]
        self.dst_q = tabs.q[rd]
        self.dst_qneg = tabs.qinv[rd]
        # normal-form tables for the oracle
        self.tw_i = tabs.tw_i[rs]
        self.twist_f = tabs.twist_f[rd]
        self.tw_f = tabs.tw_f[rd]
        self.logn = tabs.logn


_REGISTRY: dict[tuple, tuple] = {}


def _admit(rns: RNSContext, tabs: NTTKernelTables) -> tuple:
    key = (id(rns), id(tabs))
    _REGISTRY[key] = (rns, tabs)
    return key


@lru_cache(maxsize=None)
def _consts(reg_key, src, dst) -> ModUpDigitConsts:
    rns, tabs = _REGISTRY[reg_key]
    return ModUpDigitConsts(rns, tabs, src, dst)


@lru_cache(maxsize=None)
def _dispatch(reg_key, src, dst, interpret):
    """Rank-polymorphic dispatch + ``custom_vmap`` rule, cached so every
    trace of the same (digit, basis, backend) reuses ONE callable.

    The dispatch flattens any leading batch dims into the kernel's grid
    axis (batch-major rows) — zero extra materialization — so the vmap
    rule simply re-invokes it on the batched operand."""
    c = _consts(reg_key, src, dst)
    ld = len(dst)
    # numpy (NOT jnp) constants: the closure is cached across traces, so
    # captured values must never be tracers — numpy lifts into each
    # trace as a fresh constant.
    tables = (
        c.twist_i_scaled_m, c.tw_i_m, c.src_q, c.src_qneg, c.c_mont,
        c.twist_f_m, c.tw_f_m, c.dst_q, c.dst_qneg,
    )
    logn = c.logn

    def dispatch(x):
        n = x.shape[-1]
        y = modup_pallas(
            x.reshape((-1, n)), *tables, logn=logn, interpret=interpret
        )
        return y.reshape(x.shape[:-2] + (ld, n))

    fn = custom_vmap(dispatch)

    @fn.def_vmap
    def _rule(axis_size, in_batched, x):
        del axis_size, in_batched  # batch axis is at the front: fold it
        return dispatch(x), True

    return fn


def modup_digit(x, src, dst, tabs: NTTKernelTables, rns: RNSContext,
                interpret: bool | None = None):
    """(..., ls, N) uint32 bit-reversed eval -> (..., ld, N) bit-reversed
    eval: ONE fused pallas_call (INTT -> scaled tree-reduce -> NTT) per
    digit, VMEM-resident across all three phases.  ``jax.vmap``-safe."""
    if interpret is None:
        interpret = default_interpret()
    key = _admit(rns, tabs)
    return _dispatch(key, tuple(src), tuple(dst), bool(interpret))(
        x.astype(jnp.uint32)
    )


def modup_digit_oracle(x, src, dst, tabs: NTTKernelTables,
                       rns: RNSContext):
    """Exact uint64 mirror of :func:`modup_digit` (same phase fusion)."""
    key = _admit(rns, tabs)
    c = _consts(key, tuple(src), tuple(dst))
    return _ref.modup_digit_ref(
        x, jnp.asarray(c.twist_i_scaled), jnp.asarray(c.tw_i),
        jnp.asarray(c.src_q.astype(np.uint64)), jnp.asarray(c.qhat_mod),
        jnp.asarray(c.twist_f), jnp.asarray(c.tw_f),
        jnp.asarray(c.dst_q.astype(np.uint64)),
    )
