"""Pure-jnp uint64 oracle for the fused ModUp kernel.

Mirrors the kernel's phase structure exactly: INTT with the BConv scale
folded into the post-twist, per-destination-limb tree reduce, forward
NTT — all in exact uint64 ``%`` arithmetic.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.ntt.ref import ntt_fwd_ref, ntt_inv_ref


def modup_digit_ref(x, twist_i_scaled, tw_i, src_q, qhat_mod,
                    twist_f, tw_f, dst_q):
    """x: (ls, N) uint32 bit-reversed eval; tables NORMAL form uint64;
    src_q/dst_q: (ls, 1)/(ld, 1).  Returns (ld, N) uint32 bit-reversed
    eval under the destination basis."""
    t = ntt_inv_ref(x, twist_i_scaled, tw_i, src_q).astype(jnp.uint64)
    qhat_mod = qhat_mod.astype(jnp.uint64)
    dq = dst_q.astype(jnp.uint64).reshape(-1)
    ld = qhat_mod.shape[1]
    outs = []
    for j in range(ld):
        d = dq[j]
        acc = jnp.zeros(x.shape[1], dtype=jnp.uint64)
        for i in range(x.shape[0]):
            acc = (acc + (t[i] * qhat_mod[i, j]) % d) % d
        outs.append(acc)
    y = jnp.stack(outs).astype(jnp.uint32)
    return ntt_fwd_ref(y, twist_f, tw_f, dst_q)
