"""Gradient compression for the data-parallel all-reduce.

int8 per-tensor-scaled quantization: 4x less DP traffic at <0.5% relative
error per tensor (error feedback omitted — gradients are noisy at this
precision already; documented trade-off).  On a real mesh the compressed
tensors are what crosses the pod-interconnect; here the quantize ->
(all-reduce) -> dequantize pair is the unit-tested kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_grads_int8(grads):
    def comp(g):
        g32 = g.astype(jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
        return {"q": q, "scale": scale}

    return jax.tree.map(comp, grads)


def decompress_grads(comp):
    def dec(c):
        return c["q"].astype(jnp.float32) * c["scale"]

    return jax.tree.map(
        dec, comp,
        is_leaf=lambda x: isinstance(x, dict) and "q" in x,
    )
