"""AdamW on pure pytrees with dtype-configurable moment states.

bf16 moments (m, v) halve optimizer memory — required to fit
arctic-480b / jamba-398b training in 16 GB/chip HBM at 256 chips
(ZeRO-style: states inherit the FSDP param sharding).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"
    warmup_steps: int = 100

    def init(self, params) -> dict:
        dt = jnp.dtype(self.state_dtype)
        zeros = lambda p: jnp.zeros(p.shape, dt)  # noqa: E731
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def _schedule(self, step):
        warm = jnp.minimum(step.astype(jnp.float32) / self.warmup_steps, 1.0)
        return self.lr * warm

    def update(self, params, grads, state):
        step = state["step"] + 1
        # global-norm clip
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)
        ))
        scale = jnp.minimum(1.0, self.grad_clip / (gnorm + 1e-9))
        dt = jnp.dtype(self.state_dtype)
        lr = self._schedule(step)
        b1c = 1 - self.b1 ** step.astype(jnp.float32)
        b2c = 1 - self.b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * scale
            m32 = self.b1 * m.astype(jnp.float32) + (1 - self.b1) * g
            v32 = self.b2 * v.astype(jnp.float32) + (1 - self.b2) * g * g
            mhat = m32 / b1c
            vhat = v32 / b2c
            delta = mhat / (jnp.sqrt(vhat) + self.eps) \
                + self.weight_decay * p.astype(jnp.float32)
            return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                    m32.astype(dt), v32.astype(dt))

        out = jax.tree.map(upd, params, grads, state["m"], state["v"])
        new_p = jax.tree.map(lambda t: t[0], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree.map(lambda t: t[2], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        return new_p, {"m": new_m, "v": new_v, "step": step}
