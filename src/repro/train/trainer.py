"""Fault-tolerant training loop.

Production behaviors (scaled down to single-host for CI):
  * checkpoint every N steps (async, atomic) + checkpoint-on-SIGTERM
  * auto-resume from the latest complete checkpoint
  * elastic resume onto a different mesh (pipeline state is one integer)
  * step-time watchdog flags stragglers (slow steps) for rescheduling
  * optional int8 gradient compression for the DP all-reduce
  * microbatch gradient accumulation (bounds memory; overlaps the DP
    reduction of microbatch i with compute of i+1 under XLA latency
    hiding)
"""
from __future__ import annotations

import dataclasses
import signal
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.pipeline import TokenPipeline
from repro.models.steps import loss_fn
from repro.train.checkpoint import CheckpointManager
from repro.train.compression import compress_grads_int8, decompress_grads
from repro.train.optimizer import AdamW


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 25
    ckpt_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10
    microbatches: int = 1
    grad_compression: bool = False
    straggler_factor: float = 3.0   # step slower than 3x median -> flag


class Trainer:
    def __init__(self, cfg: ModelConfig, tcfg: TrainerConfig,
                 optimizer: AdamW | None = None, mesh=None):
        self.cfg = cfg
        self.tcfg = tcfg
        self.opt = optimizer or AdamW(
            state_dtype=cfg.optimizer_state_dtype)
        self.mesh = mesh
        self.ckpt = CheckpointManager(tcfg.ckpt_dir)
        self._stop = False
        self.step_times: list[float] = []
        self.stragglers: list[int] = []

    # ------------------------------------------------------------------
    def _train_step(self):
        opt, cfg, tcfg = self.opt, self.cfg, self.tcfg

        def step_fn(params, opt_state, batch):
            if tcfg.microbatches > 1:
                mb = jax.tree.map(
                    lambda x: x.reshape(
                        (tcfg.microbatches, -1) + x.shape[1:]), batch)

                def acc_body(carry, b):
                    gsum, lsum = carry
                    l, g = jax.value_and_grad(loss_fn)(params, b, cfg)
                    return (jax.tree.map(jnp.add, gsum, g), lsum + l), None

                zeros = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (gsum, lsum), _ = jax.lax.scan(acc_body, (zeros, 0.0), mb)
                grads = jax.tree.map(
                    lambda g: g / tcfg.microbatches, gsum)
                loss = lsum / tcfg.microbatches
            else:
                loss, grads = jax.value_and_grad(loss_fn)(
                    params, batch, cfg)
            if tcfg.grad_compression:
                grads = decompress_grads(compress_grads_int8(grads))
            params, opt_state = opt.update(params, grads, opt_state)
            return params, opt_state, loss

        return jax.jit(step_fn, donate_argnums=(0, 1))

    # ------------------------------------------------------------------
    def run(self, params, pipeline: TokenPipeline, start_step: int = 0,
            resume: bool = True):
        opt_state = self.opt.init(params)
        step = start_step
        if resume:
            latest = self.ckpt.latest_step()
            if latest is not None:
                step, state = self.ckpt.restore(latest)
                params, opt_state = state["params"], state["opt"]
                print(f"[trainer] resumed from step {step}")

        old = signal.signal(signal.SIGTERM, self._on_sigterm)
        step_fn = self._train_step()
        losses = []
        try:
            while step < self.tcfg.total_steps and not self._stop:
                t0 = time.time()
                batch = {
                    k: jnp.asarray(v)
                    for k, v in pipeline.batch_at(step).items()
                }
                params, opt_state, loss = step_fn(params, opt_state, batch)
                loss = float(loss)
                dt = time.time() - t0
                self.step_times.append(dt)
                med = float(np.median(self.step_times))
                if (len(self.step_times) > 5
                        and dt > self.tcfg.straggler_factor * med):
                    # single-controller mitigation: record + keep going;
                    # multi-host deployments reschedule the slow worker
                    self.stragglers.append(step)
                step += 1
                losses.append(loss)
                if step % self.tcfg.log_every == 0:
                    print(f"[trainer] step {step} loss {loss:.4f} "
                          f"({dt*1e3:.0f} ms)")
                if step % self.tcfg.ckpt_every == 0:
                    self.ckpt.save(step, {"params": params,
                                          "opt": opt_state})
        finally:
            signal.signal(signal.SIGTERM, old)
        self.ckpt.save(step, {"params": params, "opt": opt_state},
                       block=True)
        self.ckpt.wait()
        return params, opt_state, losses

    def _on_sigterm(self, *_):
        print("[trainer] SIGTERM — checkpointing before exit")
        self._stop = True
