"""Sharded checkpointing with atomic commit, async writes and elastic
resume (deliverable: fault tolerance at 1000+ node scale).

Layout:  <dir>/step_<N>/  shard files (one .npz per host in a real
multi-host deployment; single .npz here) + MANIFEST.json written LAST —
a checkpoint without a manifest is incomplete and ignored on restore,
which makes interrupted writes safe (atomic-rename commit).

Elastic resume: arrays are saved device-agnostic; ``restore`` re-shards
onto whatever mesh the new job built (different data-axis size included).
"""
from __future__ import annotations

import json
import pathlib
import shutil
import threading
import time

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}/{k}"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}#{i}"))
    else:
        out[prefix] = tree
    return out


def _unflatten(flat: dict):
    root: dict = {}

    def insert(container, parts, value):
        head = parts[0]
        is_list = head.startswith("#")
        key = int(head[1:]) if is_list else head
        if len(parts) == 1:
            if is_list:
                while len(container) <= key:
                    container.append(None)
                container[key] = value
            else:
                container[key] = value
            return
        nxt_is_list = parts[1].startswith("#")
        if is_list:
            while len(container) <= key:
                container.append(None)
            if container[key] is None:
                container[key] = [] if nxt_is_list else {}
            insert(container[key], parts[1:], value)
        else:
            if key not in container:
                container[key] = [] if nxt_is_list else {}
            insert(container[key], parts[1:], value)

    for path, v in flat.items():
        parts = []
        for seg in path.strip("/").split("/"):
            while "#" in seg:
                pre, _, rest = seg.partition("#")
                if pre:
                    parts.append(pre)
                seg = "#" + rest
                idx = ""
                i = 1
                while i < len(seg) and seg[i].isdigit():
                    idx += seg[i]
                    i += 1
                parts.append("#" + idx)
                seg = seg[i:]
            if seg:
                parts.append(seg)
        insert(root, parts, v)
    return root


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 async_write: bool = True):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_write = async_write
        self._thread: threading.Thread | None = None

    # ------------------------------ save ------------------------------
    def save(self, step: int, state: dict, block: bool = False):
        """state: arbitrary pytree (params/opt/extra)."""
        self.wait()   # never two writers at once (same-step collision)
        flat = _flatten(state)
        host, dtypes = {}, {}
        for k, v in flat.items():
            a = np.asarray(v)
            dtypes[k] = str(a.dtype)
            if a.dtype.name == "bfloat16":   # npz can't round-trip bf16
                a = a.view(np.uint16)
            host[k] = a

        def _write():
            tmp = self.dir / f".tmp_step_{step}"
            final = self.dir / f"step_{step}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            np.savez(tmp / "shard_0.npz",
                     **{k.replace("/", "|"): v for k, v in host.items()})
            (tmp / "MANIFEST.json").write_text(json.dumps({
                "step": step, "time": time.time(),
                "keys": sorted(host.keys()), "n_shards": 1,
                "dtypes": dtypes,
            }))
            if final.exists():
                shutil.rmtree(final)
            tmp.rename(final)            # atomic commit
            self._gc()

        if self.async_write and not block:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # ----------------------------- restore ----------------------------
    def steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if (p / "MANIFEST.json").exists():   # complete checkpoints only
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, step: int | None = None, shardings=None):
        """Returns (step, state).  With ``shardings`` (a pytree of
        NamedSharding matching the saved structure) arrays are placed
        sharded — this is the elastic-resume path: the mesh may differ
        from the one that saved the checkpoint."""
        step = step if step is not None else self.latest_step()
        if step is None:
            return None, None
        d = self.dir / f"step_{step}"
        manifest = json.loads((d / "MANIFEST.json").read_text())
        dtypes = manifest.get("dtypes", {})
        data = np.load(d / "shard_0.npz")
        flat = {}
        for k in data.files:
            key = k.replace("|", "/")
            a = data[k]
            if dtypes.get(key) == "bfloat16":
                import ml_dtypes

                a = a.view(ml_dtypes.bfloat16)
            flat[key] = a
        state = _unflatten(flat)
        if shardings is not None:
            flat_s = _flatten(shardings)
            state = _unflatten({
                k: jax.device_put(v, flat_s[k]) if k in flat_s else v
                for k, v in _flatten(state).items()
            })
        return step, state
