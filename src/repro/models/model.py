"""Architecture assembly: init / forward / sharding specs for all 10
assigned architectures.

Layers are stacked into repeating "pattern" super-blocks (period = 1 for
homogeneous stacks, 8 for jamba/xlstm interleaves) and executed with
jax.lax.scan — compact HLO for the 512-device dry-run.  Whisper (6+6
enc-dec) is unrolled.

Caches are explicit stacked arrays so ``decode`` lowers as a single step
on the production mesh.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import layers as L


# --------------------------- layer pattern -------------------------------

@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: str   # attn | mla | mamba | mlstm | slstm
    ffn: str     # dense | moe | none


def layer_pattern(cfg: ModelConfig) -> tuple[list[LayerSpec], int]:
    """(pattern, n_reps) with n_layers == len(pattern) * n_reps."""
    specs = []
    for i in range(cfg.n_layers):
        if cfg.family == "ssm":
            mixer = ("slstm" if cfg.slstm_every and
                     i % cfg.slstm_every == cfg.slstm_every - 1 else "mlstm")
            ffn = "none"
        elif cfg.attn_every:
            mixer = ("attn" if i % cfg.attn_every == cfg.attn_every - 1
                     else "mamba")
            ffn = ("moe" if cfg.moe and i % cfg.moe.every == 0 else "dense")
        else:
            mixer = cfg.attn if cfg.attn in ("mla",) else "attn"
            ffn = ("moe" if cfg.moe and i % cfg.moe.every == 0 else "dense")
        specs.append(LayerSpec(mixer, ffn))
    # smallest period
    for period in range(1, cfg.n_layers + 1):
        if cfg.n_layers % period == 0 and all(
            specs[i] == specs[i % period] for i in range(cfg.n_layers)
        ):
            return specs[:period], cfg.n_layers // period
    return specs, 1


# ------------------------------ init --------------------------------------

def _init_layer(key, cfg: ModelConfig, spec: LayerSpec, dtype):
    ks = jax.random.split(key, 4)
    p = {"norm1": _norm_p(cfg, dtype)}
    if spec.mixer == "attn":
        p["attn"] = L.init_attention(ks[0], cfg, dtype)
    elif spec.mixer == "mla":
        p["attn"] = L.init_mla(ks[0], cfg, dtype)
    elif spec.mixer == "mamba":
        p["mamba"] = L.init_mamba(ks[0], cfg, dtype)
    elif spec.mixer == "mlstm":
        p["mlstm"] = L.init_mlstm(ks[0], cfg, dtype)
    elif spec.mixer == "slstm":
        p["slstm"] = L.init_slstm(ks[0], cfg, dtype)
    if spec.ffn != "none":
        p["norm2"] = _norm_p(cfg, dtype)
        if spec.ffn == "moe":
            p["moe"] = L.init_moe(ks[1], cfg.d_model, cfg.moe, cfg.mlp,
                                  dtype)
        else:
            p["mlp"] = L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp,
                                  dtype, cfg.bias)
    return p


def _norm_p(cfg, dtype):
    p = {"w": jnp.ones((cfg.d_model,), dtype)}
    if cfg.norm == "layernorm":
        p["b"] = jnp.zeros((cfg.d_model,), dtype)
    return p


def init_params(cfg: ModelConfig, key=None):
    key = key if key is not None else jax.random.PRNGKey(0)
    dtype = jnp.dtype(cfg.dtype)
    pattern, reps = layer_pattern(cfg)
    keys = jax.random.split(key, reps * len(pattern) + 4)
    params = {
        "embed": jax.random.normal(
            keys[-1], (cfg.vocab, cfg.d_model), dtype) * 0.02,
        "final_norm": _norm_p(cfg, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = jax.random.normal(
            keys[-2], (cfg.d_model, cfg.vocab), dtype) * 0.02
    # stacked blocks: blocks[slot] has leading rep axis
    blocks = []
    for s, spec in enumerate(pattern):
        reps_p = [
            _init_layer(keys[r * len(pattern) + s], cfg, spec, dtype)
            for r in range(reps)
        ]
        blocks.append(jax.tree.map(lambda *xs: jnp.stack(xs), *reps_p))
    params["blocks"] = blocks

    if cfg.enc_dec:
        enc = []
        ek = jax.random.split(keys[-3], cfg.n_enc_layers + 1)
        for i in range(cfg.n_enc_layers):
            enc.append({
                "norm1": _norm_p(cfg, dtype),
                "attn": L.init_attention(ek[i], cfg, dtype),
                "norm2": _norm_p(cfg, dtype),
                "mlp": L.init_mlp(ek[i], cfg.d_model, cfg.d_ff, cfg.mlp,
                                  dtype, cfg.bias),
            })
        params["encoder"] = enc
        # decoder cross-attention, one per decoder layer (unrolled)
        xk = jax.random.split(keys[-4], cfg.n_layers)
        params["cross"] = [
            {"norm": _norm_p(cfg, dtype),
             "attn": L.init_attention(xk[i], cfg, dtype)}
            for i in range(cfg.n_layers)
        ]
    return params


# ------------------------------ caches ------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=None):
    """Stacked per-slot caches for decode, matching layer_pattern."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    pattern, reps = layer_pattern(cfg)
    di = cfg.mamba_expand * cfg.d_model
    hd_i = di // cfg.n_heads
    caches = []
    window = cfg.sliding_window if (cfg.sliding_window and
                                    max_seq > cfg.sliding_window) else 0
    for spec in pattern:
        if spec.mixer in ("attn",):
            Sc = window or max_seq
            c = {
                "k": jnp.zeros((reps, batch, Sc, cfg.n_kv_heads, cfg.hd),
                               dtype),
                "v": jnp.zeros((reps, batch, Sc, cfg.n_kv_heads, cfg.hd),
                               dtype),
            }
        elif spec.mixer == "mla":
            m = cfg.mla
            c = {
                "c_kv": jnp.zeros((reps, batch, max_seq, m.kv_lora_rank),
                                  dtype),
                "k_rope": jnp.zeros((reps, batch, max_seq, 1,
                                     m.qk_rope_dim), dtype),
            }
        elif spec.mixer == "mamba":
            c = {
                "conv": jnp.zeros((reps, batch, cfg.mamba_d_conv - 1, di),
                                  dtype),
                "ssm": jnp.zeros((reps, batch, di, cfg.mamba_d_state),
                                 jnp.float32),
            }
        elif spec.mixer == "mlstm":
            c = {
                "C": jnp.zeros((reps, batch, cfg.n_heads, hd_i, hd_i),
                               jnp.float32),
                "n": jnp.zeros((reps, batch, cfg.n_heads, hd_i),
                               jnp.float32),
            }
        else:  # slstm
            c = {
                "h": jnp.zeros((reps, batch, cfg.d_model), dtype),
                "c": jnp.zeros((reps, batch, cfg.d_model), jnp.float32),
            }
        caches.append(c)
    return {"slots": caches, "idx": jnp.zeros((), jnp.int32)}


# ------------------------------ forward -----------------------------------

def _apply_layer(p, x, cfg, spec: LayerSpec, pos, cache, idx, window):
    h = L.apply_norm(x, p["norm1"], cfg.norm)
    if spec.mixer == "attn":
        c = None if cache is None else {**cache, "idx": idx}
        o, nc = L.attention(p["attn"], h, cfg, pos, c, window)
    elif spec.mixer == "mla":
        c = None if cache is None else {**cache, "idx": idx}
        o, nc = L.mla_attention(p["attn"], h, cfg, pos, c)
    elif spec.mixer == "mamba":
        c = None if cache is None else {**cache, "idx": idx}
        o, nc = L.mamba(p["mamba"], h, cfg, c)
    elif spec.mixer == "mlstm":
        c = None if cache is None else {**cache, "idx": idx}
        o, nc = L.mlstm(p["mlstm"], h, cfg, c)
    else:
        c = None if cache is None else {**cache, "idx": idx}
        o, nc = L.slstm(p["slstm"], h, cfg, c)
    x = x + o
    if spec.ffn != "none":
        h2 = L.apply_norm(x, p["norm2"], cfg.norm)
        if spec.ffn == "moe":
            x = x + L.moe(p["moe"], h2, cfg.moe, cfg.mlp)
        else:
            x = x + L.mlp(p["mlp"], h2, cfg.mlp)
    if nc is not None:
        nc.pop("idx", None)
    return x, nc


def forward(params, tokens, cfg: ModelConfig, positions=None, cache=None,
            embeds=None):
    """tokens: (B, S) int32.  cache=None -> full causal pass (train /
    prefill); cache -> one decode step (S == 1).  embeds: stub modality
    embeddings replacing the first tokens (vlm) / encoder input (audio).

    Returns (logits, new_cache_or_None).
    """
    if cfg.enc_dec:
        return _forward_encdec(params, tokens, cfg, cache, embeds)
    B, S = tokens.shape
    dtype = jnp.dtype(cfg.dtype)
    x = params["embed"][tokens].astype(dtype)
    if embeds is not None:
        n_p = embeds.shape[1]
        x = jnp.concatenate([embeds.astype(dtype), x[:, n_p:]], axis=1)
    if positions is None:
        base = jnp.arange(S)[None, :] if cache is None \
            else (cache["idx"] + jnp.zeros((1, 1), jnp.int32))
        positions = jnp.broadcast_to(base, (B, S))
        if cfg.pos == "mrope":
            positions = jnp.broadcast_to(positions[None], (3, B, S))
    if cfg.pos == "learned":
        # sinusoidal (shape-agnostic — Whisper's encoder convention)
        pos0 = jnp.arange(S) if cache is None else cache["idx"][None]
        x = x + _sinusoid(pos0, cfg.d_model, x.dtype)[None]

    pattern, reps = layer_pattern(cfg)
    window = _active_window(cfg, pattern, cache, S)
    idx = None if cache is None else cache["idx"]

    def body(x_carry, xs):
        slot_params, slot_caches = xs
        x_c = x_carry
        new_caches = []
        for s, spec in enumerate(pattern):
            c = None if slot_caches is None else slot_caches[s]
            w = window if spec.mixer == "attn" else 0
            x_c, nc = _apply_layer(slot_params[s], x_c, cfg, spec,
                                   positions, c, idx, w)
            new_caches.append(nc if nc is not None else {})
        return x_c, tuple(new_caches)

    if reps > 1:
        xs_params = tuple(params["blocks"])
        xs_caches = (None if cache is None
                     else tuple(cache["slots"]))

        def scan_body(x_carry, xs):
            if cache is None:
                sp = xs
                sc = None
            else:
                sp, sc = xs
            return body(x_carry, (sp, sc))

        xs = xs_params if cache is None else (xs_params, xs_caches)
        x, ys = jax.lax.scan(scan_body, x, xs)
        new_cache = None
        if cache is not None:
            new_cache = {"slots": list(ys), "idx": cache["idx"] + 1}
    else:
        new_slots = []
        for s, spec in enumerate(pattern):
            p_s = jax.tree.map(lambda a: a[0], params["blocks"][s])
            c = None if cache is None else \
                jax.tree.map(lambda a: a[0], cache["slots"][s])
            w = window if spec.mixer == "attn" else 0
            x, nc = _apply_layer(p_s, x, cfg, spec, positions, c, idx, w)
            new_slots.append(jax.tree.map(lambda a: a[None], nc or {}))
        new_cache = None
        if cache is not None:
            new_cache = {"slots": new_slots, "idx": cache["idx"] + 1}

    x = L.apply_norm(x, params["final_norm"], cfg.norm)
    head = params.get("lm_head", params["embed"].T)
    logits = (x @ head).astype(jnp.float32)
    return logits, new_cache


def _sinusoid(pos, d, dtype):
    """(S,) -> (S, d) sinusoidal position embedding (shape-agnostic)."""
    half = d // 2
    freq = jnp.exp(-jnp.log(10000.0) * jnp.arange(half) / max(half - 1, 1))
    ang = pos[:, None].astype(jnp.float32) * freq[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)],
                           axis=-1).astype(dtype)


def _active_window(cfg: ModelConfig, pattern, cache, S: int) -> int:
    """Sliding-window attention is active when configured AND either the
    decode cache is window-sized (ring buffer, long_500k) or a full pass
    exceeds the window."""
    if not cfg.sliding_window:
        return 0
    if cache is None:
        return cfg.sliding_window if S > cfg.sliding_window else 0
    for i, s in enumerate(pattern):
        if s.mixer == "attn" and "k" in cache["slots"][i]:
            sc = cache["slots"][i]["k"].shape[2]
            return cfg.sliding_window if sc == cfg.sliding_window else 0
    return 0


def _forward_encdec(params, tokens, cfg, cache, embeds):
    """Whisper: embeds = (B, T_audio, d_model) stub frame embeddings."""
    dtype = jnp.dtype(cfg.dtype)
    B = tokens.shape[0]
    if embeds is None:
        embeds = jnp.zeros((B, 128, cfg.d_model), dtype)
    e = embeds.astype(dtype) + _sinusoid(
        jnp.arange(embeds.shape[1]), cfg.d_model, dtype)[None]
    Ta = e.shape[1]
    full = jnp.ones((B, Ta, Ta), bool)
    for lp in params["encoder"]:
        h = L.apply_norm(e, lp["norm1"], cfg.norm)
        e = e + _bidir_attention(lp["attn"], h, cfg, full)
        e = e + L.mlp(lp["mlp"], L.apply_norm(e, lp["norm2"], cfg.norm),
                      cfg.mlp)

    S = tokens.shape[1]
    x = params["embed"][tokens].astype(dtype)
    pos0 = jnp.arange(S) if cache is None else cache["idx"][None]
    x = x + _sinusoid(pos0, cfg.d_model, dtype)[None]
    pattern, reps = layer_pattern(cfg)
    idx = None if cache is None else cache["idx"]
    new_slots = []
    for i in range(cfg.n_layers):
        p_i = jax.tree.map(lambda a: a[i], params["blocks"][0])
        c = None if cache is None else \
            jax.tree.map(lambda a: a[i], cache["slots"][0])
        x, nc = _apply_layer(p_i, x, cfg, pattern[0], None, c, idx, 0)
        new_slots.append(nc or {})
        # cross-attention to encoder output
        cp = params["cross"][i]
        h = L.apply_norm(x, cp["norm"], cfg.norm)
        x = x + _cross_attention(cp["attn"], h, e, cfg)
    new_cache = None
    if cache is not None:
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *new_slots)
        new_cache = {"slots": [stacked], "idx": cache["idx"] + 1}
    x = L.apply_norm(x, params["final_norm"], cfg.norm)
    logits = (x @ params["embed"].T).astype(jnp.float32)
    return logits, new_cache


def _bidir_attention(p, x, cfg, mask):
    B, S, d = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (x @ p["wk"]).reshape(B, S, KV, hd)
    v = (x @ p["wv"]).reshape(B, S, KV, hd)
    out = L._sdpa(q, k, v, mask)
    return out.reshape(B, S, H * hd) @ p["wo"]


def _cross_attention(p, x, enc, cfg):
    B, S, d = x.shape
    Ta = enc.shape[1]
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (enc @ p["wk"]).reshape(B, Ta, KV, hd)
    v = (enc @ p["wv"]).reshape(B, Ta, KV, hd)
    mask = jnp.ones((B, S, Ta), bool)
    out = L._sdpa(q, k, v, mask)
    return out.reshape(B, S, H * hd) @ p["wo"]


# --------------------------- sharding specs --------------------------------

def param_specs(cfg: ModelConfig, params=None):
    """PartitionSpec tree mirroring init_params (GSPMD/NamedSharding).

    'model' = tensor/expert parallel, 'data' = FSDP when cfg.fsdp.
    Stacked blocks get a leading None (rep) axis.
    """
    f = "data" if cfg.fsdp else None

    def spec_for(path: str, ndim: int, stacked: bool):
        lead = (None,) if stacked else ()
        name = path.split("/")[-1]
        table = {
            "wq": P(*lead, f, "model"), "wk": P(*lead, f, "model"),
            "wv": P(*lead, f, "model"), "wo": P(*lead, "model", f),
            "bq": P(*lead, "model"), "bk": P(*lead, "model"),
            "bv": P(*lead, "model"),
            "wq_a": P(*lead, f, None), "wq_b": P(*lead, None, "model"),
            "wkv_a": P(*lead, f, None), "wkv_b": P(*lead, None, "model"),
            "up": P(*lead, f, "model"), "gate": P(*lead, f, "model"),
            "down": P(*lead, "model", f),
            "b_up": P(*lead, "model"), "b_down": P(*lead, None),
            "router": P(*lead, None, None),
            "in_proj": P(*lead, f, "model"),
            "conv_w": P(*lead, None, "model"),
            "x_proj": P(*lead, "model", None),
            "out_proj": P(*lead, "model", f),
            "A_log": P(*lead, "model", None), "D": P(*lead, "model"),
            "dt_bias": P(*lead, "model"),
            "w": P(*lead, None) if ndim == 1 + len(lead)
            else P(*lead, f, "model"),
            "r": P(*lead, f, "model"),
            "b": P(*lead, None),
            "q_norm": P(*lead, None), "kv_norm": P(*lead, None),
            "wif": P(*lead, "model", None),
        }
        # MoE expert tensors carry a leading expert axis -> expert-parallel
        if name in ("up", "gate", "down") and ndim == 3 + len(lead):
            if cfg.expert_shard == "ff" and f:
                # FSDP axis on the expert HIDDEN dim: the dispatch einsum
                # contracts an UNsharded d_model, killing the per-layer
                # (E, cap, f) cross-data collective (§Perf hypothesis H2)
                return {"up": P(*lead, "model", None, f),
                        "gate": P(*lead, "model", None, f),
                        "down": P(*lead, "model", f, None)}[name]
            return {"up": P(*lead, "model", f, None),
                    "gate": P(*lead, "model", f, None),
                    "down": P(*lead, "model", None, f)}[name]
        return table.get(name, P(*lead, *([None] * (ndim - len(lead)))))

    params = params if params is not None else init_params(cfg)

    def walk(tree, stacked, prefix=""):
        if isinstance(tree, dict):
            return {k: walk(v, stacked, f"{prefix}/{k}") for k, v in
                    tree.items()}
        if isinstance(tree, list):
            return [walk(v, stacked, prefix) for v in tree]
        return spec_for(prefix, tree.ndim, stacked)

    out = {}
    for k, v in params.items():
        if k == "embed":
            out[k] = P("model", None)
        elif k == "lm_head":
            out[k] = P(None, "model")
        elif k == "blocks":
            out[k] = [walk(b, True) for b in v]
        elif k in ("encoder", "cross"):
            out[k] = walk(v, False)
        else:
            out[k] = walk(v, False)
    return out
