from repro.models.model import (  # noqa: F401
    forward, init_params, param_specs,
)
