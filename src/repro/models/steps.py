"""train_step / serve_step builders + ShapeDtypeStruct input specs.

``input_specs(arch, shape)`` returns weak-type-correct stand-ins for
every model input — the dry-run lowers against these without allocating.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.configs.shapes import SHAPES, ShapeSpec
from repro.models.model import forward, init_cache, init_params


def loss_fn(params, batch, cfg: ModelConfig):
    logits, _ = forward(
        params, batch["tokens"], cfg,
        positions=batch.get("positions"),
        embeds=batch.get("embeds"),
    )
    labels = batch["labels"]
    if cfg.ce_impl == "softmax":      # baseline: full (B,S,V) log-softmax
        logp = jax.nn.log_softmax(logits, axis=-1)
        ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    else:
        # CE via logsumexp + gather: never materializes the (B, S, V) f32
        # log-softmax array (the full-vocab normalized tensor is the
        # largest single memory consumer for 100k-256k vocabularies —
        # EXPERIMENTS.md §Perf hillclimb, hypothesis H1)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(
            logits, labels[..., None], axis=-1)[..., 0]
        ll = picked - lse
    mask = (labels >= 0).astype(jnp.float32)
    return -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def make_train_step(cfg: ModelConfig, optimizer):
    """optimizer: repro.train.optimizer.AdamW-like (init/update)."""

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch, cfg)
        params, opt_state = optimizer.update(params, grads, opt_state)
        return params, opt_state, loss

    return train_step


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, cache, batch):
        """One decode step: batch["tokens"] is (B, 1)."""
        logits, cache = forward(
            params, batch["tokens"], cfg,
            positions=batch.get("positions"), cache=cache,
            embeds=batch.get("embeds"),
        )
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, cache

    return serve_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        logits, _ = forward(
            params, batch["tokens"], cfg,
            positions=batch.get("positions"),
            embeds=batch.get("embeds"),
        )
        return logits[:, -1]

    return prefill_step


# ------------------------- input specs (dry-run) --------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(arch: str, shape_name: str) -> dict:
    """ShapeDtypeStruct stand-ins for one (arch x shape) cell."""
    cfg = get_config(arch)
    sh: ShapeSpec = SHAPES[shape_name]
    B, S = sh.global_batch, sh.seq_len
    out: dict = {}
    if sh.kind == "train":
        out["tokens"] = _sds((B, S), jnp.int32)
        out["labels"] = _sds((B, S), jnp.int32)
    elif sh.kind == "prefill":
        out["tokens"] = _sds((B, S), jnp.int32)
    else:  # decode: one new token against an S-long cache
        out["tokens"] = _sds((B, 1), jnp.int32)
    if cfg.pos == "mrope":
        ps = (B, S) if sh.kind != "decode" else (B, 1)
        out["positions"] = _sds((3,) + ps, jnp.int32)
    if cfg.frontend == "vision" and sh.kind != "decode":
        n_patch = min(256, S // 2)
        out["embeds"] = _sds((B, n_patch, cfg.d_model), jnp.bfloat16)
    if cfg.frontend == "audio":
        t_audio = min(1500, S)
        out["embeds"] = _sds((B, t_audio, cfg.d_model), jnp.bfloat16)
    return out


def cache_specs(arch: str, shape_name: str):
    cfg = get_config(arch)
    sh = SHAPES[shape_name]
    return jax.eval_shape(
        lambda: init_cache(cfg, sh.global_batch, sh.seq_len)
    )


def param_shapes(cfg: ModelConfig):
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
