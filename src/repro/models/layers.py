"""Model building blocks for the 10 assigned architectures.

Pure-pytree parameters (nested dicts of jnp arrays), explicit dtypes
(bf16 weights/activations, f32 norms/softmax), KV/state caches as
explicit arrays so decode steps lower cleanly on the production mesh.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

Param = dict


def _norm_dt(x):
    return x.astype(jnp.float32)


def rms_norm(x, w, eps=1e-6):
    xf = _norm_dt(x)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps)) * w.astype(jnp.float32)) \
        .astype(x.dtype)


def layer_norm(x, w, b, eps=1e-5):
    xf = _norm_dt(x)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * w.astype(jnp.float32) + b.astype(jnp.float32)) \
        .astype(x.dtype)


def apply_norm(x, p, kind):
    if kind == "rmsnorm":
        return rms_norm(x, p["w"])
    return layer_norm(x, p["w"], p["b"])


# ------------------------------ RoPE -------------------------------------

def _rope_cos_sin(pos, rot_dim, theta, dtype):
    """pos: (..., S) int -> cos/sin (..., S, rot_dim/2)."""
    inv = 1.0 / (theta ** (np.arange(0, rot_dim, 2) / rot_dim))
    ang = pos[..., None].astype(jnp.float32) * inv[None, :]
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)


def apply_rope(x, pos, rope_pct=1.0, theta=10000.0, mrope_sections=None):
    """x: (B, S, H, hd); pos: (B, S) or (3, B, S) for M-RoPE."""
    hd = x.shape[-1]
    rot = int(hd * rope_pct) // 2 * 2
    if rot == 0:
        return x
    xr, xp = x[..., :rot], x[..., rot:]
    if mrope_sections is not None:
        # M-RoPE: split the rotary dim into (t, h, w) sections, each with
        # its own position stream (identical streams for text tokens).
        cos_parts, sin_parts = [], []
        start = 0
        for i, sec in enumerate(mrope_sections):
            c, s = _rope_cos_sin(pos[i], rot, theta, x.dtype)
            cos_parts.append(c[..., start // 2 : (start + sec) // 2])
            sin_parts.append(s[..., start // 2 : (start + sec) // 2])
            start += sec
        cos = jnp.concatenate(cos_parts, axis=-1)
        sin = jnp.concatenate(sin_parts, axis=-1)
    else:
        cos, sin = _rope_cos_sin(pos, rot, theta, x.dtype)
    cos = cos[:, :, None, :]
    sin = sin[:, :, None, :]
    x1, x2 = xr[..., : rot // 2], xr[..., rot // 2 :]
    xrot = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return jnp.concatenate([xrot, xp], axis=-1) if rot < hd else xrot


def mrope_sections(rot_dim):
    """(t, h, w) rotary sections — Qwen2-VL convention (16/24/24 scaled)."""
    t = rot_dim // 4 * 2
    rem = rot_dim - t
    h = rem // 2 // 2 * 2
    return (t, h, rot_dim - t - h)


# --------------------------- dense attention -----------------------------

def init_attention(key, cfg: ModelConfig, dtype):
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    k1, k2, k3, k4 = jax.random.split(key, 4)
    std = d ** -0.5
    p = {
        "wq": jax.random.normal(k1, (d, H * hd), dtype) * std,
        "wk": jax.random.normal(k2, (d, KV * hd), dtype) * std,
        "wv": jax.random.normal(k3, (d, KV * hd), dtype) * std,
        "wo": jax.random.normal(k4, (H * hd, d), dtype) * std,
    }
    if cfg.bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((KV * hd,), dtype)
        p["bv"] = jnp.zeros((KV * hd,), dtype)
    return p


def _sdpa(q, k, v, mask):
    """q: (B,Sq,H,hd); k/v: (B,Sk,KV,hd) — GQA via head grouping."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    g = H // KV
    q = q.reshape(B, Sq, KV, g, hd)
    logits = jnp.einsum("bqkgh,bskh->bkgqs", q, k).astype(jnp.float32)
    logits = logits / np.sqrt(hd)
    logits = jnp.where(mask[:, None, None, :, :], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w, v)
    return out.reshape(B, Sq, H, v.shape[-1])  # v head dim != q under MLA


def attention(p, x, cfg: ModelConfig, pos, cache=None, window=0):
    """Returns (out, new_cache).  cache: dict(k, v, (B,Sc,KV,hd), idx)."""
    B, S, d = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, KV, hd)
    v = v.reshape(B, S, KV, hd)
    sections = mrope_sections(int(hd * cfg.rope_pct)) \
        if cfg.pos == "mrope" else None
    if cfg.pos in ("rope", "mrope"):
        q = apply_rope(q, pos, cfg.rope_pct, cfg.rope_theta, sections)
        k = apply_rope(k, pos, cfg.rope_pct, cfg.rope_theta, sections)

    if cache is None:
        # train/prefill: causal (optionally windowed) self-attention
        ar = jnp.arange(S)
        mask = ar[None, :, None] >= ar[None, None, :]
        if window:
            mask &= ar[None, :, None] - ar[None, None, :] < window
        out = _sdpa(q, k, v, jnp.broadcast_to(mask, (B, S, S)))
        new_cache = {"k": k, "v": v}
    else:
        # decode: S == 1; write into the (ring) buffer at cache["idx"]
        Sc = cache["k"].shape[1]
        idx = cache["idx"]                      # scalar int32
        slot = (idx % Sc if window else idx).astype(jnp.int32)
        z = jnp.int32(0)                        # x64-safe index literals
        ck = jax.lax.dynamic_update_slice(
            cache["k"], k, (z, slot, z, z))
        cv = jax.lax.dynamic_update_slice(
            cache["v"], v, (z, slot, z, z))
        valid = jnp.arange(Sc)[None, :] <= (idx if not window
                                            else jnp.int32(Sc))
        if window:
            valid = jnp.arange(Sc)[None, :] < jnp.minimum(idx + 1, Sc)
        mask = jnp.broadcast_to(valid[:, None, :], (B, 1, Sc))
        out = _sdpa(q, ck, cv, mask)
        new_cache = {"k": ck, "v": cv, "idx": idx + 1}
    return out.reshape(B, S, H * hd) @ p["wo"], new_cache


# ------------------------------- MLA -------------------------------------

def init_mla(key, cfg: ModelConfig, dtype):
    m: MLAConfig = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 7)
    std = d ** -0.5
    qk_dim = m.qk_nope_dim + m.qk_rope_dim
    return {
        "wq_a": jax.random.normal(ks[0], (d, m.q_lora_rank), dtype) * std,
        "wq_b": jax.random.normal(
            ks[1], (m.q_lora_rank, H * qk_dim), dtype) * std,
        "wkv_a": jax.random.normal(
            ks[2], (d, m.kv_lora_rank + m.qk_rope_dim), dtype) * std,
        "wkv_b": jax.random.normal(
            ks[3], (m.kv_lora_rank, H * (m.qk_nope_dim + m.v_head_dim)),
            dtype) * std,
        "wo": jax.random.normal(
            ks[4], (H * m.v_head_dim, d), dtype) * std,
        "q_norm": jnp.ones((m.q_lora_rank,), dtype),
        "kv_norm": jnp.ones((m.kv_lora_rank,), dtype),
    }


def mla_attention(p, x, cfg: ModelConfig, pos, cache=None):
    """Multi-head Latent Attention (MiniCPM3/DeepSeek-style).

    The KV cache stores only the compressed latent c_kv (+ rope key) —
    the architecture's signature memory saving."""
    m: MLAConfig = cfg.mla
    B, S, d = x.shape
    H = cfg.n_heads
    qk = m.qk_nope_dim + m.qk_rope_dim

    q = rms_norm(x @ p["wq_a"], p["q_norm"]) @ p["wq_b"]
    q = q.reshape(B, S, H, qk)
    q_nope, q_rope = q[..., : m.qk_nope_dim], q[..., m.qk_nope_dim :]

    kv_a = x @ p["wkv_a"]
    c_kv = rms_norm(kv_a[..., : m.kv_lora_rank], p["kv_norm"])
    k_rope = kv_a[..., m.kv_lora_rank :].reshape(B, S, 1, m.qk_rope_dim)

    q_rope = apply_rope(q_rope, pos, 1.0, cfg.rope_theta)
    k_rope = apply_rope(k_rope, pos, 1.0, cfg.rope_theta)

    if cache is not None:
        idx = cache["idx"].astype(jnp.int32)
        z = jnp.int32(0)                        # x64-safe index literals
        c_kv = jax.lax.dynamic_update_slice(
            cache["c_kv"], c_kv, (z, idx, z))
        k_rope = jax.lax.dynamic_update_slice(
            cache["k_rope"], k_rope, (z, idx, z, z))
        new_cache = {"c_kv": c_kv, "k_rope": k_rope, "idx": idx + 1}
        Sk = c_kv.shape[1]
        valid = jnp.arange(Sk)[None, :] <= idx
    else:
        new_cache = {"c_kv": c_kv, "k_rope": k_rope}
        Sk = S
        ar = jnp.arange(S)
        valid = None

    kv = (c_kv @ p["wkv_b"]).reshape(B, Sk, H, m.qk_nope_dim + m.v_head_dim)
    k_nope, v = kv[..., : m.qk_nope_dim], kv[..., m.qk_nope_dim :]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (B, Sk, 1, m.qk_rope_dim))
         .repeat(H, axis=2)], axis=-1)
    qfull = jnp.concatenate([q_nope, q_rope], axis=-1)
    if cache is None:
        mask = jnp.broadcast_to(
            ar[None, :, None] >= ar[None, None, :], (B, S, S))
    else:
        mask = jnp.broadcast_to(valid[:, None, :], (B, 1, Sk))
    out = _sdpa(qfull, k, v, mask)
    return out.reshape(B, S, H * m.v_head_dim) @ p["wo"], new_cache


# ------------------------------- MLPs ------------------------------------

def init_mlp(key, d, d_ff, kind, dtype, bias=False):
    ks = jax.random.split(key, 3)
    std = d ** -0.5
    p = {"up": jax.random.normal(ks[0], (d, d_ff), dtype) * std,
         "down": jax.random.normal(ks[1], (d_ff, d), dtype) * (d_ff ** -0.5)}
    if kind == "swiglu":
        p["gate"] = jax.random.normal(ks[2], (d, d_ff), dtype) * std
    if bias:
        p["b_up"] = jnp.zeros((d_ff,), dtype)
        p["b_down"] = jnp.zeros((d,), dtype)
    return p


def mlp(p, x, kind):
    if kind == "swiglu":
        h = jax.nn.silu(x @ p["gate"]) * (x @ p["up"])
    else:
        h = x @ p["up"]
        if "b_up" in p:
            h = h + p["b_up"]
        h = jax.nn.gelu(h)
    out = h @ p["down"]
    if "b_down" in p:
        out = out + p["b_down"]
    return out


# ------------------------------- MoE --------------------------------------

def init_moe(key, d, mo: MoEConfig, kind, dtype):
    ks = jax.random.split(key, 5)
    E, f = mo.n_experts, mo.d_expert_ff
    std = d ** -0.5
    p = {
        "router": jax.random.normal(ks[0], (d, E), jnp.float32) * std,
        "up": jax.random.normal(ks[1], (E, d, f), dtype) * std,
        "gate": jax.random.normal(ks[2], (E, d, f), dtype) * std,
        "down": jax.random.normal(ks[3], (E, f, d), dtype) * (f ** -0.5),
    }
    if mo.dense_residual_ff:
        p["dense"] = init_mlp(ks[4], d, mo.dense_residual_ff, kind, dtype)
    return p


def moe(p, x, mo: MoEConfig, kind):
    """Sort-based top-k dispatch with static capacity (EP-friendly).

    x: (B, S, d) -> (B, S, d).  FLOPs scale with top_k (not n_experts)."""
    B, S, d = x.shape
    T = B * S
    E, k = mo.n_experts, mo.top_k
    xt = x.reshape(T, d)
    logits = (xt.astype(jnp.float32) @ p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, k)                  # (T, k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)

    cap = int(np.ceil(T * k / E * mo.capacity_factor))
    cap = max(cap, 1)
    flat_e = eidx.reshape(-1)                              # (T*k,)
    order = jnp.argsort(flat_e)                            # stable
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=E)
    starts = jnp.concatenate([jnp.zeros(1, jnp.int32),
                              jnp.cumsum(counts)[:-1].astype(jnp.int32)])
    pos = jnp.arange(T * k, dtype=jnp.int32) - starts[sorted_e]
    keep = pos < cap
    tok = order // k                                       # source token
    buf = jnp.zeros((E, cap, d), xt.dtype)
    buf = buf.at[sorted_e, jnp.where(keep, pos, cap - 1)].add(
        jnp.where(keep[:, None], xt[tok], 0))

    h = jnp.einsum("ecd,edf->ecf", buf, p["gate"])
    h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", buf, p["up"])
    out_e = jnp.einsum("ecf,efd->ecd", h, p["down"])       # (E, cap, d)

    y_flat = out_e[sorted_e, jnp.where(keep, pos, cap - 1)]
    y_flat = jnp.where(keep[:, None], y_flat, 0)
    gate_flat = gates.reshape(-1)[order]
    y = jnp.zeros((T, d), xt.dtype).at[tok].add(
        y_flat * gate_flat[:, None].astype(xt.dtype))
    y = y.reshape(B, S, d)
    if "dense" in p:
        y = y + mlp(p["dense"], x, kind)
    return y


# ------------------------------- Mamba ------------------------------------

def init_mamba(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    di = cfg.mamba_expand * d
    ds, dc = cfg.mamba_d_state, cfg.mamba_d_conv
    ks = jax.random.split(key, 6)
    std = d ** -0.5
    return {
        "in_proj": jax.random.normal(ks[0], (d, 2 * di), dtype) * std,
        "conv_w": jax.random.normal(ks[1], (dc, di), dtype) * 0.1,
        "x_proj": jax.random.normal(ks[2], (di, ds * 2 + 1), dtype) * std,
        "dt_bias": jnp.zeros((di,), jnp.float32),
        "A_log": jnp.log(jnp.tile(
            jnp.arange(1, ds + 1, dtype=jnp.float32)[None, :], (di, 1))),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": jax.random.normal(ks[5], (di, d), dtype) * std,
    }


def mamba(p, x, cfg: ModelConfig, cache=None):
    """Selective SSM (Mamba-1 style) via associative scan.

    cache (decode): {"conv": (B, dc-1, di), "ssm": (B, di, ds), "idx"}."""
    B, S, d = x.shape
    di = cfg.mamba_expand * d
    ds, dc = cfg.mamba_d_state, cfg.mamba_d_conv
    xz = x @ p["in_proj"]
    xi, z = xz[..., :di], xz[..., di:]

    if cache is None:
        pad = jnp.zeros((B, dc - 1, di), xi.dtype)
        xc = jnp.concatenate([pad, xi], axis=1)
        conv = sum(
            xc[:, i : i + S] * p["conv_w"][i][None, None, :]
            for i in range(dc)
        )
        new_conv = xc[:, -(dc - 1):] if dc > 1 else pad
    else:
        hist = jnp.concatenate([cache["conv"], xi], axis=1)  # (B, dc, di)
        conv = sum(
            hist[:, i : i + S] * p["conv_w"][i][None, None, :]
            for i in range(dc)
        )
        new_conv = hist[:, 1:]
    u = jax.nn.silu(conv)

    proj = u @ p["x_proj"]
    dt = jax.nn.softplus(
        proj[..., -1:].astype(jnp.float32) + p["dt_bias"][None, None, :]
    )
    Bm = proj[..., :ds].astype(jnp.float32)               # (B,S,ds)
    Cm = proj[..., ds : 2 * ds].astype(jnp.float32)
    A = -jnp.exp(p["A_log"])                               # (di, ds)

    # h_t = a_t * h_{t-1} + b_t ;  a_t=(B,S,di,ds), b_t likewise
    a = jnp.exp(dt[..., None] * A[None, None, :, :])
    b = (dt[..., None] * Bm[:, :, None, :]) \
        * u.astype(jnp.float32)[..., None]
    if cache is None:
        def comb(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, b1 * a2 + b2

        aa, hh = jax.lax.associative_scan(comb, (a, b), axis=1)
        new_ssm = hh[:, -1]
    else:
        hh = a * cache["ssm"][:, None] + b
        new_ssm = hh[:, -1]
    y = jnp.einsum("bsdn,bsn->bsd", hh, Cm)
    y = y + u.astype(jnp.float32) * p["D"][None, None, :]
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = y @ p["out_proj"]
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv, "ssm": new_ssm,
                     "idx": cache["idx"] + 1}
    return out, new_cache


# ------------------------------- xLSTM ------------------------------------

def init_mlstm(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    di = cfg.mamba_expand * d
    H = cfg.n_heads
    ks = jax.random.split(key, 6)
    std = d ** -0.5
    return {
        "up": jax.random.normal(ks[0], (d, 2 * di), dtype) * std,
        "wq": jax.random.normal(ks[1], (di, di), dtype) * (di ** -0.5),
        "wk": jax.random.normal(ks[2], (di, di), dtype) * (di ** -0.5),
        "wv": jax.random.normal(ks[3], (di, di), dtype) * (di ** -0.5),
        "wif": jax.random.normal(ks[4], (di, 2 * H), jnp.float32) * std,
        "down": jax.random.normal(ks[5], (di, d), dtype) * (di ** -0.5),
    }


def mlstm(p, x, cfg: ModelConfig, cache=None):
    """mLSTM block (matrix memory, exponential gating).

    Train/prefill uses the quadratic-within-sequence parallel form with a
    stabilized log-gate cumulative matrix; decode updates the (H, hd, hd)
    matrix state recurrently."""
    B, S, d = x.shape
    di = cfg.mamba_expand * d
    H = cfg.n_heads
    hd = di // H
    uz = x @ p["up"]
    u, z = uz[..., :di], uz[..., di:]
    q = (u @ p["wq"]).reshape(B, S, H, hd)
    k = (u @ p["wk"]).reshape(B, S, H, hd) / np.sqrt(hd)
    v = (u @ p["wv"]).reshape(B, S, H, hd)
    gates = (u @ p["wif"].astype(u.dtype)).astype(jnp.float32)
    ig = gates[..., :H]                                # (B,S,H) input gate
    fg = jax.nn.log_sigmoid(gates[..., H:])            # log forget gate

    if cache is None:
        # D[b,h,t,s] = F_t - F_s + i_s  (s <= t), stabilized by row max
        F = jnp.cumsum(fg, axis=1)                     # (B,S,H)
        Ft = F.transpose(0, 2, 1)                      # (B,H,S)
        D = Ft[:, :, :, None] - Ft[:, :, None, :] \
            + ig.transpose(0, 2, 1)[:, :, None, :]
        mask = jnp.tril(jnp.ones((S, S), bool))
        D = jnp.where(mask[None, None], D, -jnp.inf)
        m = jnp.max(D, axis=-1, keepdims=True)
        Dn = jnp.exp(D - m)                            # (B,H,S,S)
        att = jnp.einsum("bqhd,bshd->bhqs", q, k).astype(jnp.float32) * Dn
        norm = jnp.maximum(
            jnp.abs(jnp.sum(att, axis=-1, keepdims=True)),
            jnp.exp(-m))
        out = jnp.einsum("bhqs,bshd->bqhd",
                         (att / norm).astype(v.dtype), v)
        new_cache = None
    else:
        # recurrent: C <- f*C + i*(v k^T); n <- f*n + i*k
        i_t = jnp.exp(ig[:, 0]).astype(jnp.float32)    # (B,H)
        f_t = jnp.exp(fg[:, 0]).astype(jnp.float32)
        C = cache["C"] * f_t[..., None, None] + i_t[..., None, None] * \
            jnp.einsum("bhd,bhe->bhde", v[:, 0].astype(jnp.float32),
                       k[:, 0].astype(jnp.float32))
        n = cache["n"] * f_t[..., None] + i_t[..., None] \
            * k[:, 0].astype(jnp.float32)
        num = jnp.einsum("bhde,bhe->bhd", C, q[:, 0].astype(jnp.float32))
        den = jnp.maximum(
            jnp.abs(jnp.einsum("bhd,bhd->bh", n,
                               q[:, 0].astype(jnp.float32))), 1.0)
        out = (num / den[..., None]).astype(x.dtype)[:, None]
        new_cache = {"C": C, "n": n, "idx": cache["idx"] + 1}
    out = out.reshape(B, S, di) * jax.nn.silu(z)
    return out @ p["down"], new_cache


def init_slstm(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 2)
    std = d ** -0.5
    return {
        "w": jax.random.normal(ks[0], (d, 4 * d), dtype) * std,
        "r": jax.random.normal(ks[1], (d, 4 * d), dtype) * std,
    }


def slstm(p, x, cfg: ModelConfig, cache=None):
    """sLSTM (scalar memory, sequential scan over tokens)."""
    B, S, d = x.shape

    def step(carry, xt):
        h, c = carry
        g = xt @ p["w"] + h @ p["r"]
        i, f, z, o = jnp.split(g.astype(jnp.float32), 4, axis=-1)
        c = jax.nn.sigmoid(f) * c + jnp.exp(
            jnp.minimum(i, 0.0)) * jnp.tanh(z)
        h = (jax.nn.sigmoid(o) * jnp.tanh(c)).astype(xt.dtype)
        return (h, c), h

    if cache is None:
        h0 = jnp.zeros((B, d), x.dtype)
        c0 = jnp.zeros((B, d), jnp.float32)
        (_, _), ys = jax.lax.scan(step, (h0, c0), x.transpose(1, 0, 2))
        return ys.transpose(1, 0, 2), None
    (h, c), ys = step((cache["h"], cache["c"]), x[:, 0])
    return ys[:, None] if ys.ndim == 2 else ys, \
        {"h": h, "c": c, "idx": cache["idx"] + 1}
