"""Continuous-batching scheduler + plan-cache admission policy.

The engine caches ONE jit trace per ``(op, level, shape)`` plan, and
retraces whenever a dispatch arrives with a shape it has not seen —
including a new leading batch size.  The serving layer therefore treats
"which plans does this program touch, at which batch size" as an
explicit admission object:

* :func:`plan_signature` names the engine plans a compiled program will
  dispatch: one ``(kind, level, dnum, n_terms)`` entry per
  keyswitch-family step, where ``(level, dnum)`` identifies the
  ``KeyswitchPlan`` (the traced ModUp/IP/ModDown constants) and
  ``n_terms`` the hoisted shape (rotation count / merged-relin width).
* :class:`PlanCache` is the admission policy: a ``(signature, batch)``
  pair seen before is a HIT (dispatch is retrace-free by construction);
  a new pair is a MISS whose first execution pays the jit traces and
  warms the plans for every later request — from ANY tenant, since the
  plans carry no key material.

Batching policy (:class:`ContinuousBatcher`): requests are packed by
group — ``(tenant, program_id)``, the unit that can share one vmap
batch (same compiled plan AND same evk tensors) — and a batch launches
when the group reaches ``max_batch`` or its head request has waited
``max_wait`` virtual seconds (or the trace is draining).  Among ready
groups, the one with the OLDEST head request wins: per-tenant FIFO,
no group starvation.  Batches are right-padded to exactly
``max_batch`` slots by repeating the last request's ciphertexts, so
every dispatch reuses the single warmed batch shape — the padding cost
is the occupancy gap the ``batch_occupancy`` metric reports, the
retrace cost it avoids is a full program trace.
"""
from __future__ import annotations

import dataclasses

from repro.errors import ConfigError, PlanCacheMissError
from repro.runtime.compile import CompiledProgram
from repro.runtime.lower import KeyswitchFamilyStep
from repro.serve.queue import GroupKey, Request, RequestQueue


def plan_signature(compiled: CompiledProgram) -> tuple:
    """Engine-plan fingerprint of a compiled program.

    One entry per keyswitch-family step: ``(kind, level, dnum,
    n_terms)``.  ``(level, dnum)`` names the engine ``KeyswitchPlan``
    the step dispatches on; ``n_terms`` (rotation count, or merged
    relin width) pins the traced hoisted shape.  Two programs with
    equal signatures exercise exactly the same jit plans.
    """
    params = compiled.params
    sig = []
    for step in compiled.steps:
        if not isinstance(step, KeyswitchFamilyStep):
            continue
        dnum = len(params.digit_groups(step.level))
        if hasattr(step, "n_relin"):
            n = step.n_relin
        elif hasattr(step, "n_rot"):
            n = step.n_rot
        else:
            n = 1
        sig.append((type(step).__name__, step.level, dnum, n))
    return tuple(sig)


class PlanCache:
    """Admission policy over ``(plan signature, batch size)`` pairs."""

    def __init__(self):
        self._warm: set[tuple] = set()
        self.hits = 0
        self.misses = 0

    def admit(self, signature: tuple, batch: int) -> bool:
        """True = warm (retrace-free dispatch); False = first admission
        at this shape, the execution about to run pays the traces."""
        key = (signature, batch)
        if key in self._warm:
            self.hits += 1
            return True
        self.misses += 1
        self._warm.add(key)
        return False

    def is_warm(self, signature: tuple, batch: int) -> bool:
        return (signature, batch) in self._warm

    def require(self, signature: tuple, batch: int) -> None:
        """Strict admission: raise :class:`PlanCacheMissError` when a
        live dispatch would have to pay a jit trace.  Servers running
        with ``strict_plans=True`` call this before executing, so a
        cold shape becomes an accounted request failure instead of a
        silent multi-second trace stall inside the batch."""
        if not self.is_warm(signature, batch):
            raise PlanCacheMissError(
                "dispatch shape was never warmed",
                hint="warm this (program, width) via FHEServer.warmup "
                     "before serving, or run with strict_plans=False",
                batch=batch, warm_widths=self.warm_widths(signature))

    def warm_widths(self, signature: tuple) -> list[int]:
        """Batch sizes this signature has been traced at, ascending —
        the server pads a partial batch up to the SMALLEST warm width
        that fits instead of always paying the full max-batch shape."""
        return sorted(b for s, b in self._warm if s == signature)

    def stats(self) -> dict:
        total = self.hits + self.misses
        return {
            "warm_plans": len(self._warm),
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": self.hits / total if total else 1.0,
        }


@dataclasses.dataclass
class PackedBatch:
    """A scheduler decision: FIFO slice of one group, ready to launch."""

    group: GroupKey
    requests: list[Request]

    @property
    def tenant(self) -> str:
        return self.group[0]

    @property
    def program_id(self) -> str:
        return self.group[1]


class ContinuousBatcher:
    """Max-batch / max-wait continuous batching over the request queue."""

    def __init__(self, max_batch: int = 4, max_wait_s: float = 0.05):
        if max_batch <= 0:
            raise ConfigError("max_batch must be positive",
                              max_batch=max_batch)
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s

    def _ready(self, reqs: list[Request], now: float, drain: bool) -> bool:
        return (len(reqs) >= self.max_batch or drain
                or now - reqs[0].arrival >= self.max_wait_s)

    def pick(self, queue: RequestQueue, now: float,
             drain: bool = False) -> PackedBatch | None:
        """The next batch to launch, or None if every group should keep
        accumulating.  Among ready groups the oldest head request wins
        (per-tenant FIFO; no group starves)."""
        best: tuple[int, GroupKey, list[Request]] | None = None
        for group, reqs in queue.groups().items():
            if not self._ready(reqs, now, drain):
                continue
            if best is None or reqs[0].rid < best[0]:
                best = (reqs[0].rid, group, reqs)
        if best is None:
            return None
        _, group, reqs = best
        picked = reqs[: self.max_batch]
        queue.take(picked)
        return PackedBatch(group, picked)

    def next_flush_time(self, queue: RequestQueue) -> float | None:
        """Virtual time at which the oldest queued request forces a
        (possibly partial) batch — the clock's idle-advance target."""
        head = queue.oldest()
        return None if head is None else head.arrival + self.max_wait_s


class CircuitBreaker:
    """Per-tenant failure isolation on the virtual clock.

    One tenant repeatedly submitting poisoned requests (corrupt inputs,
    wrong-level ciphertexts) must not keep burning engine time and
    bisect passes for everyone else.  Classic three-state breaker:

    * **closed** — normal service; consecutive request failures are
      counted, any success resets the count;
    * **open** — tripped after ``threshold`` consecutive failures: the
      tenant's requests are shed (``CircuitOpenError`` reason) without
      touching the engine, until ``cooldown_s`` virtual seconds pass;
    * **half-open** — after the cooldown, exactly one probe batch is
      allowed through: success closes the breaker, failure re-opens it
      (a fresh trip, a fresh cooldown).

    All timing is virtual-clock, so chaos schedules replay exactly.
    """

    def __init__(self, threshold: int = 3, cooldown_s: float = 0.5):
        if threshold <= 0:
            raise ConfigError("breaker threshold must be positive",
                              threshold=threshold)
        self.threshold = threshold
        self.cooldown_s = cooldown_s
        self._fails: dict[str, int] = {}       # consecutive failures
        self._open_until: dict[str, float] = {}
        self._probing: set[str] = set()        # half-open probe issued
        self.trips = 0

    def allow(self, tenant: str, now: float) -> bool:
        """May this tenant's batch dispatch at virtual time ``now``?"""
        until = self._open_until.get(tenant)
        if until is None:
            return True
        if now < until:
            return False
        # cooldown elapsed: half-open — let one probe batch through
        if tenant in self._probing:
            return False
        self._probing.add(tenant)
        return True

    def record_success(self, tenant: str) -> None:
        self._fails.pop(tenant, None)
        self._open_until.pop(tenant, None)
        self._probing.discard(tenant)

    def record_failure(self, tenant: str, now: float) -> None:
        if tenant in self._probing:            # failed half-open probe
            self._probing.discard(tenant)
            self._open_until[tenant] = now + self.cooldown_s
            self.trips += 1
            return
        n = self._fails.get(tenant, 0) + 1
        self._fails[tenant] = n
        if n >= self.threshold and tenant not in self._open_until:
            self._open_until[tenant] = now + self.cooldown_s
            self._fails[tenant] = 0
            self.trips += 1

    def is_open(self, tenant: str, now: float) -> bool:
        until = self._open_until.get(tenant)
        return until is not None and now < until

    def stats(self) -> dict:
        return {"trips": self.trips,
                "open_tenants": sorted(self._open_until)}
