"""Per-tenant key/evk registry over ONE shared engine.

Multi-tenant serving separates two kinds of state the single-program
runtime kept fused together:

* **jit plans** (``KeyswitchEngine._batch_fns`` et al.) are keyed on
  ``(op, level, shape)`` and contain NO key material — they are shared
  by every tenant, which is exactly what makes cross-tenant serving
  retrace-free: tenant B's first request reuses the plan tenant A
  traced.
* **key material** (secret key, mult/conj keys, per-step rotation evks)
  is per tenant.  The registry owns one ``KeyChain`` per tenant, seeded
  deterministically, and installs it on the shared ``CKKSContext`` for
  the duration of a ``lease`` — the engine's evk *tensor* caches are
  keyed by ``id(evk)`` so tenants never collide (ARK-style
  inter-operation key reuse happens per tenant, across that tenant's
  blocks and batches).

Eviction is bounded-LRU over tenants: creating tenant ``capacity + 1``
evicts the least-recently-used tenant that is **not in flight** (an
active lease pins its keys — evicting mid-batch would invalidate evk
tensors the running jit dispatch still references).  Eviction also
purges the engine's stacked/Montgomery evk tensors for the dead
tenant's keys, so registry memory is genuinely bounded.
"""
from __future__ import annotations

import contextlib

from repro.core.ckks import CKKSContext
from repro.core.keys import KeyChain
from repro.errors import ConfigError, KeyUnavailableError

_EVICT_HINT = ("tenant keys were LRU-evicted; re-enroll the tenant "
               "(lease/warmup regenerates them bit-identically from its "
               "stable seed) or raise the registry capacity")


class TenantRegistry:
    """Bounded LRU of per-tenant ``KeyChain``s bound to one context."""

    def __init__(self, ctx: CKKSContext, capacity: int = 8,
                 base_seed: int = 1000):
        if capacity <= 0:
            raise ConfigError("registry capacity must be positive",
                              hint="at least one tenant must fit",
                              capacity=capacity)
        self.ctx = ctx
        self.capacity = capacity
        self.base_seed = base_seed
        self._chains: dict[str, KeyChain] = {}   # insertion = LRU order
        self._seeds: dict[str, int] = {}
        self._inflight: dict[str, int] = {}
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __contains__(self, tenant: str) -> bool:
        return tenant in self._chains

    def __len__(self) -> int:
        return len(self._chains)

    # ------------------------- keychains -------------------------------
    def _tenant_seed(self, tenant: str) -> int:
        """Stable per-tenant seed: the tenant's keys survive eviction +
        re-admission bit-identically (re-keygen, not re-keying)."""
        if tenant not in self._seeds:
            self._seeds[tenant] = self.base_seed + len(self._seeds)
        return self._seeds[tenant]

    def keychain(self, tenant: str, create: bool = True) -> KeyChain:
        """The tenant's keys, creating (and possibly evicting) on miss.

        ``create=False`` is the strict lookup: a request that references
        a tenant whose keys were evicted gets a typed
        :class:`KeyUnavailableError` carrying the tenant id and the
        remediation (NOT a bare ``KeyError``) — the server's retry path
        treats it as recoverable because re-keygen is deterministic.
        """
        if tenant in self._chains:
            self.hits += 1
            self._chains[tenant] = self._chains.pop(tenant)  # LRU bump
            return self._chains[tenant]
        self.misses += 1
        if not create:
            raise KeyUnavailableError(
                f"tenant '{tenant}' has no resident key material",
                hint=_EVICT_HINT, tenant=tenant,
                resident=len(self._chains), capacity=self.capacity)
        while len(self._chains) >= self.capacity:
            if not self._evict_one():
                break        # every resident tenant is in flight
        kc = KeyChain(self.ctx.params, self.ctx.pc,
                      seed=self._tenant_seed(tenant))
        self._chains[tenant] = kc
        return kc

    def _evict_one(self) -> bool:
        """Drop the LRU tenant that is not in flight; purge its evk
        tensors from the engine caches.  False if none is evictable."""
        for tenant in self._chains:        # insertion order == LRU order
            if self._inflight.get(tenant, 0) == 0:
                self.evict(tenant)
                return True
        return False

    def evict(self, tenant: str, force: bool = False) -> bool:
        """Evict one tenant's keys and purge its engine evk tensors.

        ``force=True`` evicts even an in-flight tenant — that is the
        fault the injection harness uses to exercise the server's
        ``KeyUnavailableError`` recovery; normal LRU eviction never
        does this (an active lease pins the keys).
        """
        if tenant not in self._chains:
            return False
        if not force and self._inflight.get(tenant, 0) > 0:
            return False
        kc = self._chains.pop(tenant)
        self._purge_engine_caches(kc)
        self.evictions += 1
        return True

    def _purge_engine_caches(self, kc: KeyChain) -> None:
        engine = self.ctx.engine
        dead = {id(k) for k in kc._rot_keys.values()}
        for k in (kc._mult_key, kc._conj_key):
            if k is not None:
                dead.add(id(k))
        engine._evk_full = {i: v for i, v in engine._evk_full.items()
                            if i not in dead}
        engine._evk_level = {k: v for k, v in engine._evk_level.items()
                             if k[0] not in dead}
        engine._evk_group = {k: v for k, v in engine._evk_group.items()
                             if not (set(k[0]) & dead)}

    # ------------------------- leases ----------------------------------
    @contextlib.contextmanager
    def lease(self, tenant: str, create: bool = True):
        """Install the tenant's keys on the shared context and pin them
        against eviction while the lease is held (re-entrant).
        ``create=False`` raises :class:`KeyUnavailableError` instead of
        re-keygen when the tenant was evicted."""
        kc = self.keychain(tenant, create=create)
        prev = self.ctx.keys
        self.ctx.keys = kc
        self._inflight[tenant] = self._inflight.get(tenant, 0) + 1
        try:
            yield kc
        finally:
            self._inflight[tenant] -= 1
            if self._inflight[tenant] == 0:
                del self._inflight[tenant]
            self.ctx.keys = prev

    def inflight(self, tenant: str) -> bool:
        return self._inflight.get(tenant, 0) > 0

    def stats(self) -> dict:
        total = self.hits + self.misses
        return {
            "tenants_resident": len(self._chains),
            "capacity": self.capacity,
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "hit_rate": self.hits / total if total else 1.0,
        }
