"""Serving metrics: latency percentiles, throughput, occupancy, caches.

All latencies live on the server's virtual clock (arrival timestamps
from the open-loop trace; service time measured wall-clock per executed
batch and added to the clock), so ``latency = completion - arrival``
mixes queueing delay and real engine time in the same unit (seconds).

Percentiles use the nearest-rank definition
(``sorted[ceil(p/100 * n) - 1]``) — exact on small samples, so the
metrics-arithmetic test can assert them from first principles.

``ServingReport`` is the ``ExecutionReport``-style structured record:
one aggregate view plus a per-tenant breakdown, each a plain dict ready
for ``BENCH_serving.json``.
"""
from __future__ import annotations

import dataclasses
import math


def percentile(values: list[float], p: float) -> float | None:
    """Nearest-rank percentile: ``sorted[max(1, ceil(p/100 * n)) - 1]``.

    ``None`` on an empty sample — a percentile of nothing is not 0.0
    (0.0 reads as "zero latency" in dashboards and summaries).  A
    single-sample list returns that sample for every p: ceil clamps the
    rank into [1, n] from below via ``max`` and from above via ``min``,
    so no p in (0, 100] can index off either end.
    """
    if not values:
        return None
    s = sorted(values)
    rank = max(1, math.ceil(p / 100.0 * len(s)))
    return s[min(rank, len(s)) - 1]


@dataclasses.dataclass
class TenantStats:
    """Per-tenant accumulator: latencies in virtual seconds.

    Every submitted request reaches exactly ONE terminal counter:
    ``completed`` (result delivered), ``failed`` (permanent typed error
    after retries), ``shed`` (never executed: deadline expired, breaker
    open, or overload), or ``rejected`` (bounded-queue backpressure at
    submit).  The accounting identity the chaos gate checks is
    ``completed + failed + shed + rejected == submitted``.
    """

    completed: int = 0
    rejected: int = 0
    failed: int = 0
    shed: int = 0
    latencies: list[float] = dataclasses.field(default_factory=list)

    def record(self, latency_s: float) -> None:
        self.completed += 1
        self.latencies.append(latency_s)

    def summary(self, span_s: float) -> dict:
        return {
            "completed": self.completed,
            "rejected": self.rejected,
            "failed": self.failed,
            "shed": self.shed,
            "throughput_ops": (self.completed / span_s) if span_s else 0.0,
            "p50_latency_s": percentile(self.latencies, 50),
            "p99_latency_s": percentile(self.latencies, 99),
            "mean_latency_s": (sum(self.latencies) / len(self.latencies)
                               if self.latencies else 0.0),
        }


@dataclasses.dataclass
class ServingReport:
    """Structured record of one serving run (per tenant + aggregate)."""

    span_s: float                     # virtual makespan of the run
    completed: int
    rejected: int
    batches: int
    batch_occupancy: float            # mean real/max slots per batch
    plan_cache: dict                  # admission-policy hits/misses
    registry: dict                    # TenantRegistry.stats()
    queue: dict                       # depth stats + rejections
    tenants: dict[str, dict]          # tenant -> TenantStats.summary()
    submitted: int = 0                # valid submit() calls observed
    failed: int = 0                   # permanent typed failures
    shed: int = 0                     # never executed (deadline/breaker/load)
    retries: int = 0                  # re-dispatches after transient faults
    quarantine_splits: int = 0        # bisect passes over failed batches
    breaker_trips: int = 0            # circuit-breaker open transitions
    shed_reasons: dict = dataclasses.field(default_factory=dict)
    errors: dict = dataclasses.field(default_factory=dict)  # type -> count
    latencies_s: list[float] = dataclasses.field(default_factory=list,
                                                 repr=False)

    @property
    def throughput_ops(self) -> float:
        return self.completed / self.span_s if self.span_s else 0.0

    @property
    def p50_latency_s(self) -> float | None:
        return percentile(self.latencies_s, 50)

    @property
    def p99_latency_s(self) -> float | None:
        return percentile(self.latencies_s, 99)

    @property
    def accounted(self) -> int:
        """Requests with a terminal outcome — the chaos gate asserts
        this equals the number submitted (nothing lost, nothing
        double-counted)."""
        return self.completed + self.rejected + self.failed + self.shed

    def to_dict(self) -> dict:
        return {
            "span_s": self.span_s,
            "submitted": self.submitted,
            "completed": self.completed,
            "rejected": self.rejected,
            "failed": self.failed,
            "shed": self.shed,
            "accounted": self.accounted,
            "retries": self.retries,
            "quarantine_splits": self.quarantine_splits,
            "breaker_trips": self.breaker_trips,
            "shed_reasons": self.shed_reasons,
            "errors": self.errors,
            "batches": self.batches,
            "throughput_ops": self.throughput_ops,
            "p50_latency_s": self.p50_latency_s,
            "p99_latency_s": self.p99_latency_s,
            "batch_occupancy": self.batch_occupancy,
            "plan_cache": self.plan_cache,
            "registry": self.registry,
            "queue": self.queue,
            "tenants": self.tenants,
        }
