"""repro.serve — multi-tenant continuous-batching FHE serving layer.

PRs 1-5 built a compiled, batched engine that executes ONE ciphertext
program at a time; this package turns it into a *server*: an open-loop
Poisson arrival stream of ``(tenant, program_id, ct)`` jobs is queued,
packed into the engine's existing ``*_batched`` jit plans without
retracing, executed under per-tenant keys, measured, and replayed onto
the paper's hardware timelines.

  workload  (serve.workload)  — seeded open-loop Poisson traces:
            ``Arrival(t, tenant, program_id)``;
  queue     (serve.queue)     — bounded FIFO with (tenant, program)
            batch-class views; rejection = backpressure;
  scheduler (serve.scheduler) — continuous batching (max-batch /
            max-wait, oldest-head-first groups) + the plan-cache
            admission policy over ``(level, dnum)`` plan signatures;
  registry  (serve.registry)  — per-tenant KeyChains on ONE shared
            engine, bounded LRU eviction that never touches an
            in-flight tenant, evk tensor caches purged on eviction;
  server    (serve.server)    — the virtual-clock serving loop +
            serial baseline; logs every batch as a ``BatchRecord``;
  metrics   (serve.metrics)   — throughput, nearest-rank p50/p99
            latency, batch occupancy, cache hit rates, queue depth —
            per tenant and aggregate (``ServingReport``);
  simfeed   (serve.simfeed)   — replay the batch log onto the
            ``sim.schedule`` group-pipeline timelines: what would the
            HE^2 hardware do with this traffic;
  faults    (serve.faults)    — deterministic seeded fault injection
            (transient engine faults, mid-flight key evictions,
            corrupted output limbs, latency spikes) driving the
            server's retry / quarantine-bisect / breaker / shedding
            recovery paths.

See ``docs/SERVING.md`` for the operator's guide (including the
failure-handling section) and ``benchmarks/bench_serving.py`` for the
gated end-to-end run (``--chaos`` for the fault-schedule gate).
"""
from repro.serve.faults import FaultInjector, FaultPlan  # noqa: F401
from repro.serve.metrics import ServingReport, percentile  # noqa: F401
from repro.serve.queue import Request, RequestQueue  # noqa: F401
from repro.serve.registry import TenantRegistry  # noqa: F401
from repro.serve.scheduler import (  # noqa: F401
    CircuitBreaker, ContinuousBatcher, PlanCache, plan_signature,
)
from repro.serve.server import BatchRecord, FHEServer  # noqa: F401
from repro.serve.simfeed import replay_on_hardware  # noqa: F401
from repro.serve.workload import (  # noqa: F401
    Arrival, poisson_trace, workload_request_programs,
)
