"""The serving loop: open-loop arrivals -> queue -> packed batches.

``FHEServer`` binds the pieces together on ONE shared
``CKKSContext``/``KeyswitchEngine``:

    arrivals (serve.workload)  --admit-->  RequestQueue (bounded FIFO)
        --pick-->  ContinuousBatcher (max-batch / max-wait, per
                   (tenant, program) groups, oldest-head-first)
        --admission-->  PlanCache ((signature, batch) warm set)
        --lease-->  TenantRegistry (per-tenant keys on the shared ctx)
        --execute-->  ProgramExecutor.run_batched (one vmap dispatch,
                      padded to the warmed batch shape)
        --record-->  ServingReport + BatchRecord log (simfeed replays
                     the log onto the sim.schedule timelines)

Time model: a **virtual clock**.  Arrival timestamps come from the
open-loop trace; every executed batch advances the clock by its
*measured* wall-clock duration (jit dispatch + device sync).  Request
latency = completion - arrival on that clock, so queueing delay and
engine time land in the same unit while the arrival process stays
deterministic and replayable (same ``--seed``, same trace, both
baselines, and the simulator half all see identical traffic).

Failure model: every submitted request reaches exactly one terminal
outcome — ``completed``, ``failed`` (permanent typed error), ``shed``
(never executed: deadline expired, breaker open, overload), or
``rejected`` (bounded-queue backpressure).  The recovery machinery:

* **retry with capped exponential backoff** for retryable errors
  (:data:`repro.errors.RETRYABLE_ERRORS` — transient engine faults,
  evicted keys that deterministic re-keygen restores).  Backoff time is
  virtual-clock time, so chaos runs replay exactly.
* **quarantine bisect** for permanent ciphertext errors in a multi-
  request batch: the batch splits in half and each half re-dispatches,
  recursively, until the poisoned request(s) fail alone — co-batched
  victims complete instead of failing collaterally.
* **per-tenant circuit breaker** (:class:`~repro.serve.scheduler.
  CircuitBreaker`): a tenant failing repeatedly is shed without
  touching the engine until a cooldown elapses.
* **overload shedding** at submit: when the EWMA service-time estimate
  says the queue wait already blows the request's deadline headroom,
  the request is shed with reason ``overload`` instead of queued.

The serial baseline (:meth:`FHEServer.run_serial`) answers the gate
question: same trace, same virtual clock, but every request executes
alone (batch slots = 1) in strict arrival order — what a
one-request-at-a-time service would do with the same traffic.
"""
from __future__ import annotations

import dataclasses
import time

from repro import obs
from repro.core.ckks import CKKSContext, Ciphertext
from repro.errors import (
    CiphertextError, InvalidRequestError, ReproError, is_retryable,
)
from repro.runtime import CompiledProgram, ProgramExecutor
from repro.serve.metrics import ServingReport, TenantStats
from repro.serve.queue import Request, RequestQueue
from repro.serve.registry import TenantRegistry
from repro.serve.scheduler import (
    CircuitBreaker, ContinuousBatcher, PackedBatch, PlanCache,
    plan_signature,
)
from repro.serve.workload import Arrival


@dataclasses.dataclass
class BatchRecord:
    """One executed batch on the virtual timeline (simfeed's input)."""

    start_s: float                # virtual launch time
    duration_s: float             # measured wall-clock service time
    tenant: str
    program_id: str
    n_real: int                   # requests actually served
    batch: int                    # padded dispatch width
    plan_hit: bool                # admission policy verdict
    rids: list[int]
    ok: bool = True               # dispatch finished without error
    error: str | None = None      # typed error class name when not ok
    attempt: int = 0              # 0 = first try, >0 = retry number


class FHEServer:
    """Multi-tenant continuous-batching server over compiled programs."""

    def __init__(self, ctx: CKKSContext, max_batch: int = 4,
                 max_wait_s: float = 0.05, queue_size: int = 256,
                 registry: TenantRegistry | None = None,
                 keep_outputs: bool = True,
                 default_deadline_s: float | None = None,
                 max_retries: int = 2,
                 backoff_base_s: float = 0.01,
                 backoff_cap_s: float = 0.25,
                 breaker: CircuitBreaker | None = None,
                 strict_plans: bool = False,
                 faults=None):
        if not ctx.use_engine:
            raise NotImplementedError(
                "serving requires the batched engine (use_engine=True)")
        self.ctx = ctx
        self.executor = ProgramExecutor(ctx)
        self.registry = registry if registry is not None \
            else TenantRegistry(ctx)
        self.queue = RequestQueue(queue_size)
        self.batcher = ContinuousBatcher(max_batch, max_wait_s)
        self.plan_cache = PlanCache()
        self.programs: dict[str, CompiledProgram] = {}
        self._signatures: dict[str, tuple] = {}
        self.records: list[BatchRecord] = []
        self.keep_outputs = keep_outputs
        self.outputs: dict[int, dict[str, Ciphertext]] = {}
        self._tenants: dict[str, TenantStats] = {}
        # ---- fault tolerance -------------------------------------------
        self.default_deadline_s = default_deadline_s
        self.max_retries = max_retries
        self.backoff_base_s = backoff_base_s
        self.backoff_cap_s = backoff_cap_s
        self.breaker = breaker
        self.strict_plans = strict_plans
        self.faults = faults            # FaultInjector | None (duck-typed)
        self.submitted = 0
        self.retries = 0
        self.quarantine_splits = 0
        self.shed_reasons: dict[str, int] = {}
        self.errors: dict[str, int] = {}
        self.outcomes: dict[int, str] = {}   # rid -> terminal outcome
        self._dispatch_idx = 0               # fault-plan index
        self._ewma_service_s: float | None = None
        # ---- observability (obs-gated; empty when tracing is off) ------
        # request_log: per-request lifecycle rows on the VIRTUAL clock,
        # rendered as per-tenant Perfetto lanes by obs.export.
        self.request_log: list[dict] = []
        self._first_dispatch: dict[int, float] = {}  # rid -> virtual t0

    def _log_terminal(self, req: Request, end_s: float,
                      outcome: str) -> None:
        """obs-gated request-lifecycle row (virtual clock)."""
        self.request_log.append({
            "rid": req.rid, "tenant": req.tenant,
            "program": req.program_id, "arrival_s": req.arrival,
            "start_s": self._first_dispatch.get(req.rid),
            "end_s": end_s, "outcome": outcome,
        })
        obs.event("serve.request", rid=req.rid, tenant=req.tenant,
                  outcome=outcome)

    # ------------------------- programs --------------------------------
    def register_program(self, program_id: str,
                         compiled: CompiledProgram) -> tuple:
        """Admit a compiled program; returns its engine-plan signature."""
        self.programs[program_id] = compiled
        self._signatures[program_id] = plan_signature(compiled)
        return self._signatures[program_id]

    def warmup(self, tenant: str, program_id: str,
               inputs: dict[str, Ciphertext],
               width: int | None = None) -> None:
        """Trace the program's jit plans at the serving batch shape by
        executing one padded batch (admission-policy MISS paid here, so
        live traffic is retrace-free from the first request).
        ``width`` defaults to the scheduler's max_batch; pass 1 to warm
        the serial baseline's shape."""
        B = self.batcher.max_batch if width is None else width
        self.plan_cache.admit(self._signatures[program_id], B)
        with self.registry.lease(tenant):
            self.executor.run_batched(
                self.programs[program_id],
                {tag: [ct] * B for tag, ct in inputs.items()})

    # ------------------------- submission ------------------------------
    def _stats(self, tenant: str) -> TenantStats:
        if tenant not in self._tenants:
            self._tenants[tenant] = TenantStats()
        return self._tenants[tenant]

    def submit(self, tenant: str, program_id: str,
               inputs: dict[str, Ciphertext], arrival: float,
               deadline: float | None = None,
               validate: bool = False) -> bool:
        """Queue one request; False = not admitted (backpressure
        rejection or overload shed, tallied per tenant).

        Malformed requests raise :class:`InvalidRequestError` — a
        client error is a typed refusal, not an assert that vanishes
        under ``python -O`` or a crash inside a shared batch later.
        """
        if program_id not in self.programs:
            raise InvalidRequestError(
                "unknown program id",
                hint="register_program() the compiled program first",
                program_id=program_id, known=sorted(self.programs))
        compiled = self.programs[program_id]
        missing = [t for t in compiled.inputs if t not in inputs]
        if missing:
            raise InvalidRequestError(
                "request is missing input ciphertexts",
                program_id=program_id, missing=missing)
        self.submitted += 1
        if deadline is None and self.default_deadline_s is not None:
            deadline = arrival + self.default_deadline_s
        # Overload shed: if the queue wait we can already predict blows
        # the deadline headroom, refuse now instead of executing a
        # result nobody will accept.
        if deadline is not None and self._ewma_service_s is not None:
            est_wait = ((self.queue.depth / self.batcher.max_batch + 1.0)
                        * self._ewma_service_s)
            if arrival + est_wait > deadline:
                self._shed_unqueued(tenant, "overload")
                return False
        req = self.queue.offer(tenant, program_id, inputs, arrival,
                               deadline=deadline, validate=validate)
        if req is None:
            self._stats(tenant).rejected += 1
            obs.event("serve.reject", tenant=tenant, program=program_id,
                      depth=self.queue.depth)
            return False
        obs.event("serve.submit", rid=req.rid, tenant=tenant,
                  program=program_id, arrival=arrival)
        return True

    # ------------------------- outcomes --------------------------------
    def _shed_unqueued(self, tenant: str, reason: str) -> None:
        self._stats(tenant).shed += 1
        self.shed_reasons[reason] = self.shed_reasons.get(reason, 0) + 1
        obs.event("serve.shed", tenant=tenant, reason=reason, queued=False)

    def _shed(self, reqs: list[Request], reason: str,
              now: float | None = None) -> None:
        self.shed_reasons[reason] = (self.shed_reasons.get(reason, 0)
                                     + len(reqs))
        tracing = obs.TRACER.enabled
        for r in reqs:
            self._stats(r.tenant).shed += 1
            self.outcomes[r.rid] = f"shed:{reason}"
            if tracing:
                self._log_terminal(r, now if now is not None
                                   else r.arrival, f"shed:{reason}")

    def _fail(self, reqs: list[Request], err: ReproError,
              now: float) -> None:
        name = type(err).__name__
        self.errors[name] = self.errors.get(name, 0) + len(reqs)
        tracing = obs.TRACER.enabled
        for r in reqs:
            self._stats(r.tenant).failed += 1
            self.outcomes[r.rid] = f"failed:{name}"
            if tracing:
                self._log_terminal(r, now, f"failed:{name}")
        if self.breaker is not None and reqs:
            self.breaker.record_failure(reqs[0].tenant, now)

    # ------------------------- execution -------------------------------
    def _dispatch_once(self, reqs: list[Request], tenant: str,
                       program_id: str, now: float, width: int | None,
                       attempt: int):
        """One engine dispatch, padded to ``width`` slots.

        ``width=None`` picks the smallest already-warm bucket that fits
        the real requests (falling back to max_batch), so a partial
        batch only pays for the nearest warmed shape, never a retrace.
        Returns ``(dt, error, outputs)`` — errors are *returned*, not
        raised, because the failed attempt's measured duration must
        still advance the virtual clock.
        """
        compiled = self.programs[program_id]
        sig = self._signatures[program_id]
        if width is None:
            fits = [w for w in self.plan_cache.warm_widths(sig)
                    if w >= len(reqs)]
            B = min(fits) if fits else self.batcher.max_batch
        else:
            B = width
        validate = any(r.validate for r in reqs)
        idx = self._dispatch_idx
        self._dispatch_idx += 1
        err, res, hit = None, None, False
        if obs.TRACER.enabled:
            for r in reqs:
                self._first_dispatch.setdefault(r.rid, now)
            sp = obs.span("serve.dispatch", tenant=tenant,
                          program=program_id, n_real=len(reqs), batch=B,
                          attempt=attempt, virtual_start_s=now,
                          rids=[r.rid for r in reqs])
        else:
            sp = obs.NULL_SPAN
        sp.__enter__()
        t0 = time.perf_counter()
        try:
            if self.strict_plans:
                self.plan_cache.require(sig, B)
            hit = self.plan_cache.admit(sig, B)
            if self.faults is not None:
                self.faults.before_dispatch(idx, self, tenant)
            pad = B - len(reqs)
            stacked = {
                tag: ([r.inputs[tag] for r in reqs]
                      + [reqs[-1].inputs[tag]] * pad)
                for tag in compiled.inputs
            }
            with self.registry.lease(tenant):
                res = self.executor.run_batched(compiled, stacked,
                                                validate=validate)
                for cts in res.outputs.values():
                    cts[0].c0.block_until_ready()
        except ReproError as e:
            err = e
        dt = time.perf_counter() - t0
        sp.set_attrs(plan_hit=hit, ok=err is None,
                     error=type(err).__name__ if err is not None else None)
        sp.__exit__(None, None, None)
        if self.faults is not None:
            dt += self.faults.extra_latency(idx)
            if err is None and res is not None:
                self.faults.corrupt_outputs(idx, res.outputs,
                                            n_real=len(reqs))
        if err is None:
            e = self._ewma_service_s
            self._ewma_service_s = dt if e is None else 0.8 * e + 0.2 * dt
        self.records.append(BatchRecord(
            start_s=now, duration_s=dt, tenant=tenant,
            program_id=program_id, n_real=len(reqs), batch=B,
            plan_hit=hit, rids=[r.rid for r in reqs],
            ok=err is None,
            error=type(err).__name__ if err is not None else None,
            attempt=attempt,
        ))
        return dt, err, (res.outputs if res is not None else None)

    def _deliver(self, reqs: list[Request], outputs, now: float,
                 tenant: str) -> None:
        """Terminal accounting for a successful dispatch: per-slot
        output health checks (a corrupted slot fails ONLY its own
        request — zero silently-wrong results), then completion."""
        ok: list[Request] = []
        check = self.faults is not None
        for j, r in enumerate(reqs):
            outs = {tag: cts[j] for tag, cts in outputs.items()}
            slot_err = None
            if r.validate or check:
                try:
                    for tag, ct in outs.items():
                        self.ctx.check_ciphertext(
                            ct, where=f"output[{tag}] rid={r.rid}")
                except CiphertextError as e:
                    slot_err = e
            if slot_err is not None:
                self._fail([r], slot_err, now)
                continue
            if self.keep_outputs:
                self.outputs[r.rid] = outs
            ok.append(r)
        tracing = obs.TRACER.enabled
        for r in ok:
            self._stats(r.tenant).record(now - r.arrival)
            self.outcomes[r.rid] = "completed"
            if tracing:
                self._log_terminal(r, now, "completed")
        if ok and self.breaker is not None:
            self.breaker.record_success(tenant)

    def _serve_requests(self, reqs: list[Request], tenant: str,
                        program_id: str, now: float,
                        width: int | None) -> float:
        """Dispatch + recover: retry/backoff on transient errors,
        quarantine bisect on permanent ciphertext errors.  Returns the
        advanced virtual clock; every request in ``reqs`` reaches a
        terminal outcome before this returns."""
        attempt = 0
        while True:
            dt, err, outputs = self._dispatch_once(
                reqs, tenant, program_id, now, width, attempt)
            now += dt
            if err is None:
                self._deliver(reqs, outputs, now, tenant)
                return now
            if is_retryable(err) and attempt < self.max_retries:
                backoff = min(self.backoff_cap_s,
                              self.backoff_base_s * (2 ** attempt))
                now += backoff
                self.retries += 1
                attempt += 1
                obs.event("serve.retry", tenant=tenant,
                          program=program_id, attempt=attempt,
                          backoff_s=backoff,
                          error=type(err).__name__,
                          rids=[r.rid for r in reqs])
                continue
            # Permanent error (or retries exhausted).  A poisoned
            # ciphertext in a shared batch must not fail its co-batched
            # victims: bisect and re-dispatch each half until the
            # poison fails alone.
            if isinstance(err, (CiphertextError, InvalidRequestError)) \
                    and len(reqs) > 1:
                self.quarantine_splits += 1
                mid = len(reqs) // 2
                obs.event("serve.quarantine_split",
                          error=type(err).__name__,
                          rids=[r.rid for r in reqs], mid=mid)
                now = self._serve_requests(reqs[:mid], tenant,
                                           program_id, now, width)
                now = self._serve_requests(reqs[mid:], tenant,
                                           program_id, now, width)
                return now
            self._fail(reqs, err, now)
            return now

    def _serve_batch(self, batch: PackedBatch, now: float,
                     width: int | None = None) -> float:
        """Serve one packed batch through the full degradation ladder:
        breaker gate -> deadline shed -> dispatch with recovery."""
        if self.breaker is not None \
                and not self.breaker.allow(batch.tenant, now):
            self._shed(batch.requests, "breaker_open", now)
            return now
        live: list[Request] = []
        expired: list[Request] = []
        for r in batch.requests:
            (expired if r.deadline is not None and now > r.deadline
             else live).append(r)
        if expired:
            self._shed(expired, "deadline", now)
        if not live:
            return now
        return self._serve_requests(live, batch.tenant,
                                    batch.program_id, now, width)

    # ------------------------- serving loops ---------------------------
    def run_trace(self, trace: list[Arrival], inputs_for,
                  deadline_s: float | None = None,
                  validate: bool = False) -> ServingReport:
        """Serve an open-loop arrival trace to completion.

        ``inputs_for(arrival) -> {tag: Ciphertext}`` materializes each
        request's ciphertexts; it runs under the tenant's key lease (so
        ``ctx.encrypt`` uses the right secret) and OFF the virtual
        clock — encryption is client-side work, not server time.
        ``deadline_s`` gives every request a relative deadline
        (overriding ``default_deadline_s``); ``validate`` opts every
        request into the executor's invariant checker.
        """
        arr = sorted(trace, key=lambda a: a.t)
        i, now = 0, 0.0
        while True:
            while i < len(arr) and arr[i].t <= now:
                a = arr[i]
                with self.registry.lease(a.tenant):
                    inputs = inputs_for(a)
                dl = a.t + deadline_s if deadline_s is not None else None
                self.submit(a.tenant, a.program_id, inputs, a.t,
                            deadline=dl, validate=validate)
                i += 1
            drain = i >= len(arr)
            batch = self.batcher.pick(self.queue, now, drain=drain)
            if batch is None:
                if drain and not self.queue:
                    break
                targets = [arr[i].t] if i < len(arr) else []
                flush = self.batcher.next_flush_time(self.queue)
                if flush is not None:
                    targets.append(flush)
                now = max(now, min(targets))
                continue
            now = self._serve_batch(batch, now)
        return self.report(span_s=now)

    def run_serial(self, trace: list[Arrival], inputs_for,
                   deadline_s: float | None = None,
                   validate: bool = False) -> ServingReport:
        """Baseline: the same trace, one request at a time (no packing),
        strict arrival order, on the same virtual clock."""
        arr = sorted(trace, key=lambda a: a.t)
        now = 0.0
        for a in arr:
            with self.registry.lease(a.tenant):
                inputs = inputs_for(a)
            dl = a.t + deadline_s if deadline_s is not None else None
            if not self.submit(a.tenant, a.program_id, inputs, a.t,
                               deadline=dl, validate=validate):
                continue
            req = self.queue.oldest()
            self.queue.take([req])
            now = max(now, a.t)
            batch = PackedBatch((a.tenant, a.program_id), [req])
            now = self._serve_batch(batch, now, width=1)
        return self.report(span_s=now)

    # ------------------------- reporting -------------------------------
    def report(self, span_s: float) -> ServingReport:
        lat_all = [v for s in self._tenants.values() for v in s.latencies]
        depths = self.queue.depth_samples
        occ = ([r.n_real / r.batch for r in self.records]
               if self.records else [])
        return ServingReport(
            span_s=span_s,
            completed=sum(s.completed for s in self._tenants.values()),
            rejected=sum(s.rejected for s in self._tenants.values()),
            batches=len(self.records),
            batch_occupancy=(sum(occ) / len(occ)) if occ else 0.0,
            plan_cache=self.plan_cache.stats(),
            registry=self.registry.stats(),
            queue={
                "maxsize": self.queue.maxsize,
                "max_depth": max(depths) if depths else 0,
                "mean_depth": (sum(depths) / len(depths)) if depths
                              else 0.0,
                "rejected": self.queue.rejected,
            },
            tenants={t: s.summary(span_s)
                     for t, s in sorted(self._tenants.items())},
            submitted=self.submitted,
            failed=sum(s.failed for s in self._tenants.values()),
            shed=sum(s.shed for s in self._tenants.values()),
            retries=self.retries,
            quarantine_splits=self.quarantine_splits,
            breaker_trips=(self.breaker.trips
                           if self.breaker is not None else 0),
            shed_reasons=dict(self.shed_reasons),
            errors=dict(self.errors),
            latencies_s=lat_all,
        )
