"""The serving loop: open-loop arrivals -> queue -> packed batches.

``FHEServer`` binds the pieces together on ONE shared
``CKKSContext``/``KeyswitchEngine``:

    arrivals (serve.workload)  --admit-->  RequestQueue (bounded FIFO)
        --pick-->  ContinuousBatcher (max-batch / max-wait, per
                   (tenant, program) groups, oldest-head-first)
        --admission-->  PlanCache ((signature, batch) warm set)
        --lease-->  TenantRegistry (per-tenant keys on the shared ctx)
        --execute-->  ProgramExecutor.run_batched (one vmap dispatch,
                      padded to the warmed batch shape)
        --record-->  ServingReport + BatchRecord log (simfeed replays
                     the log onto the sim.schedule timelines)

Time model: a **virtual clock**.  Arrival timestamps come from the
open-loop trace; every executed batch advances the clock by its
*measured* wall-clock duration (jit dispatch + device sync).  Request
latency = completion - arrival on that clock, so queueing delay and
engine time land in the same unit while the arrival process stays
deterministic and replayable (same ``--seed``, same trace, both
baselines, and the simulator half all see identical traffic).

The serial baseline (:meth:`FHEServer.run_serial`) answers the gate
question: same trace, same virtual clock, but every request executes
alone (batch slots = 1) in strict arrival order — what a
one-request-at-a-time service would do with the same traffic.
"""
from __future__ import annotations

import dataclasses
import time

from repro.core.ckks import CKKSContext, Ciphertext
from repro.runtime import CompiledProgram, ProgramExecutor
from repro.serve.metrics import ServingReport, TenantStats
from repro.serve.queue import RequestQueue
from repro.serve.registry import TenantRegistry
from repro.serve.scheduler import (
    ContinuousBatcher, PackedBatch, PlanCache, plan_signature,
)
from repro.serve.workload import Arrival


@dataclasses.dataclass
class BatchRecord:
    """One executed batch on the virtual timeline (simfeed's input)."""

    start_s: float                # virtual launch time
    duration_s: float             # measured wall-clock service time
    tenant: str
    program_id: str
    n_real: int                   # requests actually served
    batch: int                    # padded dispatch width
    plan_hit: bool                # admission policy verdict
    rids: list[int]


class FHEServer:
    """Multi-tenant continuous-batching server over compiled programs."""

    def __init__(self, ctx: CKKSContext, max_batch: int = 4,
                 max_wait_s: float = 0.05, queue_size: int = 256,
                 registry: TenantRegistry | None = None,
                 keep_outputs: bool = True):
        if not ctx.use_engine:
            raise NotImplementedError(
                "serving requires the batched engine (use_engine=True)")
        self.ctx = ctx
        self.executor = ProgramExecutor(ctx)
        self.registry = registry if registry is not None \
            else TenantRegistry(ctx)
        self.queue = RequestQueue(queue_size)
        self.batcher = ContinuousBatcher(max_batch, max_wait_s)
        self.plan_cache = PlanCache()
        self.programs: dict[str, CompiledProgram] = {}
        self._signatures: dict[str, tuple] = {}
        self.records: list[BatchRecord] = []
        self.keep_outputs = keep_outputs
        self.outputs: dict[int, dict[str, Ciphertext]] = {}
        self._tenants: dict[str, TenantStats] = {}

    # ------------------------- programs --------------------------------
    def register_program(self, program_id: str,
                         compiled: CompiledProgram) -> tuple:
        """Admit a compiled program; returns its engine-plan signature."""
        self.programs[program_id] = compiled
        self._signatures[program_id] = plan_signature(compiled)
        return self._signatures[program_id]

    def warmup(self, tenant: str, program_id: str,
               inputs: dict[str, Ciphertext],
               width: int | None = None) -> None:
        """Trace the program's jit plans at the serving batch shape by
        executing one padded batch (admission-policy MISS paid here, so
        live traffic is retrace-free from the first request).
        ``width`` defaults to the scheduler's max_batch; pass 1 to warm
        the serial baseline's shape."""
        B = self.batcher.max_batch if width is None else width
        self.plan_cache.admit(self._signatures[program_id], B)
        with self.registry.lease(tenant):
            self.executor.run_batched(
                self.programs[program_id],
                {tag: [ct] * B for tag, ct in inputs.items()})

    # ------------------------- submission ------------------------------
    def _stats(self, tenant: str) -> TenantStats:
        if tenant not in self._tenants:
            self._tenants[tenant] = TenantStats()
        return self._tenants[tenant]

    def submit(self, tenant: str, program_id: str,
               inputs: dict[str, Ciphertext], arrival: float) -> bool:
        """Queue one request; False = rejected (bounded-queue
        backpressure, tallied per tenant)."""
        assert program_id in self.programs, f"unknown {program_id}"
        req = self.queue.offer(tenant, program_id, inputs, arrival)
        if req is None:
            self._stats(tenant).rejected += 1
            return False
        return True

    # ------------------------- execution -------------------------------
    def _execute(self, batch: PackedBatch, now: float,
                 width: int | None = None) -> float:
        """Dispatch one packed batch padded to ``width`` slots.

        ``width=None`` picks the smallest already-warm bucket that fits
        the real requests (falling back to max_batch), so a partial
        batch only pays for the nearest warmed shape, never a retrace.
        """
        compiled = self.programs[batch.program_id]
        sig = self._signatures[batch.program_id]
        if width is None:
            fits = [w for w in self.plan_cache.warm_widths(sig)
                    if w >= len(batch.requests)]
            B = min(fits) if fits else self.batcher.max_batch
        else:
            B = width
        hit = self.plan_cache.admit(sig, B)
        reqs = batch.requests
        pad = B - len(reqs)
        stacked = {
            tag: ([r.inputs[tag] for r in reqs]
                  + [reqs[-1].inputs[tag]] * pad)
            for tag in compiled.inputs
        }
        with self.registry.lease(batch.tenant):
            t0 = time.perf_counter()
            res = self.executor.run_batched(compiled, stacked)
            for cts in res.outputs.values():
                cts[0].c0.block_until_ready()
            dt = time.perf_counter() - t0
        if self.keep_outputs:
            for j, r in enumerate(reqs):
                self.outputs[r.rid] = {tag: cts[j] for tag, cts
                                       in res.outputs.items()}
        self.records.append(BatchRecord(
            start_s=now, duration_s=dt, tenant=batch.tenant,
            program_id=batch.program_id, n_real=len(reqs), batch=B,
            plan_hit=hit, rids=[r.rid for r in reqs],
        ))
        return dt

    def _complete(self, batch: PackedBatch, now: float) -> None:
        for r in batch.requests:
            self._stats(r.tenant).record(now - r.arrival)

    # ------------------------- serving loops ---------------------------
    def run_trace(self, trace: list[Arrival], inputs_for) -> ServingReport:
        """Serve an open-loop arrival trace to completion.

        ``inputs_for(arrival) -> {tag: Ciphertext}`` materializes each
        request's ciphertexts; it runs under the tenant's key lease (so
        ``ctx.encrypt`` uses the right secret) and OFF the virtual
        clock — encryption is client-side work, not server time.
        """
        arr = sorted(trace, key=lambda a: a.t)
        i, now = 0, 0.0
        while True:
            while i < len(arr) and arr[i].t <= now:
                a = arr[i]
                with self.registry.lease(a.tenant):
                    inputs = inputs_for(a)
                self.submit(a.tenant, a.program_id, inputs, a.t)
                i += 1
            drain = i >= len(arr)
            batch = self.batcher.pick(self.queue, now, drain=drain)
            if batch is None:
                if drain and not self.queue:
                    break
                targets = [arr[i].t] if i < len(arr) else []
                flush = self.batcher.next_flush_time(self.queue)
                if flush is not None:
                    targets.append(flush)
                now = max(now, min(targets))
                continue
            now += self._execute(batch, now)
            self._complete(batch, now)
        return self.report(span_s=now)

    def run_serial(self, trace: list[Arrival], inputs_for) -> ServingReport:
        """Baseline: the same trace, one request at a time (no packing),
        strict arrival order, on the same virtual clock."""
        arr = sorted(trace, key=lambda a: a.t)
        now = 0.0
        for a in arr:
            with self.registry.lease(a.tenant):
                inputs = inputs_for(a)
            req = self.queue.offer(a.tenant, a.program_id, inputs, a.t)
            if req is None:
                self._stats(a.tenant).rejected += 1
                continue
            now = max(now, a.t)
            batch = PackedBatch((a.tenant, a.program_id), [req])
            self.queue.take([req])
            now += self._execute(batch, now, width=1)
            self._complete(batch, now)
        return self.report(span_s=now)

    # ------------------------- reporting -------------------------------
    def report(self, span_s: float) -> ServingReport:
        lat_all = [v for s in self._tenants.values() for v in s.latencies]
        depths = self.queue.depth_samples
        occ = ([r.n_real / r.batch for r in self.records]
               if self.records else [])
        return ServingReport(
            span_s=span_s,
            completed=sum(s.completed for s in self._tenants.values()),
            rejected=sum(s.rejected for s in self._tenants.values()),
            batches=len(self.records),
            batch_occupancy=(sum(occ) / len(occ)) if occ else 0.0,
            plan_cache=self.plan_cache.stats(),
            registry=self.registry.stats(),
            queue={
                "maxsize": self.queue.maxsize,
                "max_depth": max(depths) if depths else 0,
                "mean_depth": (sum(depths) / len(depths)) if depths
                              else 0.0,
                "rejected": self.queue.rejected,
            },
            tenants={t: s.summary(span_s)
                     for t, s in sorted(self._tenants.items())},
            latencies_s=lat_all,
        )
