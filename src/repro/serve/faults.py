"""Deterministic fault injection for the serving layer.

Chaos testing an FHE server has one special requirement: **replay**.  A
fault schedule that depends on wall-clock timing or global RNG state
cannot be bisected when a recovery path regresses.  So faults here are
a pure function of ``(plan seed, dispatch index)``:

    rng = np.random.default_rng([seed, dispatch_index])

Each dispatch gets its own independent generator, and every fault kind
consumes a fixed draw from it — the schedule is identical no matter how
many retries, bisect splits, or reorderings happen in between (those
*shift* later dispatch indices, which is exactly the point: the
recovery machinery's own dispatches roll fresh dice, deterministically).

Fault kinds, mirroring the error taxonomy:

* **transient engine fault** — the dispatch raises
  :class:`TransientEngineError` before touching the engine (a lost
  device, a flaky interconnect).  Retryable; the server's
  backoff-retry path must absorb these.
* **key eviction mid-flight** — the tenant's keys are force-evicted
  from the registry and the dispatch raises
  :class:`KeyUnavailableError` (a key-store read failing under the
  running request).  Retryable because re-keygen is deterministic.
* **corrupted output limb** — one slot of the batch output gets a
  residue ``>= q`` (or NaN for float limbs) written into it after
  execution.  NOT an exception: this is the silent-corruption case the
  per-slot health checks exist to catch — exactly one request must
  fail, never a wrong answer, never a co-batched victim.
* **latency spike** — extra virtual seconds added to the dispatch's
  measured duration (GC pause, noisy neighbor).  No error; exercises
  deadline shedding and timeout accounting.

The injector wraps the server from the *outside* (the server calls
``before_dispatch`` / ``corrupt_outputs`` / ``extra_latency`` hooks);
engine, executor and registry code carry no fault-injection branches.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.errors import KeyUnavailableError, TransientEngineError


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Seeded chaos schedule: per-dispatch fault probabilities."""

    seed: int = 0
    p_transient: float = 0.0    # raise TransientEngineError pre-dispatch
    p_evict: float = 0.0        # force-evict keys + KeyUnavailableError
    p_corrupt: float = 0.0      # corrupt one output slot's limb
    p_spike: float = 0.0        # add spike_s to the dispatch duration
    spike_s: float = 0.05       # virtual seconds per latency spike

    def draws(self, idx: int) -> dict:
        """The fault decisions for dispatch ``idx`` — a pure function
        of ``(seed, idx)``; draw order is fixed so decisions for one
        fault kind never perturb another's."""
        rng = np.random.default_rng([self.seed, idx])
        u = rng.random(4)       # transient, evict, corrupt, spike
        slot = int(rng.integers(0, 2 ** 31))
        return {
            "transient": bool(u[0] < self.p_transient),
            "evict": bool(u[1] < self.p_evict),
            "corrupt": bool(u[2] < self.p_corrupt),
            "spike": bool(u[3] < self.p_spike),
            "slot": slot,       # corrupt-target selector (mod n_real)
        }


def _corrupt_limb(ct) -> None:
    """Write an out-of-range residue (or NaN) into limb 0, slot 0."""
    arr = ct.c0
    if jnp.issubdtype(arr.dtype, jnp.floating):
        bad = jnp.asarray(jnp.nan, dtype=arr.dtype)
    else:
        bad = jnp.asarray(jnp.iinfo(arr.dtype).max, dtype=arr.dtype)
    ct.c0 = arr.at[0, 0].set(bad)


class FaultInjector:
    """Applies a :class:`FaultPlan` to a server's dispatch stream.

    Pass an instance as ``FHEServer(..., faults=injector)``.  The
    ``injected`` counters record what actually fired, so tests and the
    chaos bench can assert the schedule against the recovery metrics.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.injected = {"transient": 0, "evict": 0,
                         "corrupt": 0, "spike": 0}

    # ---- hooks the server calls ---------------------------------------
    def before_dispatch(self, idx: int, server, tenant: str) -> None:
        """Pre-dispatch faults: may raise a retryable typed error."""
        d = self.plan.draws(idx)
        if d["transient"]:
            self.injected["transient"] += 1
            raise TransientEngineError(
                "injected engine fault",
                hint="retryable; the dispatch never ran",
                dispatch=idx)
        if d["evict"]:
            self.injected["evict"] += 1
            server.registry.evict(tenant, force=True)
            raise KeyUnavailableError(
                "injected key-store loss mid-flight",
                hint="retryable; re-keygen on the retry lease is "
                     "bit-identical from the tenant seed",
                tenant=tenant, dispatch=idx)

    def corrupt_outputs(self, idx: int, outputs, n_real: int) -> None:
        """Post-dispatch fault: silently corrupt ONE real slot's output
        ciphertext.  The server's per-slot health check must turn this
        into exactly one request failure — never a wrong result."""
        d = self.plan.draws(idx)
        if not d["corrupt"] or n_real <= 0 or not outputs:
            return
        self.injected["corrupt"] += 1
        j = d["slot"] % n_real
        tag = sorted(outputs)[0]
        _corrupt_limb(outputs[tag][j])

    def extra_latency(self, idx: int) -> float:
        """Virtual seconds to add to this dispatch's duration."""
        d = self.plan.draws(idx)
        if d["spike"]:
            self.injected["spike"] += 1
            return self.plan.spike_s
        return 0.0
