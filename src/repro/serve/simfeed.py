"""Replay served traffic onto the HE^2 hardware timelines.

The serving loop logs every executed batch as a ``BatchRecord``; this
module feeds the SAME traffic — same programs, same batch widths, same
launch order — into the event-driven group scheduler
(``repro.sim.schedule`` via ``sim.engine.simulate_blocks``), answering
"what would the paper's xPU/xMU hardware do with this arrival trace"
next to what the jnp engine actually did.  This closes the
long-standing "interleave multi-ciphertext batches on engine timelines"
follow-on: consecutive batches' keyswitch blocks stream back-to-back
through the 2*dnum pipeline groups, so cross-BATCH overlap is modeled
exactly like cross-block overlap inside one program.

Three numbers come back:

* ``pipelined_s``   — makespan of the full packed traffic on the HE^2
  timelines (cross-batch group streaming, the hardware analogue of
  continuous batching);
* ``serial_s``      — the same requests one at a time (batch width 1,
  a hard barrier between requests): the hardware analogue of the
  serial request loop;
* ``speedup``       — serial_s / pipelined_s, the scheduler-side
  counterpart of the measured throughput gate.

Per-engine utilization of the pipelined run is attached so the bench
can report how busy the modeled xPU/xMU/link/evk stream would be under
this traffic.
"""
from __future__ import annotations

from repro.runtime.compile import CompiledProgram
from repro.runtime.report import program_blocks
from repro.sim.engine import simulate_blocks
from repro.sim.hw import HWConfig
from repro.sim.schedule import ENGINES


def replay_on_hardware(records, programs: dict[str, CompiledProgram],
                       hw: HWConfig, with_result: bool = False):
    """Simulate a serving run's batch log on the HE^2 hardware model.

    ``records``: the server's ``BatchRecord`` list (launch order);
    ``programs``: program_id -> compiled program (the server's table).
    ``with_result=True`` returns ``(summary, SimResult)`` so callers can
    reach the pipelined run's engine timelines — the stall-budget gate
    and the Perfetto exporter (``repro.obs``) both consume them.
    """
    ordered = sorted(records, key=lambda r: r.start_s)
    packed = []
    n_requests = 0
    wasted = 0          # dispatches that errored (chaos runs): the
    for rec in ordered:  # hardware still burned their blocks' time, but
        # the requests only count toward goodput on their ok dispatch
        # scale by the requests actually served, not the padded jit
        # width: hardware packs per ciphertext and has no retrace-shape
        # constraint, so padding is an engine artifact the model skips
        packed.extend(program_blocks(programs[rec.program_id],
                                     rec.n_real))
        if getattr(rec, "ok", True):
            n_requests += rec.n_real
        else:
            wasted += 1
    pipe = simulate_blocks(packed, hw, name="serving", mode="pipelined")

    # hardware analogue of the serial loop: every real request alone,
    # a hard barrier between requests (no cross-request streaming)
    serial_s = 0.0
    for rec in ordered:
        blocks = program_blocks(programs[rec.program_id], 1)
        one = simulate_blocks(blocks, hw, name="serving-serial",
                              mode="pipelined")
        serial_s += one.latency_s * rec.n_real
    summary = {
        "hw": hw.name,
        "batches": len(ordered),
        "requests": n_requests,
        "wasted_dispatches": wasted,
        "pipelined_s": pipe.latency_s,
        "serial_s": serial_s,
        "speedup": (serial_s / pipe.latency_s) if pipe.latency_s else 0.0,
        "throughput_ops": (n_requests / pipe.latency_s
                           if pipe.latency_s else 0.0),
        "utilization": {e: pipe.engine_util(e) for e in ENGINES},
        "energy_j": pipe.energy_j,
        "comm_stall_s": pipe.comm_stall_s,
        "comm_stall_frac": pipe.comm_stall_frac,
    }
    if with_result:
        return summary, pipe
    return summary
