"""Open-loop arrival generation for the serving layer.

Production FHE traffic (the ROADMAP's millions-of-users north star) is
an *open loop*: requests arrive on their own schedule regardless of
whether the server has finished the previous ones — the load regime
where continuous batching wins and a serial request loop collapses.
The generator here is a seeded Poisson process: exponential
inter-arrival gaps at ``rate_rps``, each arrival stamped with a tenant
and a program id drawn from (optionally weighted) mixes, so deep
(Chebyshev/bootstrap-shaped) and shallow (matvec) programs interleave
the way FLASH-FHE argues real deployments do.

Determinism matters twice: the benchmark gate replays the same trace
through the continuous-batching and serial baselines, and the simulator
half (``repro.serve.simfeed``) replays the very same arrivals onto the
``sim.schedule`` timelines.  Everything is derived from the single
``seed`` argument (plumbed from ``benchmarks.run --seed``).
"""
from __future__ import annotations

import dataclasses

import numpy as np


def workload_request_programs(models, params, btp=None,
                              input_level: int | None = None,
                              fusion: bool = False, exact: bool = True):
    """Compile inference workloads into servable request programs.

    The server dispatches ONE :class:`~repro.runtime.CompiledProgram`
    per request, so a single-segment workload (no bootstrap inserted)
    maps 1:1 — its program id is the workload name and its tags are the
    trace tags (``"x"`` in, ``"y"`` out).  A bootstrap-inserted
    workload publishes one program per segment (``"name/0"``,
    ``"name/1"``, ...); a client or gateway chains them by feeding each
    segment's output into the next segment's input tag — the ids stay
    stable so every hop still rides plan-cache admission.

    Returns ``(programs, chains)``: ``programs`` maps program id to
    CompiledProgram (feed to ``FHEServer.register_program``);
    ``chains`` maps each workload name to its ordered hop list of
    ``(program_id, in_tag, out_tag)``.
    """
    from repro.workloads import compile_workload

    programs, chains = {}, {}
    for model in models:
        wp = compile_workload(model, params, btp=btp,
                              input_level=input_level, fusion=fusion,
                              exact=exact)
        if len(wp.segments) == 1:
            seg = wp.segments[0]
            programs[model.name] = seg.compiled
            chains[model.name] = [(model.name, seg.in_tag, seg.out_tag)]
        else:
            hops = []
            for i, seg in enumerate(wp.segments):
                pid = f"{model.name}/{i}"
                programs[pid] = seg.compiled
                hops.append((pid, seg.in_tag, seg.out_tag))
            chains[model.name] = hops
    return programs, chains


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One open-loop arrival: WHO asks for WHAT and WHEN (seconds)."""

    t: float
    tenant: str
    program_id: str


def _probs(names: list[str],
           weights: dict[str, float] | None) -> np.ndarray | None:
    if not weights:
        return None
    p = np.array([float(weights.get(n, 0.0)) for n in names])
    if p.sum() <= 0:
        raise ValueError("weights must have positive mass on the names")
    return p / p.sum()


def poisson_trace(rate_rps: float, n: int, tenants: list[str],
                  programs: list[str], seed: int = 0,
                  tenant_weights: dict[str, float] | None = None,
                  program_weights: dict[str, float] | None = None,
                  ) -> list[Arrival]:
    """``n`` Poisson arrivals at ``rate_rps`` requests/second.

    Inter-arrival gaps are iid Exponential(1/rate); tenant and program
    of each arrival are drawn independently from the (optionally
    weighted) name lists.  Fully determined by ``seed``.
    """
    if rate_rps <= 0:
        raise ValueError("rate_rps must be positive")
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_rps, size=n)
    times = np.cumsum(gaps)
    t_idx = rng.choice(len(tenants), size=n,
                       p=_probs(tenants, tenant_weights))
    p_idx = rng.choice(len(programs), size=n,
                       p=_probs(programs, program_weights))
    return [
        Arrival(float(times[i]), tenants[int(t_idx[i])],
                programs[int(p_idx[i])])
        for i in range(n)
    ]
