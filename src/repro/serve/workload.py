"""Open-loop arrival generation for the serving layer.

Production FHE traffic (the ROADMAP's millions-of-users north star) is
an *open loop*: requests arrive on their own schedule regardless of
whether the server has finished the previous ones — the load regime
where continuous batching wins and a serial request loop collapses.
The generator here is a seeded Poisson process: exponential
inter-arrival gaps at ``rate_rps``, each arrival stamped with a tenant
and a program id drawn from (optionally weighted) mixes, so deep
(Chebyshev/bootstrap-shaped) and shallow (matvec) programs interleave
the way FLASH-FHE argues real deployments do.

Determinism matters twice: the benchmark gate replays the same trace
through the continuous-batching and serial baselines, and the simulator
half (``repro.serve.simfeed``) replays the very same arrivals onto the
``sim.schedule`` timelines.  Everything is derived from the single
``seed`` argument (plumbed from ``benchmarks.run --seed``).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One open-loop arrival: WHO asks for WHAT and WHEN (seconds)."""

    t: float
    tenant: str
    program_id: str


def _probs(names: list[str],
           weights: dict[str, float] | None) -> np.ndarray | None:
    if not weights:
        return None
    p = np.array([float(weights.get(n, 0.0)) for n in names])
    if p.sum() <= 0:
        raise ValueError("weights must have positive mass on the names")
    return p / p.sum()


def poisson_trace(rate_rps: float, n: int, tenants: list[str],
                  programs: list[str], seed: int = 0,
                  tenant_weights: dict[str, float] | None = None,
                  program_weights: dict[str, float] | None = None,
                  ) -> list[Arrival]:
    """``n`` Poisson arrivals at ``rate_rps`` requests/second.

    Inter-arrival gaps are iid Exponential(1/rate); tenant and program
    of each arrival are drawn independently from the (optionally
    weighted) name lists.  Fully determined by ``seed``.
    """
    if rate_rps <= 0:
        raise ValueError("rate_rps must be positive")
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_rps, size=n)
    times = np.cumsum(gaps)
    t_idx = rng.choice(len(tenants), size=n,
                       p=_probs(tenants, tenant_weights))
    p_idx = rng.choice(len(programs), size=n,
                       p=_probs(programs, program_weights))
    return [
        Arrival(float(times[i]), tenants[int(t_idx[i])],
                programs[int(p_idx[i])])
        for i in range(n)
    ]
