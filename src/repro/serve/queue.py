"""Bounded FIFO request queue with per-(tenant, program) group views.

The queue is the server's only admission point: ``offer`` either
accepts a request (global FIFO order, stamped with a monotone id) or
rejects it when the bound is reached — bounded-queue *backpressure*, so
an open-loop arrival burst cannot grow server memory without limit.
Rejections are the caller's to count (``FHEServer`` reports them as
``rejected`` per tenant).

Fairness model: requests keep their global arrival order, and the
continuous-batching scheduler always serves the *group* — a
``(tenant, program_id)`` batch class — whose HEAD request is oldest.
Within a group requests are packed strictly FIFO.  Together this gives
per-tenant FIFO (a tenant's own requests complete in submission order)
and no group starvation (a group's head request ages until it is the
oldest head and must be picked next).
"""
from __future__ import annotations

import dataclasses

from repro.core.ckks import Ciphertext
from repro.errors import ConfigError

# A batch class: requests sharing (tenant, program) can vmap together —
# same compiled plan AND same evk set (keys are per-tenant).
GroupKey = tuple[str, str]


@dataclasses.dataclass
class Request:
    """One in-flight job: ``(tenant, program_id, ct inputs)``.

    ``deadline`` is an absolute virtual-clock time: past it the server
    sheds the request (``RequestTimeout``) instead of executing it.
    ``validate`` opts this request into the executor's per-step
    invariant checker (ciphertext health guards); a batch validates if
    ANY member requests it.
    """

    rid: int
    tenant: str
    program_id: str
    inputs: dict[str, Ciphertext]
    arrival: float                  # virtual-clock submission time (s)
    deadline: float | None = None   # absolute virtual-clock cutoff (s)
    validate: bool = False          # opt-in invariant checking

    @property
    def group(self) -> GroupKey:
        return (self.tenant, self.program_id)


class RequestQueue:
    """Bounded FIFO of :class:`Request` with group (batch-class) views."""

    def __init__(self, maxsize: int = 256):
        if maxsize <= 0:
            raise ConfigError("queue maxsize must be positive",
                              hint="pick a bound; backpressure needs one",
                              maxsize=maxsize)
        self.maxsize = maxsize
        self._items: list[Request] = []
        self._next_rid = 0
        self.rejected = 0
        self.depth_samples: list[int] = []

    def __len__(self) -> int:
        return len(self._items)

    @property
    def depth(self) -> int:
        return len(self._items)

    def offer(self, tenant: str, program_id: str,
              inputs: dict[str, Ciphertext], arrival: float,
              deadline: float | None = None,
              validate: bool = False) -> Request | None:
        """Admit a request, or return None (backpressure) when full."""
        if len(self._items) >= self.maxsize:
            self.rejected += 1
            return None
        req = Request(self._next_rid, tenant, program_id, inputs, arrival,
                      deadline=deadline, validate=validate)
        self._next_rid += 1
        self._items.append(req)
        self.depth_samples.append(len(self._items))
        return req

    # ------------------------- group views -----------------------------
    def groups(self) -> dict[GroupKey, list[Request]]:
        """Queued requests per batch class, FIFO order preserved."""
        out: dict[GroupKey, list[Request]] = {}
        for r in self._items:
            out.setdefault(r.group, []).append(r)
        return out

    def oldest(self) -> Request | None:
        return self._items[0] if self._items else None

    def take(self, reqs: list[Request]) -> None:
        """Remove a packed batch from the queue."""
        gone = {r.rid for r in reqs}
        self._items = [r for r in self._items if r.rid not in gone]
