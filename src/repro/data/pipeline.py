"""Token data pipeline: deterministic, shardable, restartable.

Synthetic corpus by default (structured enough that a small LM's loss
visibly decreases); file-backed mode memory-maps a token array.  The
iterator state is one integer (step) — checkpoint/resume is exact, and
elastic restarts with a different data-parallel size re-derive shards.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class PipelineConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    path: str | None = None      # memory-mapped token file (int32)


class TokenPipeline:
    def __init__(self, cfg: PipelineConfig):
        self.cfg = cfg
        self._tokens = None
        if cfg.path:
            self._tokens = np.memmap(cfg.path, dtype=np.int32, mode="r")

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        """Deterministic batch for a global step (restart-exact)."""
        c = self.cfg
        if self._tokens is not None:
            n = len(self._tokens) - c.seq_len - 1
            rng = np.random.default_rng(c.seed + step)
            starts = rng.integers(0, n, c.global_batch)
            toks = np.stack([
                self._tokens[s : s + c.seq_len + 1] for s in starts
            ])
        else:
            toks = self._synthetic(step)
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }

    def _synthetic(self, step: int) -> np.ndarray:
        """Structured synthetic stream: arithmetic token sequences with
        noise — learnable next-token structure."""
        c = self.cfg
        rng = np.random.default_rng(c.seed + step)
        B, S = c.global_batch, c.seq_len + 1
        start = rng.integers(0, c.vocab, (B, 1))
        stride = rng.integers(1, 7, (B, 1))
        seq = (start + stride * np.arange(S)[None, :]) % c.vocab
        noise = rng.random((B, S)) < 0.05
        seq = np.where(noise, rng.integers(0, c.vocab, (B, S)), seq)
        return seq
