"""Reproduce the paper's headline numbers (Table IV) from the simulator.

Run: PYTHONPATH=src python examples/fhe_table4.py
"""
import sys
sys.path.insert(0, ".")
from benchmarks.common import run_stack, PAPER_LATENCY_MS, area_of  # noqa: E402


def main():
    for bench in ["bootstrapping", "helr", "resnet20", "resnet56"]:
        rows = run_stack(bench)
        print(f"--- {bench} ---")
        for name in ("SHARP", "HE2-SM", "HE2-LM"):
            r = rows[name]
            print(f"  {name:8s} {r.latency_s*1e3:8.2f} ms "
                  f"(paper {PAPER_LATENCY_MS[bench][name]}) "
                  f"EDP {r.edp:.3f} EDAP {r.edap(area_of(name)):.1f}")
        print(f"  speedup LM {rows['SHARP'].latency_s/rows['HE2-LM'].latency_s:.2f}x"
              f" | comm stall {rows['HE2-LM'].comm_stall_frac*100:.1f}%")


if __name__ == "__main__":
    main()
