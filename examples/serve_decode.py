"""Batched serving example: prefill + KV-cache decode on a reduced config.

Run: PYTHONPATH=src python examples/serve_decode.py --arch phi3_medium_14b
"""
import argparse

from repro.launch.serve import generate
from repro.configs import reduced_config
from repro.models.model import init_params
import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3_medium_14b")
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()
    cfg = reduced_config(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (2, 8)).astype(np.int32)
    out = generate(cfg, params, prompts, args.gen)
    print(f"{cfg.name}: generated {out.shape} tokens; "
          f"first row: {out[0, :16].tolist()}...")


if __name__ == "__main__":
    main()
