"""Quickstart: the paper's pipeline end-to-end on a small ring.

1. encrypt a vector, run a hoisted rotation-block (one ModUp, one ModDown)
2. compile the SAME program through the DFG runtime: trace -> PKB
   identification -> fusion -> execution with fewer ModUps, batched over
   independent ciphertexts via one vmapped jit trace
3. apply HERO: identify PKBs in a ConvBN program, fuse them (Eq. 4)
4. simulate SHARP vs HE2 on the bootstrapping benchmark (Table IV row)
5. compile the real bootstrap pipeline (ModRaise -> C2S -> EvalMod ->
   S2C) through the runtime on a tiny ring: bit-exact, fewer ModUps

Run: PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import linear
from repro.core.bootstrap import Bootstrapper
from repro.core.params import CKKSParams
from repro.core.ckks import CKKSContext
from repro.dfg.fusion import optimal_fusion
from repro.dfg.pkb import identify_pkbs
from repro.dfg.programs import bootstrapping_dfg, convbn_example
from repro.runtime import ProgramExecutor, TraceContext, compile_program
from repro.sim import HE2_LM, SHARP
from repro.sim.engine import simulate_program


def main():
    # --- 1. functional CKKS with hoisting --------------------------------
    params = CKKSParams(logN=9, L=5, alpha=2, k=3, q_bits=29, scale_bits=29)
    ctx = CKKSContext(params, seed=1)
    nh = params.num_slots
    rng = np.random.default_rng(0)
    z = rng.normal(size=nh)
    ct = ctx.encrypt(z)
    steps = [1, 2, 4]
    ptvals = [rng.normal(size=nh) for _ in steps]
    pts = [ctx.encode(v) for v in ptvals]
    out = ctx.hoisted_rotation_sum(ct, steps, pts)   # ONE ModUp, ONE ModDown
    expect = sum(np.roll(z, -s) * v for s, v in zip(steps, ptvals))
    err = np.abs(ctx.decrypt(out) - expect).max()
    print(f"[1] hoisted rotation-sum: max err {err:.2e} "
          f"(1 ModUp + 1 ModDown for {len(steps)} rotations)")

    # --- 2. the compiled runtime on a BSGS matvec -------------------------
    diags = {d: rng.normal(size=nh) for d in range(8)}
    tc = TraceContext(params)
    h = tc.input("x", level=params.L, scale=params.scale)
    tc.output(linear.matvec_bsgs(tc, h, diags, bs=4), "y")  # same source!
    ex = ProgramExecutor(ctx)

    def modups(fn):
        s = ctx.counters.snapshot()
        r = fn()
        return r, ctx.counters.delta(s).modup

    eager, m_eager = modups(lambda: linear.matvec_bsgs(ctx, ct, diags, bs=4))
    compiled = compile_program(tc)                  # bit-exact with eager
    run, m_comp = modups(lambda: ex.run(compiled, {"x": ct}, True))
    fused = compile_program(tc, fusion=True)        # HERO Eq. (4) rewrite
    _, m_fused = modups(lambda: ex.run(fused, {"x": ct}))
    bitexact = np.array_equal(np.asarray(run["y"].c0), np.asarray(eager.c0))
    print(f"[2] compiled BSGS matvec: bit-exact={bitexact}; ModUps "
          f"eager={m_eager} compiled={m_comp} fused={m_fused}; "
          f"reconciled={run.report.reconcile()['counts_match']}")
    batch = [ctx.encrypt(rng.normal(size=nh)) for _ in range(4)]
    outs = ex.run_batched(compiled, {"x": batch})["y"]  # ONE vmapped trace
    print(f"    batched {len(outs)} cts through one jit trace per plan")

    # --- 3. HERO on the Fig. 9 ConvBN case study --------------------------
    g = convbn_example().g
    pkbs = identify_pkbs(g)
    print(f"[3] ConvBN PKBs: {[p.n_rot for p in pkbs]} rotations "
          f"(in/out degree {[(p.indeg, p.outdeg) for p in pkbs]})")
    plan = optimal_fusion(pkbs, k=12, alpha=12, nh=1 << 15,
                          capacity_words=8e9 / 8)
    print(f"    HERO fuses groups {plan.groups}, saving "
          f"{plan.score*1e6:.0f} us/block; fused evk set: "
          f"{len(set(plan.fused[0].steps))} keys")

    # --- 4. simulator: SHARP vs HE2 on bootstrapping ----------------------
    sharp = simulate_program(bootstrapping_dfg(bsgs_bs=4).g, SHARP,
                             "minks", "EVF")
    he2 = simulate_program(bootstrapping_dfg(bsgs_bs=0).g, HE2_LM,
                           "hoist", "hybrid", fusion=True)
    print(f"[4] bootstrapping: SHARP {sharp.latency_s*1e3:.2f} ms vs "
          f"HE2-LM {he2.latency_s*1e3:.2f} ms -> "
          f"{sharp.latency_s/he2.latency_s:.2f}x speedup "
          f"(paper: 1.66x); comm stalls {he2.comm_stall_frac*100:.1f}%")

    # --- 5. the COMPILED bootstrap on a tiny ring --------------------------
    bp = CKKSParams(logN=6, L=19, alpha=4, k=4, q_bits=29, scale_bits=29,
                    q0_bits=30)
    bctx = CKKSContext(bp, seed=7, hamming_weight=8)
    btp = Bootstrapper(bctx, n_groups=2, mod_K=3, cheb_degree=27)
    zb = (np.random.default_rng(1).normal(size=bp.num_slots)
          + 1j * np.random.default_rng(2).normal(size=bp.num_slots)) * 0.01
    ct0 = bctx.encrypt(zb, level=0)
    bex = ProgramExecutor(bctx)

    def boot_modups(fn):
        s = bctx.counters.snapshot()
        r = fn()
        return r, bctx.counters.delta(s).modup

    out_e, m_eager = boot_modups(lambda: btp.bootstrap(ct0))
    compiled_b = btp.compile(input_scale=ct0.scale)   # same source, traced
    out_c, m_comp = boot_modups(
        lambda: bex.run(compiled_b, {"ct": ct0})["out"])
    bitexact = np.array_equal(np.asarray(out_c.c0), np.asarray(out_e.c0))
    err = np.abs(bctx.decrypt(out_c) - zb).max()
    print(f"[5] compiled bootstrap (logN=6): bit-exact={bitexact}; "
          f"ModUps eager={m_eager} compiled={m_comp}; "
          f"levels 0 -> {out_c.level}; max err {err:.1e}")


if __name__ == "__main__":
    main()
