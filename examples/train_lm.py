"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
checkpointing + resume (deliverable (b)).

Default runs a CPU-sized reduced model; pass --large for a ~100M config
(slow on CPU — the shape the driver is designed for).

Run: PYTHONPATH=src python examples/train_lm.py [--large] [--steps 200]
"""
import argparse
import dataclasses

import jax
import numpy as np

from repro.configs import get_config, reduced_config
from repro.data.pipeline import PipelineConfig, TokenPipeline
from repro.models.model import init_params
from repro.train.optimizer import AdamW
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--large", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()

    if args.large:
        # ~100M params: stablelm family scaled down
        cfg = dataclasses.replace(
            get_config("stablelm_3b"), n_layers=8, d_model=768,
            n_heads=12, n_kv_heads=12, d_ff=2048, vocab=32768)
        seq, batch = 512, 8
    else:
        cfg = reduced_config("stablelm_3b")
        seq, batch = 64, 8
    print(f"model: {cfg.n_params()/1e6:.1f}M params")

    params = init_params(cfg, jax.random.PRNGKey(0))
    pipe = TokenPipeline(PipelineConfig(vocab=cfg.vocab, seq_len=seq,
                                        global_batch=batch))
    tr = Trainer(cfg, TrainerConfig(total_steps=args.steps, ckpt_every=50,
                                    ckpt_dir="/tmp/repro_train_lm",
                                    log_every=20),
                 AdamW(lr=1e-3, warmup_steps=20))
    _, _, losses = tr.run(params, pipe, resume=True)
    print(f"loss: {np.mean(losses[:10]):.3f} -> {np.mean(losses[-10:]):.3f}")


if __name__ == "__main__":
    main()
