"""Multi-tenant serving benchmark: continuous batching vs serial loop.

An open-loop Poisson trace of ``(tenant, program_id)`` jobs — a BSGS
Chebyshev evaluation and a BSGS matvec, two distinct plan shapes across
three tenants — is served twice on the same virtual clock:

  serial      — every request executes alone, strict arrival order
                (batch slots = 1): the one-request-at-a-time service
  continuous  — the ``repro.serve`` scheduler packs same-(tenant,
                program) requests into padded ``run_batched`` dispatches
                (max-batch/max-wait), per-tenant keys on ONE shared
                engine, zero retraces after warmup

Writes BENCH_serving.json: aggregate + per-tenant throughput and
p50/p99 latency for both loops, batch occupancy, plan-cache and
registry stats, and the ``serve.simfeed`` replay of the SAME batch log
onto the HE^2-SM hardware timelines (what the paper's scheduler would
do with this traffic).

ENFORCED gates: continuous batching must (a) beat the serial loop by
>= 2x in completed-requests throughput on the virtual clock,
(b) retrace NOTHING — the engine's jit ``trace_counts`` must be flat
across the whole served trace — and (c) keep the hardware replay's
communication-stall fraction within the calibrated per-shape budget
(``STALL_BUDGET``; unrecorded shapes record the fraction and skip the
gate).

``--trace`` (``benchmarks.common.TRACE``) reruns a short prefix of the
trace under ``repro.obs`` span tracing — AFTER the gated runs, so the
per-dispatch instrumentation never perturbs the measured speedup — and
writes results/trace_serving.json: real serve-loop spans, the virtual-
clock request lanes, and the HE2-SM replay timelines in one Perfetto
file.

``--chaos`` (``benchmarks.common.CHAOS``) reruns the continuous loop
under a seeded ``serve.faults.FaultPlan`` (5% transient engine faults
plus key evictions, output corruption and latency spikes, all derived
from ``common.SEED``) with per-request validation on, and gates on
recovery: every request terminally accounted, no co-batched victim
failures (quarantine bisect isolates poison), goodput >= 0.8x the
fault-free run, and zero added retraces.  The chaos report merges into
BENCH_serving.json under the ``"chaos"`` key.
"""
from __future__ import annotations

import json
import pathlib
import time

import numpy as np

from benchmarks import common

RESULTS = pathlib.Path(__file__).parent / "results"

# Perf regression gate (CI): continuous batching vs serial request loop.
GATE_SERVING_SPEEDUP = 2.0

# Chaos gate (CI, --chaos): goodput under the fault schedule must stay
# within this fraction of the fault-free run's throughput.
GATE_CHAOS_GOODPUT = 0.8

# Communication-stall budget for the HE2-SM replay of the continuous
# run's batch log, keyed by common.SMOKE (same convention as
# bench_bootstrap.STALL_BUDGET): the smoke programs (logN=8) are
# link-bound and sit near 0.40, so this is a calibrated regression
# bound on the scheduler/replay path, not the paper's large-N 6.67%
# claim (recorded alongside in the JSON for reference).
STALL_BUDGET = {True: 0.45}           # keyed by common.SMOKE

TENANTS = ["alice", "bob", "carol"]


def _params(logn: int):
    from repro.core.params import CKKSParams

    return CKKSParams(logN=logn, L=9, alpha=2, k=3, q_bits=29,
                      scale_bits=29)


def _programs(params):
    from repro.core import linear
    from repro.core.polyeval import chebyshev_coeffs, eval_chebyshev_bsgs
    from repro.runtime import TraceContext, compile_program

    nh = params.num_slots
    rng = np.random.default_rng(common.SEED + 1)
    coeffs = chebyshev_coeffs(
        lambda t: np.sin(2 * np.pi * 1.5 * t) / (2 * np.pi), 15)
    diags = {d: rng.normal(size=nh) for d in range(8)}

    tc = TraceContext(params)
    h = tc.input("x", level=params.L, scale=params.scale)
    tc.output(eval_chebyshev_bsgs(tc, h, coeffs), "y")
    cheb = compile_program(tc)

    tc = TraceContext(params)
    h = tc.input("x", level=params.L, scale=params.scale)
    tc.output(linear.matvec_bsgs(tc, h, diags, bs=4), "y")
    matvec = compile_program(tc)
    return {"cheb": cheb, "matvec": matvec}


def _serve(ctx, programs, trace, max_batch: int, serial: bool,
           faults=None, validate: bool = False):
    """One serving run on a fresh server (shared ctx/registry keys)."""
    from repro.serve import FHEServer

    server = FHEServer(ctx, max_batch=max_batch, max_wait_s=0.15,
                       keep_outputs=False, faults=faults, max_retries=4)
    for pid, comp in programs.items():
        server.register_program(pid, comp)
    nh = ctx.params.num_slots
    # tenant-enrollment warmup: per-tenant keygen, evk device upload,
    # and jit tracing happen when a tenant registers, not per request —
    # warm batches per (tenant, program) class pay all of it off the
    # measured clock, so BOTH loops serve steady-state traffic.  The
    # continuous loop warms every power-of-two bucket once (partial
    # batches then pad to the nearest warm width, not to max_batch).
    widths = [1] if serial else \
        [w for w in (1, 2, 4, 8, 16) if w <= max_batch]
    for ti, t in enumerate(sorted({a.tenant for a in trace})):
        with server.registry.lease(t):
            ct0 = ctx.encrypt(np.zeros(nh))
        for pid in programs:
            # jit traces are tenant-agnostic: only the first tenant
            # walks every bucket, the rest just fill their evk caches
            for w in (widths if ti == 0 else widths[-1:]):
                server.warmup(t, pid, {"x": ct0}, width=w)

    rng = np.random.default_rng(common.SEED + 2)

    def inputs_for(a):
        return {"x": ctx.encrypt(rng.uniform(-1, 1, nh))}

    before = dict(ctx.engine.trace_counts)     # post-warmup snapshot
    t0 = time.perf_counter()
    if serial:
        rep = server.run_serial(trace, inputs_for, validate=validate)
    else:
        rep = server.run_trace(trace, inputs_for, validate=validate)
    wall = time.perf_counter() - t0
    after = dict(ctx.engine.trace_counts)
    retraces = (sum(after.values()) - sum(before.values()))
    return server, rep, wall, retraces


def _run_chaos() -> list[str]:
    """Chaos-mode serving run (``--chaos``): seeded fault schedule,
    recovery gates, results merged under BENCH_serving.json["chaos"]."""
    from repro.core.ckks import CKKSContext
    from repro.serve import FaultInjector, FaultPlan, poisson_trace

    RESULTS.mkdir(exist_ok=True)
    logn = 8 if common.SMOKE else 9
    n_req = 64 if common.SMOKE else 96
    max_batch = 8
    rate = 200.0

    params = _params(logn)
    ctx = CKKSContext(params, seed=3 + common.SEED)
    programs = _programs(params)
    trace = poisson_trace(rate, n_req, TENANTS, list(programs),
                          seed=common.SEED,
                          program_weights={"cheb": 0.75, "matvec": 0.25})

    # fault-free reference: same trace, same engine, no injection.
    # Validation stays ON here too — the invariant checker's device
    # syncs are a real serving cost both runs pay, so the goodput
    # ratio isolates the FAULTS' overhead (retries, backoff, spikes),
    # not the checker's.
    _, rep_clean, _, _ = _serve(ctx, programs, trace, max_batch,
                                serial=False, validate=True)
    tput_clean = rep_clean.completed / rep_clean.span_s

    # 5% transient-fault schedule + evictions/corruption/spikes,
    # all derived from the shared bench seed; validation ON for every
    # request so the invariant checker rides the whole chaos run
    plan = FaultPlan(seed=common.SEED, p_transient=0.05, p_evict=0.02,
                     p_corrupt=0.01, p_spike=0.02, spike_s=0.05)
    faults = FaultInjector(plan)
    srv, rep, wall, retraces = _serve(ctx, programs, trace, max_batch,
                                      serial=False, faults=faults,
                                      validate=True)
    goodput = rep.completed / rep.span_s if rep.span_s else 0.0
    ratio = goodput / tput_clean if tput_clean else 0.0
    unaccounted = rep.submitted - rep.accounted

    # victim check: a failed request whose FINAL dispatch was a failing
    # multi-request batch means quarantine bisect did not isolate it
    last_rec = {}
    for r in srv.records:
        for rid in r.rids:
            last_rec[rid] = r
    victims = sorted(
        rid for rid, o in srv.outcomes.items()
        if o.startswith("failed:")
        and not last_rec[rid].ok and last_rec[rid].n_real > 1)

    chaos = {
        "plan": {"seed": plan.seed, "p_transient": plan.p_transient,
                 "p_evict": plan.p_evict, "p_corrupt": plan.p_corrupt,
                 "p_spike": plan.p_spike, "spike_s": plan.spike_s},
        "injected": dict(faults.injected),
        "report": rep.to_dict(),
        "wall_s": wall,
        "goodput_ops": goodput,
        "fault_free_ops": tput_clean,
        "goodput_ratio": ratio,
        "unaccounted": unaccounted,
        "victims": victims,
        "live_retraces": retraces,
        "gate": {"min_goodput_ratio": GATE_CHAOS_GOODPUT,
                 "passed": (unaccounted == 0 and not victims
                            and ratio >= GATE_CHAOS_GOODPUT
                            and retraces == 0)},
    }
    path = RESULTS / "BENCH_serving.json"
    summary = json.loads(path.read_text()) if path.exists() else {}
    summary["chaos"] = chaos
    path.write_text(json.dumps(summary, indent=2))

    lines = [
        f"serving/chaos,{rep.span_s*1e6:.0f},"
        f"goodput={goodput:.1f}ops;ratio={ratio:.2f};"
        f"retries={rep.retries};failed={rep.failed};shed={rep.shed}",
        f"serving/chaos_injected,{sum(faults.injected.values())},"
        + ";".join(f"{k}={v}" for k, v in sorted(faults.injected.items())),
    ]
    if unaccounted != 0:
        raise RuntimeError(
            f"chaos accounting gate FAILED: {unaccounted} of "
            f"{rep.submitted} requests lack a terminal outcome")
    if victims:
        raise RuntimeError(
            f"chaos quarantine gate FAILED: co-batched victim failures "
            f"for rids {victims} (bisect must isolate the poison)")
    if retraces != 0:
        raise RuntimeError(
            f"chaos retrace gate FAILED: validation/chaos added "
            f"{retraces} jit retraces (must be 0)")
    if ratio < GATE_CHAOS_GOODPUT:
        raise RuntimeError(
            f"chaos goodput gate FAILED: {ratio:.2f}x < "
            f"{GATE_CHAOS_GOODPUT}x of the fault-free run")
    return lines


def run() -> list[str]:
    from repro import obs
    from repro.core.ckks import CKKSContext
    from repro.serve import poisson_trace, replay_on_hardware
    from repro.sim import HE2_SM

    if common.CHAOS:
        return _run_chaos()

    RESULTS.mkdir(exist_ok=True)
    logn = 8 if common.SMOKE else 9
    n_req = 64 if common.SMOKE else 96
    max_batch = 8
    rate = 200.0      # open-loop: arrivals far faster than service

    params = _params(logn)
    ctx = CKKSContext(params, seed=3 + common.SEED)
    programs = _programs(params)
    # Chebyshev-heavy mix: the deep mult chain amortizes best under
    # vmap, the rotation-heavy matvec keeps a second plan shape live
    trace = poisson_trace(rate, n_req, TENANTS, list(programs),
                          seed=common.SEED,
                          program_weights={"cheb": 0.75, "matvec": 0.25})

    common.log(f"serving: serial loop ({n_req} requests)")
    srv_serial, rep_serial, wall_serial, _ = _serve(
        ctx, programs, trace, max_batch, serial=True)

    common.log("serving: continuous-batching loop")
    srv_cont, rep_cont, wall_cont, live_retraces = _serve(
        ctx, programs, trace, max_batch, serial=False)
    warm_misses = rep_cont.plan_cache["misses"]

    tput_serial = rep_serial.completed / rep_serial.span_s
    tput_cont = rep_cont.completed / rep_cont.span_s
    speedup = tput_cont / tput_serial if tput_serial else 0.0

    common.log("serving: replaying batch log on HE2-SM timelines")
    replay, pipe = replay_on_hardware(srv_cont.records, programs,
                                      HE2_SM, with_result=True)

    # Communication-stall budget on the replayed HE2-SM timelines.
    sb_budget = STALL_BUDGET.get(common.SMOKE)
    stall = obs.analyze(pipe.timelines, latency_s=pipe.latency_s,
                        name="serving-he2sm-replay",
                        budget=(sb_budget if sb_budget is not None
                                else obs.PAPER_STALL_BUDGET))
    common.log(f"serving: replay comm-stall {stall.fraction:.4f} "
               f"(budget {sb_budget})")

    # Publish the continuous run into the global metrics registry; the
    # embedded exposition reconciles with ServingReport.accounted.
    obs.publish_serving(obs.METRICS, rep_cont)

    summary = {
        "params": {"logN": logn, "L": 9, "alpha": 2, "k": 3,
                   "tenants": TENANTS, "programs": list(programs),
                   "requests": n_req, "rate_rps": rate,
                   "max_batch": max_batch, "seed": common.SEED},
        "serial": rep_serial.to_dict(),
        "continuous": rep_cont.to_dict(),
        "wall_s": {"serial": wall_serial, "continuous": wall_cont},
        "throughput_ops": {"serial": tput_serial,
                           "continuous": tput_cont},
        "speedup": speedup,
        "live_retraces": live_retraces,
        "warmup_misses": warm_misses,
        "sim_replay": replay,
        "stall_budget": {
            **stall.as_dict(),
            "paper_budget_frac": obs.PAPER_STALL_BUDGET,
            "gated": sb_budget is not None,
        },
        "metrics": {
            name: fam["series"]
            for name, fam in obs.METRICS.snapshot().items()
            if name.startswith("serving.")
        },
        "gate": {"min_speedup": GATE_SERVING_SPEEDUP,
                 "speedup": speedup,
                 "stall_budget_frac": sb_budget,
                 "passed": (speedup >= GATE_SERVING_SPEEDUP
                            and live_retraces == 0
                            and (sb_budget is None
                                 or stall.fraction <= sb_budget))},
    }
    (RESULTS / "BENCH_serving.json").write_text(
        json.dumps(summary, indent=2))

    if common.TRACE:
        # Short traced pass AFTER the gated runs: the first 16 arrivals
        # re-served with span tracing on, combined with the gated run's
        # replay timelines into one Perfetto file.
        common.log("serving: tracing a 16-request prefix for Perfetto")
        obs.TRACER.reset()
        obs.enable()
        try:
            with obs.span("bench.serving", smoke=common.SMOKE,
                          requests=min(16, len(trace))):
                srv_tr, _, _, _ = _serve(ctx, programs, trace[:16],
                                         max_batch, serial=False)
        finally:
            obs.disable()
        trace_path = RESULTS / "trace_serving.json"
        obs.export.write_trace(
            trace_path, tracer=obs.TRACER, timelines=pipe.timelines,
            request_log=srv_tr.request_log,
            sim_process="HE2-SM replay (virtual clock)")
        obs.TRACER.reset()
        common.log(f"serving: wrote {trace_path}")

    lines = [
        f"serving/serial,{rep_serial.span_s*1e6:.0f},"
        f"tput={tput_serial:.1f}ops;p99="
        f"{rep_serial.to_dict()['p99_latency_s']*1e3:.1f}ms",
        f"serving/continuous,{rep_cont.span_s*1e6:.0f},"
        f"tput={tput_cont:.1f}ops;p99="
        f"{rep_cont.to_dict()['p99_latency_s']*1e3:.1f}ms",
        f"serving/speedup,{speedup*100:.0f},occupancy="
        f"{rep_cont.batch_occupancy:.2f};retraces={live_retraces}",
        f"serving/sim_replay,{replay['pipelined_s']*1e6:.0f},"
        f"hw_speedup={replay['speedup']:.2f}x;"
        f"link_util={replay['utilization'].get('link', 0):.2f}",
        f"serving/comm_stall,{stall.comm_stall_s*1e6:.2f},"
        f"frac={stall.fraction:.4f};budget={sb_budget};"
        f"paper={obs.PAPER_STALL_BUDGET}",
    ]
    if sb_budget is None:
        lines.append("serving/stall_gate,0,recorded-only=no calibrated "
                     "stall budget for this shape")
    for t, s in rep_cont.to_dict()["tenants"].items():
        lines.append(
            f"serving/tenant_{t},{s['p50_latency_s']*1e6:.0f},"
            f"done={s['completed']};p99={s['p99_latency_s']*1e3:.1f}ms")
    if live_retraces != 0:
        raise RuntimeError(
            f"serving retrace gate FAILED: {live_retraces} jit retraces "
            f"during live traffic (must be 0)")
    if speedup < GATE_SERVING_SPEEDUP:
        raise RuntimeError(
            f"serving perf gate FAILED: continuous batching "
            f"{speedup:.2f}x < {GATE_SERVING_SPEEDUP}x vs serial loop")
    if sb_budget is not None and stall.fraction > sb_budget:
        raise RuntimeError(
            f"serving stall-budget gate FAILED: HE2-SM replay "
            f"comm-stall {stall.fraction:.4f} > budget {sb_budget}")
    common.log("serving: all gates passed")
    return lines
