"""Pallas kernel-suite benchmark: batched jnp vs pallas, bit-exact gate.

Times every batched engine entry the compiled runtime dispatches
(ModUp / rotation / relin / hoisted rotation sum) plus one end-to-end
compiled CoeffToSlot program on BOTH backends, and writes
BENCH_pallas.json.

Two gates, both enforced (raise -> CI fails loudly):

  * bit-exactness — ALWAYS: every pallas output must equal the jnp
    output bit for bit, per op and end to end.  This is the contract
    that lets the serving layer pick the backend freely.
  * performance — only when the pallas kernels compile for real
    hardware (``interpret=False``, i.e. a TPU is attached): batched
    pallas must be at least as fast as batched jnp on the fused ModUp
    path (``pallas >= jnp``).  Off-TPU the kernels run the Pallas
    interpreter (functional parity, not speed) and only the
    bit-exactness gate applies; the timings are still recorded with
    ``interpret: true`` so the record is unambiguous.
"""
from __future__ import annotations

import json
import pathlib
import time

import numpy as np

from benchmarks import common

RESULTS = pathlib.Path(__file__).parent / "results"

# Perf gate (interpret=False only): fused-ModUp pallas must not be
# slower than the jnp contraction path on the same batched plan.
GATE_MIN_SPEEDUP = 1.0

ROT_STEPS = [1, 2, 3, 4]


def _params(logn: int):
    from repro.core.params import CKKSParams

    # L=5, alpha=2 -> dnum=3 digits; level 5 exercises the deepest plan.
    return CKKSParams(logN=logn, L=5, alpha=2, k=3, q_bits=29,
                      scale_bits=29)


def _time(fn, reps: int) -> float:
    """us/call after one warmup call (jit trace + plan-cache fill)."""
    import jax

    jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) * 1e6 / reps


def _bench_backend(ctx, comp, zs, reps: int):
    """Per-op us/call + raw outputs (for the bit-exactness gate)."""
    import jax.numpy as jnp

    from repro.runtime import ProgramExecutor

    eng = ctx.engine
    rng = np.random.default_rng(common.SEED)
    nh = ctx.params.num_slots
    cts = [ctx.encrypt(z) for z in zs]
    lvl = cts[0].level
    c0b = jnp.stack([c.c0 for c in cts])
    c1b = jnp.stack([c.c1 for c in cts])
    gs = [ctx.pc.rns.galois_for_rotation(s) for s in ROT_STEPS]
    evks = [ctx.keys.rot_key(s) for s in ROT_STEPS]
    pts = tuple(ctx.encode(rng.normal(size=nh), level=lvl)
                for _ in ROT_STEPS)
    pm_ext, pm_base, pm_ext_m = ctx._pm_stack(pts, lvl)
    mk = ctx.keys.mult_key

    ops = {
        "modup_batched": lambda: eng.modup_batched(c1b, lvl),
        "rotate_batched": lambda: eng.apply_galois_batched(
            c0b, c1b, gs[0], evks[0], lvl),
        "relin_batched": lambda: eng.relin_batched(
            c0b, c1b, c1b, mk, lvl),
        "hoisted_rotation_sum_batched": lambda:
            eng.hoisted_rotation_sum_batched(
                c0b, c1b, gs, evks, lvl, pm_ext=pm_ext, pm_base=pm_base,
                pm_ext_mont=pm_ext_m),
    }
    times = {name: _time(fn, reps) for name, fn in ops.items()}
    outs = {}
    for name, fn in ops.items():
        out = fn()
        outs[name] = (np.stack([np.asarray(o) for o in out])
                      if isinstance(out, tuple) else np.asarray(out))

    ex = ProgramExecutor(ctx)
    times["compiled_c2s_batched"] = _time(
        lambda: ex.run_batched(comp, {"x": cts}).outputs["y"][0].c0, reps)
    res = ex.run_batched(comp, {"x": cts})
    outs["compiled_c2s_batched"] = np.stack(
        [np.asarray(c.c0) for c in res.outputs["y"]])
    return times, outs


def run() -> list[str]:
    from repro.core.bootstrap import Bootstrapper
    from repro.core.ckks import CKKSContext
    from repro.kernels.modops import default_interpret
    from repro.runtime import TraceContext, compile_program

    RESULTS.mkdir(exist_ok=True)
    interpret = bool(default_interpret())
    logn = 8 if common.SMOKE else 9
    batch = 2 if common.SMOKE else 4
    reps = 1 if (common.SMOKE or interpret) else 5

    p = _params(logn)
    rng = np.random.default_rng(common.SEED)
    nh = p.num_slots
    zs = [(rng.normal(size=nh) + 1j * rng.normal(size=nh)) * 0.01
          for _ in range(batch)]

    summary: dict = {
        "params": {"logN": logn, "L": 5, "alpha": 2, "dnum": 3,
                   "batch": batch},
        "interpret": interpret,
    }
    results = {}
    for b in ("jnp", "pallas"):
        ctx = CKKSContext(p, seed=3 + common.SEED, backend=b)
        btp = Bootstrapper(ctx, n_groups=2, mod_K=3, cheb_degree=15)
        tc = TraceContext(p)
        h = tc.input("x", level=p.L, scale=p.scale)
        tc.output(btp.coeff_to_slot(h, tc), "y")
        results[b] = _bench_backend(ctx, compile_program(tc), zs, reps)
        summary[f"engine_{b}"] = results[b][0]

    # --- bit-exactness gate: ALWAYS enforced -------------------------
    mismatches = [
        op for op in results["jnp"][1]
        if not np.array_equal(results["jnp"][1][op],
                              results["pallas"][1][op])
    ]
    summary["bitexact"] = {"passed": not mismatches,
                           "mismatches": mismatches}

    # --- perf gate: only when compiled for real hardware -------------
    speedups = {op: summary["engine_jnp"][op] / summary["engine_pallas"][op]
                for op in summary["engine_jnp"]}
    summary["speedup_pallas_vs_jnp"] = speedups
    perf_gated = not interpret
    perf_ok = (not perf_gated
               or speedups["modup_batched"] >= GATE_MIN_SPEEDUP)
    summary["gate"] = {
        "bitexact_required": True,
        "perf_required": perf_gated,
        "perf_min_speedup": GATE_MIN_SPEEDUP,
        "modup_speedup": speedups["modup_batched"],
        "passed": not mismatches and perf_ok,
    }
    (RESULTS / "BENCH_pallas.json").write_text(json.dumps(summary, indent=2))

    lines = []
    for op in summary["engine_jnp"]:
        lines.append(f"pallas/{op}/jnp,{summary['engine_jnp'][op]:.0f},"
                     f"logN={logn};batch={batch}")
        lines.append(f"pallas/{op}/pallas,{summary['engine_pallas'][op]:.0f},"
                     f"interpret={interpret};speedup="
                     f"{speedups[op]:.2f}x")
    if mismatches:
        raise RuntimeError(
            f"pallas bit-exactness gate FAILED: {mismatches} differ "
            f"from the jnp backend")
    if not perf_ok:
        raise RuntimeError(
            f"pallas perf gate FAILED: modup_batched "
            f"{speedups['modup_batched']:.2f}x < {GATE_MIN_SPEEDUP}x vs jnp "
            f"(interpret=False)")
    return lines
