"""Fig. 14 ablation chain: SHARP(minks) -> SHARP(hoist) -> SHARP-xMU ->
HE2-SM(hoist) -> +HERO -> HE2-LM(hybrid) -> +INTT-Resident."""
from __future__ import annotations

import dataclasses
import json
import pathlib

from benchmarks.common import programs_for, smoke_subset
from repro.sim import HE2_LM, HE2_SM, SHARP, SHARP_XMU
from repro.sim.engine import simulate_program

RESULTS = pathlib.Path(__file__).parent / "results"


def run() -> list[str]:
    RESULTS.mkdir(exist_ok=True)
    lines, summary = [], {}
    he2_sm_no_ir = dataclasses.replace(HE2_SM, intt_resident=False)
    he2_lm_no_ir = dataclasses.replace(HE2_LM, intt_resident=False)
    for bench in smoke_subset(["bootstrapping", "helr", "resnet20"]):
        g_bsgs = programs_for(bench, bsgs=True)
        g_full = programs_for(bench, bsgs=False)
        cols = [
            ("1_SHARP_minks", simulate_program(g_bsgs, SHARP, "minks", "EVF")),
            ("2_SHARP_hoist", simulate_program(g_bsgs, SHARP, "hoist", "EVF")),
            ("3_SHARP-xMU_IRF", simulate_program(g_bsgs, SHARP_XMU, "hoist",
                                                 "IRF")),
            ("4_HE2-SM_hoist", simulate_program(g_bsgs, he2_sm_no_ir,
                                                "hoist", "IRF")),
            ("5_HE2-SM_HERO", simulate_program(g_full, he2_sm_no_ir, "hoist",
                                               "IRF", fusion=True)),
            ("6_HE2-LM_hybrid", simulate_program(g_full, he2_lm_no_ir,
                                                 "hoist", "hybrid",
                                                 fusion=True)),
            ("7_HE2-LM_+INTTres", simulate_program(g_full, HE2_LM, "hoist",
                                                   "hybrid", fusion=True)),
        ]
        base = cols[0][1].latency_s
        summary[bench] = {}
        for name, r in cols:
            summary[bench][name] = {
                "latency_ms": r.latency_s * 1e3,
                "norm": r.latency_s / base,
                "comm_stall_frac": r.comm_stall_frac,
                "mem_stall_frac": (r.mem_stall_s / r.latency_s
                                   if r.latency_s else 0.0),
                "link_util": r.engine_util("link"),
            }
            lines.append(
                f"fig14/{bench}/{name},0.0,norm={r.latency_s/base:.3f};"
                f"comm_stall={r.comm_stall_frac:.4f}"
            )
        # scheduler contribution: final column re-run with the analytic
        # serial-block model (what the ablation looked like pre-overlap)
        r_an = simulate_program(g_full, HE2_LM, "hoist", "hybrid",
                                fusion=True, mode="analytic")
        summary[bench]["7_analytic_ref"] = {
            "latency_ms": r_an.latency_s * 1e3,
            "norm": r_an.latency_s / base,
        }
        lines.append(
            f"fig14/{bench}/7_analytic_ref,0.0,"
            f"norm={r_an.latency_s/base:.3f};"
            f"sched_gain={r_an.latency_s/cols[-1][1].latency_s:.3f}x"
        )
    (RESULTS / "fig14.json").write_text(json.dumps(summary, indent=2))
    return lines
