"""Keyswitch engine microbenchmark: seed per-digit loops vs the batched
jit engine (jnp backend) vs the Pallas kernel backend.

Times ``keyswitch`` (via multiply's relin), ``rotate``, and
``hoisted_rotation_sum`` on a (logN=13, dnum=3) context — the ROADMAP's
"hot path measurably faster" tracker.  Writes BENCH_keyswitch.json with
per-op us/call and seed/engine speedups; CI uploads it as an artifact.

The pallas backend runs ``interpret=True`` on CPU (functional parity,
not speed) — it is timed with one repetition for the record, on a
reduced ring so the interpreter cost stays bounded.
"""
from __future__ import annotations

import json
import pathlib
import time

import numpy as np

from benchmarks import common

RESULTS = pathlib.Path(__file__).parent / "results"

ROT_STEPS = [1, 2, 3, 4, 5, 6]   # >= 4 hoisted rotations (acceptance gate)

# Perf regression gate: the batched jit engine must beat the seed
# per-digit path by at least this factor on hoisted_rotation_sum.
# Measured ~11-14x on CPU; enforced (raises) in smoke and full runs so
# CI fails loudly if the hot path regresses.
GATE_HOISTED_SPEEDUP = 3.0


def _params(logn: int):
    from repro.core.params import CKKSParams

    # L=5, alpha=2 -> dnum=3 decomposition digits; k=3 noise headroom.
    return CKKSParams(logN=logn, L=5, alpha=2, k=3, q_bits=29,
                      scale_bits=29)


def _time_op(fn, reps: int) -> float:
    """us/call after one warmup (jit trace / dispatch-cache fill)."""
    fn().c0.block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    out.c0.block_until_ready()
    return (time.perf_counter() - t0) * 1e6 / reps


def _bench_ctx(ctx, ct, pts, steps, reps: int) -> dict[str, float]:
    return {
        "multiply": _time_op(lambda: ctx.multiply(ct, ct), reps),
        "rotate": _time_op(lambda: ctx.rotate(ct, 3), reps),
        "hoisted_rotation_sum": _time_op(
            lambda: ctx.hoisted_rotation_sum(ct, steps, pts), reps
        ),
    }


def run() -> list[str]:
    from repro.core.ckks import CKKSContext

    RESULTS.mkdir(exist_ok=True)
    logn = 11 if common.SMOKE else 13
    pallas_logn = 9 if common.SMOKE else 11
    steps = ROT_STEPS[:4] if common.SMOKE else ROT_STEPS
    reps_seed = 1 if common.SMOKE else 2
    reps_engine = 3 if common.SMOKE else 10

    rng = np.random.default_rng(common.SEED)
    summary: dict = {"params": {"logN": logn, "L": 5, "alpha": 2, "dnum": 3,
                                "rotations": len(steps)},
                     "pallas_logN": pallas_logn}
    lines = []

    p = _params(logn)
    ctx = CKKSContext(p, seed=3 + common.SEED)
    nh = p.num_slots
    z = rng.normal(size=nh) + 1j * rng.normal(size=nh)
    ct = ctx.encrypt(z)
    pts = [ctx.encode(rng.normal(size=nh)) for _ in steps]
    for s in steps:
        ctx.keys.rot_key(s)  # keygen outside the timed region

    ctx.use_engine = False
    summary["seed"] = _bench_ctx(ctx, ct, pts, steps, reps_seed)
    ctx.use_engine = True
    summary["engine_jnp"] = _bench_ctx(ctx, ct, pts, steps, reps_engine)

    # Pallas backend (interpret mode off-TPU): parity record, 1 rep.
    pp = _params(pallas_logn)
    ctx_p = CKKSContext(pp, seed=3 + common.SEED, backend="pallas")
    zp = rng.normal(size=pp.num_slots) + 1j * rng.normal(size=pp.num_slots)
    ct_p = ctx_p.encrypt(zp)
    pts_p = [ctx_p.encode(rng.normal(size=pp.num_slots)) for _ in steps]
    summary["engine_pallas"] = _bench_ctx(ctx_p, ct_p, pts_p, steps, 1)

    summary["speedup_vs_seed"] = {
        op: summary["seed"][op] / summary["engine_jnp"][op]
        for op in summary["seed"]
    }
    for op in summary["seed"]:
        lines.append(
            f"keyswitch/{op}/seed,{summary['seed'][op]:.0f},logN={logn}"
        )
        lines.append(
            f"keyswitch/{op}/engine_jnp,{summary['engine_jnp'][op]:.0f},"
            f"speedup={summary['speedup_vs_seed'][op]:.2f}x"
        )
        lines.append(
            f"keyswitch/{op}/engine_pallas,"
            f"{summary['engine_pallas'][op]:.0f},"
            f"interpret=True;logN={pallas_logn}"
        )
    hoisted = summary["speedup_vs_seed"]["hoisted_rotation_sum"]
    summary["gate"] = {"hoisted_min_speedup": GATE_HOISTED_SPEEDUP,
                       "hoisted_speedup": hoisted,
                       "passed": hoisted >= GATE_HOISTED_SPEEDUP}
    (RESULTS / "BENCH_keyswitch.json").write_text(
        json.dumps(summary, indent=2)
    )
    if hoisted < GATE_HOISTED_SPEEDUP:
        raise RuntimeError(
            f"keyswitch engine perf gate FAILED: hoisted_rotation_sum "
            f"{hoisted:.2f}x < {GATE_HOISTED_SPEEDUP}x vs seed path"
        )
    return lines
