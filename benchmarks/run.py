"""Benchmark driver: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines and writes JSON results to
benchmarks/results/ (consumed by EXPERIMENTS.md).

Usage: python -m benchmarks.run [table4|fig14|...|all]
                                [--smoke] [--seed N] [--chaos]
                                [--quiet] [--trace] [--list]

--smoke restricts every module to its cheapest workload (CI fast path).
--seed  sets the shared base seed (``benchmarks.common.SEED``) that the
        measured benches derive plaintexts, tenant keys, and arrival
        traces from; analytic figure modules are seed-free.
--chaos runs the serving bench under its seeded fault-injection
        schedule and gates on recovery (accounting, goodput, victims,
        retraces); the chaos report lands under the ``"chaos"`` key of
        BENCH_serving.json next to the fault-free run's numbers.
--quiet gates out info-level ``benchmarks.common.log`` progress lines
        (warn/error still print; CSV results are unaffected).
--trace makes the bootstrap and serving benches run one obs-traced
        pass and write Perfetto traces (benchmarks/results/
        trace_bootstrap.json / trace_serving.json, CI artifacts).
--list  prints the available module names with a one-line description
        and exits.
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (
        bench_bootstrap, bench_keyswitch, bench_pallas, bench_runtime,
        bench_serving, bench_workloads, common, fig6_parallelism,
        fig7_bsgs, fig14_ablation, fig15_hero, fig16_util,
        fig17_sensitivity, table1_ai, table4_end2end,
    )

    modules = {
        "table1": table1_ai,
        "table4": table4_end2end,
        "keyswitch": bench_keyswitch,
        "pallas": bench_pallas,
        "runtime": bench_runtime,
        "bootstrap": bench_bootstrap,
        "workloads": bench_workloads,
        "serving": bench_serving,
        "fig6": fig6_parallelism,
        "fig7": fig7_bsgs,
        "fig14": fig14_ablation,
        "fig15": fig15_hero,
        "fig16": fig16_util,
        "fig17": fig17_sensitivity,
    }
    argv = sys.argv[1:]
    if "--list" in argv:
        for name, mod in modules.items():
            doc = (mod.__doc__ or "").strip().splitlines()
            print(f"{name:<12} {doc[0] if doc else ''}")
        return
    common.SMOKE = "--smoke" in argv
    common.CHAOS = "--chaos" in argv
    common.QUIET = "--quiet" in argv
    common.TRACE = "--trace" in argv
    args, it = [], iter(argv)
    for a in it:
        if a == "--seed":
            common.SEED = int(next(it))
        elif not a.startswith("--"):
            args.append(a)
    which = args[0] if args else "all"
    selected = modules if which == "all" else {which: modules[which]}
    print("name,us_per_call,derived")
    for name, mod in selected.items():
        t0 = time.time()
        for line in mod.run():
            print(line)
        dt = time.time() - t0
        print(f"{name}/_total,{dt*1e6:.0f},ok")


if __name__ == "__main__":
    main()
