"""Benchmark driver: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines and writes JSON results to
benchmarks/results/ (consumed by EXPERIMENTS.md).

Usage: PYTHONPATH=src python -m benchmarks.run [table4|fig14|...|all]
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (
        fig6_parallelism, fig7_bsgs, fig14_ablation, fig15_hero,
        fig16_util, fig17_sensitivity, table1_ai, table4_end2end,
    )

    modules = {
        "table1": table1_ai,
        "table4": table4_end2end,
        "fig6": fig6_parallelism,
        "fig7": fig7_bsgs,
        "fig14": fig14_ablation,
        "fig15": fig15_hero,
        "fig16": fig16_util,
        "fig17": fig17_sensitivity,
    }
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    selected = modules if which == "all" else {which: modules[which]}
    print("name,us_per_call,derived")
    for name, mod in selected.items():
        t0 = time.time()
        for line in mod.run():
            print(line)
        dt = time.time() - t0
        print(f"{name}/_total,{dt*1e6:.0f},ok")


if __name__ == "__main__":
    main()
