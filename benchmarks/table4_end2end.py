"""Table IV: end-to-end latency / EDP / EDAP vs SHARP baseline."""
from __future__ import annotations

import json
import pathlib
import time

from benchmarks.common import (
    BENCHES, PAPER_LATENCY_MS, area_of, run_stack, smoke_subset,
)

RESULTS = pathlib.Path(__file__).parent / "results"


def run() -> list[str]:
    RESULTS.mkdir(exist_ok=True)
    lines = []
    summary = {}
    for bench in smoke_subset(BENCHES):
        t0 = time.time()
        rows = run_stack(bench)
        dt = (time.time() - t0) * 1e6 / max(len(rows), 1)
        for name, r in rows.items():
            edap = r.edap(area_of(name))
            paper = PAPER_LATENCY_MS[bench].get(name)
            summary.setdefault(bench, {})[name] = {
                "latency_ms": r.latency_s * 1e3,
                "paper_latency_ms": paper,
                "edp_jms": r.edp,
                "edap": edap,
                "comm_stall_frac": r.comm_stall_frac,
                "mem_stall_frac": (r.mem_stall_s / r.latency_s
                                   if r.latency_s else 0),
            }
            lines.append(
                f"table4/{bench}/{name},{dt:.1f},"
                f"lat_ms={r.latency_s*1e3:.3f};paper={paper};"
                f"edp={r.edp:.3f};edap={edap:.1f};"
                f"comm_stall={r.comm_stall_frac:.4f}"
            )
        sp_sm = rows["SHARP"].latency_s / rows["HE2-SM"].latency_s
        sp_lm = rows["SHARP"].latency_s / rows["HE2-LM"].latency_s
        edap_gain = (rows["SHARP"].edap(area_of("SHARP"))
                     / rows["HE2-LM"].edap(area_of("HE2-LM")))
        summary[bench]["speedup_sm"] = sp_sm
        summary[bench]["speedup_lm"] = sp_lm
        summary[bench]["edap_gain_lm"] = edap_gain
        lines.append(
            f"table4/{bench}/speedup,{dt:.1f},"
            f"sm={sp_sm:.2f}x;lm={sp_lm:.2f}x;edap_gain={edap_gain:.2f}x"
        )
    (RESULTS / "table4.json").write_text(json.dumps(summary, indent=2))
    return lines
