"""Fig. 15: computation & communication volume under the algorithmic
optimizations — Min-KS / Hoisting / Hoisting w/o BSGS / HERO (fusion).

The HERO plan is scored with the scheduled group-pipeline makespan
(engine._pipeline_weights), so the DP optimizes what the event-driven
simulator measures."""
from __future__ import annotations

import json
import pathlib

from benchmarks.common import programs_for, smoke_subset
from repro.dfg.fusion import optimal_fusion
from repro.dfg.hoist import program_volumes
from repro.dfg.pkb import identify_pkbs
from repro.sim import HE2_SM
from repro.sim.engine import _pipeline_weights

RESULTS = pathlib.Path(__file__).parent / "results"


def _metrics(dfg, pkbs, strategy, dataflow="IRF"):
    v = program_volumes(dfg, pkbs, 12, 12, strategy, dataflow)
    return {
        "compute_words": v.compute_words,
        "comm_words": v.comm_words,
        "evk_set_words": v.evk_set_words,
        "modups": v.modup_count,
        "moddowns": v.moddown_count,
    }


def run() -> list[str]:
    RESULTS.mkdir(exist_ok=True)
    lines, summary = [], {}
    for bench in smoke_subset(["bootstrapping", "helr", "resnet20",
                               "bert"]):
        g_bsgs = programs_for(bench, bsgs=True)
        g_full = programs_for(bench, bsgs=False)
        pk_bsgs = identify_pkbs(g_bsgs)
        pk_full = identify_pkbs(g_full)
        plan = optimal_fusion(
            pk_full, 12, 12, 1 << 15,
            capacity_words=HE2_SM.evk_capacity_words(),
            weights=_pipeline_weights(HE2_SM),
        )
        rows = {
            "minks": _metrics(g_bsgs, pk_bsgs, "minks", "EVF"),
            "hoisting": _metrics(g_bsgs, pk_bsgs, "hoist"),
            "hoisting_no_bsgs": _metrics(g_full, pk_full, "hoist"),
            "HERO": _metrics(g_full, plan.fused, "hoist"),
        }
        rows["HERO"]["plan_saved_scheduled_ms"] = plan.score * 1e3
        base = rows["minks"]
        summary[bench] = rows
        for name, m in rows.items():
            comp_red = base["compute_words"] / max(m["compute_words"], 1)
            comm_base = max(base["comm_words"], base["evk_set_words"], 1)
            comm_red = comm_base / max(m["comm_words"] or m["evk_set_words"], 1)
            summary[bench][name]["comp_reduction_vs_minks"] = comp_red
            summary[bench][name]["comm_reduction_vs_minks"] = comm_red
            lines.append(
                f"fig15/{bench}/{name},0.0,"
                f"comp_words={m['compute_words']:.3e};"
                f"comm_words={m['comm_words']:.3e};"
                f"modups={m['modups']};comp_red={comp_red:.2f}x;"
                f"comm_red={comm_red:.2f}x"
            )
    (RESULTS / "fig15.json").write_text(json.dumps(summary, indent=2))
    return lines
