"""Fig. 6: PKB keyswitch-parallelism distribution, before/after HERO."""
from __future__ import annotations

import collections
import json
import pathlib

from benchmarks.common import programs_for, smoke_subset
from repro.dfg.fusion import optimal_fusion
from repro.dfg.pkb import identify_pkbs
from repro.sim import HE2_SM
from repro.sim.engine import _pipeline_weights

RESULTS = pathlib.Path(__file__).parent / "results"


def _bucket(ns):
    c = collections.Counter()
    for n in ns:
        if n <= 1:
            c["1"] += 1
        elif n <= 10:
            c["2-10"] += 1
        elif n <= 30:
            c["11-30"] += 1
        else:
            c[">30"] += 1
    return dict(c)


def run() -> list[str]:
    RESULTS.mkdir(exist_ok=True)
    lines, summary = [], {}
    for bench in smoke_subset(["bootstrapping", "helr", "resnet20"]):
        g_bsgs = programs_for(bench, bsgs=True)   # Min-KS/BSGS baseline
        g_full = programs_for(bench, bsgs=False)
        pk_b = identify_pkbs(g_bsgs)
        pk_f = identify_pkbs(g_full)
        plan = optimal_fusion(
            pk_f, 12, 12, 1 << 15,
            capacity_words=HE2_SM.evk_capacity_words(),
            weights=_pipeline_weights(HE2_SM),
        )
        rows = {
            "baseline_bsgs": _bucket([p.n_rot for p in pk_b]),
            "no_bsgs": _bucket([p.n_rot for p in pk_f]),
            "HERO_fused": _bucket([len(p.steps) for p in plan.fused]),
        }
        summary[bench] = rows
        for name, hist in rows.items():
            lines.append(f"fig6/{bench}/{name},0.0,{hist}")
    (RESULTS / "fig6.json").write_text(json.dumps(summary, indent=2))
    return lines
