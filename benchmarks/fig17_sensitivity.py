"""Fig. 17: HE2 sensitivity to xMU HBM bandwidth and capacity.

Run under the event-driven scheduler; the per-point link utilization
shows where the heterogeneous link stops being the bottleneck.
"""
from __future__ import annotations

import json
import pathlib

from benchmarks import common
from benchmarks.common import programs_for
from repro.sim import HE2_SM, SHARP
from repro.sim.engine import simulate_program
from repro.sim.hw import with_bandwidth, with_capacity

RESULTS = pathlib.Path(__file__).parent / "results"


def run() -> list[str]:
    RESULTS.mkdir(exist_ok=True)
    lines, summary = [], {"bandwidth": {}, "capacity": {}}
    g_full = programs_for("bootstrapping", bsgs=False)
    g_bsgs = programs_for("bootstrapping", bsgs=True)
    sharp = simulate_program(g_bsgs, SHARP, "minks", "EVF",
                             mode="pipelined")
    summary["sharp_ms"] = sharp.latency_s * 1e3

    bws = (1.0,) if common.SMOKE else (0.25, 0.5, 1.0, 2.0, 4.0)
    caps = (8.0,) if common.SMOKE else (2.0, 4.0, 8.0, 16.0)
    for bw in bws:
        hw = with_bandwidth(HE2_SM, bw)
        r = simulate_program(g_full, hw, "hoist", "IRF", fusion=True,
                             mode="pipelined")
        summary["bandwidth"][bw] = {
            "latency_ms": r.latency_s * 1e3,
            "comm_stall_frac": r.comm_stall_frac,
            "link_util": r.engine_util("link"),
        }
        lines.append(
            f"fig17/bw/{bw}TBs,0.0,lat_ms={r.latency_s*1e3:.3f};"
            f"comm_stall={r.comm_stall_frac:.3f};"
            f"vs_sharp={sharp.latency_s/r.latency_s:.2f}x"
        )
    for cap in caps:
        hw = with_capacity(HE2_SM, cap)
        r = simulate_program(g_full, hw, "hoist", "IRF", fusion=True,
                             mode="pipelined")
        summary["capacity"][cap] = {
            "latency_ms": r.latency_s * 1e3,
            "comm_stall_frac": r.comm_stall_frac,
            "link_util": r.engine_util("link"),
        }
        lines.append(
            f"fig17/cap/{cap}GB,0.0,lat_ms={r.latency_s*1e3:.3f}"
        )
    (RESULTS / "fig17.json").write_text(json.dumps(summary, indent=2))
    return lines
