"""Fig. 17: HE2 sensitivity to xMU HBM bandwidth and capacity."""
from __future__ import annotations

import json
import pathlib

from benchmarks.common import programs_for
from repro.sim import HE2_SM, SHARP
from repro.sim.engine import simulate_program
from repro.sim.hw import with_bandwidth, with_capacity

RESULTS = pathlib.Path(__file__).parent / "results"


def run() -> list[str]:
    RESULTS.mkdir(exist_ok=True)
    lines, summary = [], {"bandwidth": {}, "capacity": {}}
    g_full = programs_for("bootstrapping", bsgs=False)
    g_bsgs = programs_for("bootstrapping", bsgs=True)
    sharp = simulate_program(g_bsgs, SHARP, "minks", "EVF")
    summary["sharp_ms"] = sharp.latency_s * 1e3

    for bw in (0.25, 0.5, 1.0, 2.0, 4.0):
        hw = with_bandwidth(HE2_SM, bw)
        r = simulate_program(g_full, hw, "hoist", "IRF", fusion=True)
        summary["bandwidth"][bw] = r.latency_s * 1e3
        lines.append(
            f"fig17/bw/{bw}TBs,0.0,lat_ms={r.latency_s*1e3:.3f};"
            f"vs_sharp={sharp.latency_s/r.latency_s:.2f}x"
        )
    for cap in (2.0, 4.0, 8.0, 16.0):
        hw = with_capacity(HE2_SM, cap)
        r = simulate_program(g_full, hw, "hoist", "IRF", fusion=True)
        summary["capacity"][cap] = r.latency_s * 1e3
        lines.append(
            f"fig17/cap/{cap}GB,0.0,lat_ms={r.latency_s*1e3:.3f}"
        )
    (RESULTS / "fig17.json").write_text(json.dumps(summary, indent=2))
    return lines
