"""Shared benchmark machinery.

Pairing convention (paper Secs. VI-VII):
  * SHARP baseline        : Min-KS + EVF on BSGS(bs=4) programs
  * SHARP w. hoisting     : hoist + EVF on BSGS programs (Fig. 5/14 col 2)
  * SHARP-xMU             : hoist + IRF on BSGS programs (col 3)
  * HE2-SM (hoisting)     : hoist + IRF on BSGS programs (col 4)
  * HE2-SM (HERO)         : hoist + fusion + IRF on BSGS-disabled programs
                            (HERO's BSGS explorer disables BSGS when the
                            8 GB HBM holds the evk set — Sec. IV-C)
  * HE2-LM (HERO, hybrid) : + hybrid dataflow + INTT-Resident (cols 6-7)
"""
from __future__ import annotations

import sys
import time

from repro.dfg.programs import (
    bert_dfg, bootstrapping_dfg, helr_dfg, resnet_dfg,
)
from repro.sim import HE2_LM, HE2_SM, SHARP, SHARP_XMU
from repro.sim.engine import SimResult, simulate_program

BS_BASE = 4   # SHARP's baseline baby-step (Fig. 7(a))

# --smoke (CI fast path): restrict each benchmark module to its
# cheapest workload so `python -m benchmarks.run <fig> --smoke`
# finishes in seconds (table1 is analytic and already instant).
# Toggled by benchmarks.run.
SMOKE = False

# --seed N: shared base seed for every stochastic benchmark input
# (plaintext draws, tenant keygen offsets, Poisson arrival traces).
# The analytic figure modules ignore it; the measured benches derive
# all their rngs from it so a run is replayable end to end.
# Toggled by benchmarks.run.
SEED = 0

# --chaos: run the serving bench under its seeded fault-injection
# schedule (serve.faults.FaultPlan derived from SEED) and gate on
# recovery: zero unaccounted requests, no co-batched victim failures,
# goodput >= 0.8x the fault-free run, zero added retraces.  Only the
# serving module consumes it.  Toggled by benchmarks.run.
CHAOS = False

# --quiet: suppress info-level progress logging (warn/error still
# print).  Toggled by benchmarks.run.
QUIET = False

# --trace: the bootstrap and serving benches run one obs-traced pass
# and write Perfetto trace JSONs (trace_bootstrap.json /
# trace_serving.json under benchmarks/results/, uploaded by CI).
# Toggled by benchmarks.run.
TRACE = False

_T0 = time.perf_counter()


def log(msg: str, level: str = "info") -> None:
    """Structured, level-gated progress line on stderr.

    ``bench t=<s> level=<level> <msg>`` — greppable in CI logs
    (``grep 'level=warn'``), and on stderr so the CSV result lines on
    stdout stay machine-readable.  ``--quiet`` gates info lines out;
    warn/error always print.
    """
    if QUIET and level == "info":
        return
    t = time.perf_counter() - _T0
    print(f"bench t={t:8.2f}s level={level} {msg}",
          file=sys.stderr, flush=True)


def smoke_subset(benches: list[str]) -> list[str]:
    return benches[:1] if SMOKE else benches


def programs_for(bench: str, bsgs: bool):
    bs = BS_BASE if bsgs else 0
    if bench == "bootstrapping":
        return bootstrapping_dfg(bsgs_bs=bs).g
    if bench == "helr":
        return helr_dfg(bsgs_bs=bs).g
    if bench == "resnet20":
        return resnet_dfg(20, bsgs_bs=bs).g
    if bench == "resnet56":
        return resnet_dfg(56, bsgs_bs=bs).g
    if bench == "bert":
        return bert_dfg(bsgs_bs=2 if bsgs else 2).g
    raise KeyError(bench)


BENCHES = ["bootstrapping", "helr", "resnet20", "resnet56"]

# Paper Table IV reference latencies (ms) for validation.
PAPER_LATENCY_MS = {
    "bootstrapping": {"SHARP": 3.12, "HE2-SM": 1.42, "HE2-LM": 1.33},
    "helr": {"SHARP": 2.53, "HE2-SM": 1.79, "HE2-LM": 1.70},
    "resnet20": {"SHARP": 99.0, "HE2-SM": 69.7, "HE2-LM": 71.9},
    "resnet56": {"SHARP": 337.0, "HE2-SM": 232.0, "HE2-LM": 240.0},
}

PAPER_EDP = {
    "bootstrapping": {"SHARP": 0.94, "HE2-SM": 0.16, "HE2-LM": 0.13},
    "helr": {"SHARP": 2.56, "HE2-SM": 0.87, "HE2-LM": 0.75},
    "resnet20": {"SHARP": 648.0, "HE2-SM": 234.0, "HE2-LM": 219.0},
    "resnet56": {"SHARP": 7510.0, "HE2-SM": 2600.0, "HE2-LM": 2430.0},
}


def run_stack(bench: str) -> dict[str, SimResult]:
    g_bsgs = programs_for(bench, bsgs=True)
    g_full = programs_for(bench, bsgs=False)
    out = {}
    out["SHARP"] = simulate_program(g_bsgs, SHARP, "minks", "EVF",
                                    name="SHARP")
    out["SHARP w.Hoist"] = simulate_program(g_bsgs, SHARP, "hoist", "EVF",
                                            name="SHARP w.Hoist")
    out["SHARP-xMU"] = simulate_program(g_bsgs, SHARP_XMU, "hoist", "IRF",
                                        name="SHARP-xMU")
    out["HE2-SM hoist"] = simulate_program(g_bsgs, HE2_SM, "hoist", "IRF",
                                           name="HE2-SM hoist")
    out["HE2-SM"] = simulate_program(g_full, HE2_SM, "hoist", "IRF",
                                     fusion=True, name="HE2-SM")
    out["HE2-LM"] = simulate_program(g_full, HE2_LM, "hoist", "hybrid",
                                     fusion=True, name="HE2-LM")
    return out


def area_of(name: str) -> float:
    return {
        "SHARP": SHARP.area_mm2, "SHARP w.Hoist": SHARP.area_mm2,
        "SHARP-xMU": SHARP_XMU.area_mm2, "HE2-SM hoist": HE2_SM.area_mm2,
        "HE2-SM": HE2_SM.area_mm2, "HE2-LM": HE2_LM.area_mm2,
    }[name]
