"""Table I: arithmetic intensity (ops/byte) of CKKS operators under the
paper's parameters (N=2^16, L=35, k=alpha=12, dnum=3, 36-bit words)."""
from __future__ import annotations

import json
import pathlib

from repro.dfg.hoist import ip_volumes, moddown_volumes, modup_volumes
from repro.sim.hw import WORD_BYTES

RESULTS = pathlib.Path(__file__).parent / "results"

N, L, K, ALPHA = 1 << 16, 35, 12, 12
PAPER_AI = {"ntt": 0.89, "bconv": 1.60, "modup": 3.38, "moddown": 2.92,
            "ip": 0.12, "pmul": 0.09, "cadd": 0.07, "rescale": 0.11}


def run() -> list[str]:
    RESULTS.mkdir(exist_ok=True)
    l = L + 1
    ext = l + K
    logn = 16
    out = {}

    # NTT: N log N butterflies (1 mul + 2 add) over N words in/out
    ntt_ops = N * logn * 1.5
    ntt_bytes = 2 * N * WORD_BYTES
    out["ntt"] = ntt_ops / ntt_bytes / logn  # per-stage normalized

    # BConv l -> k limbs: l*k MACs per coeff; reads l, writes k words
    bconv_ops = ALPHA * K * N
    bconv_bytes = (ALPHA + K) * N * WORD_BYTES
    out["bconv"] = bconv_ops / bconv_bytes

    mu = modup_volumes(l, K, ALPHA, N)
    mu_bytes = (l + 3 * ext) * N * WORD_BYTES  # read digits, write ext
    out["modup"] = (mu.ntt_words * 1.5 * logn / 16 + mu.bconv_macs) / mu_bytes

    md = moddown_volumes(l, K, ALPHA, N, 2)
    md_bytes = 2 * (ext + l) * N * WORD_BYTES
    out["moddown"] = (md.ntt_words * 1.5 * logn / 16 + md.bconv_macs
                      + md.xpu_ewo_words) / md_bytes

    ipv = ip_volumes(l, K, ALPHA, N)
    ip_bytes = (3 * ext + 3 * 2 * ext + 2 * ext) * N * WORD_BYTES
    out["ip"] = ipv.ip_macs / ip_bytes

    # EWOs: 1 op per word; read 2 (or 1+pt), write 1
    out["pmul"] = 1.0 / (3 * WORD_BYTES)
    out["cadd"] = 1.0 / (3 * WORD_BYTES)
    out["rescale"] = 1.5 / (3 * WORD_BYTES)

    (RESULTS / "table1_ai.json").write_text(json.dumps(
        {"ours": out, "paper": PAPER_AI}, indent=2))
    lines = []
    for op, ai in out.items():
        lines.append(
            f"table1/{op},0.0,ai={ai:.3f};paper={PAPER_AI.get(op)};"
            f"memops={'yes' if ai < 0.5 else 'no'}"
        )
    return lines
