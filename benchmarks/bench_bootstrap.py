"""Compiled-bootstrap benchmark: the full paper pipeline (ModRaise ->
CoeffToSlot -> re/im EvalMod -> merge -> SlotToCoeff) eager vs compiled
through ``repro.runtime``.

Three configurations, same program:

  eager      — ``Bootstrapper.bootstrap`` op by op (per-call plaintext
               encoding, one ModUp per hoisted baby block, per-rotation
               giant-step keyswitches)
  compiled   — ``Bootstrapper.compile()``: traced + lowered, bit-exact
               with eager; stage plaintexts encoded once, baby-step
               blocks share ONE ModUp per anchor through the digits
               cache
  multi      — ``compile(exact=False)``: giant-step PKBs additionally
               close with ONE ModDown per block
               (``runtime.lower.MultiHoistedStep``)

Writes BENCH_bootstrap.json (including the scheduled HE2-SM latency and
timeline-integrated energy of the executed plan via
``ExecutionReport.scheduled_result``) and ENFORCES the regression gates:

  * compiled ModUps strictly below eager ModUps (and multi ModDowns
    strictly below compiled ModDowns) — the paper's communication story
  * compiled ModUps strictly below the PR-4 compiled baseline
    (``PR4_COMPILED_MODUPS``, recorded per bench shape — smoke today;
    a shape without a recorded baseline skips the gate and says so):
    relinearization now compiles through the keyswitch family (BSGS
    Chebyshev EvalMod, CMults no longer eager)
  * relin ModUp/ModDown movement: every CMult relin runs compiled
    (relin counts recorded per configuration), and the exact=False
    lowering merges >= 1 sum-of-CMult closure
  * steady-state compiled wall clock at least GATE_COMPILED_SPEEDUP x
    faster than the eager pipeline (plaintext/evk caching + shared
    ModUps; measured after one warmup run absorbing jit traces)
"""
from __future__ import annotations

import json
import pathlib
import time

import numpy as np

from benchmarks import common

RESULTS = pathlib.Path(__file__).parent / "results"

# Perf regression gate (CI): compiled steady-state vs eager pipeline.
# The structural win (plaintexts encoded once + shared ModUps) measures
# ~1.4x on the smoke shape; the gate sits low enough to absorb shared-
# runner timing noise while still catching a loss of the caching path
# (which collapses the ratio to ~1.0x).
GATE_COMPILED_SPEEDUP = 1.1

# PR-4 compiled-bootstrap ModUp counts (CMults still eager, dense T_k
# recurrence in EvalMod) at the exact bench shapes — the relin refactor
# must land strictly below these.
PR4_COMPILED_MODUPS = {True: 65}      # keyed by common.SMOKE


def _time(fn, reps: int) -> float:
    """us/run after one warmup (jit traces + plaintext caches)."""
    out = fn()
    out.c0.block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    out.c0.block_until_ready()
    return (time.perf_counter() - t0) * 1e6 / reps


def run() -> list[str]:
    from repro.core.bootstrap import Bootstrapper
    from repro.core.ckks import CKKSContext
    from repro.core.params import CKKSParams
    from repro.runtime import ProgramExecutor
    from repro.sim import HE2_SM

    RESULTS.mkdir(exist_ok=True)
    logn = 8 if common.SMOKE else 10
    L = 19 if common.SMOKE else 23
    alpha, k = (4, 4) if common.SMOKE else (3, 4)
    cheb_degree = 27 if common.SMOKE else 59
    mod_K = 3 if common.SMOKE else 5
    reps = 2

    params = CKKSParams(logN=logn, L=L, alpha=alpha, k=k, q_bits=29,
                        scale_bits=29, q0_bits=30)
    ctx = CKKSContext(params, seed=7 + common.SEED, hamming_weight=8)
    btp = Bootstrapper(ctx, n_groups=2 if common.SMOKE else 3,
                       mod_K=mod_K, cheb_degree=cheb_degree)
    nh = params.num_slots
    rng = np.random.default_rng(common.SEED)
    z = (rng.normal(size=nh) + 1j * rng.normal(size=nh)) * 0.01
    ct0 = ctx.encrypt(z, level=0)

    comp = btp.compile(input_scale=ct0.scale)
    comp_multi = btp.compile(input_scale=ct0.scale, exact=False)
    ex = ProgramExecutor(ctx)

    def counts(fn):
        before = ctx.counters.snapshot()
        out = fn()
        d = ctx.counters.delta(before)
        return out, d

    out_eager, d_eager = counts(lambda: btp.bootstrap(ct0))
    res, d_comp = counts(
        lambda: ex.run(comp, {"ct": ct0}, with_report=True))
    out_comp = res["out"]
    _, d_multi = counts(lambda: ex.run(comp_multi, {"ct": ct0}))

    bitexact = (np.array_equal(np.asarray(out_comp.c0),
                               np.asarray(out_eager.c0))
                and np.array_equal(np.asarray(out_comp.c1),
                                   np.asarray(out_eager.c1)))
    err = float(np.abs(ctx.decrypt(out_comp) - z).max())
    sched = res.report.scheduled_result(comp, HE2_SM)
    reconciled = res.report.reconcile()

    t = {
        "eager": _time(lambda: btp.bootstrap(ct0), reps),
        "compiled": _time(lambda: ex.run(comp, {"ct": ct0})["out"], reps),
        "multi": _time(lambda: ex.run(comp_multi, {"ct": ct0})["out"],
                       reps),
    }
    speedup = {kk: t["eager"] / v for kk, v in t.items()}

    summary = {
        "params": {"logN": logn, "L": L, "alpha": alpha, "k": k,
                   "cheb_degree": cheb_degree, "mod_K": mod_K},
        "lowering": {"exact": comp.summary(),
                     "multi": comp_multi.summary()},
        "modups": {"eager": d_eager.modup, "compiled": d_comp.modup,
                   "multi": d_multi.modup},
        "moddowns": {"eager": d_eager.moddown, "compiled": d_comp.moddown,
                     "multi": d_multi.moddown},
        "relins": {"eager": d_eager.relin, "compiled": d_comp.relin,
                   "multi": d_multi.relin},
        "relin_blocks_multi": d_multi.relin_blocks,
        "merged_relins_multi": comp_multi.summary()["merged_relins"],
        "bitexact_compiled_vs_eager": bitexact,
        "decrypt_err": err,
        "reconciled": reconciled["counts_match"],
        "reconciled_relin": reconciled["relin"],
        "scheduled_he2_sm_latency_ms": sched.latency_s * 1e3,
        "scheduled_he2_sm_energy_mj": sched.energy_j * 1e3,
        "us_per_bootstrap": t,
        "speedup_vs_eager": speedup,
    }

    # Evaluate every gate BEFORE writing the JSON so the on-disk record
    # reflects the real outcome (gate name -> (passed, message)).
    pr4 = PR4_COMPILED_MODUPS.get(common.SMOKE)
    gates = {
        "bitexact": (bitexact, "compiled pipeline is not bit-exact "
                               "with eager"),
        "modups_vs_eager": (
            d_comp.modup < d_eager.modup,
            f"compiled {d_comp.modup} !< eager {d_eager.modup}"),
        "modups_vs_pr4": (
            # the PR-4 baseline is recorded per bench shape; skip (and
            # say so below) when this shape has no recorded baseline
            True if pr4 is None else d_comp.modup < pr4,
            f"compiled-relin {d_comp.modup} !< PR-4 compiled "
            f"baseline {pr4}"),
        "relin_reconcile": (
            d_comp.relin > 0
            and reconciled["relin"][0] == reconciled["relin"][1],
            f"relin counts did not reconcile ({reconciled['relin']})"),
        "multi_moddowns": (
            d_multi.moddown < d_comp.moddown,
            f"multi {d_multi.moddown} !< compiled {d_comp.moddown}"),
        "relin_merge": (
            d_multi.relin_blocks >= 1,
            "exact=False merged no sum-of-CMult closure"),
        "compiled_speedup": (
            speedup["compiled"] >= GATE_COMPILED_SPEEDUP,
            f"compiled {speedup['compiled']:.2f}x < "
            f"{GATE_COMPILED_SPEEDUP}x vs eager"),
    }
    summary["gate"] = {
        "compiled_min_speedup": GATE_COMPILED_SPEEDUP,
        "compiled_speedup": speedup["compiled"],
        "pr4_compiled_modups": pr4,
        "results": {name: ok for name, (ok, _) in gates.items()},
        "passed": all(ok for ok, _ in gates.values()),
    }
    (RESULTS / "BENCH_bootstrap.json").write_text(
        json.dumps(summary, indent=2))

    lines = [
        f"bootstrap/{kk},{v:.0f},speedup={speedup[kk]:.2f}x"
        for kk, v in t.items()
    ]
    lines.append(
        f"bootstrap/modups,{d_eager.modup},compiled={d_comp.modup};"
        f"multi_moddowns={d_multi.moddown}/{d_comp.moddown}"
    )
    lines.append(
        f"bootstrap/relins,{d_comp.relin},blocks={d_multi.relin_blocks};"
        f"merged={comp_multi.summary()['merged_relins']}"
    )
    lines.append(
        f"bootstrap/sched_energy_mj,{sched.energy_j * 1e3:.4f},"
        f"latency_ms={sched.latency_s * 1e3:.4f}"
    )
    if pr4 is None:
        lines.append("bootstrap/pr4_gate,0,skipped=no PR-4 baseline "
                     "recorded for this shape (smoke only)")
    for name, (ok, msg) in gates.items():
        if not ok:
            raise RuntimeError(f"bootstrap {name} gate FAILED: {msg}")
    return lines
