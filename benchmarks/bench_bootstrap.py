"""Compiled-bootstrap benchmark: the full paper pipeline (ModRaise ->
CoeffToSlot -> re/im EvalMod -> merge -> SlotToCoeff) eager vs compiled
through ``repro.runtime``.

Three configurations, same program:

  eager      — ``Bootstrapper.bootstrap`` op by op (per-call plaintext
               encoding, one ModUp per hoisted baby block, per-rotation
               giant-step keyswitches)
  compiled   — ``Bootstrapper.compile()``: traced + lowered, bit-exact
               with eager; stage plaintexts encoded once, baby-step
               blocks share ONE ModUp per anchor through the digits
               cache
  multi      — ``compile(exact=False)``: giant-step PKBs additionally
               close with ONE ModDown per block
               (``runtime.lower.MultiHoistedStep``)

Writes BENCH_bootstrap.json (including the scheduled HE2-SM latency and
timeline-integrated energy of the executed plan via
``ExecutionReport.scheduled_result``) and ENFORCES the regression gates:

  * compiled ModUps strictly below eager ModUps (and multi ModDowns
    strictly below compiled ModDowns) — the paper's communication story
  * compiled ModUps strictly below the PR-4 compiled baseline
    (``PR4_COMPILED_MODUPS``, recorded per bench shape — smoke today;
    a shape without a recorded baseline skips the gate and says so):
    relinearization now compiles through the keyswitch family (BSGS
    Chebyshev EvalMod, CMults no longer eager)
  * relin ModUp/ModDown movement: every CMult relin runs compiled
    (relin counts recorded per configuration), and the exact=False
    lowering merges >= 1 sum-of-CMult closure
  * steady-state compiled wall clock at least GATE_COMPILED_SPEEDUP x
    faster than the eager pipeline (plaintext/evk caching + shared
    ModUps; measured after one warmup run absorbing jit traces)
  * HE2-SM communication-stall fraction of the scheduled plan within
    the calibrated per-shape budget (``STALL_BUDGET``; shapes without a
    recorded budget record the fraction and skip the gate, the paper's
    6.67% operating point is stored alongside for reference)
  * observability off by default costs <2% of the compiled runtime
    (``GATE_DISABLED_OVERHEAD``: a measured per-disabled-span cost
    scaled to the program's step count)

With ``--trace`` one extra compiled run executes under ``repro.obs``
tracing and a combined Perfetto timeline (real executor wall clock +
HE2-SM virtual schedule) lands in results/trace_bootstrap.json.
"""
from __future__ import annotations

import json
import pathlib
import time
import timeit

import numpy as np

from benchmarks import common

RESULTS = pathlib.Path(__file__).parent / "results"

# Perf regression gate (CI): compiled steady-state vs eager pipeline.
# The structural win (plaintexts encoded once + shared ModUps) measures
# ~1.4x on the smoke shape; the gate sits low enough to absorb shared-
# runner timing noise while still catching a loss of the caching path
# (which collapses the ratio to ~1.0x).
GATE_COMPILED_SPEEDUP = 1.1

# PR-4 compiled-bootstrap ModUp counts (CMults still eager, dense T_k
# recurrence in EvalMod) at the exact bench shapes — the relin refactor
# must land strictly below these.
PR4_COMPILED_MODUPS = {True: 65}      # keyed by common.SMOKE

# Communication-stall budget for the scheduled HE2-SM plan, keyed by
# common.SMOKE like PR4_COMPILED_MODUPS.  The paper's 6.67% claim
# (Sec. VI) holds at its large-N operating point (logN~16, deep L); the
# smoke shape (logN=8) is link-bound — tiny limbs amortize no compute
# under the transfers — and sits at ~0.34, so the smoke budget is a
# calibrated regression bound (catch a scheduler/fusion regression that
# widens stalls), not the paper claim itself.  Shapes without an entry
# record the measured fraction and skip the gate.
STALL_BUDGET = {True: 0.40}           # keyed by common.SMOKE

# Disabled-observability overhead gate: with obs off, the executor pays
# one disabled span() call per run plus a per-step bool check.  We bound
# a conservative estimate — (steps + 2) disabled-span calls at the
# measured per-call cost — by 2% of the compiled steady-state runtime.
GATE_DISABLED_OVERHEAD = 0.02


def _time(fn, reps: int) -> float:
    """us/run after one warmup (jit traces + plaintext caches)."""
    out = fn()
    out.c0.block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    out.c0.block_until_ready()
    return (time.perf_counter() - t0) * 1e6 / reps


def run() -> list[str]:
    from repro import obs
    from repro.core.bootstrap import Bootstrapper
    from repro.core.ckks import CKKSContext
    from repro.core.params import CKKSParams
    from repro.runtime import ProgramExecutor
    from repro.sim import HE2_SM

    RESULTS.mkdir(exist_ok=True)
    logn = 8 if common.SMOKE else 10
    L = 19 if common.SMOKE else 23
    alpha, k = (4, 4) if common.SMOKE else (3, 4)
    cheb_degree = 27 if common.SMOKE else 59
    mod_K = 3 if common.SMOKE else 5
    reps = 2

    params = CKKSParams(logN=logn, L=L, alpha=alpha, k=k, q_bits=29,
                        scale_bits=29, q0_bits=30)
    ctx = CKKSContext(params, seed=7 + common.SEED, hamming_weight=8)
    btp = Bootstrapper(ctx, n_groups=2 if common.SMOKE else 3,
                       mod_K=mod_K, cheb_degree=cheb_degree)
    nh = params.num_slots
    rng = np.random.default_rng(common.SEED)
    z = (rng.normal(size=nh) + 1j * rng.normal(size=nh)) * 0.01
    ct0 = ctx.encrypt(z, level=0)

    common.log(f"bootstrap: compiling (logN={logn}, L={L}, "
               f"cheb={cheb_degree})")
    comp = btp.compile(input_scale=ct0.scale)
    comp_multi = btp.compile(input_scale=ct0.scale, exact=False)
    ex = ProgramExecutor(ctx)

    def counts(fn):
        before = ctx.counters.snapshot()
        out = fn()
        d = ctx.counters.delta(before)
        return out, d

    common.log("bootstrap: eager/compiled/multi pipelines (counting ops)")
    out_eager, d_eager = counts(lambda: btp.bootstrap(ct0))
    res, d_comp = counts(
        lambda: ex.run(comp, {"ct": ct0}, with_report=True))
    out_comp = res["out"]
    _, d_multi = counts(lambda: ex.run(comp_multi, {"ct": ct0}))

    bitexact = (np.array_equal(np.asarray(out_comp.c0),
                               np.asarray(out_eager.c0))
                and np.array_equal(np.asarray(out_comp.c1),
                                   np.asarray(out_eager.c1)))
    err = float(np.abs(ctx.decrypt(out_comp) - z).max())
    sched = res.report.scheduled_result(comp, HE2_SM)
    reconciled = res.report.reconcile()

    # Communication-stall budget on the scheduled HE2-SM timelines.
    sb_budget = STALL_BUDGET.get(common.SMOKE)
    stall = obs.analyze(sched.timelines, latency_s=sched.latency_s,
                        name="bootstrap-he2sm",
                        budget=(sb_budget if sb_budget is not None
                                else obs.PAPER_STALL_BUDGET))
    common.log(f"bootstrap: HE2-SM comm-stall {stall.fraction:.4f} "
               f"(budget {sb_budget})")

    # Publish the run's accounting into the global metrics registry so
    # the exposition in the JSON record reconciles with OpCounters and
    # the scheduler's energy breakdown.
    obs.publish_counters(obs.METRICS, ctx.counters)
    obs.publish_energy(obs.METRICS, sched.energy_by_engine,
                       config="HE2-SM")

    common.log("bootstrap: timing steady-state pipelines")
    t = {
        "eager": _time(lambda: btp.bootstrap(ct0), reps),
        "compiled": _time(lambda: ex.run(comp, {"ct": ct0})["out"], reps),
        "multi": _time(lambda: ex.run(comp_multi, {"ct": ct0})["out"],
                       reps),
    }
    speedup = {kk: t["eager"] / v for kk, v in t.items()}

    # Disabled-overhead estimate: measure one disabled obs.span() call,
    # scale to (steps + 2) calls per run, compare against the compiled
    # steady-state runtime.  obs must be off here (the default).
    assert not obs.enabled(), "obs must be disabled for the overhead gate"
    n_calls = 20000
    per_span_s = timeit.timeit(
        lambda: obs.span("bench.noop", step=1), number=n_calls) / n_calls
    compiled_s = t["compiled"] * 1e-6
    overhead_s = (len(comp.steps) + 2) * per_span_s
    overhead_frac = overhead_s / compiled_s if compiled_s else 0.0
    common.log(f"bootstrap: disabled-obs overhead "
               f"{overhead_s * 1e6:.2f}us / compiled "
               f"{t['compiled']:.0f}us ({overhead_frac:.4%})")

    summary = {
        "params": {"logN": logn, "L": L, "alpha": alpha, "k": k,
                   "cheb_degree": cheb_degree, "mod_K": mod_K},
        "lowering": {"exact": comp.summary(),
                     "multi": comp_multi.summary()},
        "modups": {"eager": d_eager.modup, "compiled": d_comp.modup,
                   "multi": d_multi.modup},
        "moddowns": {"eager": d_eager.moddown, "compiled": d_comp.moddown,
                     "multi": d_multi.moddown},
        "relins": {"eager": d_eager.relin, "compiled": d_comp.relin,
                   "multi": d_multi.relin},
        "relin_blocks_multi": d_multi.relin_blocks,
        "merged_relins_multi": comp_multi.summary()["merged_relins"],
        "bitexact_compiled_vs_eager": bitexact,
        "decrypt_err": err,
        "reconciled": reconciled["counts_match"],
        "reconciled_relin": reconciled["relin"],
        "scheduled_he2_sm_latency_ms": sched.latency_s * 1e3,
        "scheduled_he2_sm_energy_mj": sched.energy_j * 1e3,
        "us_per_bootstrap": t,
        "speedup_vs_eager": speedup,
        "stall_budget": {
            **stall.as_dict(),
            "paper_budget_frac": obs.PAPER_STALL_BUDGET,
            "gated": sb_budget is not None,
        },
        "disabled_overhead": {
            "per_span_ns": per_span_s * 1e9,
            "est_overhead_us": overhead_s * 1e6,
            "compiled_us": t["compiled"],
            "frac": overhead_frac,
        },
        "metrics": {
            name: fam["series"]
            for name, fam in obs.METRICS.snapshot().items()
            if name.startswith(("fhe.", "sim."))
        },
    }

    # Evaluate every gate BEFORE writing the JSON so the on-disk record
    # reflects the real outcome (gate name -> (passed, message)).
    pr4 = PR4_COMPILED_MODUPS.get(common.SMOKE)
    gates = {
        "bitexact": (bitexact, "compiled pipeline is not bit-exact "
                               "with eager"),
        "modups_vs_eager": (
            d_comp.modup < d_eager.modup,
            f"compiled {d_comp.modup} !< eager {d_eager.modup}"),
        "modups_vs_pr4": (
            # the PR-4 baseline is recorded per bench shape; skip (and
            # say so below) when this shape has no recorded baseline
            True if pr4 is None else d_comp.modup < pr4,
            f"compiled-relin {d_comp.modup} !< PR-4 compiled "
            f"baseline {pr4}"),
        "relin_reconcile": (
            d_comp.relin > 0
            and reconciled["relin"][0] == reconciled["relin"][1],
            f"relin counts did not reconcile ({reconciled['relin']})"),
        "multi_moddowns": (
            d_multi.moddown < d_comp.moddown,
            f"multi {d_multi.moddown} !< compiled {d_comp.moddown}"),
        "relin_merge": (
            d_multi.relin_blocks >= 1,
            "exact=False merged no sum-of-CMult closure"),
        "compiled_speedup": (
            speedup["compiled"] >= GATE_COMPILED_SPEEDUP,
            f"compiled {speedup['compiled']:.2f}x < "
            f"{GATE_COMPILED_SPEEDUP}x vs eager"),
        "stall_budget": (
            # calibrated per shape; record-only when no budget recorded
            True if sb_budget is None else stall.fraction <= sb_budget,
            f"HE2-SM comm-stall {stall.fraction:.4f} > "
            f"budget {sb_budget}"),
        "disabled_overhead": (
            overhead_frac < GATE_DISABLED_OVERHEAD,
            f"disabled obs overhead {overhead_frac:.4%} !< "
            f"{GATE_DISABLED_OVERHEAD:.0%} of compiled runtime"),
    }
    summary["gate"] = {
        "compiled_min_speedup": GATE_COMPILED_SPEEDUP,
        "compiled_speedup": speedup["compiled"],
        "pr4_compiled_modups": pr4,
        "stall_budget_frac": sb_budget,
        "disabled_overhead_max": GATE_DISABLED_OVERHEAD,
        "results": {name: ok for name, (ok, _) in gates.items()},
        "passed": all(ok for ok, _ in gates.values()),
    }
    (RESULTS / "BENCH_bootstrap.json").write_text(
        json.dumps(summary, indent=2))

    if common.TRACE:
        # One extra compiled run under tracing, AFTER the gated timing
        # loops so per-step syncs never perturb the measurements.  The
        # artifact pairs the real executor wall clock with the HE2-SM
        # virtual schedule in a single Perfetto timeline.
        common.log("bootstrap: tracing one compiled run for Perfetto")
        obs.TRACER.reset()
        obs.enable()
        try:
            with obs.span("bench.bootstrap", smoke=common.SMOKE,
                          logN=logn, L=L):
                ex.run(comp, {"ct": ct0})
        finally:
            obs.disable()
        trace_path = RESULTS / "trace_bootstrap.json"
        obs.export.write_trace(
            trace_path, tracer=obs.TRACER, timelines=sched.timelines,
            sim_process="HE2-SM schedule (virtual clock)")
        obs.TRACER.reset()
        common.log(f"bootstrap: wrote {trace_path}")

    lines = [
        f"bootstrap/{kk},{v:.0f},speedup={speedup[kk]:.2f}x"
        for kk, v in t.items()
    ]
    lines.append(
        f"bootstrap/modups,{d_eager.modup},compiled={d_comp.modup};"
        f"multi_moddowns={d_multi.moddown}/{d_comp.moddown}"
    )
    lines.append(
        f"bootstrap/relins,{d_comp.relin},blocks={d_multi.relin_blocks};"
        f"merged={comp_multi.summary()['merged_relins']}"
    )
    lines.append(
        f"bootstrap/sched_energy_mj,{sched.energy_j * 1e3:.4f},"
        f"latency_ms={sched.latency_s * 1e3:.4f}"
    )
    lines.append(
        f"bootstrap/comm_stall,{stall.comm_stall_s * 1e6:.2f},"
        f"frac={stall.fraction:.4f};budget={sb_budget};"
        f"paper={obs.PAPER_STALL_BUDGET}"
    )
    lines.append(
        f"bootstrap/obs_disabled_overhead,{overhead_s * 1e6:.2f},"
        f"frac={overhead_frac:.5f};max={GATE_DISABLED_OVERHEAD}"
    )
    if pr4 is None:
        lines.append("bootstrap/pr4_gate,0,skipped=no PR-4 baseline "
                     "recorded for this shape (smoke only)")
    if sb_budget is None:
        lines.append("bootstrap/stall_gate,0,recorded-only=no "
                     "calibrated stall budget for this shape")
    for name, (ok, msg) in gates.items():
        if not ok:
            raise RuntimeError(f"bootstrap {name} gate FAILED: {msg}")
    common.log("bootstrap: all gates passed")
    return lines
