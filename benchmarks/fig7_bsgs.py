"""Fig. 7: BSGS (bs, gs) exploration under the EVF-monolithic model vs
the heterogeneous (IRF + hoisting) model — the optima differ."""
from __future__ import annotations

import json
import pathlib

from benchmarks import common
from repro.dfg.programs import bootstrapping_dfg
from repro.sim import HE2_SM, SHARP
from repro.sim.engine import simulate_program

RESULTS = pathlib.Path(__file__).parent / "results"


def run() -> list[str]:
    RESULTS.mkdir(exist_ok=True)
    lines, summary = [], {"EVF_SHARP": {}, "IRF_HE2": {}}
    for bs in (0, 4) if common.SMOKE else (0, 2, 4, 8, 16):
        g = bootstrapping_dfg(bsgs_bs=bs).g
        r_evf = simulate_program(g, SHARP, "minks", "EVF")
        r_irf = simulate_program(g, HE2_SM, "hoist", "IRF", fusion=True)
        label = "off" if bs == 0 else str(bs)
        summary["EVF_SHARP"][label] = r_evf.latency_s * 1e3
        summary["IRF_HE2"][label] = r_irf.latency_s * 1e3
        lines.append(
            f"fig7/bs={label},0.0,evf_ms={r_evf.latency_s*1e3:.3f};"
            f"irf_ms={r_irf.latency_s*1e3:.3f}"
        )
    best_evf = min(summary["EVF_SHARP"], key=summary["EVF_SHARP"].get)
    best_irf = min(summary["IRF_HE2"], key=summary["IRF_HE2"].get)
    summary["optimal"] = {"EVF": best_evf, "IRF_hoisting": best_irf}
    lines.append(f"fig7/optimal,0.0,evf_best=bs{best_evf};irf_best=bs{best_irf}")
    (RESULTS / "fig7.json").write_text(json.dumps(summary, indent=2))
    return lines
