"""Compiled-runtime benchmark: eager per-ct loops vs the DFG-compiled
executor (``repro.runtime``) on a BSGS matvec workload.

Four configurations, same program and same answers:

  eager      — ``linear.matvec_bsgs`` per ciphertext (per-call plaintext
               encoding, one ModUp per hoisted block + per giant rotate)
  compiled   — traced + lowered, per-ct execution: plaintexts encoded
               once, ONE ModUp shared across all baby-step blocks
  batched    — the same compiled plan over all ciphertexts at once via
               ``jax.vmap`` over the ct axis (one jit trace per plan)
  fused      — HERO fusion DP applied before lowering: the whole BSGS
               collapses into a single hoisted block (1 ModUp total)

Writes BENCH_runtime.json and ENFORCES the regression gate: compiled +
batched execution must beat the eager per-ct loop by >= 2x on the smoke
shape (measured steady-state, after one warmup run that absorbs jit
tracing and plaintext encoding).
"""
from __future__ import annotations

import json
import pathlib
import time

import numpy as np

from benchmarks import common

RESULTS = pathlib.Path(__file__).parent / "results"

# Perf regression gate (CI): compiled+batched vs eager per-ct loop.
GATE_BATCHED_SPEEDUP = 2.0


def _params(logn: int):
    from repro.core.params import CKKSParams

    return CKKSParams(logN=logn, L=5, alpha=2, k=3, q_bits=29,
                      scale_bits=29)


def _time(fn, reps: int) -> float:
    """us/run after one warmup (jit traces + plaintext caches)."""
    out = fn()
    (out[0].c0 if isinstance(out, list) else out.c0).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    (out[0].c0 if isinstance(out, list) else out.c0).block_until_ready()
    return (time.perf_counter() - t0) * 1e6 / reps


def run() -> list[str]:
    from repro.core import linear
    from repro.core.ckks import CKKSContext
    from repro.runtime import (
        ProgramExecutor, TraceContext, compile_program,
    )

    RESULTS.mkdir(exist_ok=True)
    logn = 9 if common.SMOKE else 11
    n_diag = 8 if common.SMOKE else 16
    bs = 4
    batch = 4 if common.SMOKE else 8
    reps = 2 if common.SMOKE else 3

    params = _params(logn)
    ctx = CKKSContext(params, seed=3 + common.SEED)
    nh = params.num_slots
    rng = np.random.default_rng(common.SEED)
    diags = {d: rng.normal(size=nh) for d in range(n_diag)}
    zs = [rng.normal(size=nh) + 1j * rng.normal(size=nh)
          for _ in range(batch)]
    cts = [ctx.encrypt(z) for z in zs]

    tc = TraceContext(params)
    h = tc.input("x", level=params.L, scale=params.scale)
    tc.output(linear.matvec_bsgs(tc, h, diags, bs=bs), "y")
    comp = compile_program(tc)
    comp_fused = compile_program(tc, fusion=True)
    ex = ProgramExecutor(ctx)

    def count_modups(fn):
        before = ctx.counters.snapshot()
        fn()
        return ctx.counters.delta(before).modup

    modups = {
        "eager": count_modups(
            lambda: linear.matvec_bsgs(ctx, cts[0], diags, bs=bs)),
        "compiled": count_modups(lambda: ex.run(comp, {"x": cts[0]})),
        "fused": count_modups(lambda: ex.run(comp_fused, {"x": cts[0]})),
    }

    t = {
        "eager_loop": _time(
            lambda: [linear.matvec_bsgs(ctx, c, diags, bs=bs)
                     for c in cts][-1], reps),
        "compiled_loop": _time(
            lambda: [ex.run(comp, {"x": c})["y"] for c in cts][-1], reps),
        "compiled_batched": _time(
            lambda: ex.run_batched(comp, {"x": cts})["y"], reps),
        "fused_batched": _time(
            lambda: ex.run_batched(comp_fused, {"x": cts})["y"], reps),
    }
    speedup = {k: t["eager_loop"] / v for k, v in t.items()}

    batched_x = speedup["compiled_batched"]
    summary = {
        "params": {"logN": logn, "L": 5, "alpha": 2, "diags": n_diag,
                   "bs": bs, "batch": batch},
        "lowering": {"unfused": comp.summary(),
                     "fused": comp_fused.summary()},
        "modups_per_ct": modups,
        "us_per_batch": t,
        "speedup_vs_eager_loop": speedup,
        "gate": {"batched_min_speedup": GATE_BATCHED_SPEEDUP,
                 "batched_speedup": batched_x,
                 "passed": batched_x >= GATE_BATCHED_SPEEDUP},
    }
    (RESULTS / "BENCH_runtime.json").write_text(json.dumps(summary, indent=2))

    lines = [
        f"runtime/{k},{v:.0f},speedup={speedup[k]:.2f}x"
        for k, v in t.items()
    ]
    lines.append(
        f"runtime/modups,{modups['eager']},compiled={modups['compiled']};"
        f"fused={modups['fused']}"
    )
    if not (modups["fused"] < modups["compiled"] < modups["eager"]):
        raise RuntimeError(
            f"runtime ModUp gate FAILED: expected fused < compiled < "
            f"eager, got {modups}"
        )
    if batched_x < GATE_BATCHED_SPEEDUP:
        raise RuntimeError(
            f"runtime perf gate FAILED: compiled+batched "
            f"{batched_x:.2f}x < {GATE_BATCHED_SPEEDUP}x vs eager per-ct "
            f"loop"
        )
    return lines
