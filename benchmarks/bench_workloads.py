"""Encrypted-inference workload benchmark: real applications (packed
logistic regression, small MLPs) eager vs compiled through
``repro.workloads``.

Per workload, the same model runs three ways:

  eager      — ``WorkloadProgram.run_eager``: the committed plan
               replayed op by op on the ``CKKSContext``
  compiled   — ``compile_workload`` (fusion off, exact): every segment
               lowered through ``lower_program``, executed batched via
               ``ProgramExecutor.run_batched``; bit-exact with eager
  fused      — ``compile_workload(fusion=True)``: HERO PKB fusion on,
               numerically equivalent, fewest ModUps (shallow
               workloads only — the bootstrap-inserted chain stays on
               the exact lowering)

The bootstrap-insertion workload (``mlp_boot``) compiles with
``input_level=7`` — a forced level exhaustion the planner must resolve
by splicing a ``Bootstrapper.compile`` program between the layers.

Writes BENCH_workloads.json (ModUp/ModDown counts, measured wall
latency, scheduled HE2-SM latency/energy per workload) and ENFORCES
the regression gates per workload:

  * compiled bit-exact with eager (fusion=False contract)
  * compiled ModUps strictly below eager ModUps
  * decrypt accuracy within the model's tolerance of the
    ``matvec_plain``+numpy reference (compiled AND fused runs)
  * exact predicted-vs-executed reconciliation per segment
  * the insertion workload splices >= 1 bootstrap segment
"""
from __future__ import annotations

import json
import pathlib
import time

import numpy as np

from benchmarks import common

RESULTS = pathlib.Path(__file__).parent / "results"


def _ct_eq(a, b) -> bool:
    return (np.array_equal(np.asarray(a.c0), np.asarray(b.c0))
            and np.array_equal(np.asarray(a.c1), np.asarray(b.c1)))


def run() -> list[str]:
    from repro.core.bootstrap import Bootstrapper
    from repro.core.ckks import CKKSContext
    from repro.core.params import CKKSParams
    from repro.sim import HE2_SM
    from repro.workloads import (
        WorkloadExecutor, compile_workload, logreg, mlp, mlp_bootstrap,
        scheduled_result,
    )

    RESULTS.mkdir(exist_ok=True)
    rng = np.random.default_rng(common.SEED)

    # shallow 14-level chain for the plain inference workloads
    p_wl = CKKSParams(logN=8, L=14, alpha=2, k=3, q_bits=29,
                      scale_bits=29)
    ctx_wl = CKKSContext(p_wl, seed=7 + common.SEED)
    nh = p_wl.num_slots

    # deep bootstrap-capable chain for the insertion workload (the
    # bench_bootstrap smoke shape)
    common.log("workloads: building bootstrap-capable context")
    p_bt = CKKSParams(logN=8, L=19, alpha=4, k=4, q_bits=29,
                      scale_bits=29, q0_bits=30)
    ctx_bt = CKKSContext(p_bt, seed=7 + common.SEED, hamming_weight=8)
    btp = Bootstrapper(ctx_bt, n_groups=2, mod_K=3, cheb_degree=27)

    # (name, ctx, model, btp, input_level, batch, fused config too?)
    cases = [("logreg", ctx_wl, logreg(nh, bs=4), None, 9, 2, True)]
    if not common.SMOKE:
        cases.append(("mlp", ctx_wl, mlp(nh, bs=4), None, None, 2, True))
    cases.append(("mlp_boot", ctx_bt, mlp_bootstrap(nh, bs=4), btp, 7,
                  1, False))

    records: dict = {}
    gates: dict = {}
    lines: list[str] = []
    for name, ctx, m, btp_i, in_level, batch, with_fused in cases:
        common.log(f"workloads: {name}: compiling")
        wp = compile_workload(m, ctx.params, btp=btp_i,
                              input_level=in_level)
        xs = [m.sample(rng) for _ in range(batch)]
        cts = [ctx.encrypt(x, level=in_level) if in_level is not None
               else ctx.encrypt(x) for x in xs]
        c = ctx.counters

        common.log(f"workloads: {name}: eager replay x{batch}")
        t0, s0 = time.perf_counter(), c.snapshot()
        exps = [wp.run_eager(ctx, ct, btp=btp_i) for ct in cts]
        d_eager = c.delta(s0)
        t_eager = (time.perf_counter() - t0) / batch

        common.log(f"workloads: {name}: compiled batched run")
        ex = WorkloadExecutor(ctx)
        t0, s1 = time.perf_counter(), c.snapshot()
        res = ex.run_batched(wp, cts, with_report=True)
        d_comp = c.delta(s1)
        t_comp = (time.perf_counter() - t0) / batch

        bitexact = all(_ct_eq(g, e) for g, e in zip(res.output, exps))
        errs = [float(np.abs(ctx.decrypt(o).real - m.reference(x)).max())
                for x, o in zip(xs, res.output)]
        rec = res.reconcile()
        sched = scheduled_result(wp, HE2_SM, batch=batch)

        rec_f = None
        if with_fused:
            common.log(f"workloads: {name}: fused run")
            fused = compile_workload(m, ctx.params, btp=btp_i,
                                     input_level=in_level, fusion=True)
            s2 = c.snapshot()
            res_f = ex.run_batched(fused, cts)
            d_fused = c.delta(s2)
            err_f = max(
                float(np.abs(ctx.decrypt(o).real - m.reference(x)).max())
                for x, o in zip(xs, res_f.output))
            rec_f = {"modup": d_fused.modup, "moddown": d_fused.moddown,
                     "decrypt_err": err_f,
                     "predicted_modups": fused.predicted_modups()}
            gates[f"{name}_fused_modups"] = (
                d_fused.modup <= d_comp.modup,
                f"fused {d_fused.modup} !<= compiled {d_comp.modup}")
            gates[f"{name}_fused_accuracy"] = (
                err_f < m.tolerance,
                f"fused decrypt err {err_f:.2e} !< tol {m.tolerance}")

        records[name] = {
            "layers": [s["stage"] for s in wp.plan.table],
            "n_segments": len(wp.segments),
            "n_bootstraps": wp.n_bootstraps,
            "input_level": wp.input_level,
            "output_level": wp.output_level,
            "batch": batch,
            "modups": {"eager": d_eager.modup, "compiled": d_comp.modup},
            "moddowns": {"eager": d_eager.moddown,
                         "compiled": d_comp.moddown},
            "predicted_modups": wp.predicted_modups(),
            "bitexact_compiled_vs_eager": bitexact,
            "decrypt_err": max(errs),
            "tolerance": m.tolerance,
            "reconciled": rec["counts_match"],
            "wall_s_per_ct": {"eager": t_eager, "compiled": t_comp},
            "scheduled_he2_sm_latency_ms": sched.latency_s * 1e3,
            "scheduled_he2_sm_energy_mj": sched.energy_j * 1e3,
            "fused": rec_f,
        }
        gates[f"{name}_bitexact"] = (
            bitexact, "compiled workload is not bit-exact with eager")
        gates[f"{name}_modups"] = (
            d_comp.modup < d_eager.modup,
            f"compiled {d_comp.modup} !< eager {d_eager.modup}")
        gates[f"{name}_accuracy"] = (
            max(errs) < m.tolerance,
            f"decrypt err {max(errs):.2e} !< tol {m.tolerance}")
        gates[f"{name}_reconcile"] = (
            rec["counts_match"], "op counts did not reconcile")
        lines.append(
            f"workloads/{name},{t_comp * 1e6:.0f},"
            f"modups={d_comp.modup}/{d_eager.modup};"
            f"err={max(errs):.1e};boots={wp.n_bootstraps}")

    gates["insertion"] = (
        records["mlp_boot"]["n_bootstraps"] >= 1,
        "planner spliced no bootstrap at the forced level exhaustion")

    summary = {
        "params": {"shallow": {"logN": p_wl.logN, "L": p_wl.L},
                   "deep": {"logN": p_bt.logN, "L": p_bt.L}},
        "workloads": records,
        "gate": {
            "results": {k: ok for k, (ok, _) in gates.items()},
            "passed": all(ok for ok, _ in gates.values()),
        },
    }
    (RESULTS / "BENCH_workloads.json").write_text(
        json.dumps(summary, indent=2))

    failures = [f"{k}: {msg}" for k, (ok, msg) in gates.items() if not ok]
    if failures:
        raise RuntimeError("workload gates failed: " + "; ".join(failures))
    return lines
