"""Fig. 16: hardware utilization + op-count mix under hoisting vs HERO.

Utilization comes from the event-driven group scheduler's per-engine
occupancy traces (busy time / makespan measured on the actual schedule),
not from busy-time ratios of an algebraic latency.
"""
from __future__ import annotations

import json
import pathlib

from benchmarks.common import programs_for, smoke_subset
from repro.sim import HE2_SM
from repro.sim.engine import simulate_program
from repro.sim.schedule import ENGINES

RESULTS = pathlib.Path(__file__).parent / "results"


def run() -> list[str]:
    RESULTS.mkdir(exist_ok=True)
    lines, summary = [], {}
    for bench in smoke_subset(["bootstrapping", "helr", "resnet20"]):
        g_bsgs = programs_for(bench, bsgs=True)
        g_full = programs_for(bench, bsgs=False)
        r_hoist = simulate_program(g_bsgs, HE2_SM, "hoist", "IRF",
                                   mode="pipelined")
        r_hero = simulate_program(g_full, HE2_SM, "hoist", "IRF",
                                  fusion=True, mode="pipelined")
        summary[bench] = {}
        for name, r in (("hoisting", r_hoist), ("HERO", r_hero)):
            memop_words = (r.volumes.ip_macs + r.volumes.ewo_ext_words
                           + r.volumes.ewo_words + r.volumes.autom_words)
            comop_words = r.volumes.ntt_words + r.volumes.bconv_macs
            util = {e: r.engine_util(e) for e in ENGINES}
            summary[bench][name] = {
                "xpu_util": r.xpu_util, "xmu_util": r.xmu_util,
                "engine_util": util,
                "comm_stall_frac": r.comm_stall_frac,
                "trace_events": {e: len(r.timelines[e]) for e in ENGINES},
                "memop_frac": memop_words / (memop_words + comop_words),
            }
            lines.append(
                f"fig16/{bench}/{name},0.0,xpu={r.xpu_util:.3f};"
                f"xmu={r.xmu_util:.3f};"
                f"link={util['link']:.3f};"
                f"memop_frac={memop_words/(memop_words+comop_words):.3f}"
            )
    (RESULTS / "fig16.json").write_text(json.dumps(summary, indent=2))
    return lines
