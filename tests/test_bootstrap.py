"""Bootstrap pipeline tests.

Precision note: with 30-bit RNS words (uint64 product bound) the bootstrap
scale is 2^29, so EvalMod precision is structurally ~2^-10 of q0 — real
deployments use 50-60-bit words.  Tolerances reflect that; the pipeline
structure (ModRaise -> 3-stage C2S -> EvalMod -> 3-stage S2C) is exactly
the paper's benchmark configuration [6].
"""
import numpy as np
import pytest

from repro.core.bootstrap import Bootstrapper
from repro.core.ckks import CKKSContext
from repro.core.params import CKKSParams


@pytest.fixture(scope="module")
def boot_ctx():
    p = CKKSParams(logN=10, L=23, alpha=3, k=4, q_bits=29, scale_bits=29,
                   q0_bits=30)
    return CKKSContext(p, seed=7, hamming_weight=8)


@pytest.fixture(scope="module")
def btp(boot_ctx):
    return Bootstrapper(boot_ctx, n_groups=3, mod_K=5, cheb_degree=59)


def test_stage_matrices_exact(btp, boot_ctx, rng):
    """Composed stage groups == special FFT (bit-reversal cancels)."""
    enc = boot_ctx.encoder
    nh = enc.Nh
    z = rng.normal(size=nh) + 1j * rng.normal(size=nh)
    comp = btp.c2s_groups[2] @ btp.c2s_groups[1] @ btp.c2s_groups[0]
    fsi = enc.fft_special_inv(z)
    assert np.abs(comp @ z - fsi[enc.bitrev]).max() < 1e-12
    comp_s = btp.s2c_groups[2] @ btp.s2c_groups[1] @ btp.s2c_groups[0]
    assert np.abs(comp_s @ (comp @ z) - z).max() < 1e-12


def test_stage_matrices_sparse(btp):
    """Each merged stage has few diagonals — the PKB structure HERO sees."""
    from repro.core.linear import matrix_diagonals

    for g in btp.c2s_groups + btp.s2c_groups:
        n_diags = len(matrix_diagonals(g))
        assert n_diags <= 2 ** 4 + 1, "merged stage should stay sparse"


def test_hom_c2s_s2c_identity(btp, boot_ctx, rng):
    ctx = boot_ctx
    nh = ctx.params.num_slots
    z = (rng.normal(size=nh) + 1j * rng.normal(size=nh)) * 0.01
    ct = ctx.encrypt(z)
    out = btp.slot_to_coeff(btp.coeff_to_slot(ct))
    assert np.abs(ctx.decrypt(out) - z).max() < 1e-3


@pytest.mark.slow
def test_full_bootstrap(btp, boot_ctx, rng):
    ctx = boot_ctx
    nh = ctx.params.num_slots
    z = (rng.normal(size=nh) + 1j * rng.normal(size=nh)) * 0.01
    ct0 = ctx.encrypt(z, level=0)
    out = btp.bootstrap(ct0)
    assert out.level >= 1, "bootstrap must recover usable levels"
    err = np.abs(ctx.decrypt(out) - z).max()
    assert err < 5e-3, f"bootstrap error {err}"


def test_mod_raise_exact(boot_ctx, rng):
    """ModRaise: decrypted coefficients == level-0 coefficients mod q0,
    with the q0-multiples (the I overflow) bounded by the sparse secret."""
    from repro.core import poly
    from repro.core.encoding import centered_crt

    ctx = boot_ctx
    nh = ctx.params.num_slots
    q0 = ctx.params.q_primes[0]
    z = (rng.normal(size=nh) + 1j * rng.normal(size=nh)) * 0.01
    ct0 = ctx.encrypt(z, level=0)
    btp_local = Bootstrapper.__new__(Bootstrapper)
    btp_local.ctx = ctx
    raised = Bootstrapper.mod_raise(btp_local, ct0)
    assert raised.level == ctx.params.L

    def raw_coeffs(ct):
        primes = ctx.chain(ct.level)
        mods = ctx.pc.mods(primes)
        m_eval = poly.add(
            ct.c0, poly.mul(ct.c1, ctx.keys.s_eval[: ct.level + 1], mods),
            mods,
        )
        return centered_crt(
            np.asarray(poly.intt(m_eval, primes, ctx.pc)), primes
        )

    low = raw_coeffs(ct0)
    high = raw_coeffs(raised)
    diff = high - low
    ks = diff / q0
    assert all(int(d) % q0 == 0 for d in diff), "m + q0*I structure broken"
    h = 8  # sparse secret hamming weight used by the fixture
    assert max(abs(int(k)) for k in ks) <= h + 1, "I overflow beyond bound"
