"""Unit tests for the RNS/NTT/BConv substrate.

Hypothesis-based property tests live in test_rns_props.py so that
collection never hard-errors on an interpreter without hypothesis.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import nt, poly
from repro.core.params import CKKSParams
from repro.core.rns import ntt_ref, intt_ref


@pytest.fixture(scope="module")
def params():
    return CKKSParams(logN=8, L=3, alpha=2, k=2, q_bits=29)


@pytest.fixture(scope="module")
def pc(params):
    return poly.PolyContext(params)


def test_prime_properties(params):
    two_n = 2 * params.N
    for p in params.q_primes + params.p_primes:
        assert nt.is_prime(p)
        assert p % two_n == 1, "NTT-friendly primes must be 1 mod 2N"
    assert len(set(params.q_primes + params.p_primes)) == params.L + 1 + params.k


def test_digit_groups(params):
    groups = params.digit_groups(params.L)
    assert sum(len(g) for g in groups) == params.L + 1
    assert len(groups) == params.dnum


def test_ntt_ref_roundtrip(params, pc):
    rng = np.random.default_rng(0)
    t = pc.rns.tables[0]
    a = rng.integers(0, t.p, params.N, dtype=np.uint64)
    assert np.array_equal(intt_ref(ntt_ref(a, t), t), a)


def test_ntt_negacyclic_convolution():
    """NTT-domain product == schoolbook negacyclic convolution (exact)."""
    p = CKKSParams(logN=6, L=1, alpha=1, k=1, q_bits=29)
    pc = poly.PolyContext(p)
    t = pc.rns.tables[0]
    rng = np.random.default_rng(1)
    N = p.N
    a = rng.integers(0, t.p, N, dtype=np.uint64)
    b = rng.integers(0, t.p, N, dtype=np.uint64)
    prod = intt_ref((ntt_ref(a, t) * ntt_ref(b, t)) % np.uint64(t.p), t)
    c = np.zeros(N, dtype=object)
    for i in range(N):
        for j in range(N):
            k = i + j
            if k < N:
                c[k] = (c[k] + int(a[i]) * int(b[j])) % t.p
            else:
                c[k - N] = (c[k - N] - int(a[i]) * int(b[j])) % t.p
    assert np.array_equal(prod, np.array([int(x) % t.p for x in c], dtype=np.uint64))


def test_jnp_ntt_matches_ref(params, pc):
    rng = np.random.default_rng(2)
    primes = params.q_chain(params.L)
    x = np.stack([rng.integers(0, q, params.N, dtype=np.uint64) for q in primes])
    fx = np.asarray(poly.ntt(jnp.asarray(x), primes, pc))
    for i, q in enumerate(primes):
        t = pc.rns.tables[pc.rns.prime_index[q]]
        assert np.array_equal(fx[i], ntt_ref(x[i], t)), f"limb {i}"
    ix = np.asarray(poly.intt(jnp.asarray(fx), primes, pc))
    assert np.array_equal(ix, x)


def test_bconv_crt_consistency(params, pc):
    """FBC result == exact value + k*prod(src) for a consistent small k."""
    rng = np.random.default_rng(3)
    src, dst = params.q_chain(1), params.p_primes
    Q = 1
    for s in src:
        Q *= s
    xs = np.stack([rng.integers(0, q, params.N, dtype=np.uint64) for q in src])
    ys = np.asarray(poly.bconv(jnp.asarray(xs), tuple(src), tuple(dst), pc))
    for c in range(0, params.N, 37):  # spot-check coefficients
        X = 0
        for i, q in enumerate(src):
            qhat = Q // q
            X = (X + int(xs[i, c]) * nt.modinv(qhat, q) * qhat) % Q
        assert any(
            all(int(ys[j, c]) == (X + k * Q) % d for j, d in enumerate(dst))
            for k in range(len(src) + 1)
        ), f"coefficient {c}: no consistent FBC multiple"


def test_automorphism_roundtrip(params, pc):
    rng = np.random.default_rng(4)
    primes = params.q_chain(params.L)
    x = np.stack([rng.integers(0, q, params.N, dtype=np.uint64) for q in primes])
    g = pc.rns.galois_for_rotation(3)
    ginv = pow(g, -1, 2 * params.N)
    y = poly.automorphism(jnp.asarray(x), primes, g, pc, eval_domain=False)
    z = poly.automorphism(y, primes, ginv, pc, eval_domain=False)
    assert np.array_equal(np.asarray(z), x)


def test_automorphism_composition(params, pc):
    """sigma_a(sigma_b(x)) == sigma_{a*b}(x)."""
    rng = np.random.default_rng(5)
    primes = params.q_chain(1)
    x = jnp.asarray(
        np.stack([rng.integers(0, q, params.N, dtype=np.uint64) for q in primes])
    )
    two_n = 2 * params.N
    ga = pc.rns.galois_for_rotation(3)
    gb = pc.rns.galois_for_rotation(7)
    y1 = poly.automorphism(
        poly.automorphism(x, primes, ga, pc, eval_domain=False),
        primes, gb, pc, eval_domain=False,
    )
    y2 = poly.automorphism(x, primes, (ga * gb) % two_n, pc, eval_domain=False)
    assert np.array_equal(np.asarray(y1), np.asarray(y2))
