"""Fault-tolerance invariants: taxonomy, health guards, chaos recovery.

Covers the acceptance gates of the fault-tolerant serving layer:
  * typed error taxonomy (hierarchy, context/hint rendering, the
    retryable classification the server's backoff loop consults)
  * ciphertext health guards catch corruption, scale drift, level
    exhaustion and chain mismatches as typed errors
  * registry eviction surfaces ``KeyUnavailableError`` (tenant id +
    remediation), never a raw ``KeyError``
  * deterministic chaos schedules: transient faults retry to success,
    mid-flight key evictions recover via deterministic re-keygen (and
    still decrypt correctly), zero silently-wrong results
  * quarantine bisect isolates exactly the poisoned request — zero
    co-batched victims
  * the per-tenant circuit breaker trips, sheds, and recovers
  * deadline-expired requests are shed, not executed
  * every request is terminally accounted:
    completed + failed + shed + rejected == submitted
  * invariant-guard mode adds ZERO engine retraces
"""
import dataclasses
import types

import numpy as np
import pytest

from repro.core import linear
from repro.core.ckks import CKKSContext, Ciphertext
from repro.core.params import CKKSParams
from repro.errors import (
    CiphertextError, ConfigError, CorruptCiphertextError,
    InvalidRequestError, KeyUnavailableError, LevelExhaustedError,
    ModulusChainMismatchError, PlanCacheMissError, ReproError,
    ScaleDriftError, ServingError, TransientEngineError, is_retryable,
)
from repro.serve import (
    Arrival, CircuitBreaker, FaultInjector, FaultPlan, FHEServer,
    PlanCache, TenantRegistry,
)
from repro.serve.faults import _corrupt_limb

N_DIAG, BS = 4, 2


@pytest.fixture(scope="module")
def sctx():
    params = CKKSParams(logN=8, L=4, alpha=2, k=2, q_bits=29,
                        scale_bits=29)
    return CKKSContext(params, seed=3)


@pytest.fixture(scope="module")
def sprog(sctx):
    from repro.runtime import TraceContext, compile_program

    params = sctx.params
    rng = np.random.default_rng(11)
    diags = {d: rng.normal(size=params.num_slots) for d in range(N_DIAG)}
    tc = TraceContext(params)
    h = tc.input("x", level=params.L, scale=params.scale)
    tc.output(linear.matvec_bsgs(tc, h, diags, bs=BS), "y")
    return compile_program(tc), diags


def _server(sctx, sprog, **kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_wait_s", 0.0)
    server = FHEServer(sctx, **kw)
    server.register_program("a", sprog[0])
    return server


def _warm(server, sctx, widths):
    with server.registry.lease("warm"):
        ct0 = sctx.encrypt(np.zeros(sctx.params.num_slots))
    for w in widths:
        server.warmup("warm", "a", {"x": ct0}, width=w)


def _inputs_maker(sctx, record=None, poison=()):
    nh = sctx.params.num_slots
    rng = np.random.default_rng(29)
    calls = {"n": 0}

    def inputs_for(a):
        z = rng.normal(size=nh) + 1j * rng.normal(size=nh)
        ct = sctx.encrypt(z)
        if calls["n"] in poison:
            _corrupt_limb(ct)
        calls["n"] += 1
        if record is not None:
            record.append((a, z))
        return {"x": ct}

    return inputs_for


def _assert_accounted(rep):
    assert rep.accounted == rep.submitted, \
        f"unaccounted requests: {rep.to_dict()}"


# ------------------------- taxonomy ------------------------------------
def test_error_taxonomy_and_rendering():
    """Hierarchy, context rendering, and the retryable classification."""
    for cls in (LevelExhaustedError, ScaleDriftError,
                ModulusChainMismatchError, CorruptCiphertextError):
        assert issubclass(cls, CiphertextError)
        assert issubclass(cls, ReproError)
    for cls in (KeyUnavailableError, PlanCacheMissError,
                TransientEngineError, InvalidRequestError):
        assert issubclass(cls, ServingError)
    err = KeyUnavailableError("keys gone", hint="re-enroll",
                              tenant="t0", capacity=8)
    assert err.context == {"tenant": "t0", "capacity": 8}
    s = str(err)
    assert "keys gone" in s and "tenant='t0'" in s and "re-enroll" in s
    # retry policy: environment faults retry, data faults never do
    assert is_retryable(TransientEngineError("x"))
    assert is_retryable(KeyUnavailableError("x"))
    assert not is_retryable(CorruptCiphertextError("x"))
    assert not is_retryable(PlanCacheMissError("x"))
    assert not is_retryable(ValueError("x"))


def test_health_guards_typed(sctx):
    """Core guards raise typed errors, not asserts or silent garbage."""
    nh = sctx.params.num_slots
    ct = sctx.encrypt(np.ones(nh))
    sctx.check_ciphertext(ct)                       # healthy passes
    bad = Ciphertext(ct.c0, ct.c1, ct.level, ct.scale)
    _corrupt_limb(bad)
    with pytest.raises(CorruptCiphertextError):
        sctx.check_ciphertext(bad, where="test")
    with pytest.raises(ScaleDriftError):
        sctx.check_ciphertext(
            Ciphertext(ct.c0, ct.c1, ct.level, float("nan")))
    with pytest.raises(ModulusChainMismatchError):
        sctx.check_ciphertext(                       # limbs != level+1
            Ciphertext(ct.c0[:-1], ct.c1[:-1], ct.level, ct.scale))
    # op guards: level mismatch and exhausted chain are typed too
    low = sctx.level_down(ct, ct.level - 1)
    with pytest.raises(ModulusChainMismatchError):
        sctx.add(ct, low)
    bottom = sctx.level_down(ct, 0)
    with pytest.raises(LevelExhaustedError):
        sctx.rescale(bottom)


def test_evk_cache_admission_guard(sctx):
    """A mis-shaped evk is rejected at the cache boundary with a typed
    chain-mismatch error, not deep inside a jit trace."""
    good = sctx.keys.mult_key
    engine = sctx.engine
    with pytest.raises(ModulusChainMismatchError):
        engine._admit_evk(types.SimpleNamespace(digits=good.digits[:-1]))
    clipped = [d[:, :-1, :] for d in good.digits]
    with pytest.raises(ModulusChainMismatchError):
        engine._admit_evk(types.SimpleNamespace(digits=clipped))


def test_registry_eviction_typed_error(sctx):
    """Evicted tenants surface KeyUnavailableError with the tenant id
    and a remediation hint — never a raw KeyError."""
    reg = TenantRegistry(sctx, capacity=2, base_seed=9000)
    reg.keychain("A")
    assert reg.evict("A", force=True)
    with pytest.raises(KeyUnavailableError) as ei:
        reg.keychain("A", create=False)
    assert ei.value.context["tenant"] == "A"
    assert "re-enroll" in str(ei.value)
    with pytest.raises(KeyUnavailableError):
        with reg.lease("A", create=False):
            pass
    with pytest.raises(ConfigError):
        TenantRegistry(sctx, capacity=0)


# ------------------------- chaos schedules -----------------------------
def test_transient_faults_retry_to_completion(sctx, sprog):
    """A seeded transient-fault schedule: every request completes via
    retry/backoff, failed attempts are logged, accounting holds."""
    faults = FaultInjector(FaultPlan(seed=21, p_transient=0.35))
    server = _server(sctx, sprog, faults=faults, max_retries=4)
    _warm(server, sctx, [1, 2])
    trace = [Arrival(0.0, f"t{i % 2}", "a") for i in range(8)]
    rep = server.run_trace(trace, _inputs_maker(sctx))
    assert faults.injected["transient"] >= 1, "schedule never fired"
    assert rep.completed == 8 and rep.failed == 0 and rep.shed == 0
    assert rep.retries == faults.injected["transient"]
    _assert_accounted(rep)
    failed_recs = [r for r in server.records if not r.ok]
    assert failed_recs and all(r.error == "TransientEngineError"
                               for r in failed_recs)
    # the retry that succeeded carries an attempt number > 0
    assert any(r.ok and r.attempt > 0 for r in server.records)


def test_key_eviction_recovers_and_decrypts(sctx, sprog):
    """Mid-flight forced key evictions: the retry re-keygens from the
    stable tenant seed and the outputs STILL decrypt correctly under
    each tenant's key — recovery is bit-faithful, not just green."""
    faults = FaultInjector(FaultPlan(seed=5, p_evict=0.4))
    server = _server(sctx, sprog, faults=faults, max_retries=4)
    _warm(server, sctx, [1, 2])
    log: list = []
    trace = [Arrival(0.0, t, "a") for t in
             ["alice", "bob", "alice", "bob", "alice", "bob"]]
    rep = server.run_trace(trace, _inputs_maker(sctx, record=log))
    assert faults.injected["evict"] >= 1, "schedule never fired"
    assert rep.completed == 6 and rep.failed == 0
    # at least one eviction hit a resident tenant (a fault firing
    # before the tenant's first lease is a no-op on the registry)
    assert server.registry.evictions >= 1
    _assert_accounted(rep)
    _, diags = sprog
    for rid, (a, z) in enumerate(log):
        expect = sum(np.asarray(v) * np.roll(z, -d)
                     for d, v in diags.items())
        with server.registry.lease(a.tenant):
            got = sctx.decrypt(server.outputs[rid]["y"])
        np.testing.assert_allclose(got, expect, atol=1e-3)


def test_corrupted_output_fails_only_its_request(sctx, sprog):
    """Silent output corruption becomes exactly ONE request failure —
    never a wrong result handed back, never a co-batched victim."""
    faults = FaultInjector(FaultPlan(seed=3, p_corrupt=0.5))
    server = _server(sctx, sprog, faults=faults)
    _warm(server, sctx, [1, 2])
    trace = [Arrival(0.0, "t0", "a") for _ in range(6)]
    rep = server.run_trace(trace, _inputs_maker(sctx))
    assert faults.injected["corrupt"] >= 1, "schedule never fired"
    assert rep.failed == faults.injected["corrupt"]
    assert rep.completed == 6 - rep.failed
    assert rep.errors == {"CorruptCiphertextError": rep.failed}
    _assert_accounted(rep)
    # every completed output that was kept is healthy
    for outs in server.outputs.values():
        for ct in outs.values():
            sctx.check_ciphertext(ct)


def test_latency_spikes_consume_virtual_time(sctx, sprog):
    """Injected latency spikes land in the virtual clock: every
    dispatch's recorded duration includes the spike."""
    faults = FaultInjector(FaultPlan(seed=7, p_spike=1.0, spike_s=0.5))
    server = _server(sctx, sprog, faults=faults)
    _warm(server, sctx, [1, 2])
    trace = [Arrival(0.0, "t0", "a") for _ in range(4)]
    rep = server.run_trace(trace, _inputs_maker(sctx))
    assert rep.completed == 4
    assert all(r.duration_s >= 0.5 for r in server.records)
    assert rep.span_s >= 0.5 * len(server.records)


# ------------------------- quarantine bisect ---------------------------
def test_quarantine_bisect_isolates_poison(sctx, sprog):
    """One poisoned request in a 4-wide batch: bisect re-dispatches
    until the poison fails ALONE; the three victims complete."""
    server = _server(sctx, sprog, max_batch=4)
    _warm(server, sctx, [1, 2, 4])
    trace = [Arrival(0.0, "t0", "a") for _ in range(4)]
    rep = server.run_trace(trace, _inputs_maker(sctx, poison={2}),
                           validate=True)
    assert rep.completed == 3 and rep.failed == 1
    assert rep.quarantine_splits == 2          # [0..3] -> [2,3] -> [2]
    assert server.outcomes[2].startswith("failed:CorruptCiphertextError")
    assert {r for r, o in server.outcomes.items()
            if o == "completed"} == {0, 1, 3}
    _assert_accounted(rep)
    # the poisoned rid is the only one missing an output
    assert set(server.outputs) == {0, 1, 3}


# ------------------------- circuit breaker -----------------------------
def test_circuit_breaker_state_machine():
    br = CircuitBreaker(threshold=2, cooldown_s=1.0)
    assert br.allow("t", 0.0)
    br.record_failure("t", 0.0)
    assert br.allow("t", 0.0) and br.trips == 0
    br.record_failure("t", 0.0)                # second consecutive: trip
    assert br.trips == 1 and br.is_open("t", 0.5)
    assert not br.allow("t", 0.5)
    assert br.allow("t", 1.5)                  # half-open: one probe
    assert not br.allow("t", 1.5)              # only one probe at a time
    br.record_failure("t", 1.5)                # probe failed: re-open
    assert br.trips == 2 and not br.allow("t", 2.0)
    assert br.allow("t", 3.0)                  # next probe after cooldown
    br.record_success("t")                     # probe ok: closed
    assert br.allow("t", 3.0) and not br.is_open("t", 3.0)
    with pytest.raises(ConfigError):
        CircuitBreaker(threshold=0)


def test_breaker_sheds_poison_tenant(sctx, sprog):
    """A tenant failing repeatedly trips its breaker: later requests
    are shed without touching the engine; other tenants are unharmed."""
    server = _server(sctx, sprog, max_batch=1,
                     breaker=CircuitBreaker(threshold=2, cooldown_s=1e9))
    _warm(server, sctx, [1])
    trace = [Arrival(0.0, "evil", "a"), Arrival(0.0, "evil", "a"),
             Arrival(0.0, "evil", "a"), Arrival(0.0, "good", "a"),
             Arrival(0.0, "good", "a")]
    rep = server.run_trace(trace, _inputs_maker(sctx, poison={0, 1, 2}),
                           validate=True)
    assert rep.failed == 2                     # two failures trip it
    assert rep.shed == 1 and rep.shed_reasons == {"breaker_open": 1}
    assert rep.breaker_trips == 1
    assert rep.tenants["good"]["completed"] == 2
    assert rep.tenants["evil"]["failed"] == 2
    assert rep.tenants["evil"]["shed"] == 1
    _assert_accounted(rep)


# ------------------------- deadlines + shedding ------------------------
def test_deadline_expired_requests_shed_not_executed(sctx, sprog):
    """Requests whose virtual deadline passed while queued are shed —
    no engine dispatch ever runs for them."""
    server = _server(sctx, sprog, max_batch=1)
    _warm(server, sctx, [1])
    trace = [Arrival(0.0, "t0", "a") for _ in range(4)]
    rep = server.run_trace(trace, _inputs_maker(sctx), deadline_s=1e-9)
    assert rep.completed == 1                  # only the first makes it
    assert rep.shed == 3
    assert rep.shed_reasons == {"deadline": 3}
    _assert_accounted(rep)
    executed = {r for rec in server.records for r in rec.rids}
    assert executed == {0}, "a shed request was executed"


def test_overload_shed_at_submit(sctx, sprog):
    """When the EWMA service estimate says the queue wait blows the
    deadline headroom, submit refuses with reason ``overload``."""
    server = _server(sctx, sprog)
    ct = sctx.encrypt(np.zeros(sctx.params.num_slots))
    server._ewma_service_s = 100.0             # pretend service is slow
    ok = server.submit("t0", "a", {"x": ct}, arrival=0.0, deadline=1.0)
    assert not ok
    assert server.shed_reasons == {"overload": 1}
    assert server._stats("t0").shed == 1
    assert server.submitted == 1


def test_submit_typed_validation(sctx, sprog):
    server = _server(sctx, sprog)
    ct = sctx.encrypt(np.zeros(sctx.params.num_slots))
    with pytest.raises(InvalidRequestError):
        server.submit("t0", "nope", {"x": ct}, arrival=0.0)
    with pytest.raises(InvalidRequestError):
        server.submit("t0", "a", {}, arrival=0.0)
    assert server.submitted == 0               # invalid never counted


# ------------------------- strict plan admission -----------------------
def test_strict_plan_cache(sctx, sprog):
    """PlanCache.require refuses cold shapes; a strict server turns the
    refusal into an accounted request failure, not a trace stall."""
    pc = PlanCache()
    with pytest.raises(PlanCacheMissError):
        pc.require(("sig",), 2)
    pc.admit(("sig",), 2)
    pc.require(("sig",), 2)                    # warm: no raise

    server = _server(sctx, sprog, strict_plans=True)   # NO warmup
    trace = [Arrival(0.0, "t0", "a")]
    rep = server.run_trace(trace, _inputs_maker(sctx))
    assert rep.completed == 0 and rep.failed == 1
    assert rep.errors == {"PlanCacheMissError": 1}
    _assert_accounted(rep)


# ------------------------- zero retraces with validation ---------------
def test_validation_adds_zero_retraces(sctx, sprog):
    """Invariant-guard mode runs outside jit: after warmup, serving a
    trace with validate=True leaves ``engine.trace_counts`` unchanged."""
    server = _server(sctx, sprog)
    _warm(server, sctx, [1, 2])
    before = dict(sctx.engine.trace_counts)
    trace = [Arrival(0.0, "t0", "a") for _ in range(6)]
    rep = server.run_trace(trace, _inputs_maker(sctx), validate=True)
    assert rep.completed == 6
    assert dict(sctx.engine.trace_counts) == before, \
        "validation mode retraced a jit plan"


# ------------------------- record schema -------------------------------
def test_batch_record_failure_fields(sctx, sprog):
    """BatchRecord carries the failure schema simfeed and the bench
    read: ok flag, typed error name, attempt number."""
    from repro.serve import BatchRecord

    fields = {f.name for f in dataclasses.fields(BatchRecord)}
    assert {"ok", "error", "attempt"} <= fields
