"""Homomorphic linear transform (diagonal + BSGS) tests."""
import numpy as np

from repro.core import linear


def _sparse_matrix(rng, nh, diag_steps):
    A = np.zeros((nh, nh), dtype=complex)
    for d in diag_steps:
        v = rng.normal(size=nh)
        for i in range(nh):
            A[i, (i + d) % nh] = v[i]
    return A


def test_matvec_diag(ctx, rng):
    nh = ctx.params.num_slots
    z = rng.normal(size=nh) + 1j * rng.normal(size=nh)
    A = _sparse_matrix(rng, nh, [0, 1, 3, 9])
    ct = ctx.encrypt(z)
    y = ctx.decrypt(linear.matvec_diag(ctx, ct, linear.matrix_diagonals(A)))
    ref = A @ z
    assert np.abs(y - ref).max() / np.abs(ref).max() < 1e-3


def test_matvec_bsgs_matches_diag(ctx, rng):
    nh = ctx.params.num_slots
    z = rng.normal(size=nh) + 1j * rng.normal(size=nh)
    A = _sparse_matrix(rng, nh, [0, 1, 2, 5, 8, 13, 21, 34])
    diags = linear.matrix_diagonals(A)
    ct = ctx.encrypt(z)
    ref = A @ z
    y1 = ctx.decrypt(linear.matvec_diag(ctx, ct, diags))
    y2 = ctx.decrypt(linear.matvec_bsgs(ctx, ct, diags, bs=8))
    assert np.abs(y1 - ref).max() / np.abs(ref).max() < 1e-3
    assert np.abs(y2 - ref).max() / np.abs(ref).max() < 1e-3


def test_bsgs_various_bs(ctx, rng):
    """BSGS result is bs-invariant (paper Fig. 7 explores this trade-off)."""
    nh = ctx.params.num_slots
    z = rng.normal(size=nh) + 1j * rng.normal(size=nh)
    A = _sparse_matrix(rng, nh, list(range(12)))
    diags = linear.matrix_diagonals(A)
    ref = A @ z
    ct = ctx.encrypt(z)
    for bs in (2, 4, 6):
        y = ctx.decrypt(linear.matvec_bsgs(ctx, ct, diags, bs=bs))
        assert np.abs(y - ref).max() / np.abs(ref).max() < 2e-3, f"bs={bs}"
