"""Compiled runtime tests: trace -> PKB lowering -> engine execution.

Covers the acceptance gates of the runtime subsystem:
  * compiled matvec-BSGS / Chebyshev bit-exact with the eager path
  * compiled execution performs FEWER ModUps (shared-anchor hoisting),
    fusion fewer still — asserted via the op counters
  * vmap-batched execution bit-exact with the per-ct loop, one jit
    trace per batched plan (``engine.trace_counts``)
  * predicted-vs-executed op-count reconciliation + plan-shape check
  * the execution report feeds the event-driven group scheduler
"""
import numpy as np
import pytest

from repro.core import linear
from repro.core.ckks import CKKSContext
from repro.core.params import CKKSParams
from repro.runtime import ProgramExecutor, TraceContext, compile_program

from parity import assert_program_parity, ct_equal as _ct_equal


def _sparse(rng, nh, diag_steps):
    A = np.zeros((nh, nh), dtype=complex)
    for d in diag_steps:
        v = rng.normal(size=nh)
        for i in range(nh):
            A[i, (i + d) % nh] = v[i]
    return A


def _trace_matvec(params, diags, bs=0):
    tc = TraceContext(params)
    h = tc.input("x", level=params.L, scale=params.scale)
    if bs:
        out = linear.matvec_bsgs(tc, h, diags, bs=bs)
    else:
        out = linear.matvec_diag(tc, h, diags)
    tc.output(out, "y")
    return tc


@pytest.fixture(scope="module")
def rctx():
    params = CKKSParams(logN=9, L=5, alpha=2, k=3, q_bits=29, scale_bits=29)
    return CKKSContext(params, seed=7)


@pytest.fixture(scope="module")
def bsgs_case(rctx):
    rng = np.random.default_rng(5)
    nh = rctx.params.num_slots
    A = _sparse(rng, nh, list(range(8)))
    diags = linear.matrix_diagonals(A)
    z = rng.normal(size=nh) + 1j * rng.normal(size=nh)
    return A, diags, z, rctx.encrypt(z)


# ----------------------- bit-exact parity --------------------------------

def test_compiled_matvec_diag_bitexact(rctx, bsgs_case):
    A, diags, z, ct = bsgs_case
    tc = _trace_matvec(rctx.params, diags)
    comp = compile_program(tc)
    assert comp.n_hoisted == 1          # one PKB -> one hoisted block
    got = assert_program_parity(
        rctx, comp, {"x": ct},
        lambda ctx, c: linear.matvec_diag(ctx, c, diags))
    ref = A @ z
    assert np.abs(rctx.decrypt(got) - ref).max() / np.abs(ref).max() < 1e-3


def test_compiled_bsgs_bitexact_fewer_modups(rctx, bsgs_case):
    A, diags, z, ct = bsgs_case
    comp = compile_program(_trace_matvec(rctx.params, diags, bs=4))
    # the baby-step blocks share ONE ModUp through the digits cache
    assert_program_parity(
        rctx, comp, {"x": ct},
        lambda ctx, c: linear.matvec_bsgs(ctx, c, diags, bs=4),
        fewer_modups=True, reconcile=True)


def test_fused_bsgs_fewest_modups(rctx, bsgs_case):
    """HERO fusion collapses baby x giant into ONE hoisted block: a
    single ModUp/ModDown, numerically equivalent to the eager result."""
    A, diags, z, ct = bsgs_case
    tc = _trace_matvec(rctx.params, diags, bs=4)
    comp = compile_program(tc)
    fused = compile_program(tc, fusion=True)
    assert fused.fusion_plan is not None and fused.fusion_plan.groups
    ex = ProgramExecutor(rctx)
    c = rctx.counters
    s0 = c.snapshot()
    ex.run(comp, {"x": ct})
    unfused_counts = c.delta(s0)
    s1 = c.snapshot()
    got = ex.run(fused, {"x": ct})["y"]
    fused_counts = c.delta(s1)
    assert fused_counts.modup == 1 and fused_counts.moddown == 1
    assert fused_counts.modup < unfused_counts.modup
    ref = A @ z
    assert np.abs(rctx.decrypt(got) - ref).max() / np.abs(ref).max() < 1e-3


@pytest.fixture(scope="module")
def cheb_ctx():
    p = CKKSParams(logN=9, L=9, alpha=2, k=3, q_bits=29, scale_bits=29)
    return CKKSContext(p, seed=11)


@pytest.fixture(scope="module")
def cheb_case(cheb_ctx):
    from repro.core.polyeval import chebyshev_coeffs, eval_chebyshev

    rng = np.random.default_rng(9)
    nh = cheb_ctx.params.num_slots
    x = rng.uniform(-1, 1, nh)
    fn = lambda t: np.sin(2 * np.pi * 1.5 * t) / (2 * np.pi)  # noqa: E731
    coeffs = chebyshev_coeffs(fn, 15)
    ct = cheb_ctx.encrypt(x)
    tc = TraceContext(cheb_ctx.params)
    h = tc.input("x", level=ct.level, scale=ct.scale)
    tc.output(eval_chebyshev(tc, h, coeffs), "y")
    return x, fn, coeffs, ct, compile_program(tc)


def test_compiled_chebyshev_bitexact(cheb_ctx, cheb_case):
    from repro.core.polyeval import eval_chebyshev

    x, fn, coeffs, ct, comp = cheb_case
    got = assert_program_parity(
        cheb_ctx, comp, {"x": ct},
        lambda ctx, c: eval_chebyshev(ctx, c, coeffs))
    assert np.abs(cheb_ctx.decrypt(got).real - fn(x)).max() < 5e-3


# ----------------------- vmap batching -----------------------------------

def test_batched_matvec_bitexact(rctx, bsgs_case):
    A, diags, z, ct = bsgs_case
    rng = np.random.default_rng(17)
    nh = rctx.params.num_slots
    comp = compile_program(_trace_matvec(rctx.params, diags, bs=4))
    cts = [ct] + [
        rctx.encrypt(rng.normal(size=nh) + 1j * rng.normal(size=nh))
        for _ in range(2)
    ]
    outs = assert_program_parity(
        rctx, comp, {"x": cts},
        lambda ctx, c: linear.matvec_bsgs(ctx, c, diags, bs=4),
        batched=True)
    assert len(outs) == 3


def test_batched_one_trace_per_plan(cheb_ctx, cheb_case):
    """Every batched plan (keyswitch_b / hoisted_b / ...) traces once:
    re-running the batch is pure cache hits."""
    x, fn, coeffs, ct, comp = cheb_case
    ex = ProgramExecutor(cheb_ctx)
    cts = [ct, ct]
    ex.run_batched(comp, {"x": cts})
    batched = {k: v for k, v in cheb_ctx.engine.trace_counts.items()
               if str(k[0]).endswith("_b")}
    assert batched, "batched plans must register trace events"
    ex.run_batched(comp, {"x": cts})   # second run: no retrace
    assert all(v == 1 for v in cheb_ctx.engine.trace_counts.values() if v)
    assert {k: v for k, v in cheb_ctx.engine.trace_counts.items()
            if str(k[0]).endswith("_b")} == batched


# ------------------- predicted vs executed reconciliation ----------------

def test_reconciliation_and_plan_shapes(rctx, bsgs_case):
    A, diags, z, ct = bsgs_case
    for fusion in (False, True):
        comp = compile_program(_trace_matvec(rctx.params, diags, bs=4),
                               fusion=fusion)
        res = ProgramExecutor(rctx).run(comp, {"x": ct}, with_report=True)
        rec = res.report.reconcile()
        assert rec["counts_match"], rec
        # word volumes: the hoist model's uniform-digit approximation vs
        # the engine plans' true short last groups
        assert abs(rec["bconv_ratio"] - 1.0) < 1e-9
        assert abs(rec["ip_macs_ratio"] - 1.0) < 1e-9
        assert 0.9 < rec["ntt_ratio"] < 1.15
        assert res.report.validate_plan_shapes(rctx.params)


def test_batched_report_scales_with_batch(rctx, bsgs_case):
    A, diags, z, ct = bsgs_case
    comp = compile_program(_trace_matvec(rctx.params, diags, bs=4))
    ex = ProgramExecutor(rctx)
    res = ex.run_batched(comp, {"x": [ct, ct]}, with_report=True)
    rec = res.report.reconcile()
    assert res.report.batch == 2
    assert rec["counts_match"], rec


def test_seed_path_report_reconciles(rctx, bsgs_case):
    """use_engine=False has no digits sharing: the report predicts one
    ModUp per hoisted block and still reconciles exactly."""
    A, diags, z, ct = bsgs_case
    comp = compile_program(_trace_matvec(rctx.params, diags, bs=4))
    ex = ProgramExecutor(rctx)
    rctx.use_engine = False
    try:
        res = ex.run(comp, {"x": ct}, with_report=True)
    finally:
        rctx.use_engine = True
    rec = res.report.reconcile()
    assert rec["counts_match"], rec
    # one ModUp per hoisted block on the seed path: more than the
    # engine-mode prediction, which shares digits per anchor
    from repro.runtime.report import predicted_volumes

    assert (res.report.predicted.modup_count
            > predicted_volumes(comp, shared_modup=True).modup_count)


def test_report_feeds_group_scheduler(rctx, bsgs_case):
    from repro.sim import HE2_SM

    A, diags, z, ct = bsgs_case
    comp = compile_program(_trace_matvec(rctx.params, diags, bs=4))
    res = ProgramExecutor(rctx).run(comp, {"x": ct}, with_report=True)
    sched = res.report.scheduled_result(comp, HE2_SM, mode="pipelined")
    assert sched.latency_s > 0
    assert sched.timelines and set(sched.engine_busy_s)
    analytic = res.report.scheduled_result(comp, HE2_SM, mode="analytic")
    assert analytic.xpu_busy_s == pytest.approx(sched.xpu_busy_s)


# ------------------- engine digits + counters plumbing -------------------

def test_hoisted_digits_parity(rctx, bsgs_case):
    """Precomputed-digits hoisted sum is bit-exact with the monolithic
    one — the cross-block ModUp sharing changes no values."""
    A, diags, z, ct = bsgs_case
    steps = [1, 3, 7]
    pts = [rctx.encode(np.real(diags[1]), level=ct.level) for _ in steps]
    a = rctx.hoisted_rotation_sum(ct, steps, pts, rescale=False)
    digits = rctx.hoist_digits(ct)
    b = rctx.hoisted_rotation_sum(ct, steps, pts, rescale=False,
                                  digits=digits)
    assert _ct_equal(a, b)


def test_counters_seed_engine_parity(rctx):
    """Both dispatch paths tally identical op counts for the same ops."""
    rng = np.random.default_rng(23)
    nh = rctx.params.num_slots
    z = rng.normal(size=nh)
    ct = rctx.encrypt(z)
    c = rctx.counters

    def ops():
        rctx.rotate(ct, 3)
        rctx.multiply(ct, ct)
        rctx.hoisted_rotation_sum(ct, [1, 2], None)

    s0 = c.snapshot()
    ops()
    engine_counts = c.delta(s0)
    rctx.use_engine = False
    try:
        s1 = c.snapshot()
        ops()
        seed_counts = c.delta(s1)
    finally:
        rctx.use_engine = True
    assert engine_counts == seed_counts
    assert engine_counts.modup == 3 and engine_counts.rotation == 3


# ------------------- OpVolumes per-digit legs ----------------------------

def test_modup_legs_match_totals():
    from repro.dfg.hoist import modup_volumes

    for l in (6, 7, 12):
        v = modup_volumes(l, k=3, alpha=2, N=512)
        assert len(v.modup_legs) == -(-l // 2)
        assert sum(b for _, b in v.modup_legs) == v.modup_bconv_macs
        both = v + v
        assert len(both.modup_legs) == len(v.modup_legs)
        assert both.modup_legs[0][0] == 2 * v.modup_legs[0][0]
        assert v.scaled(2.0).modup_legs[0][1] == 2 * v.modup_legs[0][1]
    # differing dnum blocks cannot keep a per-digit attribution
    assert (modup_volumes(6, 3, 2, 512)
            + modup_volumes(12, 3, 2, 512)).modup_legs == ()


def test_moddown_legs_match_totals():
    """ModDown legs follow the IP-accumulation streaming order: one
    (ntt, bconv, ewo) leg per decomposition digit, summing exactly to
    the block totals; a short last digit gets a shorter leg."""
    from repro.dfg.hoist import moddown_volumes

    for l in (6, 7, 12):
        v = moddown_volumes(l, k=3, alpha=2, N=512, components=2)
        assert len(v.moddown_legs) == -(-l // 2)
        assert sum(n for n, _, _ in v.moddown_legs) == pytest.approx(
            v.moddown_ntt_words)
        assert sum(b for _, b, _ in v.moddown_legs) == pytest.approx(
            v.moddown_bconv_macs)
        assert sum(e for _, _, e in v.moddown_legs) == pytest.approx(
            v.xpu_ewo_words)
        both = v + v
        assert len(both.moddown_legs) == len(v.moddown_legs)
        assert both.moddown_legs[0][2] == 2 * v.moddown_legs[0][2]
        assert v.scaled(2.0).moddown_legs[0][1] == 2 * v.moddown_legs[0][1]
    # odd l: the last digit is short and its leg proportionally smaller
    v7 = moddown_volumes(7, 3, 2, 512)
    assert v7.moddown_legs[-1][1] == v7.moddown_legs[0][1] / 2
    assert (moddown_volumes(6, 3, 2, 512)
            + moddown_volumes(12, 3, 2, 512)).moddown_legs == ()


def test_down_slice_weights_behavior():
    """Uniform digits -> uniform down weights (behavior-preserving);
    a short last digit drains faster; non-tiling groups fall back."""
    from repro.dfg.hoist import moddown_volumes
    from repro.sim.hw import HE2_SM
    from repro.sim.schedule import _down_slice_weights

    v = moddown_volumes(6, 3, 2, 512)        # 3 uniform digits
    w = _down_slice_weights(v, HE2_SM, 6)
    assert w == pytest.approx([1 / 6] * 6)
    v7 = moddown_volumes(7, 3, 2, 512)       # short last digit
    w7 = _down_slice_weights(v7, HE2_SM, 8)
    assert sum(w7) == pytest.approx(1.0)
    assert w7[3] < w7[0] and w7[7] < w7[4]
    assert _down_slice_weights(v7, HE2_SM, 6) == pytest.approx([1 / 6] * 6)
