"""End-to-end encrypted-inference workload tests.

The acceptance gates of the workloads subsystem (ISSUE 9 / ROADMAP open
item 4): packed logistic regression and a small MLP run through the
compiled runtime's ``run_batched`` bit-exact with their eager replay at
strictly fewer ModUps, reconcile exactly, and decrypt within each
model's tolerance of the ``matvec_plain``+numpy reference; the
level-tracking planner splices a bootstrap when the input level is
forced too low, verified by decrypt accuracy after the exhaustion
(tier-1) and full eager parity (slow).
"""
import numpy as np
import pytest

from repro.core.bootstrap import Bootstrapper
from repro.core.ckks import CKKSContext
from repro.core.params import CKKSParams
from repro.errors import LevelExhaustedError
from repro.workloads import (
    WorkloadExecutor, compile_workload, logreg, mlp, mlp_bootstrap,
    plan_cuts, scheduled_result, workload_blocks,
)

from parity import ct_equal


@pytest.fixture(scope="module")
def wctx():
    p = CKKSParams(logN=8, L=14, alpha=2, k=3, q_bits=29, scale_bits=29)
    return CKKSContext(p, seed=7)


@pytest.fixture(scope="module")
def boot_ctx():
    # the deep bootstrap-capable shape of test_runtime_bootstrap's slow
    # pipeline test; planning on it is symbolic and cheap
    p = CKKSParams(logN=8, L=19, alpha=4, k=4, q_bits=29, scale_bits=29,
                   q0_bits=30)
    ctx = CKKSContext(p, seed=7, hamming_weight=8)
    btp = Bootstrapper(ctx, n_groups=2, mod_K=3, cheb_degree=27)
    return ctx, btp


def _run_gates(ctx, m, wp, xs, btp=None, input_level=None):
    """The workload sandwich: batched compiled vs per-ct eager replay
    (bit-exact, strictly fewer ModUps, exact reconcile), then decrypt
    accuracy against the plaintext reference."""
    cts = [ctx.encrypt(x, level=input_level) if input_level is not None
           else ctx.encrypt(x) for x in xs]
    c = ctx.counters
    s0 = c.snapshot()
    exps = [wp.run_eager(ctx, ct, btp=btp) for ct in cts]
    d_eager = c.delta(s0)

    ex = WorkloadExecutor(ctx)
    s1 = c.snapshot()
    res = ex.run_batched(wp, cts, with_report=True)
    d_comp = c.delta(s1)

    for got, exp in zip(res.output, exps):
        assert ct_equal(got, exp), "compiled workload != eager bitstream"
        assert got.scale == exp.scale and got.level == exp.level
    assert d_comp.modup < d_eager.modup, (d_comp.modup, d_eager.modup)
    rec = res.reconcile()
    assert rec["counts_match"], rec
    for x, got in zip(xs, res.output):
        err = np.abs(ctx.decrypt(got).real - m.reference(x)).max()
        assert err < m.tolerance, (err, m.tolerance)
    return res


def test_logreg_batched_e2e(wctx, rng):
    """Packed logistic regression (matvec-BSGS + degree-15 sigmoid):
    9 levels, run from input_level=9 on the L=14 chain."""
    p = wctx.params
    m = logreg(p.num_slots, bs=4)
    wp = compile_workload(m, p, input_level=9)
    assert wp.n_bootstraps == 0 and len(wp.segments) == 1
    assert wp.output_level == 0
    xs = [m.sample(rng) for _ in range(2)]
    _run_gates(wctx, m, wp, xs, input_level=9)


def test_mlp_batched_e2e(wctx, rng):
    """Two dense+sigmoid layers: the full 14-level budget."""
    p = wctx.params
    m = mlp(p.num_slots, bs=4)
    wp = compile_workload(m, p)
    assert wp.n_bootstraps == 0
    xs = [m.sample(rng) for _ in range(2)]
    _run_gates(wctx, m, wp, xs)


def test_workload_feeds_scheduler(wctx):
    """Lowered workload blocks drive the Sec. V group scheduler."""
    from repro.sim import HE2_SM

    p = wctx.params
    m = logreg(p.num_slots, bs=4)
    wp = compile_workload(m, p, input_level=9)
    blocks = workload_blocks(wp, batch=2)
    assert blocks
    assert sum(b.volumes.modup_count for b in blocks) > 0
    sched = scheduled_result(wp, HE2_SM, batch=2)
    assert sched.latency_s > 0 and sched.timelines


def test_plan_without_bootstrapper_raises(wctx):
    """Level exhaustion without a Bootstrapper is a typed error."""
    p = wctx.params
    m = mlp_bootstrap(p.num_slots, bs=4)
    with pytest.raises(LevelExhaustedError, match="Bootstrapper"):
        plan_cuts(m, p, input_level=7)


def test_plan_inserts_cut_at_forced_exhaustion(boot_ctx):
    """input_level=7 fits layer 1 (7 levels) but not the head: the
    planner must splice exactly one bootstrap between the layers, and
    score the candidate boundaries."""
    ctx, btp = boot_ctx
    p = ctx.params
    m = mlp_bootstrap(p.num_slots, bs=4)
    plan = plan_cuts(m, p, btp=btp, input_level=7)
    assert plan.n_bootstraps == 1
    assert plan.spans == [(0, 1), (1, 2)]
    assert plan.cuts[0].after_stage == 1
    assert plan.cuts[0].scores[1] is not None
    assert plan.output_level >= 1
    assert any(row["stage"] == "<bootstrap>" for row in plan.table)
    # with the full chain available no cut is needed
    deep = plan_cuts(m, p, btp=btp)
    assert deep.n_bootstraps == 0


def test_bootstrap_insertion_decrypt_accuracy(boot_ctx, rng):
    """Forced level exhaustion, tier-1 half: compile the bootstrap-
    inserted chain and check the compiled run decrypts within tolerance
    and reconciles (full eager parity is the slow test below)."""
    ctx, btp = boot_ctx
    p = ctx.params
    m = mlp_bootstrap(p.num_slots, bs=4)
    wp = compile_workload(m, p, btp=btp, input_level=7)
    assert wp.n_bootstraps == 1 and len(wp.segments) == 3
    x = m.sample(rng)
    ct = ctx.encrypt(x, level=7)
    res = WorkloadExecutor(ctx).run(wp, ct, with_report=True)
    err = np.abs(ctx.decrypt(res.output).real - m.reference(x)).max()
    assert err < m.tolerance, (err, m.tolerance)
    rec = res.reconcile()
    assert rec["counts_match"], rec
    assert len(rec["segments"]) == 3


@pytest.mark.slow
def test_bootstrap_insertion_full_parity(boot_ctx, rng):
    """Forced level exhaustion, full sandwich: the three-segment chain
    (compute -> bootstrap -> compute) is bit-exact with the eager
    replay at strictly fewer ModUps."""
    ctx, btp = boot_ctx
    p = ctx.params
    m = mlp_bootstrap(p.num_slots, bs=4)
    wp = compile_workload(m, p, btp=btp, input_level=7)
    xs = [m.sample(rng)]
    _run_gates(ctx, m, wp, xs, btp=btp, input_level=7)


def test_workload_summary_shape(wctx):
    p = wctx.params
    m = logreg(p.num_slots, bs=4)
    wp = compile_workload(m, p, input_level=9)
    s = wp.summary()
    assert s["workload"] == "logreg" and s["n_segments"] == 1
    assert s["predicted_modups"] == wp.predicted_modups() > 0
    assert [row["stage"] for row in s["levels"]] == ["logits"]


def test_workload_backed_serving(rng):
    """serve.workload_request_programs: a compiled workload serves
    requests through FHEServer's continuous-batching loop."""
    from repro.serve import FHEServer, poisson_trace, \
        workload_request_programs

    p = CKKSParams(logN=8, L=8, alpha=2, k=3, q_bits=29, scale_bits=29)
    ctx = CKKSContext(p, seed=3)
    m = logreg(p.num_slots, degree=7, bs=4)    # 7 levels: fits L=8
    programs, chains = workload_request_programs([m], p)
    assert chains[m.name] == [(m.name, "x", "y")]

    server = FHEServer(ctx, max_batch=2, max_wait_s=0.0)
    for pid, comp in programs.items():
        server.register_program(pid, comp)
    trace = poisson_trace(200.0, 4, ["t0"], [m.name], seed=1)

    with server.registry.lease("t0"):
        ct0 = ctx.encrypt(np.zeros(p.num_slots))
    for w in (1, 2):
        server.warmup("t0", m.name, {"x": ct0}, width=w)

    sent = {}

    def inputs_for(a):
        x = m.sample(rng)
        sent[len(sent)] = x
        return {"x": ctx.encrypt(x)}

    rep = server.run_trace(trace, inputs_for)
    assert rep.completed == 4 and rep.failed == 0
    with server.registry.lease("t0"):
        for rid in range(4):
            got = ctx.decrypt(server.outputs[rid]["y"]).real
            err = np.abs(got - m.reference(sent[rid])).max()
            assert err < m.tolerance, (rid, err)
