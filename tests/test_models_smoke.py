"""Per-architecture smoke tests: reduced config, one forward/train step
on CPU, asserting output shapes + finiteness (deliverable (f))."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, reduced_config
from repro.models.model import forward, init_cache, init_params
from repro.models.steps import loss_fn


def _batch(cfg, rng, B=2, S=16):
    tokens = rng.integers(0, cfg.vocab, (B, S)).astype(np.int32)
    batch = {"tokens": jnp.asarray(tokens),
             "labels": jnp.asarray(tokens)}
    if cfg.pos == "mrope":
        pos = np.broadcast_to(np.arange(S)[None, None], (3, B, S))
        batch["positions"] = jnp.asarray(pos.astype(np.int32))
    if cfg.frontend == "vision":
        batch["embeds"] = jnp.asarray(
            rng.normal(size=(B, 4, cfg.d_model)).astype(np.float32),
            dtype=jnp.bfloat16)
    if cfg.frontend == "audio":
        batch["embeds"] = jnp.asarray(
            rng.normal(size=(B, 8, cfg.d_model)).astype(np.float32),
            dtype=jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_finite(arch):
    cfg = reduced_config(arch)
    rng = np.random.default_rng(0)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, rng)
    logits, _ = forward(params, batch["tokens"], cfg,
                        positions=batch.get("positions"),
                        embeds=batch.get("embeds"))
    B, S = batch["tokens"].shape
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_loss_finite(arch):
    cfg = reduced_config(arch)
    rng = np.random.default_rng(1)
    params = init_params(cfg, jax.random.PRNGKey(1))
    batch = _batch(cfg, rng)
    loss, grads = jax.value_and_grad(loss_fn)(params, batch, cfg)
    assert bool(jnp.isfinite(loss)), f"{arch}: loss {loss}"
    leaves = jax.tree.leaves(grads)
    assert leaves and all(bool(jnp.isfinite(g).all()) for g in leaves)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch):
    cfg = reduced_config(arch)
    rng = np.random.default_rng(2)
    params = init_params(cfg, jax.random.PRNGKey(2))
    B, S_max = 2, 16
    cache = init_cache(cfg, B, S_max)
    tok = jnp.asarray(rng.integers(0, cfg.vocab, (B, 1)).astype(np.int32))
    kwargs = {}
    if cfg.pos == "mrope":
        kwargs["positions"] = jnp.zeros((3, B, 1), jnp.int32)
    logits, cache2 = forward(params, tok, cfg, cache=cache, **kwargs)
    assert logits.shape == (B, 1, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    assert int(cache2["idx"]) == 1
    # a second step advances the cache
    logits3, cache3 = forward(params, tok, cfg, cache=cache2, **kwargs)
    assert int(cache3["idx"]) == 2


def test_decode_matches_prefill_dense():
    """Teacher-forced decode == full forward (dense arch, exactness)."""
    cfg = reduced_config("phi3_medium_14b")
    rng = np.random.default_rng(3)
    params = init_params(cfg, jax.random.PRNGKey(3))
    B, S = 1, 8
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)).astype(np.int32))
    full_logits, _ = forward(params, toks, cfg)
    cache = init_cache(cfg, B, S)
    outs = []
    for t in range(S):
        lg, cache = forward(params, toks[:, t : t + 1], cfg, cache=cache)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec, dtype=np.float32),
        np.asarray(full_logits, dtype=np.float32),
        rtol=0.15, atol=0.15,
    )


def test_decode_matches_prefill_mla():
    cfg = reduced_config("minicpm3_4b")
    rng = np.random.default_rng(4)
    params = init_params(cfg, jax.random.PRNGKey(4))
    B, S = 1, 6
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)).astype(np.int32))
    full_logits, _ = forward(params, toks, cfg)
    cache = init_cache(cfg, B, S)
    outs = []
    for t in range(S):
        lg, cache = forward(params, toks[:, t : t + 1], cfg, cache=cache)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec, dtype=np.float32),
        np.asarray(full_logits, dtype=np.float32),
        rtol=0.2, atol=0.2,
    )


def test_moe_routes_tokens():
    """MoE output depends on router (not all-zero / not dense-equal)."""
    cfg = reduced_config("moonshot_v1_16b_a3b")
    rng = np.random.default_rng(5)
    params = init_params(cfg, jax.random.PRNGKey(5))
    batch = _batch(cfg, rng)
    logits, _ = forward(params, batch["tokens"], cfg)
    assert float(jnp.abs(logits).max()) > 0
