"""Simulator behaviour tests: paper-claim reproduction + monotonicity
properties (more bandwidth never slower, etc.).

Hypothesis-based property tests live in test_sim_props.py so that
collection never hard-errors on an interpreter without hypothesis.
"""
import pytest

from repro.dfg.programs import bootstrapping_dfg, helr_dfg
from repro.sim import HE2_LM, HE2_SM, SHARP, SHARP_XMU
from repro.sim.engine import simulate_program
from repro.sim.hw import with_bandwidth


@pytest.fixture(scope="module")
def boot_bsgs():
    return bootstrapping_dfg(bsgs_bs=4).g


@pytest.fixture(scope="module")
def boot_full():
    return bootstrapping_dfg(bsgs_bs=0).g


def test_sharp_bootstrap_calibration(boot_bsgs):
    """Simulated SHARP bootstrapping within 15% of the paper's 3.12 ms."""
    r = simulate_program(boot_bsgs, SHARP, "minks", "EVF")
    assert abs(r.latency_s * 1e3 - 3.12) / 3.12 < 0.15


def test_he2_speedup_over_sharp(boot_bsgs, boot_full):
    """HE2-LM speedup vs SHARP near the paper's 1.66x for bootstrapping."""
    sharp = simulate_program(boot_bsgs, SHARP, "minks", "EVF")
    he2 = simulate_program(boot_full, HE2_LM, "hoist", "hybrid", fusion=True)
    speedup = sharp.latency_s / he2.latency_s
    assert 1.3 < speedup < 2.3, f"speedup {speedup:.2f} vs paper 1.66"


def test_hoisting_degrades_evf(boot_bsgs):
    """Fig. 5/14: hoisting on EVF increases memory stalls vs Min-KS."""
    minks = simulate_program(boot_bsgs, SHARP, "minks", "EVF")
    hoist = simulate_program(boot_bsgs, SHARP, "hoist", "EVF")
    assert hoist.mem_stall_s > minks.mem_stall_s
    assert hoist.latency_s > minks.latency_s


def test_naive_hetero_comm_dominates(boot_bsgs):
    """Fig. 4: SHARP-xMU exposes large comm stalls on the critical path."""
    r = simulate_program(boot_bsgs, SHARP_XMU, "hoist", "IRF")
    assert r.comm_stall_frac > 0.4


def test_he2_hides_communication(boot_full):
    """Paper: communication stalls reduced to ~6.7% on HE2-LM."""
    r = simulate_program(boot_full, HE2_LM, "hoist", "hybrid", fusion=True)
    assert r.comm_stall_frac < 0.12


def test_dual_overlap_beats_naive(boot_bsgs):
    naive = simulate_program(boot_bsgs, SHARP_XMU, "hoist", "IRF")
    he2 = simulate_program(boot_bsgs, HE2_SM, "hoist", "IRF")
    assert he2.latency_s < naive.latency_s


def test_hybrid_no_worse_than_irf():
    g = helr_dfg(bsgs_bs=4).g
    irf = simulate_program(g, HE2_LM, "hoist", "IRF", fusion=True)
    hyb = simulate_program(g, HE2_LM, "hoist", "hybrid", fusion=True)
    assert hyb.latency_s <= irf.latency_s * 1.02


def test_edap_improvement(boot_bsgs, boot_full):
    sharp = simulate_program(boot_bsgs, SHARP, "minks", "EVF")
    he2 = simulate_program(boot_full, HE2_LM, "hoist", "hybrid", fusion=True)
    edap_gain = sharp.edap(SHARP.area_mm2) / he2.edap(HE2_LM.area_mm2)
    assert edap_gain > 3.0, f"EDAP gain {edap_gain:.1f} (paper: 9.23x)"


@pytest.mark.parametrize("bw", [0.25, 1.0, 4.0])
def test_bandwidth_monotonic(bw):
    """More link bandwidth never slows HE2 down (Fig. 17(a))."""
    g = bootstrapping_dfg(bsgs_bs=0).g
    lo = simulate_program(g, with_bandwidth(HE2_SM, bw), "hoist", "IRF")
    hi = simulate_program(g, with_bandwidth(HE2_SM, bw * 2), "hoist", "IRF")
    assert hi.latency_s <= lo.latency_s * (1 + 1e-9)


def test_energy_positive_and_consistent(boot_bsgs):
    r = simulate_program(boot_bsgs, HE2_SM, "hoist", "IRF")
    assert r.energy_j > 0
    assert r.edp == pytest.approx(r.energy_j * r.latency_s * 1e3)
    assert 0 <= r.xpu_util <= 1 and 0 <= r.xmu_util <= 1
