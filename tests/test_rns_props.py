"""Hypothesis property tests for the RNS/NTT substrate.

Kept separate from test_rns.py and guarded with importorskip so a bare
interpreter (no hypothesis) still collects and runs the unit tests.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import poly  # noqa: E402
from repro.core.params import CKKSParams  # noqa: E402
from repro.core.rns import ntt_ref  # noqa: E402


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_prop_ntt_linear(seed):
    """NTT(a + b) == NTT(a) + NTT(b) (mod p)."""
    p = CKKSParams(logN=6, L=1, alpha=1, k=1, q_bits=29)
    pc = poly.PolyContext(p)
    t = pc.rns.tables[0]
    rng = np.random.default_rng(seed)
    a = rng.integers(0, t.p, p.N, dtype=np.uint64)
    b = rng.integers(0, t.p, p.N, dtype=np.uint64)
    lhs = ntt_ref((a + b) % np.uint64(t.p), t)
    rhs = (ntt_ref(a, t) + ntt_ref(b, t)) % np.uint64(t.p)
    assert np.array_equal(lhs, rhs)


@settings(max_examples=10, deadline=None)
@given(r1=st.integers(0, 31), r2=st.integers(0, 31))
def test_prop_galois_additive(r1, r2):
    """Rotation additivity: galois(r1)*galois(r2) == galois(r1+r2) mod 2N.

    This is the algebraic fact behind PKB fusion (Eq. (4))."""
    p = CKKSParams(logN=6, L=1, alpha=1, k=1, q_bits=29)
    pc = poly.PolyContext(p)
    two_n = 2 * p.N
    g = (pc.rns.galois_for_rotation(r1) * pc.rns.galois_for_rotation(r2)) % two_n
    assert g == pc.rns.galois_for_rotation((r1 + r2) % p.num_slots)
