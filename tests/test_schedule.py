"""Event-driven group scheduler tests: event-model invariants, analytic
agreement on serial designs, and the paper's comm-hiding regression."""
import pytest

from repro.dfg.hoist import OpVolumes
from repro.dfg.programs import bootstrapping_dfg
from repro.sim import HE2_LM, HE2_SM, SHARP, SHARP_XMU
from repro.sim.engine import (
    Block, _block_engine_times, simulate_blocks, simulate_program,
)
from repro.sim.schedule import (
    ENGINES, Task, run_schedule, schedule_blocks,
)


def _volumes(scale=1.0):
    v = OpVolumes()
    n = 1 << 16
    v.ntt_words = 40 * n * scale
    v.modup_ntt_words = 25 * n * scale
    v.moddown_ntt_words = 15 * n * scale
    v.bconv_macs = 300 * n * scale
    v.modup_bconv_macs = 200 * n * scale
    v.moddown_bconv_macs = 100 * n * scale
    v.xpu_ewo_words = 8 * n * scale
    v.ip_macs = 500 * n * scale
    v.ewo_ext_words = 30 * n * scale
    v.autom_words = 20 * n * scale
    v.comm_up_words = 60 * n * scale
    v.comm_down_words = 25 * n * scale
    v.modup_count = 3
    return v


@pytest.fixture(scope="module")
def boot_full():
    return bootstrapping_dfg(bsgs_bs=0).g


@pytest.fixture(scope="module")
def boot_bsgs():
    return bootstrapping_dfg(bsgs_bs=4).g


# ----------------------- event-model invariants -------------------------

def test_deps_respected_and_no_double_booking():
    tasks = [
        Task(0, "xpu", 2.0, [], "a", 0, 0),
        Task(1, "link", 1.0, [0], "b", 0, 0),
        Task(2, "xmu", 3.0, [1], "c", 0, 0),
        Task(3, "xpu", 2.5, [], "d", 1, 0),
        Task(4, "xmu", 1.0, [3], "e", 1, 0),
    ]
    sched = run_schedule(tasks)
    by_id = {t.tid: t for t in tasks}
    for t in tasks:
        for d in t.deps:
            assert t.start >= by_id[d].end - 1e-12
    for e in ENGINES:
        tl = sched.timeline(e)
        for a, b in zip(tl, tl[1:]):
            assert b.start >= a.end - 1e-12, f"double-booked {e}"
    # xpu: t0 [0,2], t3 [2,4.5]; link: t1 [2,3]; xmu: t2 [3,6], t4 [6,7]
    assert sched.makespan == pytest.approx(7.0)


def test_deadlock_detection():
    # dep on a task that never completes is impossible by construction;
    # a cycle must raise instead of hanging
    tasks = [
        Task(0, "xpu", 1.0, [1], "a", 0, 0),
        Task(1, "xmu", 1.0, [0], "b", 0, 0),
    ]
    with pytest.raises(RuntimeError, match="deadlock"):
        run_schedule(tasks)


def test_program_timelines_no_overlap(boot_bsgs):
    r = simulate_program(boot_bsgs, HE2_SM, "hoist", "IRF",
                         mode="pipelined")
    assert r.timelines
    for engine, spans in r.timelines.items():
        for (s0, e0, _), (s1, e1, _) in zip(spans, spans[1:]):
            assert s1 >= e0 - 1e-15, f"{engine} double-booked"
        for s, e, _ in spans:
            assert 0.0 <= s <= e <= r.latency_s + 1e-15
        busy = sum(e - s for s, e, _ in spans)
        assert busy == pytest.approx(r.engine_busy_s[engine])


def test_busy_time_conservation(boot_bsgs):
    """Scheduling reorders work but must not create or destroy any."""
    a = simulate_program(boot_bsgs, HE2_SM, "hoist", "IRF", mode="analytic")
    p = simulate_program(boot_bsgs, HE2_SM, "hoist", "IRF", mode="pipelined")
    assert p.xpu_busy_s == pytest.approx(a.xpu_busy_s)
    assert p.xmu_busy_s == pytest.approx(a.xmu_busy_s)
    assert p.comm_busy_s == pytest.approx(a.comm_busy_s)
    assert p.engine_busy_s["xpu"] == pytest.approx(a.xpu_busy_s)
    assert p.engine_busy_s["link"] == pytest.approx(a.comm_busy_s)


# ------------------- analytic vs scheduled agreement --------------------

def test_serial_block_agreement_naive_hetero():
    """On a non-pipelined design a single block's scheduled makespan is
    exactly the analytic serialized critical path."""
    b = Block(_volumes(), dnum=3)
    a = simulate_blocks([b], SHARP_XMU, "naive", mode="analytic")
    p = simulate_blocks([b], SHARP_XMU, "naive", mode="pipelined")
    assert p.latency_s == pytest.approx(a.latency_s, rel=1e-12)
    assert p.comm_stall_s == pytest.approx(a.comm_stall_s, rel=1e-9)


def test_serial_block_agreement_monolithic():
    """Monolithic designs overlap only the evk stream: max(compute, evk)."""
    b = Block(_volumes(), dnum=3, evk_keys=((("k", 1), 5e8),),
              streams_evk=True)
    a = simulate_blocks([b], SHARP, "mono", mode="analytic")
    p = simulate_blocks([b], SHARP, "mono", mode="pipelined")
    assert p.latency_s == pytest.approx(a.latency_s, rel=1e-12)
    assert p.mem_stall_s == pytest.approx(a.mem_stall_s, rel=1e-9)


def test_single_pipelined_block_not_slower_than_analytic():
    """The event scheduler's fill/drain is exact, the closed form is an
    upper bound (it serializes the evk stream into the fill term)."""
    b = Block(_volumes(), dnum=3)
    a = simulate_blocks([b], HE2_SM, "one", mode="analytic")
    p = simulate_blocks([b], HE2_SM, "one", mode="pipelined")
    assert p.latency_s <= a.latency_s * (1 + 1e-9)
    bound = max(p.engine_busy_s.values())  # busiest single engine
    assert p.latency_s >= bound - 1e-15


def test_cross_block_overlap_strictly_helps():
    blocks = [Block(_volumes(), dnum=3) for _ in range(6)]
    a = simulate_blocks(blocks, HE2_SM, "chain", mode="analytic")
    p = simulate_blocks(blocks, HE2_SM, "chain", mode="pipelined")
    assert p.latency_s < a.latency_s


# ---------------------- paper-claim regressions -------------------------

def test_he2_lm_scheduled_regression(boot_full):
    """HE2-LM on bootstrapping: scheduled latency <= analytic, and the
    measured comm-stall fraction stays in single digits (paper: 6.67%)."""
    a = simulate_program(boot_full, HE2_LM, "hoist", "hybrid", fusion=True,
                         mode="analytic")
    p = simulate_program(boot_full, HE2_LM, "hoist", "hybrid", fusion=True,
                         mode="pipelined")
    assert p.latency_s <= a.latency_s * (1 + 1e-9)
    assert p.comm_stall_frac < 0.10
    assert p.comm_stall_frac < 0.15  # hard acceptance bound


def test_sharp_unchanged_by_scheduler(boot_bsgs):
    """Barrier semantics: designs without dual-level overlap must get
    identical latency from both models (no phantom pipelining)."""
    for hw in (SHARP, SHARP_XMU):
        a = simulate_program(boot_bsgs, hw, "hoist",
                             "EVF" if hw is SHARP else "IRF",
                             mode="analytic")
        p = simulate_program(boot_bsgs, hw, "hoist",
                             "EVF" if hw is SHARP else "IRF",
                             mode="pipelined")
        assert p.latency_s == pytest.approx(a.latency_s, rel=1e-12), hw.name


def test_utilization_traces_consistent(boot_full):
    r = simulate_program(boot_full, HE2_LM, "hoist", "hybrid", fusion=True,
                         mode="pipelined")
    assert set(r.timelines) == set(ENGINES)
    for e in ENGINES:
        assert 0.0 <= r.engine_util(e) <= 1.0 + 1e-12
    assert r.engine_util("xpu") == pytest.approx(r.xpu_util)
    assert r.engine_util("xmu") == pytest.approx(r.xmu_util)
    # something actually ran on every compute engine
    assert r.engine_util("xpu") > 0 and r.engine_util("xmu") > 0


def test_scheduled_blocks_ordering():
    """Group g of block i+1 may start on the xPU before block i fully
    drains (cross-block streaming), but never before its own group's
    data dependency."""
    blocks = [Block(_volumes(), dnum=3) for _ in range(2)]
    bt = [(_block_engine_times(b.volumes, HE2_SM, b.dnum, 0.0), b.volumes)
          for b in blocks]
    sched = schedule_blocks(bt, HE2_SM)
    b1_first_xpu = min(t.start for t in sched.tasks
                       if t.block == 1 and t.engine == "xpu")
    b0_last_end = max(t.end for t in sched.tasks if t.block == 0)
    assert b1_first_xpu < b0_last_end  # overlap happened
