"""Batched jit keyswitch engine: backend parity with the seed per-digit
path (bit-exact ciphertexts), jit plan caching, and PModUp caching."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.ckks import CKKSContext
from repro.core.params import CKKSParams


def _ct_equal(a, b):
    return (np.array_equal(np.asarray(a.c0), np.asarray(b.c0))
            and np.array_equal(np.asarray(a.c1), np.asarray(b.c1)))


def _seeded(ctx, fn):
    """Run ``fn`` on the seed per-digit path of the same context (same
    keys), restoring the engine afterwards."""
    ctx.use_engine = False
    try:
        return fn()
    finally:
        ctx.use_engine = True


@pytest.fixture(scope="module")
def ectx():
    params = CKKSParams(logN=9, L=5, alpha=2, k=3, q_bits=29, scale_bits=29)
    return CKKSContext(params, seed=11)


@pytest.fixture(scope="module")
def enc(ectx):
    rng = np.random.default_rng(3)
    nh = ectx.params.num_slots
    z = rng.normal(size=nh) + 1j * rng.normal(size=nh)
    return z, ectx.encrypt(z), rng


# --------------------- jnp engine vs seed path ---------------------------

def test_multiply_parity(ectx, enc):
    z, ct, _ = enc
    got = ectx.multiply(ct, ct)
    exp = _seeded(ectx, lambda: ectx.multiply(ct, ct))
    assert _ct_equal(got, exp)
    assert np.abs(ectx.decrypt(got) - z * z).max() < 1e-3


@pytest.mark.parametrize("steps", [1, 7, 100])
def test_rotate_parity(ectx, enc, steps):
    z, ct, _ = enc
    got = ectx.rotate(ct, steps)
    exp = _seeded(ectx, lambda: ectx.rotate(ct, steps))
    assert _ct_equal(got, exp)
    assert np.abs(ectx.decrypt(got) - np.roll(z, -steps)).max() < 1e-3


def test_conjugate_parity(ectx, enc):
    _, ct, _ = enc
    assert _ct_equal(
        ectx.conjugate(ct), _seeded(ectx, lambda: ectx.conjugate(ct))
    )


def test_hoisted_rotation_sum_parity(ectx, enc):
    z, ct, rng = enc
    steps = [1, 5, 17]
    got = ectx.hoisted_rotation_sum(ct, steps, None)
    exp = _seeded(ectx, lambda: ectx.hoisted_rotation_sum(ct, steps, None))
    assert _ct_equal(got, exp)
    assert np.abs(
        ectx.decrypt(got) - sum(np.roll(z, -s) for s in steps)
    ).max() < 2e-3


def test_hoisted_rotation_sum_pt_parity(ectx, enc):
    z, ct, rng = enc
    nh = ectx.params.num_slots
    steps = [2, 9, 11, 30]
    ptvals = [rng.normal(size=nh) for _ in steps]
    pts = [ectx.encode(v) for v in ptvals]
    got = ectx.hoisted_rotation_sum(ct, steps, pts)
    exp = _seeded(ectx, lambda: ectx.hoisted_rotation_sum(ct, steps, pts))
    assert _ct_equal(got, exp)
    expected = sum(np.roll(z, -s) * v for s, v in zip(steps, ptvals))
    assert np.abs(ectx.decrypt(got) - expected).max() < 2e-3


def test_keyswitch_parity_at_lower_level(ectx, enc):
    """Level-independent gadget: engine matches seed after level drops."""
    z, ct, _ = enc
    nh = ectx.params.num_slots
    low = ectx.pt_mul(ct, ectx.encode(np.ones(nh)))
    got = ectx.rotate(low, 4)
    exp = _seeded(ectx, lambda: ectx.rotate(low, 4))
    assert _ct_equal(got, exp)
    assert np.abs(ectx.decrypt(got) - np.roll(z, -4)).max() < 5e-3


@pytest.mark.parametrize("steps", [1, 6])
def test_automorphism_eval_matches_coeff_roundtrip(ectx, enc, steps):
    """Eval-domain Galois gather == INTT -> permute -> NTT, bit-exact."""
    from repro.core import poly

    _, ct, _ = enc
    primes = ectx.chain(ct.level)
    g = ectx.pc.rns.galois_for_rotation(steps)
    got = poly.automorphism_eval(ct.c1, g, ectx.pc)
    exp = poly.automorphism(ct.c1, primes, g, ectx.pc)
    assert np.array_equal(np.asarray(got), np.asarray(exp))


# --------------------- pallas backend parity -----------------------------

@pytest.fixture(scope="module")
def pallas_pair():
    params = CKKSParams(logN=8, L=3, alpha=2, k=2, q_bits=29, scale_bits=26)
    return (CKKSContext(params, seed=5),
            CKKSContext(params, seed=5, backend="pallas"))


def test_pallas_backend_parity(pallas_pair):
    """Montgomery uint32 kernel path decrypt-matches the uint64 jnp
    engine bit-exactly for multiply / rotate / hoisted-rotation-sum."""
    ctx_j, ctx_p = pallas_pair
    rng = np.random.default_rng(9)
    nh = ctx_j.params.num_slots
    z = rng.normal(size=nh) + 1j * rng.normal(size=nh)
    ct_j, ct_p = ctx_j.encrypt(z), ctx_p.encrypt(z)
    assert np.array_equal(np.asarray(ct_j.c0), np.asarray(ct_p.c0))

    assert _ct_equal(ctx_j.multiply(ct_j, ct_j), ctx_p.multiply(ct_p, ct_p))
    assert _ct_equal(ctx_j.rotate(ct_j, 5), ctx_p.rotate(ct_p, 5))
    ptvals = [rng.normal(size=nh) for _ in range(2)]
    h_j = ctx_j.hoisted_rotation_sum(
        ct_j, [1, 5], [ctx_j.encode(v) for v in ptvals]
    )
    h_p = ctx_p.hoisted_rotation_sum(
        ct_p, [1, 5], [ctx_p.encode(v) for v in ptvals]
    )
    assert _ct_equal(h_j, h_p)
    expected = sum(np.roll(z, -s) * v for s, v in zip([1, 5], ptvals))
    assert np.abs(ctx_p.decrypt(h_p) - expected).max() < 2e-2


def test_pallas_backend_seed_parity(pallas_pair):
    """Pallas engine also decrypt-matches the seed per-digit path."""
    ctx_j, ctx_p = pallas_pair
    rng = np.random.default_rng(13)
    nh = ctx_j.params.num_slots
    z = rng.normal(size=nh) + 1j * rng.normal(size=nh)
    ct_p = ctx_p.encrypt(z)
    got = ctx_p.hoisted_rotation_sum(ct_p, [2, 9], None)
    exp = _seeded(
        ctx_p, lambda: ctx_p.hoisted_rotation_sum(ct_p, [2, 9], None)
    )
    assert _ct_equal(got, exp)


def test_bad_backend_rejected():
    with pytest.raises(ValueError):
        CKKSContext(
            CKKSParams(logN=8, L=1, alpha=1, k=1), backend="cuda"
        )


# --------------------- jit plan caching ----------------------------------

def test_jit_one_trace_per_level(ectx, enc):
    """Re-dispatch at the same level never retraces: one trace per
    (level, op-shape) plan."""
    _, ct, _ = enc
    lvl = ct.level
    eng = ectx.engine
    ectx.multiply(ct, ct)
    ectx.multiply(ct, ct)          # CMult dispatches the relin plan
    assert eng.trace_counts[("relin", lvl, False)] == 1
    ectx.rotate(ct, 1)
    ectx.rotate(ct, 9)     # different step, same plan
    ectx.conjugate(ct)     # different galois, same plan
    assert eng.trace_counts[("galois", lvl)] == 1
    ectx.hoisted_rotation_sum(ct, [1, 2], None)
    ectx.hoisted_rotation_sum(ct, [3, 8], None)  # same R -> cache hit
    assert eng.trace_counts[("hoisted", lvl, 2, False)] == 1


def test_pmodup_cached(ectx, enc):
    _, ct, rng = enc
    nh = ectx.params.num_slots
    pt = ectx.encode(rng.normal(size=nh))
    a = ectx._pmodup(pt, ct.level)
    b = ectx._pmodup(pt, ct.level)
    assert a is b
    assert isinstance(a, jnp.ndarray)
