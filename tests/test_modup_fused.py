"""Fused Pallas ModUp kernel tests.

The fused kernel (``kernels/modup``) runs a digit's INTT -> BConv
scale+tree-reduce -> NTT in ONE ``pallas_call`` with the digit's limbs
VMEM-resident (the BConv scale is folded into the INTT post-twist).
Tier-1 pins it three ways:

  * bit-exact against a plain uint64 oracle (``modup_digit_oracle``)
    built from the reference NTTs — no Montgomery, no fusion
  * bit-exact against the jnp engine path (``backend='jnp'`` ModUp),
    across dnum in {2, 3} (uniform and short-last-digit splits),
    multiple levels, and batch widths 1 and 4
  * one jit trace per (level, batch) plan: re-dispatch with fresh data
    must not retrace (``trace_counts`` stable)
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.ckks import CKKSContext
from repro.core.params import CKKSParams
from repro.kernels.modup.ops import modup_digit, modup_digit_oracle

# level 5 -> l=6 limbs -> dnum=3 (uniform); level 3 -> l=4 -> dnum=2;
# level 4 -> l=5 -> dnum=3 with a short last digit
PARAMS = CKKSParams(logN=8, L=5, alpha=2, k=3, q_bits=29, scale_bits=26)
LEVELS = (5, 4, 3)


@pytest.fixture(scope="module")
def ctxs():
    return {b: CKKSContext(PARAMS, seed=5, backend=b)
            for b in ("jnp", "pallas")}


def _rand_residues(rng, primes, n, batch=None):
    shape = (len(primes), n) if batch is None else (batch, len(primes), n)
    out = np.empty(shape, dtype=np.uint32)
    for i, q in enumerate(primes):
        out[..., i, :] = rng.integers(0, q, size=shape[:-2] + (n,),
                                      dtype=np.uint64).astype(np.uint32)
    return out


@pytest.mark.parametrize("level", LEVELS)
def test_fused_kernel_matches_uint64_oracle(ctxs, level):
    """Every digit of every decomposition: fused kernel == plain uint64
    oracle, for batch widths 1 and 4."""
    eng = ctxs["pallas"].engine
    plan = eng._plan(level)
    rng = np.random.default_rng(level)
    for g, D in enumerate(plan.groups):
        src, dst = tuple(D), plan.ext
        for batch in (None, 4):
            x = _rand_residues(rng, src, plan.N, batch)
            got = modup_digit(jnp.asarray(x), src, dst, eng.tabs,
                              eng.pc.rns, interpret=True)
            # the uint64 oracle is rank-2; check batches row by row
            exp = (modup_digit_oracle(jnp.asarray(x), src, dst, eng.tabs,
                                      eng.pc.rns)
                   if batch is None else
                   jnp.stack([modup_digit_oracle(jnp.asarray(r), src, dst,
                                                 eng.tabs, eng.pc.rns)
                              for r in x]))
            assert np.array_equal(np.asarray(got), np.asarray(exp)), \
                f"level={level} digit={g} batch={batch}"


@pytest.mark.parametrize("level", LEVELS)
def test_fused_modup_matches_jnp_engine(ctxs, level):
    """Full engine ModUp (fused pallas kernel + own-limb passthrough)
    is bit-exact with the jnp op-by-op path, unbatched and batched."""
    rng = np.random.default_rng(level)
    primes = ctxs["jnp"].chain(level)
    a1 = _rand_residues(rng, primes, PARAMS.N).astype(np.uint64)
    a4 = _rand_residues(rng, primes, PARAMS.N, 4).astype(np.uint64)
    outs = {}
    for b, ctx in ctxs.items():
        outs[b] = (ctx.engine.modup(jnp.asarray(a1), level),
                   ctx.engine.modup_batched(jnp.asarray(a4), level),
                   ctx.engine.modup_batched(jnp.asarray(a4[:1]), level))
    for got, exp in zip(outs["pallas"], outs["jnp"]):
        assert got.shape == exp.shape
        assert np.array_equal(np.asarray(got), np.asarray(exp))


def test_fused_modup_vmap_composes(ctxs):
    """jit(vmap(modup_digit)) folds the batch into the kernel grid and
    matches per-row dispatch bit-exactly."""
    eng = ctxs["pallas"].engine
    plan = eng._plan(LEVELS[0])
    src, dst = tuple(plan.groups[0]), plan.ext
    rng = np.random.default_rng(0)
    x = jnp.asarray(_rand_residues(rng, src, PARAMS.N, 4))

    fn = jax.jit(jax.vmap(
        lambda r: modup_digit(r, src, dst, eng.tabs, eng.pc.rns,
                              interpret=True)))
    got = fn(x)
    rows = [modup_digit(x[i], src, dst, eng.tabs, eng.pc.rns,
                        interpret=True) for i in range(4)]
    assert np.array_equal(np.asarray(got), np.stack([np.asarray(r)
                                                     for r in rows]))


def test_modup_batched_plan_cache_hits(ctxs):
    """A warmed (level, batch) ModUp plan re-dispatches with ZERO new
    traces on the pallas backend — fresh data, same trace_counts."""
    eng = ctxs["pallas"].engine
    rng = np.random.default_rng(9)
    level = LEVELS[0]
    primes = ctxs["pallas"].chain(level)
    for batch in (1, 4):
        a = _rand_residues(rng, primes, PARAMS.N, batch).astype(np.uint64)
        eng.modup_batched(jnp.asarray(a), level)      # warm the plan
        before = dict(eng.trace_counts)
        a2 = _rand_residues(rng, primes, PARAMS.N, batch).astype(np.uint64)
        out = eng.modup_batched(jnp.asarray(a2), level)
        assert dict(eng.trace_counts) == before
        assert out.shape[0] == batch
