"""Distributed keyswitch (IRF vs EVF shardings) — correctness on an
8-device mesh + the paper's communication-volume ordering, measured from
the compiled HLO.  Runs in a subprocess (device-count override)."""
import json
import os
import pathlib
import subprocess
import sys
import textwrap

import pytest

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    import jax.numpy as jnp
    import numpy as np

    jax.config.update("jax_enable_x64", True)

    from repro.core.distributed import (
        comm_bytes_per_device, ip_evf, ip_irf, reference_ip,
    )

    mesh = jax.make_mesh((8,), ("model",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    dnum, L, N = 3, 16, 256
    rng = np.random.default_rng(0)
    qs = np.array([536608769 + 4096 * i for i in range(L)],
                  dtype=np.uint64)[:, None]
    digits = rng.integers(0, 2**29, (dnum, L, N)).astype(np.uint64)
    evk = rng.integers(0, 2**29, (dnum, 2, L, N)).astype(np.uint64)

    ref0, ref1 = reference_ip(jnp.asarray(digits), jnp.asarray(evk),
                              jnp.asarray(qs))

    irf_fn, _ = ip_irf(mesh)
    evf_fn, _ = ip_evf(mesh)
    with mesh:
        i0, i1 = irf_fn(jnp.asarray(digits), jnp.asarray(evk),
                        jnp.asarray(qs))
        e0, e1 = evf_fn(jnp.asarray(digits), jnp.asarray(evk),
                        jnp.asarray(qs))
    # analytic volumes (the CPU backend lowers in-process all_to_all to
    # transposes, so HLO parsing is blind here; these are exact for the
    # fixed layouts)
    b_irf = comm_bytes_per_device("IRF", dnum, L, N, 8)
    b_evf = comm_bytes_per_device("EVF", dnum, L, N, 8)

    ok_irf = bool(np.array_equal(np.asarray(i0), np.asarray(ref0))
                  and np.array_equal(np.asarray(i1), np.asarray(ref1)))
    ok_evf = bool(np.array_equal(np.asarray(e0), np.asarray(ref0))
                  and np.array_equal(np.asarray(e1), np.asarray(ref1)))
    print(json.dumps({
        "ok_irf": ok_irf, "ok_evf": ok_evf,
        "irf_bytes": b_irf, "evf_bytes": b_evf,
    }))
""")


@pytest.mark.slow
def test_irf_evf_correct_and_comm_ordering():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["ok_irf"], "IRF distributed IP != reference"
    assert res["ok_evf"], "EVF distributed IP != reference"
    # The paper's Fig. 3 trade-off: moving intermediates (IRF) costs less
    # than moving keys (EVF) for a single keyswitch — and hoisting
    # amortizes the IRF transfer across a whole PKB.
    assert res["irf_bytes"] < res["evf_bytes"], (
        f"IRF {res['irf_bytes']} !< EVF {res['evf_bytes']}"
    )
