"""HERO DFG framework tests: PKB identification, hoisting counts,
fusion DP (incl. homomorphic validation of the Eq. (4) rewrite)."""
import numpy as np
import pytest

from repro.dfg.fusion import (
    CostWeights, fuse_functional, fuse_pair, fuse_score, optimal_fusion,
)
from repro.dfg.graph import OpKind
from repro.dfg.hoist import pkb_volumes, program_volumes
from repro.dfg.pkb import identify_pkbs, keyswitch_layers
from repro.dfg.programs import (
    PROGRAMS, bootstrapping_dfg, convbn_example, helr_dfg,
)
from repro.dfg.trace import ProgramBuilder


def test_layering_serial_vs_parallel():
    b = ProgramBuilder(N=1 << 10, alpha=2)
    x = b.input(6)
    r1 = x.rot(1)            # layer 0
    r2 = x.rot(2)            # layer 0 (parallel)
    r3 = r1.cadd(r2).rot(4)  # layer 1 (serial)
    r3.output()
    layers = keyswitch_layers(b.g)
    rots = [n for n in b.g.nodes.values() if n.op == OpKind.ROT]
    assert sorted(layers[n.id] for n in rots) == [0, 0, 1]


def test_pkb_identification_convbn():
    pkbs = identify_pkbs(convbn_example().g)
    assert [p.n_rot for p in pkbs] == [8, 7, 7]
    assert all(p.indeg == 1 and p.outdeg == 1 for p in pkbs)


def test_hoisting_reduces_modups():
    pkbs = identify_pkbs(convbn_example().g)
    p = pkbs[0]
    plain = pkb_volumes(p, k=12, alpha=12, strategy="plain", dataflow="IRF")
    hoist = pkb_volumes(p, k=12, alpha=12, strategy="hoist", dataflow="IRF")
    assert plain.modup_count == p.n_rot
    assert hoist.modup_count == p.indeg
    assert hoist.comm_words < plain.comm_words
    assert hoist.ip_count == plain.ip_count  # IPs unchanged by hoisting
    # hoisting shifts EWOs to the extended domain (paper Sec. II-C)
    assert hoist.ewo_ext_words > 0 and plain.ewo_ext_words == 0


def test_minks_increases_keyswitches():
    pkbs = identify_pkbs(convbn_example().g)
    p = pkbs[0]
    minks = pkb_volumes(p, 12, 12, "minks", "EVF")
    plain = pkb_volumes(p, 12, 12, "plain", "EVF")
    assert minks.keyswitch_count >= plain.keyswitch_count
    assert minks.evk_set_words <= plain.evk_set_words


def test_fuse_pair_step_sums():
    pkbs = identify_pkbs(convbn_example().g)
    fused = fuse_pair(pkbs[0], pkbs[1], nh=1 << 15)
    s1, s2 = set(pkbs[0].steps), set(pkbs[1].steps)
    assert set(fused.steps) == {(a + b) % (1 << 15) for a in s1 for b in s2}
    assert fused.n_rot == len(set(fused.steps))  # merged duplicate paths


def test_fusion_dp_convbn():
    """Fig. 9: the three ConvBN PKBs fuse into one under ample capacity."""
    pkbs = identify_pkbs(convbn_example().g)
    plan = optimal_fusion(pkbs, k=12, alpha=12, nh=1 << 15,
                          capacity_words=8e9 / 8)
    assert plan.score > 0
    assert plan.groups == [[0, 1, 2]]


def test_fusion_respects_capacity():
    """Tiny evk budget -> no fusion allowed."""
    pkbs = identify_pkbs(convbn_example().g)
    plan = optimal_fusion(pkbs, k=12, alpha=12, nh=1 << 15,
                          capacity_words=1.0)
    assert plan.groups == [[0], [1], [2]]
    assert plan.score == 0.0


def test_fusion_dp_beats_greedy_pairwise():
    """DP must be at least as good as any fixed pairing."""
    pkbs = identify_pkbs(convbn_example().g)
    w = CostWeights()
    cap = 8e9 / 8
    dp = optimal_fusion(pkbs, 12, 12, 1 << 15, cap, w)
    pair01 = fuse_score([pkbs[0], pkbs[1]], 12, 12, 1 << 15, w, cap)
    pair12 = fuse_score([pkbs[1], pkbs[2]], 12, 12, 1 << 15, w, cap)
    best_pair = max(s[0] for s in (pair01, pair12) if s is not None)
    assert dp.score >= best_pair - 1e-12


def test_program_volumes_hero_reduction():
    """HERO (hoist, IRF) must cut comm massively vs per-rotation IRF."""
    g = bootstrapping_dfg().g
    pkbs = identify_pkbs(g)
    plain = program_volumes(g, pkbs, 12, 12, "plain", "IRF")
    hoist = program_volumes(g, pkbs, 12, 12, "hoist", "IRF")
    assert hoist.comm_words < plain.comm_words / 3
    assert hoist.modup_count < plain.modup_count / 5


@pytest.mark.parametrize("name", list(PROGRAMS))
def test_benchmark_programs_build(name):
    g = PROGRAMS[name]().g
    pkbs = identify_pkbs(g)
    assert len(pkbs) > 0
    assert g.topo_order()  # acyclic


def test_helr_low_parallelism():
    """Fig. 6: HELR is dominated by parallelism-1 PKBs."""
    pkbs = identify_pkbs(helr_dfg(with_bootstrap=False).g)
    ones = sum(1 for p in pkbs if p.n_rot == 1)
    assert ones >= len(pkbs) * 0.8


# ------------------ homomorphic validation of Eq. (4) --------------------

def test_fusion_functional_equivalence(ctx, rng):
    """Fused PKB evaluates to the same ciphertext as the serial pair."""
    from repro.core import linear  # noqa: F401

    nh = ctx.params.num_slots
    z = rng.normal(size=nh) + 1j * rng.normal(size=nh)
    ct = ctx.encrypt(z)

    steps1, steps2 = [1, 2, 3], [4, 8]
    pts1 = [rng.normal(size=nh) for _ in steps1]
    pts2 = [rng.normal(size=nh) for _ in steps2]

    # serial: PKB2( PKB1(x) )
    inner = ctx.hoisted_rotation_sum(
        ct, steps1, [ctx.encode(p) for p in pts1], rescale=False
    )
    serial = ctx.hoisted_rotation_sum(
        inner, steps2, [ctx.encode(p, level=inner.level) for p in pts2],
        rescale=False,
    )

    # fused: single PKB with summed steps and rotated plaintext products;
    # plaintext product of two scale-D encodings == one scale-D^2 encoding
    fsteps, fpts = fuse_functional(steps1, pts1, steps2, pts2, nh)
    fused_pts = [
        ctx.encode(p, level=ct.level, scale=ctx.params.scale ** 2)
        for p in fpts
    ]
    fused = ctx.hoisted_rotation_sum(ct, fsteps, fused_pts, rescale=False)

    expected = np.zeros(nh, dtype=complex)
    acc1 = sum(np.roll(z, -s) * p for s, p in zip(steps1, pts1))
    expected = sum(np.roll(acc1, -s) * p for s, p in zip(steps2, pts2))

    d_serial = ctx.decrypt(serial)
    d_fused = ctx.decrypt(fused)
    assert np.abs(d_serial - expected).max() < 2e-2
    assert np.abs(d_fused - expected).max() < 2e-2
    assert np.abs(d_fused - d_serial).max() < 3e-2
