"""Compiled bootstrap tests: the full paper pipeline through the runtime.

Tier-1 covers the hot structure at a small shape: compiled CoeffToSlot /
SlotToCoeff are bit-exact with the eager path at strictly fewer ModUps
(baby-step blocks share one ModUp per anchor via the digits cache), and
the ``exact=False`` multi-anchor lowering closes every BSGS giant-step
sum with ONE ModDown inside a measured error bound.  The slow-marked
test runs the whole ModRaise -> C2S -> re/im EvalMod -> merge -> S2C
pipeline compiled vs eager (bit-exact, fewer ModUps, decryption
accuracy).
"""
import numpy as np
import pytest

from repro.core.bootstrap import Bootstrapper, auto_bsgs_bs
from repro.core.ckks import CKKSContext
from repro.core.params import CKKSParams
from repro.runtime import ProgramExecutor, TraceContext, compile_program
from repro.runtime.lower import MultiHoistedStep

from parity import assert_program_parity, ct_equal as _ct_equal


@pytest.fixture(scope="module")
def small_boot():
    # C2S/S2C only need n_groups levels each — a shallow chain keeps the
    # tier-1 matvec-parity tests fast.
    p = CKKSParams(logN=8, L=5, alpha=2, k=3, q_bits=29, scale_bits=29)
    ctx = CKKSContext(p, seed=7)
    btp = Bootstrapper(ctx, n_groups=2, mod_K=3, cheb_degree=15)
    return ctx, btp


@pytest.fixture(scope="module")
def c2s_traced(small_boot):
    ctx, btp = small_boot
    p = ctx.params
    tc = TraceContext(p)
    h = tc.input("x", level=p.L, scale=p.scale)
    tc.output(btp.coeff_to_slot(h, tc), "y")
    return tc


def test_auto_bsgs_bs_strided():
    """The default block size respects the FFT stride: offsets k*gap
    split into pow2-many shared baby steps; sparse matrices stay dense."""
    nh = 256
    offs = [(k * 16) % nh for k in range(17)]
    bs = auto_bsgs_bs(offs, nh)
    assert bs == 16 * 4                       # 4 baby steps of stride 16
    assert {d % bs for d in offs} <= {0, 16, 32, 48}
    assert auto_bsgs_bs([0, 1, 2], nh) == 0   # too sparse
    assert auto_bsgs_bs(list(range(9)), nh) == 2


def test_bootstrapper_default_exposes_giant_steps(small_boot, c2s_traced):
    """Default (bsgs_bs=None) derives a BSGS split — the traced C2S has
    at least two keyswitch layers (baby + giant), which is what the
    fusion/multi-anchor machinery needs to see."""
    ctx, btp = small_boot
    assert btp.bsgs_bs is None
    layers = {p.layer for p in compile_program(c2s_traced).pkbs}
    assert len(layers) >= 2


def test_compiled_c2s_bitexact_fewer_modups(small_boot, c2s_traced, rng):
    ctx, btp = small_boot
    nh = ctx.params.num_slots
    z = (rng.normal(size=nh) + 1j * rng.normal(size=nh)) * 0.01
    ct = ctx.encrypt(z)
    c = ctx.counters

    s0 = c.snapshot()
    exp = btp.coeff_to_slot(ct)
    eager = c.delta(s0)

    comp = compile_program(c2s_traced)
    assert comp.n_hoisted > 0
    ex = ProgramExecutor(ctx)
    s1 = c.snapshot()
    got = ex.run(comp, {"x": ct})["y"]
    compiled = c.delta(s1)

    assert _ct_equal(got, exp)
    assert got.scale == exp.scale and got.level == exp.level
    assert compiled.modup < eager.modup
    assert compiled.moddown == eager.moddown   # exact mode keeps ModDowns


def test_compiled_s2c_bitexact_fewer_modups(small_boot, rng):
    ctx, btp = small_boot
    p = ctx.params
    nh = p.num_slots
    z = (rng.normal(size=nh) + 1j * rng.normal(size=nh)) * 0.01
    ct = ctx.encrypt(z)

    tc = TraceContext(p)
    h = tc.input("x", level=p.L, scale=p.scale)
    tc.output(btp.slot_to_coeff(h, tc), "y")
    comp = compile_program(tc)
    assert_program_parity(
        ctx, comp, {"x": ct},
        lambda c, t: btp.slot_to_coeff(t),
        fewer_modups=True)


def test_multi_anchor_one_moddown_error_bound(small_boot, c2s_traced, rng):
    """exact=False lowers the giant-step PKBs to single-ModDown blocks:
    strictly fewer ModDowns at the same ModUp count, and the output
    stays within the merged-ModDown rounding bound of the exact path."""
    ctx, btp = small_boot
    p = ctx.params
    nh = p.num_slots
    z = (rng.normal(size=nh) + 1j * rng.normal(size=nh)) * 0.01
    ct = ctx.encrypt(z)
    c = ctx.counters

    comp = compile_program(c2s_traced)
    multi = compile_program(c2s_traced, exact=False)
    n_multi = sum(1 for s in multi.steps if isinstance(s, MultiHoistedStep))
    assert n_multi > 0 and multi.n_multi == n_multi
    assert not multi.exact and comp.exact

    ex = ProgramExecutor(ctx)
    s0 = c.snapshot()
    exact_out = ex.run(comp, {"x": ct})["y"]
    d_exact = c.delta(s0)
    s1 = c.snapshot()
    multi_out = ex.run(multi, {"x": ct})["y"]
    d_multi = c.delta(s1)

    assert d_multi.moddown < d_exact.moddown
    assert d_multi.modup == d_exact.modup
    assert not _ct_equal(multi_out, exact_out)   # genuinely different path

    # merged-ModDown rounding: each ModDown the multi path skips defers
    # an O(k)-integer-coefficient rounding into the accumulated sum;
    # decoded, that is at most ~N*(k+1)/scale per merged point.
    n_merged = d_exact.moddown - d_multi.moddown
    bound = n_merged * p.N * (p.k + 1) / p.scale
    diff = np.abs(ctx.decrypt(multi_out) - ctx.decrypt(exact_out)).max()
    assert diff < bound, (diff, bound)

    # reconciliation holds for the multi lowering too
    res = ex.run(multi, {"x": ct}, with_report=True)
    rec = res.report.reconcile()
    assert rec["counts_match"], rec


@pytest.mark.slow
def test_full_compiled_bootstrap(rng):
    """End-to-end: the compiled pipeline is bit-exact with the eager
    bootstrap, performs strictly fewer ModUps, reconciles against the
    hoist model, feeds the group scheduler, and decrypts accurately."""
    from repro.sim import HE2_SM

    p = CKKSParams(logN=8, L=19, alpha=4, k=4, q_bits=29, scale_bits=29,
                   q0_bits=30)
    ctx = CKKSContext(p, seed=7, hamming_weight=8)
    btp = Bootstrapper(ctx, n_groups=2, mod_K=3, cheb_degree=27)
    nh = p.num_slots
    z = (rng.normal(size=nh) + 1j * rng.normal(size=nh)) * 0.01
    ct0 = ctx.encrypt(z, level=0)
    c = ctx.counters

    s0 = c.snapshot()
    exp = btp.bootstrap(ct0)
    eager = c.delta(s0)
    assert exp.level >= 1

    comp = btp.compile(input_scale=ct0.scale)
    ex = ProgramExecutor(ctx)
    s1 = c.snapshot()
    res = ex.run(comp, {"ct": ct0}, with_report=True)
    compiled = c.delta(s1)
    got = res["out"]

    assert _ct_equal(got, exp)
    assert got.scale == exp.scale and got.level == exp.level
    assert compiled.modup < eager.modup
    rec = res.report.reconcile()
    assert rec["counts_match"], rec
    assert res.report.validate_plan_shapes(p)
    sched = res.report.scheduled_result(comp, HE2_SM)
    assert sched.latency_s > 0 and sched.timelines

    err = np.abs(ctx.decrypt(got) - z).max()
    assert err < 5e-2, f"compiled bootstrap error {err}"

    # exact=False: fewer ModDowns, same accuracy class
    multi = btp.compile(input_scale=ct0.scale, exact=False)
    assert multi.n_multi > 0
    s2 = c.snapshot()
    got_m = ex.run(multi, {"ct": ct0})["out"]
    d_multi = c.delta(s2)
    assert d_multi.moddown < compiled.moddown
    err_m = np.abs(ctx.decrypt(got_m) - z).max()
    assert err_m < err * 1.5 + 1e-3
