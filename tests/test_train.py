"""Training substrate tests: optimizer, checkpoint/resume, compression,
data pipeline determinism, end-to-end loss decrease (deliverable (b))."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import reduced_config
from repro.data.pipeline import PipelineConfig, TokenPipeline
from repro.models.model import init_params
from repro.models.steps import loss_fn
from repro.train.checkpoint import CheckpointManager
from repro.train.compression import compress_grads_int8, decompress_grads
from repro.train.optimizer import AdamW
from repro.train.trainer import Trainer, TrainerConfig


def test_adamw_decreases_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0, warmup_steps=1)
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}
        params, state = opt.update(params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 0.3


def test_adamw_bf16_states():
    opt = AdamW(state_dtype="bfloat16")
    params = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    state = opt.init(params)
    assert state["m"]["w"].dtype == jnp.bfloat16
    params2, state2 = opt.update(params, {"w": jnp.ones((4, 4))}, state)
    assert state2["m"]["w"].dtype == jnp.bfloat16
    assert params2["w"].dtype == jnp.bfloat16


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    state = {"params": {"a": jnp.arange(6).reshape(2, 3),
                        "blocks": [{"w": jnp.ones((2, 2))},
                                   {"w": jnp.zeros((2, 2))}]},
             "opt": {"step": jnp.array(7)}}
    mgr.save(7, state)
    step, restored = mgr.restore()
    assert step == 7
    np.testing.assert_array_equal(restored["params"]["a"],
                                  np.arange(6).reshape(2, 3))
    np.testing.assert_array_equal(restored["params"]["blocks"][1]["w"],
                                  np.zeros((2, 2)))


def test_checkpoint_incomplete_ignored(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    mgr.save(5, {"x": jnp.ones(3)})
    # simulate a crashed write: directory without MANIFEST
    bad = tmp_path / "step_9"
    bad.mkdir()
    (bad / "shard_0.npz").write_bytes(b"garbage")
    assert mgr.latest_step() == 5


def test_checkpoint_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"x": jnp.ones(2)})
    assert mgr.steps() == [3, 4]


def test_grad_compression_error_bounded():
    rng = np.random.default_rng(0)
    grads = {"a": jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32)),
             "b": jnp.asarray(rng.normal(size=(17,)).astype(np.float32))}
    deq = decompress_grads(compress_grads_int8(grads))
    for k in grads:
        err = np.abs(np.asarray(deq[k]) - np.asarray(grads[k])).max()
        scale = np.abs(np.asarray(grads[k])).max()
        assert err <= scale / 127.0 + 1e-6


def test_pipeline_deterministic_and_restartable():
    cfg = PipelineConfig(vocab=64, seq_len=8, global_batch=4, seed=3)
    p1, p2 = TokenPipeline(cfg), TokenPipeline(cfg)
    b1, b2 = p1.batch_at(11), p2.batch_at(11)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (4, 8)
    # labels are next-token shifted
    full = p1._synthetic(11)
    np.testing.assert_array_equal(b1["labels"], full[:, 1:])


@pytest.mark.slow
def test_trainer_loss_decreases_and_resumes(tmp_path):
    """End-to-end: train a tiny LM, interrupt, resume, loss decreases."""
    cfg = reduced_config("stablelm_3b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    pipe = TokenPipeline(PipelineConfig(
        vocab=cfg.vocab, seq_len=16, global_batch=8, seed=0))
    tcfg = TrainerConfig(total_steps=30, ckpt_every=10,
                         ckpt_dir=str(tmp_path), log_every=100)
    tr = Trainer(cfg, tcfg, AdamW(lr=2e-3, warmup_steps=5))
    params_out, _, losses = tr.run(params, pipe, resume=False)
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1, \
        f"loss did not decrease: {losses[:3]} -> {losses[-3:]}"

    # resume continues from the checkpoint, not from scratch
    tcfg2 = TrainerConfig(total_steps=35, ckpt_every=10,
                          ckpt_dir=str(tmp_path), log_every=100)
    tr2 = Trainer(cfg, tcfg2, AdamW(lr=2e-3, warmup_steps=5))
    fresh = init_params(cfg, jax.random.PRNGKey(0))
    _, _, losses2 = tr2.run(fresh, pipe, resume=True)
    assert len(losses2) <= 6, "resume should only run the remaining steps"


def test_microbatch_accumulation_equivalent():
    """grad(batch) == mean of grad(microbatches) for the same tokens."""
    cfg = reduced_config("qwen2_vl_2b")
    params = init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab, (4, 8)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks),
             "positions": jnp.broadcast_to(
                 jnp.arange(8)[None, None], (3, 4, 8)).astype(jnp.int32)}
    g_full = jax.grad(loss_fn)(params, batch, cfg)
    halves = [
        jax.grad(loss_fn)(
            params,
            {k: v[:, :2] if k == "positions" else v[:2]
             for k, v in batch.items()},
            cfg),
        jax.grad(loss_fn)(
            params,
            {k: v[:, 2:] if k == "positions" else v[2:]
             for k, v in batch.items()},
            cfg),
    ]
    g_acc = jax.tree.map(lambda a, b: (a + b) / 2, *halves)
    flat_f = jax.tree.leaves(g_full)
    flat_a = jax.tree.leaves(g_acc)
    for f, a in zip(flat_f, flat_a):
        np.testing.assert_allclose(np.asarray(f, np.float32),
                                   np.asarray(a, np.float32),
                                   rtol=0.15, atol=2e-2)
