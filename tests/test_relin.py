"""Relinearization as a keyswitch-family member.

Covers the relin acceptance gates:
  * engine ``relin`` bit-exact with keyswitch-then-add (and with the
    seed per-digit path) — CMults are just the other keyswitch flavor
  * eager vs engine vs vmap-batched CMult tally IDENTICAL ``OpCounters``
    (modup/moddown/ip counts and NTT/BConv/IP word volumes)
  * ``trace_counts`` stays flat across dispatches of the relin jit
    plans (one trace per (op, level, shape) plan)
  * ``multi_relin_sum`` closes n relins with ONE ModDown inside a
    measured error envelope (ARK-style lazy ModDown)
  * the BSGS Chebyshev evaluation compiles end to end: ``RelinStep``s
    bit-exact under ``exact=True``, ``MultiRelinStep`` merges the
    giant-step product sums under ``exact=False`` at fewer ModDowns,
    and predicted-vs-executed reconciliation stays exact
  * batched relin/rotation paths on backend='pallas' are bit-exact with
    the jnp backend (the kernel suite is vmap-compatible via
    ``custom_vmap`` rules; there is no batched-pallas gate any more)
"""
import numpy as np
import pytest

from repro.core.ckks import CKKSContext, Ciphertext, tensor_product
from repro.core.params import CKKSParams
from repro.dfg.graph import OpKind
from repro.core.polyeval import (
    cheb_divmod, chebyshev_coeffs, eval_chebyshev, eval_chebyshev_bsgs,
)
from repro.runtime import ProgramExecutor, TraceContext, compile_program
from repro.runtime.lower import MultiRelinStep, RelinStep

from parity import assert_program_parity, ct_equal as _ct_equal


@pytest.fixture(scope="module")
def relin_ctx():
    p = CKKSParams(logN=8, L=9, alpha=2, k=3, q_bits=29, scale_bits=29)
    return CKKSContext(p, seed=13)


@pytest.fixture(scope="module")
def cheb_case(relin_ctx):
    rng = np.random.default_rng(3)
    nh = relin_ctx.params.num_slots
    x = rng.uniform(-1, 1, nh)
    fn = lambda t: np.sin(2 * np.pi * 1.5 * t) / (2 * np.pi)  # noqa: E731
    coeffs = chebyshev_coeffs(fn, 15)
    return x, fn, coeffs


# ----------------------- engine relin parity -----------------------------

def test_cheb_divmod_identity():
    import numpy.polynomial.chebyshev as C

    rng = np.random.default_rng(0)
    for d, g in ((28, 16), (15, 8), (9, 8), (8, 8)):
        c = rng.normal(size=d + 1).astype(complex)
        q, r = cheb_divmod(c, g)
        x = np.linspace(-1, 1, 13)
        tg = [0] * g + [1]
        got = C.chebval(x, q) * C.chebval(x, tg) + C.chebval(x, r)
        assert np.abs(C.chebval(x, c) - got).max() < 1e-12
        assert len(r) == g


def test_relin_bitexact_with_seed_multiply(relin_ctx):
    """Engine CMult (jit relin plan) == seed per-digit CMult, bit for
    bit — the two dispatch paths of the keyswitch family agree."""
    ctx = relin_ctx
    rng = np.random.default_rng(7)
    nh = ctx.params.num_slots
    a = ctx.encrypt(rng.normal(size=nh))
    b = ctx.encrypt(rng.normal(size=nh))
    got = ctx.multiply(a, b, rescale=False)
    ctx.use_engine = False
    try:
        exp = ctx.multiply(a, b, rescale=False)
    finally:
        ctx.use_engine = True
    assert _ct_equal(got, exp)
    assert got.scale == exp.scale and got.level == exp.level


def test_relin_digits_interface(relin_ctx):
    """Pre-computed d2 digits (engine ``modup``) slot into ``relin``
    exactly like rotation digits — bit-exact with the internal ModUp."""
    ctx = relin_ctx
    rng = np.random.default_rng(8)
    nh = ctx.params.num_slots
    a = ctx.encrypt(rng.normal(size=nh))
    b = ctx.encrypt(rng.normal(size=nh))
    lvl = a.level
    mods = ctx.pc.mods(ctx.chain(lvl))
    d0, d1, d2 = tensor_product(a, b, mods)
    key = ctx.keys.mult_key
    c0, c1 = ctx.engine.relin(d0, d1, d2, key, lvl)
    digits = ctx.engine.modup(d2, lvl)
    c0d, c1d = ctx.engine.relin(d0, d1, d2, key, lvl, digits=digits)
    assert np.array_equal(np.asarray(c0), np.asarray(c0d))
    assert np.array_equal(np.asarray(c1), np.asarray(c1d))


def test_counters_cmult_parity_eager_engine_batched(relin_ctx):
    """Seed, engine, and vmap-batched CMults tally identical per-ct
    counters — invocation counts AND plan-shape-derived word volumes."""
    ctx = relin_ctx
    rng = np.random.default_rng(9)
    nh = ctx.params.num_slots
    B = 3
    cts = [(ctx.encrypt(rng.normal(size=nh)),
            ctx.encrypt(rng.normal(size=nh))) for _ in range(B)]
    c = ctx.counters

    s0 = c.snapshot()
    for a, b in cts:
        ctx.multiply(a, b, rescale=False)
    engine_counts = c.delta(s0)

    ctx.use_engine = False
    try:
        s1 = c.snapshot()
        for a, b in cts:
            ctx.multiply(a, b, rescale=False)
        seed_counts = c.delta(s1)
    finally:
        ctx.use_engine = True
    assert engine_counts == seed_counts
    assert engine_counts.relin == B and engine_counts.modup == B

    # batched: one relin_batched dispatch covers all B ciphertexts
    lvl = cts[0][0].level
    mods = ctx.pc.mods(ctx.chain(lvl))
    import jax.numpy as jnp

    a_b = Ciphertext(jnp.stack([p[0].c0 for p in cts]),
                     jnp.stack([p[0].c1 for p in cts]), lvl,
                     cts[0][0].scale)
    b_b = Ciphertext(jnp.stack([p[1].c0 for p in cts]),
                     jnp.stack([p[1].c1 for p in cts]), lvl,
                     cts[0][1].scale)
    d0, d1, d2 = tensor_product(a_b, b_b, mods)
    s2 = c.snapshot()
    c0b, c1b = ctx.engine.relin_batched(d0, d1, d2, ctx.keys.mult_key,
                                        lvl)
    batched_counts = c.delta(s2)
    assert batched_counts == engine_counts
    # and the values match the per-ct engine path bit for bit
    for i, (a, b) in enumerate(cts):
        exp = ctx.multiply(a, b, rescale=False)
        assert np.array_equal(np.asarray(c0b[i]), np.asarray(exp.c0))
        assert np.array_equal(np.asarray(c1b[i]), np.asarray(exp.c1))


def test_relin_trace_counts_flat_across_batches(relin_ctx):
    """Re-dispatching a relin jit plan at the same (level, shape) is a
    cache hit: ``trace_counts`` stays at one trace per plan."""
    ctx = relin_ctx
    rng = np.random.default_rng(10)
    nh = ctx.params.num_slots
    import jax.numpy as jnp

    lvl = ctx.params.L
    mods = ctx.pc.mods(ctx.chain(lvl))
    before = ctx.engine.trace_counts.get(("relin_b", lvl, False), 0)
    for B in (2, 2, 2):
        pairs = [(ctx.encrypt(rng.normal(size=nh)),
                  ctx.encrypt(rng.normal(size=nh))) for _ in range(B)]
        a_b = Ciphertext(jnp.stack([p[0].c0 for p in pairs]),
                         jnp.stack([p[0].c1 for p in pairs]), lvl, 1.0)
        b_b = Ciphertext(jnp.stack([p[1].c0 for p in pairs]),
                         jnp.stack([p[1].c1 for p in pairs]), lvl, 1.0)
        d0, d1, d2 = tensor_product(a_b, b_b, mods)
        ctx.engine.relin_batched(d0, d1, d2, ctx.keys.mult_key, lvl)
    after = ctx.engine.trace_counts[("relin_b", lvl, False)]
    assert after - before == 1      # three same-shape dispatches: 1 trace


# ----------------------- multi-relin (ONE ModDown) -----------------------

def test_multi_relin_one_moddown(relin_ctx):
    """n CMult terms close with ONE ModDown; the merged sum stays within
    the deferred approximate-FBC rounding envelope of the exact sum."""
    ctx = relin_ctx
    rng = np.random.default_rng(11)
    nh = ctx.params.num_slots
    n = 3
    xs = [rng.normal(size=nh) * 0.3 for _ in range(2 * n)]
    pairs = [(ctx.encrypt(xs[2 * i]), ctx.encrypt(xs[2 * i + 1]))
             for i in range(n)]
    lvl = pairs[0][0].level
    mods = ctx.pc.mods(ctx.chain(lvl))
    c = ctx.counters

    s0 = c.snapshot()
    exact = None
    for a, b in pairs:
        t = ctx.multiply(a, b, rescale=False)
        exact = t if exact is None else ctx.add(exact, t)
    d_exact = c.delta(s0)

    s1 = c.snapshot()
    d0s, d1s, digs = [], [], []
    for a, b in pairs:
        d0, d1, d2 = tensor_product(a, b, mods)
        d0s.append(d0)
        d1s.append(d1)
        digs.append(ctx.engine.modup(d2, lvl))
    c0, c1 = ctx.engine.multi_relin_sum(d0s, d1s, digs,
                                        ctx.keys.mult_key, lvl)
    d_multi = c.delta(s1)
    merged = Ciphertext(c0, c1, lvl, exact.scale)

    assert d_exact.moddown == n and d_multi.moddown == 1
    assert d_exact.modup == d_multi.modup == n
    assert d_exact.ip == d_multi.ip == n
    assert d_exact.relin == d_multi.relin == n
    assert d_multi.relin_blocks == 1
    assert not _ct_equal(merged, exact)     # genuinely different path

    # the deferred approximate-FBC roundings must not cost accuracy:
    # both paths decode the same plaintext product sum equally well
    ref = sum(xs[2 * i] * xs[2 * i + 1] for i in range(n))
    err_exact = np.abs(ctx.decrypt(exact).real - ref).max()
    err_multi = np.abs(ctx.decrypt(merged).real - ref).max()
    assert err_multi < err_exact * 1.5 + 1e-4, (err_multi, err_exact)


# ----------------------- compiled BSGS Chebyshev -------------------------

def _trace_cheb(params, coeffs):
    tc = TraceContext(params)
    h = tc.input("x", level=params.L, scale=params.scale)
    tc.output(eval_chebyshev_bsgs(tc, h, coeffs), "y")
    return tc


def test_bsgs_cheb_fewer_relins_same_accuracy(relin_ctx, cheb_case):
    """The giant-step evaluation needs O(sqrt d) CMults instead of the
    dense recurrence's O(d), at the same accuracy and output level."""
    ctx = relin_ctx
    x, fn, coeffs = cheb_case
    c = ctx.counters
    s0 = c.snapshot()
    dense = eval_chebyshev(ctx, ctx.encrypt(x), coeffs)
    d_dense = c.delta(s0)
    s1 = c.snapshot()
    bsgs = eval_chebyshev_bsgs(ctx, ctx.encrypt(x), coeffs)
    d_bsgs = c.delta(s1)
    assert d_bsgs.relin < d_dense.relin
    assert bsgs.level >= dense.level
    ref = fn(x)
    assert np.abs(ctx.decrypt(bsgs).real - ref).max() < 5e-3
    assert np.abs(ctx.decrypt(dense).real - ref).max() < 5e-3


def test_compiled_cheb_bitexact_relinsteps(relin_ctx, cheb_case):
    """exact=True: every CMULT lowers to a RelinStep (none stay eager)
    and the compiled run is bit-exact with the eager evaluation."""
    ctx = relin_ctx
    x, fn, coeffs = cheb_case
    ct = ctx.encrypt(x)

    tc = _trace_cheb(ctx.params, coeffs)
    comp = compile_program(tc)
    n_relin = sum(1 for s in comp.steps if isinstance(s, RelinStep))
    assert n_relin == comp.dfg.count(OpKind.CMULT)
    assert n_relin > 0

    assert_program_parity(
        ctx, comp, {"x": ct},
        lambda c, t: eval_chebyshev_bsgs(c, t, coeffs))


def test_compiled_cheb_multi_relin_fewer_moddowns(relin_ctx, cheb_case):
    """exact=False merges the giant-step product sums: MultiRelinSteps
    appear, total ModDowns drop at unchanged ModUps, reconciliation of
    predicted-vs-executed relin counts stays exact, and accuracy holds."""
    ctx = relin_ctx
    x, fn, coeffs = cheb_case
    ct = ctx.encrypt(x)
    c = ctx.counters

    tc = _trace_cheb(ctx.params, coeffs)
    comp = compile_program(tc)
    multi = compile_program(tc, exact=False)
    n_multi = sum(1 for s in multi.steps
                  if isinstance(s, MultiRelinStep))
    assert n_multi > 0 and multi.n_multi_relin == n_multi
    assert multi.summary()["merged_relins"] >= 2 * n_multi

    ex = ProgramExecutor(ctx)
    s0 = c.snapshot()
    exact_out = ex.run(comp, {"x": ct})["y"]
    d_exact = c.delta(s0)
    s1 = c.snapshot()
    res = ex.run(multi, {"x": ct}, with_report=True)
    d_multi = c.delta(s1)
    multi_out = res["y"]

    assert d_multi.moddown < d_exact.moddown
    assert d_multi.modup == d_exact.modup
    assert d_multi.relin == d_exact.relin
    rec = res.report.reconcile()
    assert rec["counts_match"], rec

    ref = fn(x)
    err_exact = np.abs(ctx.decrypt(exact_out).real - ref).max()
    err_multi = np.abs(ctx.decrypt(multi_out).real - ref).max()
    assert err_multi < err_exact * 1.5 + 1e-3


def test_compiled_cheb_batched(relin_ctx, cheb_case):
    """Batched execution drives relin_batched/multi_relin_sum_batched:
    bit-exact with the per-ct run, one jit trace per relin plan."""
    ctx = relin_ctx
    x, fn, coeffs = cheb_case
    rng = np.random.default_rng(12)
    nh = ctx.params.num_slots
    xs = [x, rng.uniform(-1, 1, nh)]
    cts = [ctx.encrypt(v) for v in xs]

    tc = _trace_cheb(ctx.params, coeffs)
    ex = ProgramExecutor(ctx)
    for comp in (compile_program(tc), compile_program(tc, exact=False)):
        before = dict(ctx.engine.trace_counts)
        outs = ex.run_batched(comp, {"x": cts})["y"]
        for ct, out_b in zip(cts, outs):
            out_1 = ex.run(comp, {"x": ct})["y"]
            assert _ct_equal(out_b, out_1)
        after = ctx.engine.trace_counts
        new_relin_traces = [
            k for k in after
            if k[0] in ("relin_b", "multi_relin_b")
            and after[k] != before.get(k)
        ]
        assert all(after[k] == 1 for k in new_relin_traces)


def test_multi_relin_pallas_parity():
    """Unbatched relin/multi_relin_sum run on BOTH backends: the pallas
    fused-IP accumulation is bit-exact with the jnp contraction."""
    p = CKKSParams(logN=8, L=3, alpha=2, k=2, q_bits=29, scale_bits=26)
    ctxs = {b: CKKSContext(p, seed=5, backend=b)
            for b in ("jnp", "pallas")}
    rng = np.random.default_rng(2)
    nh = p.num_slots
    xs = [rng.normal(size=nh) * 0.3 for _ in range(4)]
    outs = {}
    for b, ctx in ctxs.items():
        pairs = [(ctx.encrypt(xs[0]), ctx.encrypt(xs[1])),
                 (ctx.encrypt(xs[2]), ctx.encrypt(xs[3]))]
        lvl = pairs[0][0].level
        mods = ctx.pc.mods(ctx.chain(lvl))
        d0s, d1s, digs = [], [], []
        for a, bb in pairs:
            d0, d1, d2 = tensor_product(a, bb, mods)
            d0s.append(d0)
            d1s.append(d1)
            digs.append(ctx.engine.modup(d2, lvl))
        outs[b] = (
            ctx.engine.multi_relin_sum(d0s, d1s, digs,
                                       ctx.keys.mult_key, lvl),
            ctx.engine.relin(d0s[0], d1s[0], None, ctx.keys.mult_key,
                             lvl, digits=digs[0]),
        )
    for got, exp in zip(outs["pallas"], outs["jnp"]):
        assert np.array_equal(np.asarray(got[0]), np.asarray(exp[0]))
        assert np.array_equal(np.asarray(got[1]), np.asarray(exp[1]))


# ------------------- batched pallas parity -------------------------------
# These replace the former pallas-vmap gate tests: the kernel suite is
# vmap-compatible (custom_vmap rules fold the batch into the kernel
# grids), so every *_batched engine entry runs on backend='pallas' and
# must be bit-exact with the jnp backend.

@pytest.fixture(scope="module")
def _pallas_pair():
    p = CKKSParams(logN=8, L=3, alpha=2, k=2, q_bits=29, scale_bits=26)
    return {b: CKKSContext(p, seed=5, backend=b)
            for b in ("jnp", "pallas")}


def _batched_tensor_square(ctx, msgs):
    import jax.numpy as jnp
    cts = [ctx.encrypt(m) for m in msgs]
    lvl = cts[0].level
    mods = ctx.pc.mods(ctx.chain(lvl))
    trips = [tensor_product(a, a, mods) for a in cts]
    return tuple(jnp.stack([t[i] for t in trips]) for i in range(3)), lvl


def test_pallas_batched_rotation_parity(_pallas_pair):
    """Batched rotation (apply_galois_batched -> full keyswitch) on
    backend='pallas' is bit-exact with the jnp backend."""
    import jax.numpy as jnp
    rng = np.random.default_rng(1)
    nh = next(iter(_pallas_pair.values())).params.num_slots
    msgs = [rng.normal(size=nh) * 0.3 for _ in range(3)]
    outs = {}
    for b, ctx in _pallas_pair.items():
        cts = [ctx.encrypt(m) for m in msgs]
        c0b = jnp.stack([c.c0 for c in cts])
        c1b = jnp.stack([c.c1 for c in cts])
        g = ctx.pc.rns.galois_for_rotation(2)
        outs[b] = ctx.engine.apply_galois_batched(
            c0b, c1b, g, ctx.keys.rot_key(2), cts[0].level)
    for got, exp in zip(outs["pallas"], outs["jnp"]):
        assert np.array_equal(np.asarray(got), np.asarray(exp))


def test_pallas_batched_relin_parity(_pallas_pair):
    """Batched relin on backend='pallas' (fused-IP under vmap) is
    bit-exact with the jnp backend, with and without cached digits."""
    rng = np.random.default_rng(1)
    nh = next(iter(_pallas_pair.values())).params.num_slots
    msgs = [rng.normal(size=nh) * 0.3 for _ in range(2)]
    outs = {}
    for b, ctx in _pallas_pair.items():
        (d0, d1, d2), lvl = _batched_tensor_square(ctx, msgs)
        digs = ctx.engine.modup_batched(d2, lvl)
        outs[b] = (
            ctx.engine.relin_batched(d0, d1, d2, ctx.keys.mult_key, lvl),
            ctx.engine.relin_batched(d0, d1, None, ctx.keys.mult_key,
                                     lvl, digits=digs),
        )
    for got, exp in zip(outs["pallas"], outs["jnp"]):
        for g_arr, e_arr in zip(got, exp):
            assert np.array_equal(np.asarray(g_arr), np.asarray(e_arr))


def test_pallas_batched_multi_relin_parity(_pallas_pair):
    """Batched multi_relin_sum (one shared ModDown across products) on
    backend='pallas' is bit-exact with the jnp backend."""
    rng = np.random.default_rng(3)
    nh = next(iter(_pallas_pair.values())).params.num_slots
    msgs = [rng.normal(size=nh) * 0.3 for _ in range(2)]
    outs = {}
    for b, ctx in _pallas_pair.items():
        (d0, d1, d2), lvl = _batched_tensor_square(ctx, msgs)
        digs = ctx.engine.modup_batched(d2, lvl)
        outs[b] = ctx.engine.multi_relin_sum_batched(
            [d0, d0], [d1, d1], [digs, digs], ctx.keys.mult_key, lvl)
    for got, exp in zip(outs["pallas"], outs["jnp"]):
        assert np.array_equal(np.asarray(got), np.asarray(exp))
