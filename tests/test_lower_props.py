"""Property tests for the lowering algebra.

The compiled runtime's core claim is algebraic: for ANY traced program
built from rotations, plaintext-keyed sums, and relinearizing products,
the ``fusion=False`` lowering is bit-exact with the eager replay and
the execution report reconciles exactly.  This module samples that
space — random diagonal sums (random steps incl. the special-cased
step 0, random coefficients), random BSGS splits, relin chains, and
random input levels — instead of the handful of hand-picked shapes the
unit suites cover.

The generators and the parity check are plain functions, exercised by
deterministic representative cases that run everywhere; when hypothesis
is installed (CI installs ``.[test]``) the ``@given`` sweeps explore
hundreds of op sequences and shrink failures to minimal graphs.
"""
import numpy as np
import pytest

from repro.core import linear
from repro.core.ckks import CKKSContext
from repro.core.params import CKKSParams
from repro.runtime import TraceContext, compile_program

from parity import assert_program_parity

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:            # tier-1 without hypothesis: deterministic
    HAVE_HYPOTHESIS = False    # representatives below still run


@pytest.fixture(scope="module")
def pctx():
    p = CKKSParams(logN=7, L=6, alpha=2, k=3, q_bits=29, scale_bits=29)
    return CKKSContext(p, seed=17)


def _diags(nh: int, steps, seed: int) -> dict:
    rng = np.random.default_rng(seed)
    return {int(s): rng.normal(size=nh) for s in steps}


def _apply_blocks(cx, h, blocks, nh):
    """Replay a drawn op sequence on any context (eager or tracing)."""
    for b in blocks:
        kind = b[0]
        if kind == "diag":
            h = linear.matvec_diag(cx, h, _diags(nh, b[1], b[2]))
        elif kind == "bsgs":
            h = linear.matvec_bsgs(cx, h, _diags(nh, b[1], b[2]), bs=b[3])
        elif kind == "square":
            h = cx.multiply(h, h)
        elif kind == "rot":
            h = cx.rotate(h, b[1])
        else:                                      # pragma: no cover
            raise AssertionError(kind)
    return h


def _levels_needed(blocks) -> int:
    return sum(1 for b in blocks if b[0] in ("diag", "bsgs", "square"))


def _check_parity(ctx, blocks, input_level: int, seed: int = 99):
    """The property: trace -> lower -> execute == eager replay, bit for
    bit, with exact predicted-vs-executed reconciliation."""
    p = ctx.params
    nh = p.num_slots
    assert input_level >= _levels_needed(blocks)

    tc = TraceContext(p)
    h = tc.input("x", level=input_level, scale=p.scale)
    tc.output(_apply_blocks(tc, h, blocks, nh), "y")
    comp = compile_program(tc)

    rng = np.random.default_rng(seed)
    ct = ctx.encrypt(rng.normal(size=nh), level=input_level)
    assert_program_parity(
        ctx, comp, {"x": ct},
        lambda cx, t: _apply_blocks(cx, t, blocks, nh),
        reconcile=True)


# ------------------- deterministic representatives -----------------------

CASES = [
    # zero-step diagonal inside a PKB (the identity-rotation fold)
    [("diag", (0, 1, 5), 1)],
    # BSGS baby/giant split feeding a relin
    [("bsgs", (0, 1, 2, 3, 9, 11), 2, 2), ("square",)],
    # bare rotation between keyed sums — anchor is a rotation output
    [("diag", (1, 3), 4), ("rot", 7), ("diag", (0, 2), 5)],
    # relin chain then a sum at the lowered level
    [("square",), ("square",), ("diag", (2, 6), 6)],
]


@pytest.mark.parametrize("blocks", CASES, ids=lambda b: b[0][0] + str(len(b)))
def test_lowering_parity_representatives(pctx, blocks):
    _check_parity(pctx, blocks, input_level=pctx.params.L)


def test_lowering_parity_shallow_input(pctx):
    """Random-level coverage floor: same property off the top level."""
    _check_parity(pctx, [("diag", (1, 4), 7), ("square",)], input_level=3)


# ------------------------ hypothesis sweeps ------------------------------

if HAVE_HYPOTHESIS:
    def _block_st(nh):
        steps = st.lists(st.integers(0, nh - 1), min_size=1, max_size=4,
                         unique=True).map(tuple)
        seeds = st.integers(0, 2**16)
        return st.one_of(
            st.tuples(st.just("diag"), steps, seeds),
            st.tuples(st.just("bsgs"), steps, seeds,
                      st.sampled_from((2, 4))),
            st.tuples(st.just("square")),
            st.tuples(st.just("rot"), st.integers(1, nh - 1)),
        )

    @st.composite
    def _programs(draw, nh, L):
        blocks = draw(st.lists(_block_st(nh), min_size=1, max_size=4))
        lo = max(_levels_needed(blocks), 1)
        level = draw(st.integers(lo, L))
        return blocks, level

    @settings(max_examples=20, deadline=None,
              suppress_health_check=list(HealthCheck))
    @given(data=st.data())
    def test_lowering_parity_random_graphs(pctx, data):
        nh, L = pctx.params.num_slots, pctx.params.L
        blocks, level = data.draw(_programs(nh, L))
        _check_parity(pctx, blocks, input_level=level, seed=7)
