"""Docs integrity: links and module references resolve.

Three checks over ``docs/ARCHITECTURE.md``, ``docs/SERVING.md``,
``docs/OBSERVABILITY.md``, ``docs/WORKLOADS.md`` and the README:
  * every relative markdown link target exists on disk (anchors and
    external http(s) links are skipped);
  * every backticked repo path (``src/...``, ``benchmarks/...``,
    ``tests/...``, ``docs/...``) names a real file or directory — the
    paper-to-module tables must not drift from the tree;
  * every dotted ``repro.*`` module the serving guide names imports —
    the operator guide must track the package layout.
"""
import importlib
import pathlib
import re

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent
ARCH = REPO / "docs" / "ARCHITECTURE.md"
SERVING = REPO / "docs" / "SERVING.md"
OBS = REPO / "docs" / "OBSERVABILITY.md"
WORKLOADS = REPO / "docs" / "WORKLOADS.md"

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)#\s]+)[^)]*\)")
PATH_RE = re.compile(r"`((?:src|benchmarks|tests|docs|examples)/[^`*?]+)`")
MODULE_RE = re.compile(r"`(repro(?:\.\w+)+)`")


def test_architecture_doc_exists():
    assert ARCH.is_file(), "docs/ARCHITECTURE.md is part of the deal"
    text = ARCH.read_text()
    for section in ("paper", "Trace", "Recipe"):
        assert section in text


def test_serving_doc_exists():
    assert SERVING.is_file(), "docs/SERVING.md is part of the deal"
    text = SERVING.read_text()
    for section in ("Architecture", "Metrics", "Knobs",
                    "Chebyshev workload to 3 tenants"):
        assert section in text


def test_observability_doc_exists():
    assert OBS.is_file(), "docs/OBSERVABILITY.md is part of the deal"
    text = OBS.read_text()
    for section in ("Quick start", "What is instrumented",
                    "Metrics registry", "communication-stall budget",
                    "Perfetto export anatomy", "Guarantees"):
        assert section in text
    # the calibration story must keep the paper figure visible
    assert "6.67%" in text and "ui.perfetto.dev" in text


def test_workloads_doc_exists():
    assert WORKLOADS.is_file(), "docs/WORKLOADS.md is part of the deal"
    text = WORKLOADS.read_text()
    for section in ("Module map", "Packing layout",
                    "Automatic bootstrap insertion", "Gates"):
        assert section in text
    # the bit-exactness + ModUp contract must stay stated
    assert "bit-exact" in text and "ModUps" in text


@pytest.mark.parametrize(
    "doc", ["docs/ARCHITECTURE.md", "docs/SERVING.md",
            "docs/OBSERVABILITY.md", "docs/WORKLOADS.md", "README.md"])
def test_doc_relative_links_resolve(doc):
    path = REPO / doc
    assert path.is_file()
    base = path.parent
    bad = []
    for target in LINK_RE.findall(path.read_text()):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        if not (base / target).exists():
            bad.append(target)
    assert not bad, f"{doc}: dead relative links: {bad}"


@pytest.mark.parametrize("doc", [ARCH, SERVING, OBS, WORKLOADS])
def test_doc_module_paths_resolve(doc):
    bad = []
    for ref in PATH_RE.findall(doc.read_text()):
        if not (REPO / ref).exists():
            bad.append(ref)
    assert not bad, f"{doc.name}: stale module references: {bad}"


@pytest.mark.parametrize("doc", [SERVING, OBS, WORKLOADS])
def test_doc_dotted_modules_import(doc):
    bad = []
    for mod in sorted(set(MODULE_RE.findall(doc.read_text()))):
        try:
            importlib.import_module(mod)
        except ImportError:
            bad.append(mod)
    assert not bad, f"{doc.name} names unimportable modules: {bad}"
