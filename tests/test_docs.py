"""Docs integrity: ARCHITECTURE.md links and module references resolve.

Two checks over ``docs/ARCHITECTURE.md`` (and the README):
  * every relative markdown link target exists on disk (anchors and
    external http(s) links are skipped);
  * every backticked repo path (``src/...``, ``benchmarks/...``,
    ``tests/...``, ``docs/...``) names a real file or directory — the
    paper-to-module table must not drift from the tree.
"""
import pathlib
import re

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent
ARCH = REPO / "docs" / "ARCHITECTURE.md"

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)#\s]+)[^)]*\)")
PATH_RE = re.compile(r"`((?:src|benchmarks|tests|docs|examples)/[^`*?]+)`")


def test_architecture_doc_exists():
    assert ARCH.is_file(), "docs/ARCHITECTURE.md is part of the deal"
    text = ARCH.read_text()
    for section in ("paper", "Trace", "Recipe"):
        assert section in text


@pytest.mark.parametrize("doc", ["docs/ARCHITECTURE.md", "README.md"])
def test_doc_relative_links_resolve(doc):
    path = REPO / doc
    assert path.is_file()
    base = path.parent
    bad = []
    for target in LINK_RE.findall(path.read_text()):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        if not (base / target).exists():
            bad.append(target)
    assert not bad, f"{doc}: dead relative links: {bad}"


def test_architecture_module_paths_resolve():
    bad = []
    for ref in PATH_RE.findall(ARCH.read_text()):
        if not (REPO / ref).exists():
            bad.append(ref)
    assert not bad, f"stale module references: {bad}"
