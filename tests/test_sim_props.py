"""Hypothesis property tests for the performance simulator.

Guarded with importorskip so a bare interpreter (no hypothesis) still
collects and runs the behaviour tests in test_sim.py.
"""
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.dfg.programs import bootstrapping_dfg  # noqa: E402
from repro.sim import HE2_SM  # noqa: E402
from repro.sim.engine import simulate_program  # noqa: E402
from repro.sim.hw import with_bandwidth  # noqa: E402


@settings(max_examples=6, deadline=None)
@given(bw=st.sampled_from([0.25, 0.5, 1.0, 2.0, 4.0]),
       mode=st.sampled_from(["pipelined", "analytic"]))
def test_prop_bandwidth_monotonic(bw, mode):
    """More link bandwidth never slows HE2 down (Fig. 17(a)), in both
    the scheduled and the analytic model."""
    g = bootstrapping_dfg(bsgs_bs=0).g
    lo = simulate_program(g, with_bandwidth(HE2_SM, bw), "hoist", "IRF",
                          mode=mode)
    hi = simulate_program(g, with_bandwidth(HE2_SM, bw * 2), "hoist",
                          "IRF", mode=mode)
    assert hi.latency_s <= lo.latency_s * (1 + 1e-9)
