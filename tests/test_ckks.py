"""End-to-end CKKS scheme correctness (encrypt -> op -> decrypt)."""
import numpy as np
import pytest


def _rand_slots(rng, nh, scale=1.0):
    return (rng.normal(size=nh) + 1j * rng.normal(size=nh)) * scale


def test_encrypt_decrypt(ctx, rng):
    nh = ctx.params.num_slots
    z = _rand_slots(rng, nh)
    assert np.abs(ctx.decrypt(ctx.encrypt(z)) - z).max() < 1e-5


def test_add_sub(ctx, rng):
    nh = ctx.params.num_slots
    z1, z2 = _rand_slots(rng, nh), _rand_slots(rng, nh)
    ct1, ct2 = ctx.encrypt(z1), ctx.encrypt(z2)
    assert np.abs(ctx.decrypt(ctx.add(ct1, ct2)) - (z1 + z2)).max() < 1e-5
    assert np.abs(ctx.decrypt(ctx.sub(ct1, ct2)) - (z1 - z2)).max() < 1e-5


def test_plaintext_ops(ctx, rng):
    nh = ctx.params.num_slots
    z1, z2 = _rand_slots(rng, nh), _rand_slots(rng, nh)
    ct = ctx.encrypt(z1)
    pt = ctx.encode(z2)
    assert np.abs(ctx.decrypt(ctx.pt_add(ct, pt)) - (z1 + z2)).max() < 1e-5
    out = ctx.pt_mul(ct, pt)
    assert out.level == ct.level - 1, "pt_mul rescales one level"
    assert np.abs(ctx.decrypt(out) - z1 * z2).max() < 1e-3


def test_ciphertext_multiply(ctx, rng):
    nh = ctx.params.num_slots
    z1, z2 = _rand_slots(rng, nh), _rand_slots(rng, nh)
    out = ctx.multiply(ctx.encrypt(z1), ctx.encrypt(z2))
    assert np.abs(ctx.decrypt(out) - z1 * z2).max() < 1e-3


def test_multiply_depth(ctx, rng):
    """((z^2)^2) across two levels."""
    nh = ctx.params.num_slots
    z = _rand_slots(rng, nh, 0.5)
    ct = ctx.encrypt(z)
    sq = ctx.multiply(ct, ct)
    sq2 = ctx.multiply(sq, sq)
    assert np.abs(ctx.decrypt(sq2) - z**4).max() < 5e-3


@pytest.mark.parametrize("steps", [1, 2, 7, 100])
def test_rotate(ctx, rng, steps):
    nh = ctx.params.num_slots
    z = _rand_slots(rng, nh)
    out = ctx.rotate(ctx.encrypt(z), steps)
    assert np.abs(ctx.decrypt(out) - np.roll(z, -steps)).max() < 1e-3


def test_conjugate(ctx, rng):
    nh = ctx.params.num_slots
    z = _rand_slots(rng, nh)
    out = ctx.conjugate(ctx.encrypt(z))
    assert np.abs(ctx.decrypt(out) - np.conj(z)).max() < 1e-3


def test_rotate_composition(ctx, rng):
    """Rot(Rot(ct, a), b) == Rot(ct, a+b) — the PKB-fusion identity."""
    nh = ctx.params.num_slots
    z = _rand_slots(rng, nh)
    ct = ctx.encrypt(z)
    ab = ctx.rotate(ctx.rotate(ct, 3), 5)
    direct = ctx.rotate(ct, 8)
    assert np.abs(ctx.decrypt(ab) - ctx.decrypt(direct)).max() < 2e-3


def test_rescale_bookkeeping(ctx, rng):
    nh = ctx.params.num_slots
    z = _rand_slots(rng, nh)
    ct = ctx.encrypt(z)
    out = ctx.multiply(ct, ct, rescale=False)
    assert out.level == ct.level
    r = ctx.rescale(out)
    assert r.level == ct.level - 1
    q_last = ctx.chain(ct.level)[-1]
    assert abs(r.scale - out.scale / q_last) < 1e-6


def test_hoisted_rotation_sum_matches_naive(ctx, rng):
    nh = ctx.params.num_slots
    z = _rand_slots(rng, nh)
    ct = ctx.encrypt(z)
    steps = [1, 5, 17]
    ptvals = [rng.normal(size=nh) for _ in steps]
    pts = [ctx.encode(v) for v in ptvals]
    h = ctx.hoisted_rotation_sum(ct, steps, pts)
    expected = sum(np.roll(z, -s) * v for s, v in zip(steps, ptvals))
    assert np.abs(ctx.decrypt(h) - expected).max() < 2e-3


def test_hoisted_rotation_sum_no_pt(ctx, rng):
    nh = ctx.params.num_slots
    z = _rand_slots(rng, nh)
    ct = ctx.encrypt(z)
    steps = [2, 9]
    h = ctx.hoisted_rotation_sum(ct, steps, None)
    expected = sum(np.roll(z, -s) for s in steps)
    assert np.abs(ctx.decrypt(h) - expected).max() < 2e-3


def test_keyswitch_at_lower_level(ctx, rng):
    """Level-independent gadget: rotation still correct after rescale."""
    nh = ctx.params.num_slots
    z = _rand_slots(rng, nh)
    ct = ctx.encrypt(z)
    ones = ctx.encode(np.ones(nh))
    low = ctx.pt_mul(ct, ones)  # burn a level
    low = ctx.pt_mul(low, ctx.encode(np.ones(nh), level=low.level))
    out = ctx.rotate(low, 4)
    assert np.abs(ctx.decrypt(out) - np.roll(z, -4)).max() < 5e-3
