"""Homomorphic polynomial evaluation tests."""
import numpy as np
import pytest

from repro.core.ckks import CKKSContext
from repro.core.params import CKKSParams
from repro.core.polyeval import (
    chebyshev_coeffs, eval_chebyshev, eval_poly_horner,
)


@pytest.fixture(scope="module")
def deep_ctx():
    p = CKKSParams(logN=9, L=12, alpha=3, k=4, q_bits=29, scale_bits=29)
    return CKKSContext(p, seed=11)


def test_chebyshev_sine(deep_ctx, rng):
    ctx = deep_ctx
    nh = ctx.params.num_slots
    x = rng.uniform(-1, 1, nh)
    K = 3.5
    fn = lambda t: np.sin(2 * np.pi * K * t) / (2 * np.pi)  # noqa: E731
    coeffs = chebyshev_coeffs(fn, 31)
    out = eval_chebyshev(ctx, ctx.encrypt(x), coeffs)
    assert np.abs(ctx.decrypt(out).real - fn(x)).max() < 5e-3
    assert out.level >= 1


def test_horner_sigmoid(deep_ctx, rng):
    """HELR's degree-3 sigmoid approximation."""
    ctx = deep_ctx
    nh = ctx.params.num_slots
    x = rng.uniform(-4, 4, nh) / 8.0
    c3 = np.array([0.5, 1.20096, 0.0, -0.81562])  # sigmoid approx on [-8,8]/8
    out = eval_poly_horner(ctx, ctx.encrypt(x), c3)
    exp = c3[0] + c3[1] * x + c3[3] * x**3
    assert np.abs(ctx.decrypt(out).real - exp).max() < 1e-3
