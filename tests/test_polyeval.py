"""Homomorphic polynomial evaluation tests.

Includes direct numerics coverage for the plaintext half of
``core/polyeval.py`` — the Chebyshev division identity behind the BSGS
split and the interpolation error of ``chebyshev_coeffs`` — previously
exercised only indirectly through EvalMod.
"""
import numpy as np
import pytest

from repro.core.ckks import CKKSContext
from repro.core.params import CKKSParams
from repro.core.polyeval import (
    cheb_divmod, chebyshev_coeffs, eval_chebyshev, eval_poly_horner,
)


@pytest.fixture(scope="module")
def deep_ctx():
    p = CKKSParams(logN=9, L=12, alpha=3, k=4, q_bits=29, scale_bits=29)
    return CKKSContext(p, seed=11)


def test_cheb_divmod_reconstruction_random(rng):
    """c = q * T_g + r (deg r < g) for every legal (degree, giant-step)
    pair: random complex coefficients, all g <= d <= 2g splits."""
    import numpy.polynomial.chebyshev as C

    x = np.linspace(-1, 1, 37)
    for _ in range(40):
        g = int(rng.integers(1, 33))
        d = int(rng.integers(g, 2 * g + 1))
        c = rng.normal(size=d + 1) + 1j * rng.normal(size=d + 1)
        q, r = cheb_divmod(c, g)
        assert len(r) == g and len(q) == d - g + 1
        tg = np.zeros(g + 1)
        tg[g] = 1.0
        recon = C.chebval(x, q) * C.chebval(x, tg) + C.chebval(x, r)
        assert np.abs(C.chebval(x, c) - recon).max() < 1e-10


def test_cheb_divmod_rejects_illegal_split():
    with pytest.raises(AssertionError):
        cheb_divmod(np.zeros(10), 4)        # deg 9 > 2*4


def test_chebyshev_coeffs_error_bounds(rng):
    """Interpolation at Chebyshev nodes is near-minimax: the sampled
    max error over [-1, 1] stays within the classical truncation bound
    for analytic functions, and decays as the degree grows."""
    x = rng.uniform(-1, 1, 4096)
    cases = [
        (lambda t: 1.0 / (1.0 + np.exp(-4.0 * t)), {7: 3e-3, 15: 1e-5}),
        (lambda t: np.tanh(t), {7: 1e-4, 15: 1e-8}),
        (lambda t: np.sin(3.0 * t), {7: 5e-4, 15: 1e-10}),
    ]
    for fn, bounds in cases:
        errs = {}
        for degree, bound in bounds.items():
            c = chebyshev_coeffs(fn, degree)
            assert len(c) == degree + 1
            err = np.abs(
                np.polynomial.chebyshev.chebval(x, c) - fn(x)).max()
            assert err < bound, (degree, err, bound)
            errs[degree] = err
        assert errs[15] < errs[7]           # higher degree, tighter fit


def test_chebyshev_coeffs_exact_on_polynomials():
    """A degree-d polynomial is reproduced exactly (up to fp) by the
    degree-d interpolant: interpolation at d+1 nodes is interpolatory."""
    coeffs = chebyshev_coeffs(lambda t: 2 * t**3 - t + 0.25, 3)
    x = np.linspace(-1, 1, 101)
    got = np.polynomial.chebyshev.chebval(x, coeffs)
    assert np.abs(got - (2 * x**3 - x + 0.25)).max() < 1e-12


def test_chebyshev_sine(deep_ctx, rng):
    ctx = deep_ctx
    nh = ctx.params.num_slots
    x = rng.uniform(-1, 1, nh)
    K = 3.5
    fn = lambda t: np.sin(2 * np.pi * K * t) / (2 * np.pi)  # noqa: E731
    coeffs = chebyshev_coeffs(fn, 31)
    out = eval_chebyshev(ctx, ctx.encrypt(x), coeffs)
    assert np.abs(ctx.decrypt(out).real - fn(x)).max() < 5e-3
    assert out.level >= 1


def test_horner_sigmoid(deep_ctx, rng):
    """HELR's degree-3 sigmoid approximation."""
    ctx = deep_ctx
    nh = ctx.params.num_slots
    x = rng.uniform(-4, 4, nh) / 8.0
    c3 = np.array([0.5, 1.20096, 0.0, -0.81562])  # sigmoid approx on [-8,8]/8
    out = eval_poly_horner(ctx, ctx.encrypt(x), c3)
    exp = c3[0] + c3[1] * x + c3[3] * x**3
    assert np.abs(ctx.decrypt(out).real - exp).max() < 1e-3
