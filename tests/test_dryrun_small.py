"""Dry-run machinery test on a small 8-device mesh (subprocess — the
device-count override must happen before jax initializes)."""
import json
import os
import pathlib
import subprocess
import sys
import textwrap

import pytest

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import reduced_config
    from repro.launch import plan as plan_mod
    from repro.launch.dryrun import collective_bytes
    from repro.launch.mesh import make_small_mesh
    from repro.models.model import init_cache, init_params
    from repro.models.steps import make_serve_step, make_train_step
    from repro.train.optimizer import AdamW

    cfg = reduced_config("phi3_medium_14b")
    mesh = make_small_mesh(8)
    params_sds = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    opt = AdamW()
    opt_sds = jax.eval_shape(opt.init, params_sds)
    batch_sds = {
        "tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32),
        "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32),
    }
    p_plan = plan_mod.param_plan(cfg, mesh, params_sds)
    o_plan = plan_mod.opt_plan(cfg, mesh, opt_sds, p_plan)
    b_plan = plan_mod.batch_plan(mesh, batch_sds)
    with mesh:
        step = make_train_step(cfg, opt)
        lowered = jax.jit(step, in_shardings=(p_plan, o_plan, b_plan),
                          out_shardings=(p_plan, o_plan, None)).lower(
            params_sds, opt_sds, batch_sds)
        compiled = lowered.compile()
        cost = compiled.cost_analysis()
        coll = collective_bytes(compiled.as_text())

        # decode path too
        cache_sds = jax.eval_shape(lambda: init_cache(cfg, 8, 64))
        c_plan = plan_mod.cache_plan(cfg, mesh, cache_sds)
        serve = make_serve_step(cfg)
        dec_batch = {"tokens": jax.ShapeDtypeStruct((8, 1), jnp.int32)}
        db_plan = plan_mod.batch_plan(mesh, dec_batch)
        lowered2 = jax.jit(serve, in_shardings=(p_plan, c_plan, db_plan),
                           out_shardings=(None, c_plan)).lower(
            params_sds, cache_sds, dec_batch)
        compiled2 = lowered2.compile()

    print(json.dumps({
        "flops": cost.get("flops", 0),
        "coll_total": coll["total_bytes"],
        "ar_count": coll["counts"]["all-reduce"],
        "decode_ok": True,
    }))
""")


@pytest.mark.slow
def test_small_mesh_dryrun_train_and_decode():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], env=env,
        capture_output=True, text=True, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["flops"] > 0, "cost analysis must report flops"
    assert res["ar_count"] > 0, "DP training must emit all-reduces"
    assert res["decode_ok"]


def test_collective_parser():
    from repro.launch.dryrun import collective_bytes

    hlo = """
      %all-reduce.1 = f32[1024,256]{1,0} all-reduce(%dot), replica_groups={}
      %ag = bf16[32,128]{1,0} all-gather(%p0), dimensions={0}
      %x = f32[8]{0} add(%a, %b)
    """
    c = collective_bytes(hlo)
    assert c["bytes"]["all-reduce"] == 1024 * 256 * 4
    assert c["bytes"]["all-gather"] == 32 * 128 * 2
    assert c["counts"]["all-reduce"] == 1
    assert c["total_bytes"] == 1024 * 256 * 4 + 32 * 128 * 2
