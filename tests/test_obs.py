"""Observability layer (``repro.obs``): tracer, registry, budget, export.

Covers the ISSUE-8 acceptance points:
  * nested span ordering + structured attribute/event propagation,
    including across ``run_batched`` vmap dispatch
  * disabled-by-default: instrumented code adds ZERO jit retraces with
    tracing ON, and a disabled span call is a no-op singleton
  * metrics registry reconciles exactly with ``OpCounters`` and
    ``ServingReport.accounted``
  * stall-budget interval math from first principles, and agreement
    with the scheduler's own ``comm_stall_s`` on a real compiled plan
  * Perfetto/Chrome-trace JSON schema validity for a combined
    sim-timeline + real-span export
"""
import json
import threading

import numpy as np
import pytest

from repro import obs
from repro.core import linear
from repro.core.ckks import CKKSContext
from repro.core.params import CKKSParams
from repro.obs import budget as ob
from repro.obs.export import PID_REAL, PID_SIM, write_trace
from repro.obs.registry import MetricsRegistry
from repro.obs.tracer import NULL_SPAN, Tracer
from repro.runtime import ProgramExecutor, TraceContext, compile_program
from repro.sim import HE2_SM

N_DIAG, BS = 4, 2


@pytest.fixture(autouse=True)
def _obs_clean():
    """Every test starts and ends with the global tracer off and empty."""
    obs.disable()
    obs.TRACER.reset()
    obs.METRICS.reset()
    yield
    obs.disable()
    obs.TRACER.reset()
    obs.METRICS.reset()


@pytest.fixture(scope="module")
def octx():
    params = CKKSParams(logN=8, L=4, alpha=2, k=2, q_bits=29,
                        scale_bits=29)
    return CKKSContext(params, seed=17)


@pytest.fixture(scope="module")
def oprog(octx):
    params = octx.params
    rng = np.random.default_rng(5)
    diags = {d: rng.normal(size=params.num_slots)
             for d in range(N_DIAG)}
    tc = TraceContext(params)
    h = tc.input("x", level=params.L, scale=params.scale)
    tc.output(linear.matvec_bsgs(tc, h, diags, bs=BS), "y")
    return compile_program(tc)


# ---------------------------------------------------------------- tracer

def test_disabled_span_is_noop_singleton():
    tr = Tracer()
    s = tr.span("anything", k=1)
    assert s is NULL_SPAN and not s
    with s as inner:
        inner.set_attrs(ignored=True)
        inner.event("ignored")
    tr.event("standalone")          # also a no-op while disabled
    assert tr.spans() == [] and tr.instants == []


def test_nested_span_ordering_and_attrs():
    tr = Tracer()
    tr.enable()
    with tr.span("outer", job=7) as outer:
        with tr.span("inner") as inner:
            inner.set_attrs(step=1)
            tr.event("tick", n=3)   # attaches to the CURRENT span
        assert tr.current() is outer
    done = tr.spans()
    # children finish (and land) before their parents
    assert [s.name for s in done] == ["inner", "outer"]
    inner, outer = done
    assert inner.parent_id == outer.span_id
    assert outer.parent_id is None
    assert outer.attrs == {"job": 7} and inner.attrs == {"step": 1}
    assert [e[0] for e in inner.events] == ["tick"]
    assert inner.events[0][2] == {"n": 3}
    assert outer.start_ns <= inner.start_ns <= inner.end_ns <= outer.end_ns
    # name filtering, including '*' prefix match
    assert [s.name for s in tr.spans("inner")] == ["inner"]
    assert len(tr.spans("out*")) == 1


def test_span_records_exception_and_still_closes():
    tr = Tracer()
    tr.enable()
    with pytest.raises(ValueError):
        with tr.span("boom"):
            raise ValueError("nope")
    (s,) = tr.spans()
    assert s.attrs["error"] == "ValueError"
    assert s.end_ns is not None
    assert tr.current() is None


def test_thread_local_context_propagation():
    tr = Tracer()
    tr.enable()
    seen = {}

    def worker(tag):
        with tr.span(f"w.{tag}") as w:
            with tr.span(f"w.{tag}.child"):
                pass
            seen[tag] = w.span_id

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(3)]
    with tr.span("main"):
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    by_name = {s.name: s for s in tr.spans()}
    assert by_name["main"].parent_id is None
    for i in range(3):
        child = by_name[f"w.{i}.child"]
        # a worker's child nests under ITS thread's span, never "main"
        assert child.parent_id == seen[i]
        assert child.thread == by_name[f"w.{i}"].thread
        assert child.thread != by_name["main"].thread


# -------------------------------------------------------------- registry

def test_registry_families_and_exposition():
    reg = MetricsRegistry()
    c = reg.counter("req.total", help="requests")
    c.inc(tenant="a")
    c.inc(2, tenant="a")
    c.inc(tenant="b")
    assert c.value(tenant="a") == 3 and c.value(tenant="b") == 1
    with pytest.raises(ValueError):
        c.inc(-1)
    g = reg.gauge("depth")
    g.set(4)
    g.set(7)                        # gauges overwrite
    assert g.value() == 7 and g.value(missing="x") is None
    h = reg.histogram("lat_s")
    for v in (0.0005, 0.003, 0.003, 99.0):
        h.observe(v)
    assert h.count() == 4 and h.sum() == pytest.approx(99.0065)
    (series,) = h.series().values()
    assert series["overflow"] == 1  # 99.0 beyond the last bucket edge
    # same name, different family -> hard error
    with pytest.raises(TypeError):
        reg.counter("depth")
    snap = reg.snapshot()
    assert snap["req.total"]["series"] == {"tenant=a": 3.0, "tenant=b": 1.0}
    text = reg.to_text()
    assert "# TYPE req.total counter" in text
    assert "req.total{tenant=a} 3.0" in text
    assert "lat_s_count 4" in text
    json.loads(reg.to_json())       # exposition is valid JSON


# ---------------------------------------------------------------- budget

def test_interval_math_first_principles():
    assert ob.merge_intervals([(3, 4), (0, 2), (1, 3)]) == [(0, 4)]
    assert ob.subtract_intervals([(0, 10)], [(2, 4), (6, 7)]) == \
        [(0, 2), (4, 6), (7, 10)]
    assert ob.subtract_intervals([(0, 5)], [(0, 5)]) == []
    assert ob.total([(0, 2), (1, 3), (10, 11)]) == pytest.approx(4.0)
    # link busy 0..8; compute covers 0..3 and 5..6 -> stalls 3..5, 6..8
    tl = {
        "link": [(0.0, 8.0, "up")],
        "xpu": [(0.0, 3.0, "ntt")],
        "xmu": [(5.0, 6.0, "ip")],
    }
    assert ob.stall_intervals(tl) == [(3.0, 5.0), (6.0, 8.0)]
    sb = ob.analyze(tl, latency_s=10.0, name="toy", budget=0.5)
    assert sb.comm_stall_s == pytest.approx(4.0)
    assert sb.fraction == pytest.approx(0.4)
    assert sb.within and "toy" in sb.describe()
    d = sb.as_dict()
    assert d["comm_stall_frac"] == pytest.approx(0.4)
    assert d["within_budget"] is True
    with pytest.raises(RuntimeError):
        ob.check(ob.analyze(tl, latency_s=10.0, budget=0.1))


def test_budget_matches_scheduler_accounting(octx, oprog):
    """analyze() on the scheduled timelines reproduces the scheduler's
    own exposed-communication number exactly."""
    ex = ProgramExecutor(octx)
    ct = octx.encrypt(np.random.default_rng(0).normal(
        size=octx.params.num_slots))
    res = ex.run(oprog, {"x": ct}, with_report=True)
    sched = res.report.scheduled_result(oprog, HE2_SM)
    sb = ob.analyze(sched.timelines, latency_s=sched.latency_s)
    assert sb.comm_stall_s == pytest.approx(sched.comm_stall_s, rel=1e-9)
    assert sb.fraction == pytest.approx(sched.comm_stall_frac, rel=1e-9)


# ----------------------------------------------- instrumented hot path

def test_zero_retraces_and_step_attrs_with_obs_enabled(octx, oprog):
    """Tracing ON adds no jit retraces, and executor spans carry the
    per-step op-count deltas that reconcile with OpCounters."""
    ex = ProgramExecutor(octx)
    nh = octx.params.num_slots
    rng = np.random.default_rng(1)
    one = {"x": octx.encrypt(rng.normal(size=nh))}
    two = {"x": [octx.encrypt(rng.normal(size=nh)) for _ in range(2)]}
    ex.run(oprog, one)              # warm every jit plan untraced
    ex.run_batched(oprog, two)
    before = dict(octx.engine.trace_counts)

    obs.enable()
    snap = octx.counters.snapshot()
    ex.run(oprog, one)
    ex.run_batched(oprog, two)
    obs.disable()
    assert dict(octx.engine.trace_counts) == before, \
        "observability added a jit retrace"

    runs = obs.TRACER.spans("exec.run")
    assert [s.attrs["batch"] for s in runs] == [0, 2]
    steps = obs.TRACER.spans("exec.step.*")
    assert steps and all(s.parent_id in {r.span_id for r in runs}
                         for s in steps)
    # attribute propagation across the vmap dispatch: batched hoisted
    # steps count batch-times the single-shot ModUps
    hoisted = obs.TRACER.spans("exec.step.HoistedStep")
    single = [s for s in hoisted if s.attrs["batch"] == 0]
    batched = [s for s in hoisted if s.attrs["batch"] == 2]
    assert single and batched
    assert sum(s.attrs["modup"] for s in batched) == \
        2 * sum(s.attrs["modup"] for s in single)
    # span-level deltas sum to the OpCounters delta for the whole pair
    # (hoisted blocks AND eager giant-step rotations both carry ModUps)
    d = octx.counters.delta(snap)
    assert sum(s.attrs["modup"] for s in steps) == d.modup


def test_metrics_reconcile_with_opcounters(octx, oprog):
    ex = ProgramExecutor(octx)
    ct = octx.encrypt(np.random.default_rng(2).normal(
        size=octx.params.num_slots))
    ex.run(oprog, {"x": ct})
    obs.publish_counters(obs.METRICS, octx.counters)
    snap = obs.METRICS.snapshot()
    for field, value in octx.counters.as_dict().items():
        assert snap[f"fhe.{field}"]["series"][""] == value


def test_serving_spans_and_accounting_reconcile(octx, oprog):
    """A traced serving run: per-request terminal outcomes land in the
    request log, dispatch spans exist, and the published registry view
    reconciles with ServingReport.accounted."""
    from repro.serve import Arrival, FHEServer

    server = FHEServer(octx, max_batch=2, max_wait_s=0.0)
    server.register_program("p", oprog)
    nh = octx.params.num_slots
    with server.registry.lease("warm"):
        ct0 = octx.encrypt(np.zeros(nh))
    server.warmup("warm", "p", {"x": ct0})

    rng = np.random.default_rng(3)

    def inputs_for(a):
        return {"x": octx.encrypt(rng.normal(size=nh))}

    trace = [Arrival(0.0, t, "p") for t in ("a", "b", "a", "b")]
    obs.enable()
    rep = server.run_trace(trace, inputs_for)
    obs.disable()
    assert rep.completed == 4

    assert len(server.request_log) == 4
    assert {r["outcome"] for r in server.request_log} == {"completed"}
    assert sorted(r["rid"] for r in server.request_log) == [0, 1, 2, 3]
    for r in server.request_log:
        assert r["arrival_s"] <= r["start_s"] <= r["end_s"]
    dispatches = obs.TRACER.spans("serve.dispatch")
    assert dispatches and all(s.attrs["ok"] for s in dispatches)
    assert sum(len(s.attrs["rids"]) for s in dispatches) == 4

    obs.publish_serving(obs.METRICS, rep)
    snap = obs.METRICS.snapshot()
    assert snap["serving.accounted"]["series"][""] == rep.accounted
    assert snap["serving.completed"]["series"][""] == rep.completed
    assert snap["serving.latency_s"]["series"][""]["count"] == 4


# ---------------------------------------------------------------- export

def test_perfetto_trace_schema(tmp_path, octx, oprog):
    """A combined export (real spans + virtual schedule) is valid
    Chrome Trace Event JSON with both clock domains present."""
    ex = ProgramExecutor(octx)
    ct = octx.encrypt(np.random.default_rng(4).normal(
        size=octx.params.num_slots))
    res = ex.run(oprog, {"x": ct}, with_report=True)
    sched = res.report.scheduled_result(oprog, HE2_SM)
    obs.enable()
    with obs.span("smoke", kind="test"):
        ex.run(oprog, {"x": ct})
    obs.disable()

    path = tmp_path / "trace.json"
    write_trace(str(path), tracer=obs.TRACER, timelines=sched.timelines)
    doc = json.loads(path.read_text())
    assert isinstance(doc["traceEvents"], list) and doc["traceEvents"]
    assert doc["displayTimeUnit"] == "ms"

    by_ph = {}
    for ev in doc["traceEvents"]:
        assert {"ph", "pid", "tid", "name"} <= set(ev)
        by_ph.setdefault(ev["ph"], []).append(ev)
        if ev["ph"] == "X":
            assert ev["ts"] >= 0 and ev["dur"] >= 0
    assert set(by_ph) <= {"X", "M", "i"}

    procs = {ev["pid"]: ev["args"]["name"] for ev in by_ph["M"]
             if ev["name"] == "process_name"}
    assert PID_SIM in procs and PID_REAL in procs

    lanes = {ev["args"]["name"] for ev in by_ph["M"]
             if ev["name"] == "thread_name" and ev["pid"] == PID_SIM}
    assert {"xpu", "xmu", "link", "evk"} <= lanes
    assert "stall (comm exposed)" in lanes

    # the stall lane's slices total the budget module's stall time
    stall_us = sum(ev["dur"] for ev in by_ph["X"]
                   if ev["pid"] == PID_SIM and ev["name"] == "comm-stall")
    assert stall_us / 1e6 == pytest.approx(sched.comm_stall_s, rel=1e-6)

    # real spans nest: exec.step slices sit inside the exec.run window
    real = [ev for ev in by_ph["X"] if ev["pid"] == PID_REAL]
    run = next(ev for ev in real if ev["name"] == "exec.run")
    for ev in real:
        if ev["name"].startswith("exec.step."):
            assert ev["ts"] >= run["ts"]
            assert ev["ts"] + ev["dur"] <= run["ts"] + run["dur"] + 1e-3
            assert ev["args"]["parent_span"] == run["args"]["span_id"]


def test_kernel_dispatch_events_and_backend_attrs(octx, oprog):
    """Engine entry points emit ``engine.kernel_dispatch`` events
    (backend, fused vs op-by-op ModUp, interpret mode) and executor
    step spans carry the backend they dispatched to."""
    ex = ProgramExecutor(octx)
    ct = octx.encrypt(np.random.default_rng(3).normal(
        size=octx.params.num_slots))
    obs.enable()
    ex.run(oprog, {"x": ct})
    obs.disable()
    steps = obs.TRACER.spans("exec.step.*")
    assert steps
    assert all(s.attrs["backend"] == "jnp"
               and s.attrs["interpret"] is False for s in steps)
    evs = [e for s in obs.TRACER.spans() for e in s.events
           if e[0] == "engine.kernel_dispatch"]
    evs += [(n, ts, a) for n, ts, _t, a in obs.TRACER.instants
            if n == "engine.kernel_dispatch"]
    assert evs, "engine dispatch emitted no kernel_dispatch events"
    for _, _, attrs in evs:
        assert attrs["backend"] == "jnp"
        assert attrs["modup"] == "op-by-op"
        assert attrs["interpret"] is False


def test_kernel_dispatch_event_pallas_fused():
    """On backend='pallas' the dispatch event reports the fused ModUp
    kernel and whether the Pallas interpreter is in use."""
    p = CKKSParams(logN=8, L=3, alpha=2, k=2, q_bits=29, scale_bits=29)
    ctx = CKKSContext(p, seed=5, backend="pallas")
    ct = ctx.encrypt(np.random.default_rng(0).normal(size=p.num_slots))
    obs.enable()
    ctx.engine.modup(ct.c1, ct.level)
    obs.disable()
    evs = [(n, a) for n, _ts, _t, a in obs.TRACER.instants
           if n == "engine.kernel_dispatch"]
    assert evs
    name, attrs = evs[0]
    assert attrs["backend"] == "pallas"
    assert attrs["modup"] == "fused"
    assert attrs["interpret"] == ctx.engine.interpret


def test_validate_failure_emits_span_event(octx, oprog, monkeypatch):
    """A ``validate=True`` block-boundary failure emits a span event
    carrying the failing block's step volumes before the typed error
    propagates."""
    from repro.errors import ScaleDriftError

    ex = ProgramExecutor(octx)
    ct = octx.encrypt(np.random.default_rng(6).normal(
        size=octx.params.num_slots))
    ex.run(oprog, {"x": ct}, validate=True)  # healthy run passes

    def poisoned(ct, where=""):
        # only the keyswitch block-boundary check trips (the input
        # check runs first and would short-circuit the block path)
        if "Step" in where:
            raise ScaleDriftError(f"injected drift {where}", scale=-1.0)

    monkeypatch.setattr(octx, "check_ciphertext", poisoned)
    obs.enable()
    with pytest.raises(ScaleDriftError):
        ex.run(oprog, {"x": ct}, validate=True)
    obs.disable()
    events = [e for s in obs.TRACER.spans()
              for e in s.events if e[0] == "exec.validate_failure"]
    events += [(n, ts, a) for n, ts, _t, a in obs.TRACER.instants
               if n == "exec.validate_failure"]
    assert events, "validation failure did not emit a span event"
    _, _, attrs = events[0]
    assert attrs["error"] == "ScaleDriftError"
    assert "modup_count" in attrs and "comm_up_words" in attrs
    assert attrs["step"] and "out" in attrs
