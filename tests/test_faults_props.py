"""Property tests for fault-tolerant serving (hypothesis; skipped when
hypothesis is not installed — CI installs it via ``.[test]``).

THE accounting property: for ANY seeded chaos schedule (transient
faults, key evictions, output corruption), ANY batch/queue/retry
configuration, every submitted request reaches exactly one terminal
outcome — ``completed + failed + shed + rejected == submitted``.  The
engine dispatch is stubbed (health-checkable ciphertexts, zero real
FHE work) so hypothesis can explore hundreds of schedules in seconds;
the real-engine versions of these paths are pinned by
``tests/test_faults.py``.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core import linear  # noqa: E402
from repro.core.ckks import CKKSContext, Ciphertext  # noqa: E402
from repro.core.params import CKKSParams  # noqa: E402
from repro.runtime import TraceContext, compile_program  # noqa: E402
from repro.runtime.exec import ExecResult  # noqa: E402
from repro.serve import (  # noqa: E402
    Arrival, CircuitBreaker, FaultInjector, FaultPlan, FHEServer,
)


@pytest.fixture(scope="module")
def sctx():
    params = CKKSParams(logN=8, L=4, alpha=2, k=2, q_bits=29,
                        scale_bits=29)
    return CKKSContext(params, seed=3)


@pytest.fixture(scope="module")
def sprog(sctx):
    params = sctx.params
    rng = np.random.default_rng(11)
    diags = {d: rng.normal(size=params.num_slots) for d in range(3)}
    tc = TraceContext(params)
    h = tc.input("x", level=params.L, scale=params.scale)
    tc.output(linear.matvec_diag(tc, h, diags), "y")
    return compile_program(tc)


@pytest.fixture(scope="module")
def ct0(sctx):
    return sctx.encrypt(np.zeros(sctx.params.num_slots))


def _stub_executor(server, ct):
    """Replace the engine dispatch with an instant fake that returns
    fresh healthy ciphertext wrappers (so injected corruption of one
    slot never aliases another slot or a later dispatch)."""
    def fake_run_batched(compiled, stacked, with_report=False,
                         validate=False):
        B = len(next(iter(stacked.values())))
        outs = [Ciphertext(ct.c0, ct.c1, ct.level, ct.scale)
                for _ in range(B)]
        return ExecResult({"y": outs})

    server.executor.run_batched = fake_run_batched


@settings(max_examples=60, deadline=None,
          suppress_health_check=[HealthCheck.function_scoped_fixture])
@given(seed=st.integers(0, 2 ** 16),
       p_transient=st.floats(0.0, 0.5),
       p_evict=st.floats(0.0, 0.5),
       p_corrupt=st.floats(0.0, 0.5),
       n=st.integers(1, 12),
       max_batch=st.integers(1, 4),
       max_retries=st.integers(0, 3),
       queue_size=st.integers(1, 8))
def test_every_request_terminally_accounted(
        sctx, sprog, ct0, seed, p_transient, p_evict, p_corrupt, n,
        max_batch, max_retries, queue_size):
    faults = FaultInjector(FaultPlan(
        seed=seed, p_transient=p_transient, p_evict=p_evict,
        p_corrupt=p_corrupt))
    server = FHEServer(
        sctx, max_batch=max_batch, max_wait_s=0.0,
        queue_size=queue_size, faults=faults, max_retries=max_retries,
        breaker=CircuitBreaker(threshold=2, cooldown_s=1e-6))
    server.register_program("a", sprog)
    _stub_executor(server, ct0)

    trace = [Arrival(0.0, f"t{i % 3}", "a") for i in range(n)]
    rep = server.run_trace(
        trace, lambda a: {"x": Ciphertext(ct0.c0, ct0.c1, ct0.level,
                                          ct0.scale)})

    assert rep.submitted == n
    assert rep.accounted == n, \
        f"lost requests under chaos: {rep.to_dict()}"
    # per-tenant view reconciles with the aggregate
    assert sum(t["completed"] + t["failed"] + t["shed"] + t["rejected"]
               for t in rep.tenants.values()) == n
    # every queued request carries a terminal outcome string
    for rid, outcome in server.outcomes.items():
        assert outcome == "completed" or outcome.startswith("failed:") \
            or outcome.startswith("shed:")
