"""Pallas kernel validation: interpret=True vs pure-jnp oracles vs the
exact uint64 core, swept over shapes and limb counts."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import poly
from repro.core.params import CKKSParams
from repro.kernels import modops
from repro.kernels.ntt.ops import (
    ntt_fwd, ntt_fwd_oracle, ntt_inv, ntt_inv_oracle, tables_for,
)
from repro.kernels.bconv.ops import bconv_kernel, bconv_oracle
from repro.kernels.fused_ip.ops import fused_ip_kernel, fused_ip_oracle


# ------------------------------ modops ----------------------------------

@pytest.mark.parametrize("q", [0x3FFFE001, 536608769, 268369921, 40961])
def test_mul32_split_and_mont(q):
    rng = np.random.default_rng(q)
    a = rng.integers(0, q, 4096, dtype=np.uint32)
    b = rng.integers(0, q, 4096, dtype=np.uint32)
    hi, lo = modops.mul32_split(jnp.asarray(a), jnp.asarray(b))
    full = a.astype(np.uint64) * b.astype(np.uint64)
    got = np.asarray(hi).astype(np.uint64) * (1 << 32) + np.asarray(lo)
    assert np.array_equal(got, full)
    if q % 2 == 1:
        qinv = modops.qinv_neg_host(q)
        b_m = modops.to_mont_host(b.astype(np.uint64), q)
        r = modops.mont_mul(
            jnp.asarray(a), jnp.asarray(b_m), jnp.uint32(q), jnp.uint32(qinv)
        )
        assert np.array_equal(np.asarray(r).astype(np.uint64), full % q)


def test_add_sub_mod():
    q = np.uint32(536608769)
    rng = np.random.default_rng(0)
    a = rng.integers(0, q, 1000, dtype=np.uint32)
    b = rng.integers(0, q, 1000, dtype=np.uint32)
    s = np.asarray(modops.add_mod(jnp.asarray(a), jnp.asarray(b), q))
    d = np.asarray(modops.sub_mod(jnp.asarray(a), jnp.asarray(b), q))
    assert np.array_equal(s.astype(np.uint64),
                          (a.astype(np.uint64) + b) % q)
    assert np.array_equal(d.astype(np.uint64),
                          (a.astype(np.uint64) + int(q) - b) % q)


# ------------------------------- NTT -------------------------------------

@pytest.mark.parametrize("logn,L", [(6, 1), (8, 3), (10, 2)])
def test_ntt_kernel_vs_oracle_roundtrip(logn, L):
    p = CKKSParams(logN=logn, L=L, alpha=1, k=1, q_bits=29)
    tabs = tables_for(p)
    primes = p.q_chain(L)
    rng = np.random.default_rng(logn)
    x = np.stack([rng.integers(0, q, p.N, dtype=np.uint32) for q in primes])
    xj = jnp.asarray(x)
    f_k = np.asarray(ntt_fwd(xj, primes, tabs))
    f_o = np.asarray(ntt_fwd_oracle(xj, primes, tabs))
    np.testing.assert_array_equal(f_k, f_o)
    i_k = np.asarray(ntt_inv(jnp.asarray(f_k), primes, tabs))
    i_o = np.asarray(ntt_inv_oracle(jnp.asarray(f_o), primes, tabs))
    np.testing.assert_array_equal(i_k, i_o)
    np.testing.assert_array_equal(i_k, x)


def test_ntt_kernel_consistent_with_core():
    """Kernel eval domain is a permutation of core's; negacyclic products
    agree exactly."""
    p = CKKSParams(logN=8, L=3, alpha=2, k=2, q_bits=29)
    tabs = tables_for(p)
    pc = poly.PolyContext(p)
    primes = p.q_chain(p.L)
    rng = np.random.default_rng(5)
    mods = np.array(primes, dtype=np.uint64)[:, None]
    x = np.stack([rng.integers(0, q, p.N, dtype=np.uint32) for q in primes])
    y = np.stack([rng.integers(0, q, p.N, dtype=np.uint32) for q in primes])
    fx = np.asarray(ntt_fwd(jnp.asarray(x), primes, tabs)).astype(np.uint64)
    fy = np.asarray(ntt_fwd(jnp.asarray(y), primes, tabs)).astype(np.uint64)
    prod_k = np.asarray(
        ntt_inv(jnp.asarray(((fx * fy) % mods).astype(np.uint32)), primes, tabs)
    ).astype(np.uint64)
    cfx = np.asarray(poly.ntt(jnp.asarray(x.astype(np.uint64)), primes, pc))
    cfy = np.asarray(poly.ntt(jnp.asarray(y.astype(np.uint64)), primes, pc))
    prod_c = np.asarray(
        poly.intt(jnp.asarray((cfx * cfy) % mods), primes, pc)
    )
    np.testing.assert_array_equal(prod_k, prod_c)
    for i in range(len(primes)):
        np.testing.assert_array_equal(
            np.sort(fx[i]), np.sort(cfx[i]), err_msg=f"limb {i} eval multiset"
        )


# ------------------------------ BConv ------------------------------------

@pytest.mark.parametrize("logn,ls,ld", [(6, 2, 2), (8, 3, 2), (8, 4, 4)])
def test_bconv_kernel_vs_oracle(logn, ls, ld):
    p = CKKSParams(logN=logn, L=max(ls - 1, 1), alpha=1, k=ld, q_bits=29)
    pc = poly.PolyContext(p)
    src = p.q_chain(ls - 1)
    dst = p.p_primes[:ld]
    rng = np.random.default_rng(logn + ls)
    x = np.stack([rng.integers(0, q, p.N, dtype=np.uint32) for q in src])
    xj = jnp.asarray(x)
    got = np.asarray(bconv_kernel(xj, src, dst, pc.rns))
    exp = np.asarray(bconv_oracle(xj, src, dst, pc.rns))
    np.testing.assert_array_equal(got, exp)


def test_bconv_kernel_vs_core():
    p = CKKSParams(logN=8, L=2, alpha=1, k=2, q_bits=29)
    pc = poly.PolyContext(p)
    src, dst = p.q_chain(2), p.p_primes
    rng = np.random.default_rng(9)
    x = np.stack([rng.integers(0, q, p.N, dtype=np.uint32) for q in src])
    got = np.asarray(
        bconv_kernel(jnp.asarray(x), src, dst, pc.rns)
    ).astype(np.uint64)
    core = np.asarray(
        poly.bconv(jnp.asarray(x.astype(np.uint64)), tuple(src), tuple(dst), pc)
    )
    np.testing.assert_array_equal(got, core)


def test_bconv_kernel_blocked():
    """Coefficient-blocked grid gives identical results (VMEM tiling)."""
    p = CKKSParams(logN=8, L=2, alpha=1, k=2, q_bits=29)
    pc = poly.PolyContext(p)
    src, dst = p.q_chain(2), p.p_primes
    rng = np.random.default_rng(10)
    x = jnp.asarray(
        np.stack([rng.integers(0, q, p.N, dtype=np.uint32) for q in src])
    )
    full = np.asarray(bconv_kernel(x, src, dst, pc.rns, block=0))
    blocked = np.asarray(bconv_kernel(x, src, dst, pc.rns, block=64))
    np.testing.assert_array_equal(full, blocked)


# ----------------------------- fused IP ----------------------------------

@pytest.mark.parametrize("dnum,l,n,with_pt", [
    (2, 3, 256, False), (3, 5, 256, True), (4, 4, 1024, True),
])
def test_fused_ip_kernel_vs_oracle(dnum, l, n, with_pt):
    p = CKKSParams(logN=8, L=l - 1, alpha=1, k=1, q_bits=29)
    q = np.array(p.q_chain(l - 1), dtype=np.uint32)
    rng = np.random.default_rng(dnum * l)
    digits = np.stack(
        [np.stack([rng.integers(0, qq, n, dtype=np.uint32) for qq in q])
         for _ in range(dnum)]
    )
    evk = np.stack(
        [np.stack([np.stack([rng.integers(0, qq, n, dtype=np.uint32)
                             for qq in q]) for _ in range(2)])
         for _ in range(dnum)]
    )
    pt = (np.stack([rng.integers(0, qq, n, dtype=np.uint32) for qq in q])
          if with_pt else None)
    a0, a1 = fused_ip_kernel(digits, evk, pt, q)
    e0, e1 = fused_ip_oracle(digits, evk, pt, q)
    np.testing.assert_array_equal(np.asarray(a0), np.asarray(e0))
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(e1))
