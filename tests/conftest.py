"""Shared fixtures.  Small parameter sets keep the full scheme fast on CPU.

NOTE: device count must stay 1 here — the multi-pod dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 in its own process
(see src/repro/launch/dryrun.py), never globally.
"""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def small_params():
    from repro.core.params import CKKSParams

    # k > alpha suppresses keyswitch noise (X_j/P ~ 2^-29 per extra prime).
    return CKKSParams(logN=9, L=5, alpha=2, k=3, q_bits=29, scale_bits=29)


@pytest.fixture(scope="session")
def ctx(small_params):
    from repro.core.ckks import CKKSContext

    return CKKSContext(small_params, seed=7)


@pytest.fixture()
def rng():
    return np.random.default_rng(42)
