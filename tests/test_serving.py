"""Serving-layer invariants (``repro.serve``).

Covers the acceptance gates of the multi-tenant serving subsystem:
  * per-tenant FIFO under mixed plan shapes (oldest-head-first groups)
  * zero retraces across packed live traffic (``engine.trace_counts``
    flat after warmup; admission-policy hits account for every batch)
  * bounded-queue backpressure (rejections counted, depth bounded)
  * registry eviction never evicts an in-flight tenant's keys, and
    eviction purges the engine's evk tensor caches
  * metrics arithmetic (nearest-rank p50/p99, throughput)
  * per-tenant correctness: outputs decrypt under the RIGHT tenant key
"""
import numpy as np
import pytest

from repro.core import linear
from repro.core.ckks import CKKSContext
from repro.core.params import CKKSParams
from repro.runtime import TraceContext, compile_program
from repro.serve import (
    Arrival, FHEServer, TenantRegistry, percentile, plan_signature,
    poisson_trace,
)
from repro.serve.metrics import TenantStats

N_DIAG_A, BS_A = 4, 2           # program "a": BSGS matvec
N_DIAG_B = 3                    # program "b": single-block matvec


@pytest.fixture(scope="module")
def sctx():
    params = CKKSParams(logN=8, L=4, alpha=2, k=2, q_bits=29,
                        scale_bits=29)
    return CKKSContext(params, seed=3)


@pytest.fixture(scope="module")
def sprogs(sctx):
    """Two compiled programs with DIFFERENT plan shapes."""
    params = sctx.params
    nh = params.num_slots
    rng = np.random.default_rng(11)
    diags_a = {d: rng.normal(size=nh) for d in range(N_DIAG_A)}
    diags_b = {d: rng.normal(size=nh) for d in range(N_DIAG_B)}

    tc = TraceContext(params)
    h = tc.input("x", level=params.L, scale=params.scale)
    tc.output(linear.matvec_bsgs(tc, h, diags_a, bs=BS_A), "y")
    prog_a = compile_program(tc)

    tc = TraceContext(params)
    h = tc.input("x", level=params.L, scale=params.scale)
    tc.output(linear.matvec_diag(tc, h, diags_b), "y")
    prog_b = compile_program(tc)
    assert plan_signature(prog_a) != plan_signature(prog_b)
    return {"a": (prog_a, diags_a), "b": (prog_b, diags_b)}


def _server(sctx, sprogs, **kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_wait_s", 0.0)
    server = FHEServer(sctx, **kw)
    for pid, (comp, _) in sprogs.items():
        server.register_program(pid, comp)
    return server


def _inputs_maker(sctx, record=None):
    nh = sctx.params.num_slots
    rng = np.random.default_rng(29)

    def inputs_for(a):
        z = rng.normal(size=nh) + 1j * rng.normal(size=nh)
        if record is not None:
            record.append((a, z))
        return {"x": sctx.encrypt(z)}

    return inputs_for


def _warm(server, sctx, pids, width=None):
    with server.registry.lease("warm"):
        ct0 = sctx.encrypt(np.zeros(sctx.params.num_slots))
    for pid in pids:
        server.warmup("warm", pid, {"x": ct0}, width=width)


def test_fifo_fairness_per_tenant(sctx, sprogs):
    """Mixed plan shapes: within every (tenant, program) batch class
    requests complete in submission order, and batches launch
    oldest-head-first (no tenant's head request is ever bypassed by a
    younger head from another class)."""
    server = _server(sctx, sprogs, max_batch=2)
    trace = poisson_trace(500.0, 20, ["t0", "t1", "t2"], ["a", "b"],
                          seed=5)
    _warm(server, sctx, ["a", "b"])
    log: list = []
    rep = server.run_trace(trace, _inputs_maker(sctx, record=log))
    assert rep.completed == 20
    # rid i <=> i-th admitted arrival (nothing rejected here)
    arrival_of = {rid: a.t for rid, (a, _) in enumerate(log)}
    done: dict[tuple, list[int]] = {}
    for rec in server.records:
        done.setdefault((rec.tenant, rec.program_id), []).extend(rec.rids)
    assert {t for t, _ in done} == {"t0", "t1", "t2"}
    for group, rids in done.items():
        assert rids == sorted(rids), \
            f"class {group} completed out of FIFO order: {rids}"
    heads = [arrival_of[rec.rids[0]] for rec in server.records]
    assert heads == sorted(heads), \
        "scheduler launched a younger batch head before an older one"


def test_zero_retraces_across_packed_batches(sctx, sprogs):
    """After warmup, live traffic never retraces a jit plan: the
    engine's trace_counts stay flat and every batch is an
    admission-policy hit."""
    server = _server(sctx, sprogs, max_batch=2)
    _warm(server, sctx, ["a", "b"])
    before = dict(sctx.engine.trace_counts)
    trace = poisson_trace(500.0, 16, ["t0", "t1", "t2", "t3"],
                          ["a", "b"], seed=9)
    rep = server.run_trace(trace, _inputs_maker(sctx))
    assert rep.completed == 16
    assert dict(sctx.engine.trace_counts) == before, \
        "packed serving retraced a jit plan"
    assert rep.plan_cache["hits"] == rep.batches
    assert rep.plan_cache["misses"] == 2       # the two warmups only


def test_bounded_queue_backpressure(sctx, sprogs):
    """An arrival burst beyond the bound is rejected, counted, and the
    queue depth never exceeds maxsize."""
    server = _server(sctx, sprogs, max_batch=2, queue_size=3)
    _warm(server, sctx, ["a"])
    burst = [Arrival(0.0, f"t{i % 2}", "a") for i in range(8)]
    rep = server.run_trace(burst, _inputs_maker(sctx))
    assert rep.completed == 3
    assert rep.rejected == 5
    assert rep.queue["rejected"] == 5
    assert rep.queue["max_depth"] <= 3
    per_tenant_rej = sum(t["rejected"] for t in rep.tenants.values())
    assert per_tenant_rej == 5


def test_eviction_never_evicts_inflight(sctx):
    """A leased (in-flight) tenant's keys survive registry churn; once
    released, eviction proceeds and purges the engine evk caches."""
    registry = TenantRegistry(sctx, capacity=1, base_seed=7000)
    kc_a = registry.keychain("A")
    with registry.lease("A"):
        # force key material + engine evk tensors for tenant A
        ct = sctx.encrypt(np.ones(sctx.params.num_slots))
        sctx.rotate(ct, 1)
        a_ids = {id(k) for k in kc_a._rot_keys.values()}
        # capacity exceeded while A is in flight: A must NOT be evicted
        registry.keychain("B")
        assert "A" in registry and registry.keychain("A") is kc_a
        assert registry.evictions == 0
    # lease released: the next admission evicts LRU non-inflight (B was
    # bumped by its own creation; A was bumped by the identity check
    # above, so B is LRU)
    registry.keychain("C")
    assert registry.evictions >= 1
    assert len(registry) <= registry.capacity + 1
    # evict A explicitly and check the engine cache purge
    while "A" in registry._chains and registry._evict_one():
        pass
    assert all(k[0] not in a_ids for k in sctx.engine._evk_level)
    assert all(i not in a_ids for i in sctx.engine._evk_full)


def test_metrics_arithmetic():
    """Nearest-rank percentiles + throughput from first principles."""
    lats = [0.1 * k for k in range(1, 11)]          # 0.1 .. 1.0
    assert percentile(lats, 50) == pytest.approx(0.5)
    assert percentile(lats, 99) == pytest.approx(1.0)
    assert percentile(lats, 100) == pytest.approx(1.0)
    assert percentile([0.7], 50) == pytest.approx(0.7)
    # empty sample: None, not a fake 0.0 latency
    assert percentile([], 99) is None
    assert percentile([], 50) is None

    # rank arithmetic at the boundary sizes (nearest-rank definition:
    # sorted[max(1, ceil(p/100 * n)) - 1], clamped into [1, n])
    # n=1: every p returns the sample
    for p in (0.1, 1, 50, 99, 100):
        assert percentile([0.7], p) == pytest.approx(0.7)
    # n=2: p<=50 -> first, p>50 -> second
    two = [1.0, 2.0]
    assert percentile(two, 1) == pytest.approx(1.0)
    assert percentile(two, 50) == pytest.approx(1.0)
    assert percentile(two, 51) == pytest.approx(2.0)
    assert percentile(two, 99) == pytest.approx(2.0)
    assert percentile(two, 100) == pytest.approx(2.0)
    # n=100: rank p exactly (identity on 1..100), p99 is the 99th value
    hundred = [float(k) for k in range(1, 101)]
    assert percentile(hundred, 1) == pytest.approx(1.0)
    assert percentile(hundred, 50) == pytest.approx(50.0)
    assert percentile(hundred, 99) == pytest.approx(99.0)
    assert percentile(hundred, 100) == pytest.approx(100.0)
    # empty-tenant snapshot: percentile fields are None, not 0.0
    empty = TenantStats().summary(span_s=1.0)
    assert empty["p50_latency_s"] is None
    assert empty["p99_latency_s"] is None
    assert empty["completed"] == 0

    st = TenantStats()
    for v in lats:
        st.record(v)
    st.rejected = 2
    s = st.summary(span_s=5.0)
    assert s["completed"] == 10 and s["rejected"] == 2
    assert s["throughput_ops"] == pytest.approx(2.0)
    assert s["p50_latency_s"] == pytest.approx(0.5)
    assert s["p99_latency_s"] == pytest.approx(1.0)
    assert s["mean_latency_s"] == pytest.approx(0.55)


def test_outputs_decrypt_under_tenant_keys(sctx, sprogs):
    """Each served output decrypts correctly under ITS tenant's secret
    key — key material never leaks across the shared engine."""
    server = _server(sctx, sprogs, max_batch=2)
    _warm(server, sctx, ["a"])
    log: list = []
    trace = [Arrival(0.0, "alice", "a"), Arrival(0.0, "bob", "a"),
             Arrival(0.0, "alice", "a"), Arrival(0.0, "bob", "a")]
    rep = server.run_trace(trace, _inputs_maker(sctx, record=log))
    assert rep.completed == 4
    _, diags_a = sprogs["a"]
    for rid, (a, z) in enumerate(log):
        expect = sum(np.asarray(v) * np.roll(z, -d)
                     for d, v in diags_a.items())
        with server.registry.lease(a.tenant):
            got = sctx.decrypt(server.outputs[rid]["y"])
        np.testing.assert_allclose(got, expect, atol=1e-3)
