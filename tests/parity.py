"""Shared parity helpers for the compiled-runtime test pyramid.

Every runtime test asserts the same sandwich: execute a
``CompiledProgram``, compare with the eager replay bit for bit, and
optionally check that the op counters moved the right way and that the
execution report reconciles exactly.  This module is that sandwich,
written once — ``test_runtime.py``, ``test_runtime_bootstrap.py``,
``test_relin.py``, ``test_workloads.py`` and the property suite all
import it.  (tests/ has no ``__init__.py``; pytest's rootdir prepend
makes ``from parity import ...`` work from any sibling test file.)
"""
import numpy as np

from repro.runtime import ProgramExecutor


def ct_equal(a, b):
    """Bit-exact ciphertext comparison: both polynomial components."""
    return (np.array_equal(np.asarray(a.c0), np.asarray(b.c0))
            and np.array_equal(np.asarray(a.c1), np.asarray(b.c1)))


def assert_ct_equal(got, exp, what="compiled output"):
    """Bit-exactness plus the metadata the bitstream can't carry."""
    assert got.level == exp.level, (what, got.level, exp.level)
    assert got.scale == exp.scale, (what, got.scale, exp.scale)
    assert ct_equal(got, exp), f"{what}: bitstreams differ"


def assert_program_parity(ctx, program, feeds, eager_fn, out="y",
                          batched=False, exact=True, fewer_modups=False,
                          reconcile=False, rel_tol=1e-3):
    """The parity sandwich: ``eager_fn`` vs ``ProgramExecutor``.

    ``feeds`` maps the single input tag to a Ciphertext (or, with
    ``batched``, a list of them).  ``eager_fn(ctx, ct)`` produces the
    eager reference per input.  ``exact`` compares bit-for-bit (the
    ``fusion=False`` guarantee); otherwise decrypt-domain within
    ``rel_tol`` relative error.  ``fewer_modups`` asserts the compiled
    run's ModUp counter lands strictly below the eager run's;
    ``reconcile`` asserts exact predicted-vs-executed reconciliation.
    Returns the compiled output (a Ciphertext, or a list if batched).
    """
    (tag, val), = feeds.items()
    cts = list(val) if batched else [val]
    c = ctx.counters
    s0 = c.snapshot()
    exps = [eager_fn(ctx, ct) for ct in cts]
    eager = c.delta(s0)

    ex = ProgramExecutor(ctx)
    s1 = c.snapshot()
    if batched:
        res = ex.run_batched(program, {tag: cts}, with_report=reconcile)
        outs = res[out]
    else:
        res = ex.run(program, {tag: cts[0]}, with_report=reconcile)
        outs = [res[out]]
    compiled = c.delta(s1)

    for got, exp in zip(outs, exps):
        assert got.level == exp.level, (got.level, exp.level)
        assert got.scale == exp.scale, (got.scale, exp.scale)
        if exact:
            assert ct_equal(got, exp), "compiled output != eager bitstream"
        else:
            g, e = ctx.decrypt(got), ctx.decrypt(exp)
            denom = max(np.abs(e).max(), 1e-12)
            assert np.abs(g - e).max() / denom < rel_tol
    if fewer_modups:
        assert compiled.modup < eager.modup, (compiled.modup, eager.modup)
    if reconcile:
        rec = res.report.reconcile()
        assert rec["counts_match"], rec
    return outs if batched else outs[0]
